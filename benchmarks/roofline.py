"""Roofline report: one row per (arch x shape) dry-run cell.

Reads the probe JSONs written by ``repro.launch.dryrun --probe`` (layer-exact
extrapolated cost/collective analysis) plus the scan-based compile records
(memory analysis / fits-HBM).  ``us_per_call`` is the roofline-predicted step
time (max of the three terms) in microseconds on the 16x16 v5e pod."""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh: str, name: str) -> dict | None:
    p = DRYRUN / mesh / name
    return json.loads(p.read_text()) if p.exists() else None


def main() -> None:
    mesh = "pod16x16"
    probe_files = sorted((DRYRUN / mesh).glob("*__probe.json")) if (DRYRUN / mesh).exists() else []
    if not probe_files:
        emit("roofline_missing", 0.0, note="run repro.launch.dryrun --probe first")
        return
    for pf in probe_files:
        rec = json.loads(pf.read_text())
        arch, shape, rules = pf.stem.split("__")[:3]
        scan = load(mesh, f"{arch}__{shape}__{rules}.json") or {}
        step_s = max(rec["compute_seconds"], rec["memory_seconds"],
                     rec["collective_seconds"])
        emit(f"roofline_{arch}_{shape}_{rules}", step_s * 1e6,
             dominant=rec["dominant"],
             compute_ms=f"{rec['compute_seconds']*1e3:.2f}",
             memory_ms=f"{rec['memory_seconds']*1e3:.2f}",
             collective_ms=f"{rec['collective_seconds']*1e3:.2f}",
             useful_flops=f"{rec['useful_flops_ratio']:.3f}",
             fits_hbm=scan.get("fits_hbm", "n/a"))


if __name__ == "__main__":
    main()
