"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (``derived`` packs each table's
figure-of-merit as ``key=value`` pairs joined by ``;``).

  PYTHONPATH=src python -m benchmarks.run [section ...] [--engine ENGINE]

Sections (default: all):
  fig2      single-device policy comparison, Azure + DeepLearning
  fig3      device-count sweep for MM-GP-EI
  fig4      policy comparison on four devices
  fig5      synthetic Matérn near-linear-speedup sweep
  control   control-plane microbenchmarks (GP/EI hot path)
  stream    streaming control plane under tenant churn (stream_churn)
  shard     sharded scoring plane: decision latency vs |L| x mesh size
            (shard_scale; multi-shard rows need forced host devices, e.g.
            XLA_FLAGS=--xla_force_host_platform_device_count=4)
  devchurn  elastic device plane: batched vs sequential assignment cost,
            device-aware vs speed-oblivious regret, autoscale (device_churn)
  eventlog  event-sourced durability: incremental vs full compaction pause,
            snapshot/restore/log-append cost (eventlog, DESIGN.md §12)
  dtrace    span-level cost attribution of one sharded decision + the
            disabled-tracer overhead bar (decision_trace, DESIGN.md §13;
            multi-shard rows need forced host devices)
  obs       live health plane: the all-planes-disabled per-event site
            stack as a share of a decision (< 1% bar) + per-plane enabled
            costs — export tick, health detectors, forensics record
            (obs_overhead, DESIGN.md §14)
  capacity  capacity plane: weak-scaling-gap decomposition into per-shard
            skew / all_gather / dispatch (>= 80% attributed bar at S=8),
            per-device skew probe, accounting-sample cost (capacity,
            DESIGN.md §15; multi-shard rows need forced host devices)
  chaos     failure-domain hardening: hardened engine vs failure-free twin
            regret bound + unsupervised stranding baseline (chaos,
            DESIGN.md §16)
  roofline  data-plane cost-model rooflines

Each section also records its rows to a machine-readable
``BENCH_<suite>.json`` (e.g. BENCH_control_plane.json,
BENCH_stream_churn.json) in the working directory — the committed perf
trajectory baseline.

Flags (forwarded to the figure scripts):
  --engine {event,batched}   episode engine for fig2-5.  ``event`` is the
                             host event loop (one episode at a time);
                             ``batched`` runs whole sweeps as a single
                             vmap(lax.scan) call via repro.core.sim_batched.
  --seeds S                  widen batched sweeps (fig5 many-seed mode).

Set BENCH_FAST=1 for a quick pass (fewer seeds/device counts).
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import common
from .common import positive_int

SECTIONS = ("fig2", "fig3", "fig4", "fig5", "control", "stream", "shard",
            "devchurn", "eventlog", "dtrace", "obs", "capacity", "chaos",
            "roofline")

# section -> BENCH_<suite>.json written next to the CSV (perf trajectory)
SUITE_NAMES = {
    "fig2": "fig2", "fig3": "fig3", "fig4": "fig4", "fig5": "fig5",
    "control": "control_plane", "stream": "stream_churn",
    "shard": "shard_scale", "devchurn": "device_churn",
    "eventlog": "eventlog", "dtrace": "decision_trace",
    "obs": "obs_overhead", "capacity": "capacity", "chaos": "chaos",
    "roofline": "roofline",
}


def _parse_args():
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("sections", nargs="*", metavar="section",
                   help=f"benchmark sections to run: {', '.join(SECTIONS)} "
                        "(default: all)")
    p.add_argument("--engine", choices=("event", "batched"), default="event",
                   help="episode engine for fig2-5 (default: event)")
    p.add_argument("--seeds", type=positive_int, default=None,
                   help="seeds per configuration for fig2-5")
    p.add_argument("--smoke", action="store_true",
                   help="toy shapes for every suite (sets BENCH_FAST=1 "
                        "before section import) — the CI smoke job")
    # strict parse: run.py declares every flag the figure scripts accept, so
    # a typo'd flag fails loudly here instead of silently running defaults
    args = p.parse_args()
    bad = [s for s in args.sections if s not in SECTIONS]
    if bad:
        p.error(f"unknown section(s) {bad}; choose from {', '.join(SECTIONS)}")
    return args


def main() -> None:
    args = _parse_args()
    if args.smoke:
        # must precede the lazy section imports: they bind common.FAST then
        common.set_fast(True)
    want = list(args.sections) or list(SECTIONS)
    print("name,us_per_call,derived")
    failures = []
    for section in want:
        try:
            if section == "fig2":
                from . import fig2_single_device as m
            elif section == "fig3":
                from . import fig3_multi_device as m
            elif section == "fig4":
                from . import fig4_four_devices as m
            elif section == "fig5":
                from . import fig5_synthetic_speedup as m
            elif section == "control":
                from . import control_plane as m
            elif section == "stream":
                from . import stream_churn as m
            elif section == "shard":
                from . import shard_scale as m
            elif section == "devchurn":
                from . import device_churn as m
            elif section == "eventlog":
                from . import eventlog as m
            elif section == "dtrace":
                from . import decision_trace as m
            elif section == "obs":
                from . import obs_overhead as m
            elif section == "capacity":
                from . import capacity as m
            elif section == "chaos":
                from . import chaos as m
            elif section == "roofline":
                from . import roofline as m
            else:
                raise KeyError(section)
            common.begin_suite(SUITE_NAMES[section])
            m.main()
            path = common.end_suite()
            if path is not None:
                print(f"# wrote {path}", file=sys.stderr)
        except Exception:
            common.abort_suite()   # partial rows must not clobber baselines
            failures.append(section)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
