"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set BENCH_FAST=1 for a quick
pass (fewer seeds/device counts).

  PYTHONPATH=src python -m benchmarks.run [section ...]

Sections: fig2 fig3 fig4 fig5 control roofline (default: all).
"""

from __future__ import annotations

import sys
import traceback


SECTIONS = ("fig2", "fig3", "fig4", "fig5", "control", "roofline")


def main() -> None:
    want = [a for a in sys.argv[1:] if not a.startswith("-")] or list(SECTIONS)
    print("name,us_per_call,derived")
    failures = []
    for section in want:
        try:
            if section == "fig2":
                from . import fig2_single_device as m
            elif section == "fig3":
                from . import fig3_multi_device as m
            elif section == "fig4":
                from . import fig4_four_devices as m
            elif section == "fig5":
                from . import fig5_synthetic_speedup as m
            elif section == "control":
                from . import control_plane as m
            elif section == "roofline":
                from . import roofline as m
            else:
                raise KeyError(section)
            m.main()
        except Exception:
            failures.append(section)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
