"""Event-sourced control plane costs (DESIGN.md §12) -> BENCH_eventlog.json.

Four measurements:

* ``eventlog_compact_full`` vs ``eventlog_compact_incremental`` — the
  pause-bound claim: one stop-the-world ``compact()`` rebalance on a
  churned 128-tenant plane, against the same rebalance split into
  ``max_moves=1`` passes.  The figure of merit is the MAX per-pass pause —
  the longest stall any single decision sees — which must sit strictly
  below the full-compaction pause (asserted here, so a regression fails
  the bench job before it reaches the committed baseline).

* ``eventlog_snapshot`` / ``eventlog_restore`` — the price of durability
  at a boundary: one full-state snapshot through ``checkpoint.store`` of a
  churned streaming engine, and one ``recover()`` (arrays + GP replay)
  from it.

* ``eventlog_append_processed`` — the per-event write-through cost of the
  durable log (vs the in-memory default, recorded in the same row).

* ``eventlog_end_to_end_overhead`` — everything together: the same churn
  trace replayed with durability off and with a durable log +
  every-32-events snapshots; the derived figure is the percent overhead.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from repro.core import ControlPlane
from repro.core.fleet import Fleet
from repro.core.tenancy import _matern_block_chol
from repro.stream import EventLog, StreamEngine, poisson_churn_trace, recover

from .common import FAST, emit, time_us, timed


def _churned_plane(tenants: int, m: int, shards: int) -> ControlPlane:
    """The shard_scale compaction scenario: every other tenant retired, so
    spans are skewed and many blocks are movable (and seeded, so the full
    and incremental modes start from identical layouts)."""
    K_block, _ = _matern_block_chol(m, 0.2, 0.04)
    cp = ControlPlane(np.random.default_rng(0), model_capacity=tenants * m,
                      tenant_capacity=tenants, num_shards=shards)
    handles = [cp.add_tenant(K_block, np.zeros(m), np.ones(m))
               for _ in range(tenants)]
    rng = np.random.default_rng(1)
    for h in handles:
        g = int(h.models[rng.integers(m)])
        cp.record_start(g)
        cp.record_observation(g, float(rng.uniform()))
    for t in range(0, tenants, 2):
        cp.retire_tenant(t)
    return cp


def bench_compaction_modes() -> None:
    tenants = 16 if FAST else 128
    m, shards = 16, 8

    cp = _churned_plane(tenants, m, shards)
    full_s, remap = timed(cp.compact, 1.05)
    full_us = full_s * 1e6

    cp2 = _churned_plane(tenants, m, shards)
    pass_us: list[float] = []
    moves = 0
    while True:
        pass_s, r = timed(cp2.compact, 1.05, max_moves=1)
        dt = pass_s * 1e6
        if not r:
            break
        pass_us.append(dt)
        moves += len(r)
        assert len(pass_us) < 10 * tenants, "incremental compaction diverged"
    inc_max = max(pass_us)

    emit("eventlog_compact_full", full_us, tenants_live=tenants // 2,
         moves=len(remap), shards=shards,
         imbalance_after=f"{cp._layout.imbalance():.2f}")
    emit("eventlog_compact_incremental", inc_max, tenants_live=tenants // 2,
         passes=len(pass_us), moves=moves,
         total_us=f"{sum(pass_us):.1f}",
         max_over_full=f"{inc_max / full_us:.3f}",
         imbalance_after=f"{cp2._layout.imbalance():.2f}")
    # the pause-bound acceptance claim, enforced at measurement time — at
    # full shapes only: a 16-tenant FAST pass moves too few blocks for the
    # gap to clear CI timing noise (full-size margin is ~5x)
    assert FAST or inc_max < full_us, (
        f"incremental max pause {inc_max:.0f}us >= full pause {full_us:.0f}us")


def _trace_and_factory():
    sessions = 20 if FAST else 120
    trace = poisson_churn_trace(
        num_sessions=sessions, arrival_rate=1.0, seed=0,
        m_min=2, m_max=16, session_scale=25.0, num_failure_slices=2)

    def make(**kw):
        return StreamEngine(Fleet.partition_pod(256, 8), "mdmt", seed=0,
                            max_live_models=120, num_shards=4,
                            compact_every=4, **kw)
    return trace, make


def bench_snapshot_restore_append() -> None:
    trace, make = _trace_and_factory()
    with tempfile.TemporaryDirectory() as d:
        logdir, snapdir = Path(d) / "log", Path(d) / "snap"
        eng = make(log=EventLog(logdir))
        res = eng.run(trace)
        eng.snapshot_root = str(snapdir)

        iters = 3 if FAST else 10
        snap_us = time_us(eng.save_snapshot, iters=iters, warmup=1)
        eng.log.close()

        log = EventLog.load(logdir)
        restore_us = time_us(lambda: recover(make, str(snapdir), log),
                             iters=iters, warmup=1)
        live = int(np.count_nonzero(eng.cp.model_live))
        emit("eventlog_snapshot", snap_us, events=eng.event_index,
             trials=len(res.trials), live_models=live)
        emit("eventlog_restore", restore_us, from_step=eng.event_index,
             trials=len(res.trials), live_models=live)

        durable = EventLog(Path(d) / "bench_log")
        rec = (3, 12.5, "finish", [2, 57, 14])
        n = 200 if FAST else 2000
        us_durable = time_us(lambda: durable.append_processed(*rec),
                             iters=n, warmup=10)
        durable.close()
        mem = EventLog()
        us_mem = time_us(lambda: mem.append_processed(*rec),
                         iters=n, warmup=10)
        emit("eventlog_append_processed", us_durable,
             in_memory_us=f"{us_mem:.2f}")


def bench_end_to_end_overhead() -> None:
    trace, make = _trace_and_factory()
    plain_eng = make()
    plain_s, _ = timed(plain_eng.run, trace)

    with tempfile.TemporaryDirectory() as d:
        eng = make(log=EventLog(Path(d) / "log"),
                   snapshot_root=str(Path(d) / "snap"), snapshot_every=32)
        durable_s, _ = timed(eng.run, trace)
        eng.log.close()
        snapshots = len(list((Path(d) / "snap").glob("step_*")))

    events = eng.event_index
    emit("eventlog_end_to_end_overhead",
         (durable_s - plain_s) / max(events, 1) * 1e6,
         events=events, snapshots=snapshots,
         plain_s=f"{plain_s:.2f}", durable_s=f"{durable_s:.2f}",
         overhead_pct=f"{100 * (durable_s - plain_s) / plain_s:.1f}")


def main() -> None:
    bench_compaction_modes()
    bench_snapshot_restore_append()
    bench_end_to_end_overhead()


if __name__ == "__main__":
    main()
