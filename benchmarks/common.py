"""Shared benchmark utilities: timing, CSV row emission, engine selection.

Every benchmark prints rows:  name,us_per_call,derived
(one logical row per paper-table entry; `derived` packs the table's
figure-of-merit as `key=value` pairs joined by `;`).

The episode-driven figures (fig2/fig3/fig4/fig5) accept ``--engine
{event,batched}``: ``event`` is the host event loop in
``repro.core.scheduler``; ``batched`` runs the whole sweep as one
``vmap(lax.scan)`` call via ``repro.core.sim_batched`` (DESIGN.md §6).
``--seeds`` overrides the per-figure seed count for either engine
(many-seed batched sweeps are nearly free once the batch is compiled).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path


FAST = os.environ.get("BENCH_FAST", "0") == "1"

# Version of the BENCH_<suite>.json payload shape.  Bump when the envelope
# changes incompatibly; row keys may grow freely within a version.
#   1: {"schema_version", "git_sha", "suite", "rows": {name: {...}}}
#      (pre-versioned files were the bare rows dict); the optional
#      "environment" stamp (platform/device/fast metadata consumed by
#      benchmarks/regress.py) grew within version 1 — payloads without it
#      are legacy baselines, compared only under --allow-legacy.
BENCH_SCHEMA_VERSION = 1


def set_fast(value: bool = True) -> None:
    """Flip FAST at runtime (benchmarks.run --smoke).  Must run before the
    section modules are imported — they bind ``FAST`` at import time."""
    global FAST
    FAST = value
    os.environ["BENCH_FAST"] = "1" if value else "0"


def git_sha() -> str:
    """Short git SHA of the working tree (env override GIT_SHA for CI
    detached states), or "unknown" outside a repo."""
    sha = os.environ.get("GIT_SHA")
    if sha:
        return sha[:12]
    try:
        import subprocess
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def environment() -> dict:
    """The measurement environment stamp that rides in every BENCH payload.

    ``benchmarks/regress.py`` matches these fields before diffing two runs:
    timings from different platforms, device kinds/counts, or fast-mode
    settings are apples-to-oranges and must be refused, not averaged.
    Device fields degrade to "none"/0 when jax is unavailable so the stamp
    itself never fails a suite.
    """
    import platform
    env = {
        "platform": platform.system().lower() or "unknown",
        "machine": platform.machine() or "unknown",
        "python": platform.python_version(),
        "fast": FAST,
        "device_kind": "none",
        "device_count": 0,
    }
    try:
        import jax
        devs = jax.devices()
        env["device_kind"] = devs[0].device_kind if devs else "none"
        env["device_count"] = len(devs)
    except Exception:
        pass
    return env

# rows of the suite currently being recorded (None = recording disabled);
# benchmarks/run.py brackets each section with begin_suite()/end_suite() so
# the perf trajectory lands in machine-readable BENCH_<suite>.json files
# alongside the human-readable CSV on stdout.
_suite_name: str | None = None
_suite_rows: dict[str, dict] | None = None


def begin_suite(name: str) -> None:
    """Start recording emit() rows under suite ``name``."""
    global _suite_name, _suite_rows
    _suite_name = name
    _suite_rows = {}


def end_suite(out_dir: str | Path = ".") -> Path | None:
    """Write the recorded rows to BENCH_<suite>.json and stop recording.
    Returns the path (None if nothing was recorded).  Every emission is
    stamped with the schema version and the git SHA it was measured at, so
    the committed perf trajectory stays machine-comparable across PRs."""
    global _suite_name, _suite_rows
    name, rows = _suite_name, _suite_rows
    _suite_name = _suite_rows = None
    if name is None or rows is None:
        return None
    payload = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": git_sha(),
        "suite": name,
        "environment": environment(),
        "rows": rows,
    }
    path = Path(out_dir) / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True))
    return path


def abort_suite() -> None:
    """Stop recording WITHOUT writing — a failed section must not clobber
    the committed baseline with partial rows."""
    global _suite_name, _suite_rows
    _suite_name = _suite_rows = None


def positive_int(value: str) -> int:
    iv = int(value)
    if iv < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {iv}")
    return iv


def parse_engine_args(argv=None) -> argparse.Namespace:
    """Parse the shared --engine/--seeds flags.

    Tolerates bare section names (benchmarks.run passes sys.argv through)
    but rejects unknown *flags*, so a typo'd option fails loudly instead of
    silently running the default engine — also when a figure script is run
    directly (``python -m benchmarks.fig5_synthetic_speedup --engine ...``).
    """
    p = argparse.ArgumentParser(
        description="episode-engine selection (shared by fig2-5)")
    p.add_argument("--engine", choices=("event", "batched"), default="event")
    p.add_argument("--seeds", type=positive_int, default=None)
    # handled by benchmarks.run before sections import; accepted here so the
    # flag survives the strict stray-flag check when argv passes through
    p.add_argument("--smoke", action="store_true")
    args, rest = p.parse_known_args(argv)
    if args.smoke and not FAST:
        # standalone figure scripts bind FAST at import, long before this
        # parse — silently running full-size shapes would betray the flag
        p.error("--smoke only takes effect via `python -m benchmarks.run "
                "--smoke`; for a standalone figure script set BENCH_FAST=1")
    stray = [t for t in rest if t.startswith("-")]
    if stray:
        p.error(f"unrecognized arguments: {' '.join(stray)}")
    return args


def emit(name: str, us_per_call: float, **derived) -> None:
    packed = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{packed}")
    if _suite_rows is not None:
        _suite_rows[name] = {"us_per_call": round(us_per_call, 1),
                             **{k: str(v) for k, v in derived.items()}}


def block_ready(x):
    """``jax.block_until_ready`` with a graceful identity fallback — the one
    device-timing primitive (re-exported from ``repro.obs.trace`` so the
    tracer's span sync and the benchmarks measure the same way)."""
    try:
        from repro.obs.trace import block_ready as _br
    except ImportError:       # benchmarks runnable without src on the path
        try:
            import jax
            return jax.block_until_ready(x)
        except Exception:
            return x
    return _br(x)


def time_us(fn, *args, iters: int = 20, warmup: int = 3, sync: bool = False,
            **kw) -> float:
    """Mean wall time of ``fn(*args, **kw)`` in µs, after ``warmup`` calls.

    Default blocks once after the loop — right for measuring steady-state
    dispatch throughput of an async pipeline.  ``sync=True`` blocks on every
    iteration (and on every warmup call), which is what a *latency* number
    needs: per-call time including execution, the recipe the old per-file
    ``decide_sync`` wrappers duplicated."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        if sync:
            block_ready(out)
    t0 = time.perf_counter()
    if sync:
        for _ in range(iters):
            block_ready(fn(*args, **kw))
    else:
        for _ in range(iters):
            out = fn(*args, **kw)
        block_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def timed(fn, *args, **kw):
    """One synced call: ``(seconds, result)``.  For one-shot costs (a
    compaction pass, a snapshot write) where an iteration loop would
    mutate state it shouldn't."""
    t0 = time.perf_counter()
    out = block_ready(fn(*args, **kw))
    return time.perf_counter() - t0, out
