"""Shared benchmark utilities: timing + CSV row emission.

Every benchmark prints rows:  name,us_per_call,derived
(one logical row per paper-table entry; `derived` packs the table's
figure-of-merit as `key=value` pairs joined by `;`).
"""

from __future__ import annotations

import os
import time


FAST = os.environ.get("BENCH_FAST", "0") == "1"


def emit(name: str, us_per_call: float, **derived) -> None:
    packed = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{packed}")


def time_us(fn, *args, iters: int = 20, warmup: int = 3, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6
