"""Shared benchmark utilities: timing, CSV row emission, engine selection.

Every benchmark prints rows:  name,us_per_call,derived
(one logical row per paper-table entry; `derived` packs the table's
figure-of-merit as `key=value` pairs joined by `;`).

The episode-driven figures (fig2/fig3/fig4/fig5) accept ``--engine
{event,batched}``: ``event`` is the host event loop in
``repro.core.scheduler``; ``batched`` runs the whole sweep as one
``vmap(lax.scan)`` call via ``repro.core.sim_batched`` (DESIGN.md §6).
``--seeds`` overrides the per-figure seed count for either engine
(many-seed batched sweeps are nearly free once the batch is compiled).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path


FAST = os.environ.get("BENCH_FAST", "0") == "1"

# rows of the suite currently being recorded (None = recording disabled);
# benchmarks/run.py brackets each section with begin_suite()/end_suite() so
# the perf trajectory lands in machine-readable BENCH_<suite>.json files
# alongside the human-readable CSV on stdout.
_suite_name: str | None = None
_suite_rows: dict[str, dict] | None = None


def begin_suite(name: str) -> None:
    """Start recording emit() rows under suite ``name``."""
    global _suite_name, _suite_rows
    _suite_name = name
    _suite_rows = {}


def end_suite(out_dir: str | Path = ".") -> Path | None:
    """Write the recorded rows to BENCH_<suite>.json and stop recording.
    Returns the path (None if nothing was recorded)."""
    global _suite_name, _suite_rows
    name, rows = _suite_name, _suite_rows
    _suite_name = _suite_rows = None
    if name is None or rows is None:
        return None
    path = Path(out_dir) / f"BENCH_{name}.json"
    path.write_text(json.dumps(rows, indent=2, sort_keys=True))
    return path


def abort_suite() -> None:
    """Stop recording WITHOUT writing — a failed section must not clobber
    the committed baseline with partial rows."""
    global _suite_name, _suite_rows
    _suite_name = _suite_rows = None


def positive_int(value: str) -> int:
    iv = int(value)
    if iv < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {iv}")
    return iv


def parse_engine_args(argv=None) -> argparse.Namespace:
    """Parse the shared --engine/--seeds flags.

    Tolerates bare section names (benchmarks.run passes sys.argv through)
    but rejects unknown *flags*, so a typo'd option fails loudly instead of
    silently running the default engine — also when a figure script is run
    directly (``python -m benchmarks.fig5_synthetic_speedup --engine ...``).
    """
    p = argparse.ArgumentParser(
        description="episode-engine selection (shared by fig2-5)")
    p.add_argument("--engine", choices=("event", "batched"), default="event")
    p.add_argument("--seeds", type=positive_int, default=None)
    args, rest = p.parse_known_args(argv)
    stray = [t for t in rest if t.startswith("-")]
    if stray:
        p.error(f"unrecognized arguments: {' '.join(stray)}")
    return args


def emit(name: str, us_per_call: float, **derived) -> None:
    packed = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{packed}")
    if _suite_rows is not None:
        _suite_rows[name] = {"us_per_call": round(us_per_call, 1),
                             **{k: str(v) for k, v in derived.items()}}


def time_us(fn, *args, iters: int = 20, warmup: int = 3, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6
