"""Shared benchmark utilities: timing, CSV row emission, engine selection.

Every benchmark prints rows:  name,us_per_call,derived
(one logical row per paper-table entry; `derived` packs the table's
figure-of-merit as `key=value` pairs joined by `;`).

The episode-driven figures (fig2/fig3/fig4/fig5) accept ``--engine
{event,batched}``: ``event`` is the host event loop in
``repro.core.scheduler``; ``batched`` runs the whole sweep as one
``vmap(lax.scan)`` call via ``repro.core.sim_batched`` (DESIGN.md §6).
``--seeds`` overrides the per-figure seed count for either engine
(many-seed batched sweeps are nearly free once the batch is compiled).
"""

from __future__ import annotations

import argparse
import os
import time


FAST = os.environ.get("BENCH_FAST", "0") == "1"


def positive_int(value: str) -> int:
    iv = int(value)
    if iv < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {iv}")
    return iv


def parse_engine_args(argv=None) -> argparse.Namespace:
    """Parse the shared --engine/--seeds flags.

    Tolerates bare section names (benchmarks.run passes sys.argv through)
    but rejects unknown *flags*, so a typo'd option fails loudly instead of
    silently running the default engine — also when a figure script is run
    directly (``python -m benchmarks.fig5_synthetic_speedup --engine ...``).
    """
    p = argparse.ArgumentParser(
        description="episode-engine selection (shared by fig2-5)")
    p.add_argument("--engine", choices=("event", "batched"), default="event")
    p.add_argument("--seeds", type=positive_int, default=None)
    args, rest = p.parse_known_args(argv)
    stray = [t for t in rest if t.startswith("-")]
    if stray:
        p.error(f"unrecognized arguments: {' '.join(stray)}")
    return args


def emit(name: str, us_per_call: float, **derived) -> None:
    packed = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{packed}")


def time_us(fn, *args, iters: int = 20, warmup: int = 3, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6
