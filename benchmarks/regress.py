"""Noise-aware perf-regression plane over the committed BENCH baselines.

The committed ``BENCH_<suite>.json`` files are the repo's perf trajectory;
until now nothing *read* them — a 2x decision-latency regression would ride
into main unnoticed as long as tests passed.  This tool closes the loop:

  python -m benchmarks.regress --check \\
      --baseline-dir baselines --fresh-dir .

compares every fresh suite against its committed baseline and exits
non-zero on regression.  Three refusal rules keep the comparison honest
(timings that are not apples-to-apples are *skipped*, never averaged):

* **schema match** — payloads must share ``schema_version``.
* **environment match** — the ``environment`` stamp
  (``benchmarks/common.py``: platform, machine, device kind/count, fast
  mode) must be identical; a laptop run never gates against a CI baseline.
  Baselines predating the stamp are *legacy*: skipped unless
  ``--allow-legacy`` (which compares rows but flags the missing stamp).
* **noise floor** — a row regresses only when fresh >= ``--threshold`` x
  baseline (default 1.5x) AND the absolute delta >= ``--min-us`` (default
  1000µs): ratio alone would flag 3µs -> 5µs scheduler jitter, the floor
  alone would miss a real 2x on a slow row.

Outputs: a ``regress_report.json`` artifact (every row's verdict, for CI
upload) and an append-only ``BENCH_history.jsonl`` line per run (suite,
git SHA, environment, per-row µs) — the longitudinal record the one-shot
baseline diff cannot give.  Exit codes: 0 ok/skipped, 1 regression, 2
usage/IO error.  ``--strict`` also fails on suites missing from the
baseline dir (new suites pass by default — they have no baseline yet).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .common import BENCH_SCHEMA_VERSION

REGRESS_SCHEMA_VERSION = 1

#: environment-stamp fields that must match for timings to be comparable
ENV_MATCH_FIELDS = ("platform", "machine", "device_kind", "device_count",
                    "fast")


def load_suite(path: Path) -> dict:
    """Load one BENCH payload; raises ValueError on a non-dict or a
    pre-versioned bare-rows file (those predate the envelope and carry no
    suite name to match on)."""
    data = json.loads(path.read_text())
    if not isinstance(data, dict) or "rows" not in data:
        raise ValueError(f"{path}: not a BENCH payload (no 'rows')")
    return data


def env_mismatch(base: dict, fresh: dict) -> list[str]:
    """Environment-stamp fields that differ (empty = comparable)."""
    be, fe = base.get("environment"), fresh.get("environment")
    if be is None or fe is None:
        return []                # legacy handling is the caller's decision
    return [f for f in ENV_MATCH_FIELDS if be.get(f) != fe.get(f)]


def compare_suites(base: dict, fresh: dict, *, threshold: float,
                   min_us: float, allow_legacy: bool) -> dict:
    """Row-by-row comparison of one suite.  Returns the suite verdict:
    ``status`` is ``ok`` | ``regression`` | ``skipped`` (with a
    ``reason``), plus per-row records for the report artifact."""
    suite = fresh.get("suite", "?")
    if base.get("schema_version") != fresh.get("schema_version"):
        return {"suite": suite, "status": "skipped",
                "reason": f"schema_version mismatch "
                          f"({base.get('schema_version')} vs "
                          f"{fresh.get('schema_version')})", "rows": []}
    legacy = base.get("environment") is None
    if legacy and not allow_legacy:
        return {"suite": suite, "status": "skipped",
                "reason": "baseline has no environment stamp "
                          "(legacy; rerun with --allow-legacy to compare)",
                "rows": []}
    bad_fields = env_mismatch(base, fresh)
    if bad_fields:
        return {"suite": suite, "status": "skipped",
                "reason": f"environment mismatch on {bad_fields}",
                "rows": []}

    rows = []
    regressed = False
    for name, brow in sorted(base["rows"].items()):
        frow = fresh["rows"].get(name)
        if frow is None:
            rows.append({"name": name, "status": "missing_in_fresh"})
            continue
        b, f = float(brow["us_per_call"]), float(frow["us_per_call"])
        ratio = f / b if b > 0 else float("inf")
        is_reg = ratio >= threshold and (f - b) >= min_us
        regressed |= is_reg
        rows.append({"name": name, "baseline_us": b, "fresh_us": f,
                     "ratio": round(ratio, 3),
                     "status": "regression" if is_reg else "ok"})
    for name in sorted(set(fresh["rows"]) - set(base["rows"])):
        rows.append({"name": name, "status": "new_in_fresh"})
    return {"suite": suite,
            "status": "regression" if regressed else "ok",
            "legacy_baseline": legacy, "rows": rows}


def append_history(history: Path, payload: dict) -> None:
    """One longitudinal JSONL line per fresh suite run."""
    line = {"schema_version": REGRESS_SCHEMA_VERSION,
            "suite": payload.get("suite"),
            "git_sha": payload.get("git_sha"),
            "environment": payload.get("environment"),
            "rows": {name: row.get("us_per_call")
                     for name, row in payload.get("rows", {}).items()}}
    with open(history, "a", encoding="utf-8") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.regress", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--check", action="store_true",
                   help="exit 1 when any comparable suite regresses")
    p.add_argument("--baseline-dir", type=Path, default=Path("."),
                   help="directory of committed BENCH_*.json baselines")
    p.add_argument("--fresh-dir", type=Path, default=Path("."),
                   help="directory of freshly measured BENCH_*.json")
    p.add_argument("--threshold", type=float, default=1.5,
                   help="regression ratio: fresh/baseline (default 1.5)")
    p.add_argument("--min-us", type=float, default=1000.0,
                   help="absolute regression floor in µs (default 1000)")
    p.add_argument("--report", type=Path, default=Path("regress_report.json"),
                   help="verdict artifact path")
    p.add_argument("--history", type=Path, default=None,
                   help="append one JSONL line per fresh suite here")
    p.add_argument("--allow-legacy", action="store_true",
                   help="compare against baselines without an environment "
                        "stamp instead of skipping them")
    p.add_argument("--strict", action="store_true",
                   help="also fail on fresh suites with no baseline")
    args = p.parse_args(argv)

    fresh_paths = sorted(args.fresh_dir.glob("BENCH_*.json"))
    if not fresh_paths:
        print(f"regress: no BENCH_*.json under {args.fresh_dir}",
              file=sys.stderr)
        return 2

    results = []
    missing_baseline = []
    for fp in fresh_paths:
        try:
            fresh = load_suite(fp)
        except (ValueError, json.JSONDecodeError) as e:
            print(f"regress: unreadable fresh payload: {e}", file=sys.stderr)
            return 2
        bp = args.baseline_dir / fp.name
        if not bp.exists():
            missing_baseline.append(fresh.get("suite", fp.name))
            results.append({"suite": fresh.get("suite", fp.name),
                            "status": "skipped",
                            "reason": "no committed baseline", "rows": []})
        else:
            try:
                base = load_suite(bp)
            except (ValueError, json.JSONDecodeError) as e:
                print(f"regress: unreadable baseline: {e}", file=sys.stderr)
                return 2
            results.append(compare_suites(
                base, fresh, threshold=args.threshold, min_us=args.min_us,
                allow_legacy=args.allow_legacy))
        if args.history is not None:
            append_history(args.history, fresh)

    report = {"schema_version": REGRESS_SCHEMA_VERSION,
              "threshold": args.threshold, "min_us": args.min_us,
              "schema_expected": BENCH_SCHEMA_VERSION,
              "suites": results}
    args.report.write_text(json.dumps(report, indent=2, sort_keys=True))

    regressions = [r for r in results if r["status"] == "regression"]
    for r in results:
        detail = r.get("reason", "")
        bad = [row["name"] for row in r["rows"]
               if row.get("status") == "regression"]
        if bad:
            detail = f"rows: {', '.join(bad)}"
        print(f"regress: {r['suite']}: {r['status']}"
              + (f" ({detail})" if detail else ""))
    print(f"# wrote {args.report}", file=sys.stderr)

    if args.check and regressions:
        return 1
    if args.check and args.strict and missing_baseline:
        print(f"regress: --strict: no baseline for {missing_baseline}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
