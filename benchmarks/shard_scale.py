"""Sharded scoring plane at scale: decision latency vs |L| and mesh size.

Three measurements (DESIGN.md §10):

* ``shard_decide_L{n}_S{s}`` — one full decision (readout -> EIrate ->
  global argmax) over |L| live models on an s-way shard mesh, via the
  fused ``shardgp.score._readout_decide`` program: each shard streams its
  slice of the (k_obs, n) W readout buffer once, scores locally, reduces
  its top-k, and one all_gather picks the global argmax.  Strong scaling:
  fixed |L|, growing mesh.

* ``shard_weak_L{n}_S{s}`` — weak scaling: |L| = per_shard * s, so each
  shard's slice stays constant; ``eff`` is t(S=1)/t(S) (1.0 = perfect).

* ``shard_compaction_L{n}`` — index-space compaction pause: a churned
  control plane (half the tenants retired, maximally skewed spans) timed
  through one full ``compact()`` rebalance + mirror refresh.

Mesh sizes sweep {1, 2, 4, 8} clipped to the visible device count — on one
real device only S=1 runs; CI forces a 4-device host mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.  On this CPU
container the "devices" share the same cores, so the scaling numbers
validate plumbing and shape-stability, not speedup; the kernel path is the
XLA reference off-TPU (``kernels/ops`` dispatch rule).

|L|=1M is gated behind BENCH_SHARD_1M=1 (the W buffer alone is
k_obs * 1M * 4 bytes).
"""

from __future__ import annotations

import os

import numpy as np

from .common import FAST, emit, time_us, timed

K_OBS = 64          # observed-set rows of the synthetic W readout buffer
TOPK = 4


def _mesh_sizes() -> list[int]:
    import jax
    avail = len(jax.devices())
    return [s for s in (1, 2, 4, 8) if s <= avail]


def _sizes() -> list[int]:
    if FAST:
        return [2048]
    sizes = [10_000, 100_000]
    if os.environ.get("BENCH_SHARD_1M", "0") == "1":
        sizes.append(1_000_000)
    return sizes


def _synthetic_state(n: int, num_tenants: int, rng: np.random.Generator):
    """A service-scale scoring state with a plausible posterior: W rows are
    damped random directions (so var = kdiag - sum W^2 stays positive),
    one owner per model (the dynamic plane's invariant)."""
    W = (rng.standard_normal((K_OBS, n)) * 0.05).astype(np.float32)
    alpha = rng.standard_normal(K_OBS).astype(np.float32)
    mu0 = np.zeros(n, dtype=np.float32)
    kdiag = (0.04 + (W * W).sum(axis=0)).astype(np.float32)
    best = rng.uniform(-0.5, 0.5, num_tenants).astype(np.float32)
    owner = rng.integers(0, num_tenants, size=n)
    member = np.zeros((num_tenants, n), dtype=bool)
    member[owner, np.arange(n)] = True
    cost = rng.uniform(0.5, 2.0, n).astype(np.float32)
    selected = rng.random(n) < 0.1
    return W, alpha, mu0, kdiag, best, member, cost, selected


def _bench_decide(n: int, shards: int, iters: int) -> float:
    """µs per fused readout->score->argmax decision at |L|=n on ``shards``."""
    import jax

    from repro.shardgp import ShardedScorer

    from jax.sharding import NamedSharding

    from repro.shardgp.score import P_MODELS, P_W

    rng = np.random.default_rng(0)
    num_tenants = max(8, min(256, n // 64))
    cap = ((n + shards - 1) // shards) * shards
    W, alpha, mu0, kdiag, best, member, cost, selected = _synthetic_state(
        cap, num_tenants, rng)
    sc = ShardedScorer(shards, topk=TOPK)
    sc.refresh(member, cost)
    # the W buffer and per-model vectors are device-resident in the service
    # hot loop — pre-place them so the timing measures the decision program,
    # not a 25MB host->device copy per call
    W = jax.device_put(W, NamedSharding(sc.mesh, P_W))
    mu0 = jax.device_put(mu0, NamedSharding(sc.mesh, P_MODELS))
    kdiag = jax.device_put(kdiag, NamedSharding(sc.mesh, P_MODELS))
    selected = jax.device_put(selected, NamedSharding(sc.mesh, P_MODELS))

    return time_us(sc.readout_decide_topk, W, alpha, mu0, kdiag, best,
                   selected, iters=iters, warmup=2, sync=True)


def bench_strong_and_weak_scaling() -> None:
    iters = 5 if FAST else 20
    meshes = _mesh_sizes()
    base_weak: dict[int, float] = {}

    for n in _sizes():
        base = None
        for s in meshes:
            us = _bench_decide(n, s, iters)
            if base is None:
                base = us
            emit(f"shard_decide_L{n}_S{s}", us, live_models=n, shards=s,
                 k_obs=K_OBS, topk=TOPK, speedup=f"{base / us:.2f}")

    per_shard = 2048 if FAST else 25_000
    for s in meshes:
        n = per_shard * s
        us = _bench_decide(n, s, iters)
        if s == 1:
            base_weak[per_shard] = us
        eff = base_weak[per_shard] / us
        emit(f"shard_weak_L{n}_S{s}", us, live_models=n, shards=s,
             per_shard=per_shard, eff=f"{eff:.2f}")


def bench_compaction_pause() -> None:
    """Wall-clock of one compact() rebalance on a churned control plane."""
    from repro.core import ControlPlane
    from repro.core.tenancy import _matern_block_chol

    tenants = 16 if FAST else 128
    m = 16
    shards = max(_mesh_sizes())
    K_block, _ = _matern_block_chol(m, 0.2, 0.04)
    cp = ControlPlane(np.random.default_rng(0), model_capacity=tenants * m,
                      tenant_capacity=tenants, num_shards=shards)
    handles = [cp.add_tenant(K_block, np.zeros(m), np.ones(m))
               for _ in range(tenants)]
    rng = np.random.default_rng(1)
    # one observation per tenant (the layout spreads blocks across spans,
    # so tenant t's ids come from its handle, not t*m arithmetic)
    for h in handles:
        g = int(h.models[rng.integers(m)])
        cp.record_start(g)
        cp.record_observation(g, float(rng.uniform()))
    # retire every other tenant -> skewed spans, lots of movable blocks
    for t in range(0, tenants, 2):
        cp.retire_tenant(t)
    pause_s, remap = timed(cp.compact, 1.05)
    pause_us = pause_s * 1e6
    emit(f"shard_compaction_L{tenants * m}", pause_us,
         tenants_live=tenants // 2, moves=len(remap), shards=shards,
         imbalance_after=f"{cp._layout.imbalance():.2f}")


def main() -> None:
    bench_strong_and_weak_scaling()
    bench_compaction_pause()


if __name__ == "__main__":
    main()
