"""Capacity plane measurements: weak-scaling-gap decomposition, per-shard
skew, dispatch overhead, and accounting-sample cost
(-> BENCH_capacity.json).

BENCH_shard_scale.json reports the symptom — weak-scaling efficiency 0.16
at S=8 (44.9ms at S=1/25k models vs 282.4ms at S=8/200k) — without naming
a cause.  This suite decomposes that gap into the three candidate causes
the sharded program can exhibit, each measured independently:

* ``capacity_weak_gap_L{n}_S{s}`` — the decomposition row.  The *gap* is
  fused(S) - fused(S=1) at fixed per-shard load (weak scaling: each
  shard's slice is constant, so a perfectly scaling program has gap 0).
  Attribution terms, all deltas vs the S=1 reference:
    - ``skew_us``      — (readout + score) phase time growth: per-shard
      compute that should be constant but grows with S (on this CPU
      container the forced host "devices" share physical cores, so this
      term is contention + scheduler imbalance — exactly what the barrier
      at the slowest shard turns into decision latency);
    - ``allgather_us`` — gather/pick phase growth: the cross-shard
      candidate exchange, the only term that *must* grow with S;
    - ``dispatch_us``  — growth of a trivially small shard_map program's
      per-call time: partitioning + launch overhead, independent of |L|.
  ``attributed_pct`` = their sum over the gap.  **Acceptance: >= 80% at
  S=8** (asserted at measurement time, like decision_trace's >= 90% span
  bar).  Phase deltas come from separately jitted phase programs
  (``ShardedScorer.phase_times``), so their sum can legitimately land
  above 100% of the fused gap — attribution is about naming causes, the
  fused number is about speed.

* ``capacity_shard_skew_S{s}`` — the same per-shard workload pinned to
  each device in turn (single-device meshes, ``obs.profile.per_shard_skew``);
  ``skew`` is max/mean, the time-axis twin of the layout plane's slot
  imbalance index.

* ``capacity_accounting_sample`` — the cost of one
  ``CapacityAccountant.sample`` pass (capacity_stats introspection + gauge
  publication) on a churned control plane: the price the engines pay per
  sampled window, which must stay negligible next to a decision.

Committed numbers use the BENCH_shard_scale protocol:
``XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu``.
"""

from __future__ import annotations

import numpy as np

from . import common
from .common import emit, time_us
from .decision_trace import _setup
from .shard_scale import TOPK, _synthetic_state


def _mesh_sizes() -> list[int]:
    """(1, S) with S the full host mesh capped at the committed protocol's
    8 — so the 8-device protocol measures S=8 and the CI smoke's forced
    4-device host still exercises the multi-shard decomposition at S=4."""
    import jax
    avail = min(len(jax.devices()), 8)
    return [1] if avail == 1 else [1, avail]


def bench_weak_gap() -> None:
    from repro.obs.profile import dispatch_overhead_us

    fast = common.FAST          # read at call time: --smoke sets it late
    iters = 5 if fast else 20
    per_shard = 2048 if fast else 25_000
    meshes = _mesh_sizes()

    # the S=1 reference: same per-shard load, no sharding
    sc1, args1 = _setup(per_shard, 1)
    fused1 = time_us(sc1.readout_decide_topk, *args1, iters=iters,
                     warmup=2, sync=True)
    ph1 = sc1.phase_times(*args1, iters=iters, warmup=2)
    disp1 = dispatch_overhead_us(sc1.mesh)
    emit(f"capacity_weak_gap_L{per_shard}_S1", fused1,
         live_models=per_shard, shards=1, per_shard=per_shard,
         readout_us=f"{ph1['readout_us']:.1f}",
         score_us=f"{ph1['score_us']:.1f}",
         gather_us=f"{ph1['gather_us']:.1f}",
         dispatch_us=f"{disp1:.1f}")

    for s in meshes:
        if s == 1:
            continue
        n = per_shard * s
        sc, args = _setup(n, s)
        fused = time_us(sc.readout_decide_topk, *args, iters=iters,
                        warmup=2, sync=True)
        ph = sc.phase_times(*args, iters=iters, warmup=2)
        disp = dispatch_overhead_us(sc.mesh)

        gap = fused - fused1
        skew = ((ph["readout_us"] + ph["score_us"])
                - (ph1["readout_us"] + ph1["score_us"]))
        gather = ph["gather_us"] - ph1["gather_us"]
        dispatch = disp - disp1
        attributed = (100.0 * (skew + gather + dispatch) / gap
                      if gap > 0 else 0.0)
        emit(f"capacity_weak_gap_L{n}_S{s}", fused,
             live_models=n, shards=s, per_shard=per_shard,
             base_us=f"{fused1:.1f}", gap_us=f"{gap:.1f}",
             skew_us=f"{skew:.1f}", allgather_us=f"{gather:.1f}",
             dispatch_us=f"{dispatch:.1f}",
             attributed_pct=f"{attributed:.1f}")
        # the tentpole acceptance bar, enforced at measurement time
        assert fast or s < 8 or attributed >= 80.0, (
            f"decomposition attributes only {attributed:.1f}% of the "
            f"S={s} weak-scaling gap (need >= 80%)")


def bench_shard_skew() -> None:
    import jax
    from jax.sharding import NamedSharding

    from repro.obs.profile import per_shard_skew
    from repro.shardgp import ShardedScorer
    from repro.shardgp.score import P_MODELS, P_W

    fast = common.FAST
    iters = 3 if fast else 10
    per_shard = 2048 if fast else 25_000
    devices = jax.devices()[:max(_mesh_sizes())]
    if len(devices) < 2:
        return                 # one device: no skew to measure

    def make_thunk(shard_index: int, mesh):
        # every device gets the IDENTICAL single-shard workload — any
        # timing spread is the platform's, not the data's
        rng = np.random.default_rng(0)
        num_tenants = max(8, min(256, per_shard // 64))
        (W, alpha, mu0, kdiag, best, member, cost,
         selected) = _synthetic_state(per_shard, num_tenants, rng)
        sc = ShardedScorer(topk=TOPK, mesh=mesh)
        sc.refresh(member, cost)
        W = jax.device_put(W, NamedSharding(mesh, P_W))
        mu0 = jax.device_put(mu0, NamedSharding(mesh, P_MODELS))
        kdiag = jax.device_put(kdiag, NamedSharding(mesh, P_MODELS))
        sel = jax.device_put(selected, NamedSharding(mesh, P_MODELS))
        return lambda: sc.readout_decide_topk(W, alpha, mu0, kdiag,
                                              best, sel)

    res = per_shard_skew(make_thunk, devices, iters=iters, warmup=2)
    per = ";".join(f"{u:.0f}" for u in res["per_shard_us"])
    emit(f"capacity_shard_skew_S{len(devices)}", res["mean_us"],
         shards=len(devices), per_shard=per_shard,
         max_us=f"{res['max_us']:.1f}", min_us=f"{res['min_us']:.1f}",
         skew=f"{res['skew']:.3f}", per_shard_us=per)


def bench_accounting_sample() -> None:
    from repro.core import ControlPlane
    from repro.core.tenancy import _matern_block_chol
    from repro.obs import CapacityAccountant, MetricsRegistry

    fast = common.FAST
    tenants = 16 if fast else 128
    m = 16
    shards = max(_mesh_sizes())
    K_block, _ = _matern_block_chol(m, 0.2, 0.04)
    cp = ControlPlane(np.random.default_rng(0), model_capacity=tenants * m,
                      tenant_capacity=tenants, num_shards=shards)
    rng = np.random.default_rng(1)
    for _ in range(tenants):
        h = cp.add_tenant(K_block, np.zeros(m), np.ones(m))
        g = int(h.models[rng.integers(m)])
        cp.record_start(g)
        cp.record_observation(g, float(rng.uniform()))

    class _EngineShim:
        """The minimal engine surface ``CapacityAccountant.sample`` reads —
        measures the sample pass itself, not a full engine run."""
        def __init__(self, cp):
            self.cp = cp
            self.fleet = type("F", (), {"slices": []})()
            self.health = None

        def _capacity_extra(self):
            return {}

    shim = _EngineShim(cp)
    acc = CapacityAccountant(MetricsRegistry())
    us = time_us(lambda: acc.sample(0.0, 0, shim),
                 iters=50 if fast else 200, warmup=5)
    acc.samples.clear()
    emit("capacity_accounting_sample", us, tenants=tenants,
         models=tenants * m, shards=shards)


def main() -> None:
    bench_weak_gap()
    bench_shard_skew()
    bench_accounting_sample()


if __name__ == "__main__":
    import argparse
    import sys
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="toy shapes (same effect as BENCH_FAST=1)")
    if p.parse_args().smoke:
        common.set_fast(True)
    common.begin_suite("capacity")
    main()
    path = common.end_suite()
    if path is not None:
        print(f"# wrote {path}", file=sys.stderr)
