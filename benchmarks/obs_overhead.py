"""Live-health-plane overhead (-> BENCH_obs_overhead.json, DESIGN.md §14).

Two measurements:

* ``obs_overhead_L{n}_S{s}`` — the engines' per-event live-plane site
  stack with EVERY plane disabled (exporter/health/forensics/metrics all
  ``None``: four attribute loads + ``is not None`` branches, exactly the
  ``_drain`` hot-path sites), timed directly over thousands of iterations
  and expressed as a share of the bare fused |L|=n decision.
  **Acceptance: < 1% at |L|=100k**, asserted below and re-checked in CI by
  ``tests/test_obs.py::test_disabled_obs_stack_overhead_under_one_percent``
  against the committed BENCH_decision_trace.json baseline.

* ``obs_enabled_*`` — the marginal per-call cost of each plane when it IS
  attached: a non-boundary ``MetricsExporter.tick`` (the common case — a
  window boundary pays one registry snapshot + JSON line), a
  ``HealthMonitor.on_event``/``on_observation`` detector pass, and a
  ``ForensicsRecorder.on_decision`` over a k=4 top-k.  These bound what an
  operator pays for turning the monitoring on; none of them sit inside a
  jit program.

The |L| sweep reuses the decision_trace protocol (pre-placed device
buffers, same synthetic state) so ``overhead_pct`` is computed against the
same bare-decision number the committed dtrace baseline carries.
"""

from __future__ import annotations

import numpy as np

from . import common
from .common import FAST, emit, time_us
from .decision_trace import _mesh_sizes, _setup, _sizes


def _engine_all_planes_off():
    from repro.core.fleet import Fleet
    from repro.stream import StreamEngine

    eng = StreamEngine(Fleet.partition_pod(16, 1), "mdmt", seed=0)
    assert (eng.exporter is None and eng.health is None
            and eng.forensics is None and eng.metrics is None)
    return eng


def bench_disabled_sites() -> None:
    eng = _engine_all_planes_off()

    def sites():
        # the per-event live-plane stack in StreamEngine._drain, all off
        if eng.forensics is not None:
            eng.forensics.begin_event(0.0, 0)
        if eng.metrics is not None:
            pass
        if eng.health is not None:
            eng._health_tick()
        if eng.exporter is not None:
            eng.exporter.tick(0.0, 0)

    iters = 10 if FAST else 30
    site_us = time_us(sites, iters=300 if FAST else 5000, warmup=50)
    for n in _sizes():
        for s in _mesh_sizes():
            sc, args = _setup(n, s)
            bare_us = time_us(sc.readout_decide_topk, *args,
                              iters=iters, warmup=2, sync=True)
            overhead = 100.0 * site_us / bare_us
            emit(f"obs_overhead_L{n}_S{s}", site_us,
                 live_models=n, shards=s, bare_us=f"{bare_us:.1f}",
                 overhead_pct=f"{overhead:.4f}")
            assert FAST or n < 100_000 or overhead < 1.0, (
                f"disabled live-plane stack is {overhead:.2f}% of the "
                f"L={n} S={s} decision (need < 1%)")


def bench_enabled_plane_costs() -> None:
    from repro.obs import (ForensicsRecorder, HealthMonitor, MetricsExporter,
                           MetricsRegistry)

    iters = 300 if FAST else 5000

    reg = MetricsRegistry()
    reg.counter("engine.events").inc(10)
    reg.gauge("engine.queue_depth").set(3)
    reg.histogram("engine.decision_seconds").observe(1e-4)
    ex = MetricsExporter(reg, window=10.0)
    ex.tick(0.0, 0)                    # consume the first window boundary
    tick_us = time_us(lambda: ex.tick(1.0, 1), iters=iters, warmup=50)
    emit("obs_enabled_export_tick", tick_us, boundary="no")

    hm = HealthMonitor(slo={"device_utilization": 0.5}, window=1e12)
    summary = {"device_utilization": 0.4}
    ev_us = time_us(
        lambda: hm.on_event(1.0, 1, queue_depth=3, backlog=2,
                            free_classes=("base",),
                            summary_fn=lambda: summary),
        iters=iters, warmup=50)
    emit("obs_enabled_health_event", ev_us, detectors="queue+starve+burn")
    obs_us = time_us(
        lambda: hm.on_observation(1.0, 1, "t0", False, d2=1e-3,
                                  jitter=1e-6),
        iters=iters, warmup=50)
    emit("obs_enabled_health_observation", obs_us, detectors="stall+cond")

    fr = ForensicsRecorder()
    fr.begin_event(0.0, 0)
    vals = np.array([0.4, 0.3, 0.2, 0.1])
    gids = np.arange(4)
    costs = np.ones(4)
    mu = np.zeros(4)
    sd = np.ones(4)

    def decide():
        fr.on_decision(scorer="fused", values=vals, gids=gids,
                       eff_costs=costs, mu=mu, sd=sd)
        fr.records.clear()             # keep the bench allocation-flat

    dec_us = time_us(decide, iters=iters, warmup=50)
    emit("obs_enabled_forensics_decision", dec_us, topk=4)


def main() -> None:
    bench_disabled_sites()
    bench_enabled_plane_costs()


if __name__ == "__main__":
    import argparse
    import sys
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="toy shapes (same effect as BENCH_FAST=1)")
    if p.parse_args().smoke:
        common.set_fast(True)
    common.begin_suite("obs_overhead")
    main()
    path = common.end_suite()
    if path is not None:
        print(f"# wrote {path}", file=sys.stderr)
