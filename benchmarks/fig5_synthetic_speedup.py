"""Paper Fig. 5: near-linear device speedup on the synthetic workload.

Protocol (Section 6.3): 50 users x 50 models, performance sampled per user
from a zero-mean Matérn nu=5/2 GP, samples shifted non-negative; measure the
average time for the instantaneous regret to hit 0.01, repeating per device
count; the paper observes near-linear speedup.

Engines (``--engine``):
  event    one host event-loop episode per (device count, seed) — exact, slow.
  batched  the whole (device count x seed) grid as ONE vmap(lax.scan) call
           (repro.core.sim_batched), with a fresh GP sample per seed.  Use
           ``--seeds S`` for many-seed mode (default 16 -> 64+ episodes);
           the marginal cost of extra seeds is tiny once compiled.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    EpisodeSpec,
    regret_curves,
    simulate,
    simulate_batch,
    synthetic_matern_problem,
    synthetic_matern_z,
)

from .common import FAST, emit, parse_engine_args

DEVICES = (1, 2, 4, 8, 16) if not FAST else (1, 4, 16)
REPEATS = 2 if FAST else 5
CUTOFF = 0.01


def run_event(seeds: int) -> None:
    base = None
    for M in DEVICES:
        ts, dec = [], []
        for rep in range(seeds):
            prob = synthetic_matern_problem(num_users=50, num_models_per_user=50,
                                            seed=rep)
            res = simulate(prob, "mdmt", num_devices=M, seed=rep)
            ts.append(regret_curves(res).time_to_instantaneous(CUTOFF))
            dec.append(res.decision_seconds / max(res.decisions, 1) * 1e6)
        t = float(np.mean(ts))
        if base is None:
            base = t
        emit(f"fig5_synthetic_M{M}", float(np.mean(dec)),
             t_reach_0p01=f"{t:.0f}",
             speedup_vs_M1=f"{base / t:.2f}",
             ideal=f"{M}",
             linearity=f"{base / t / M:.2f}")


def run_batched(seeds: int) -> None:
    """Whole grid in one accelerator call: prior shared, z resampled per seed
    via the per-episode ``z_true`` override."""
    prob = synthetic_matern_problem(num_users=50, num_models_per_user=50, seed=0)
    z_per_seed = [
        synthetic_matern_z(num_users=50, num_models_per_user=50, seed=s)
        for s in range(seeds)]
    specs = [EpisodeSpec("mdmt", M, seed=s, z_true=z_per_seed[s])
             for M in DEVICES for s in range(seeds)]
    batch = simulate_batch(prob, specs)
    tt = batch.time_to_instantaneous(CUTOFF).reshape(len(DEVICES), seeds)
    us_per_episode = batch.wall_seconds / len(specs) * 1e6
    base = None
    for Mi, M in enumerate(DEVICES):
        t = float(np.mean(tt[Mi]))
        if base is None:
            base = t
        emit(f"fig5_synthetic_batched_M{M}", us_per_episode,
             t_reach_0p01=f"{t:.0f}",
             speedup_vs_M1=f"{base / t:.2f}",
             ideal=f"{M}",
             linearity=f"{base / t / M:.2f}")
    emit("fig5_batched_wall", us_per_episode,
         episodes=f"{len(specs)}",
         wall_s=f"{batch.wall_seconds:.1f}")


def main() -> None:
    args = parse_engine_args()
    if args.engine == "batched":
        seeds = args.seeds if args.seeds is not None else (4 if FAST else 16)
        run_batched(seeds=seeds)
    else:
        run_event(seeds=args.seeds if args.seeds is not None else REPEATS)


if __name__ == "__main__":
    main()
