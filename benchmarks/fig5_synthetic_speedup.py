"""Paper Fig. 5: near-linear device speedup on the synthetic workload.

Protocol (Section 6.3): 50 users x 50 models, performance sampled per user
from a zero-mean Matérn nu=5/2 GP, samples shifted non-negative; measure the
average time for the instantaneous regret to hit 0.01, repeating per device
count; the paper observes near-linear speedup."""

from __future__ import annotations

import numpy as np

from repro.core import regret_curves, simulate, synthetic_matern_problem

from .common import FAST, emit

DEVICES = (1, 2, 4, 8, 16) if not FAST else (1, 4, 16)
REPEATS = 2 if FAST else 5
CUTOFF = 0.01


def main() -> None:
    base = None
    for M in DEVICES:
        ts, dec = [], []
        for rep in range(REPEATS):
            prob = synthetic_matern_problem(num_users=50, num_models_per_user=50,
                                            seed=rep)
            res = simulate(prob, "mdmt", num_devices=M, seed=rep)
            ts.append(regret_curves(res).time_to_instantaneous(CUTOFF))
            dec.append(res.decision_seconds / max(res.decisions, 1) * 1e6)
        t = float(np.mean(ts))
        if base is None:
            base = t
        emit(f"fig5_synthetic_M{M}", float(np.mean(dec)),
             t_reach_0p01=f"{t:.0f}",
             speedup_vs_M1=f"{base / t:.2f}",
             ideal=f"{M}",
             linearity=f"{base / t / M:.2f}")


if __name__ == "__main__":
    main()
