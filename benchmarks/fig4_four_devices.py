"""Paper Fig. 4: policy comparison with four computation devices.

Accepts the same ``--engine {event,batched}`` flag as fig2."""

from __future__ import annotations

from . import fig2_single_device
from .common import parse_engine_args


def main() -> None:
    args = parse_engine_args()
    fig2_single_device.run(num_devices=4, tag="fig4",
                           engine=args.engine, num_seeds=args.seeds)


if __name__ == "__main__":
    main()
