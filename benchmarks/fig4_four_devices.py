"""Paper Fig. 4: policy comparison with four computation devices."""

from __future__ import annotations

from . import fig2_single_device


def main() -> None:
    fig2_single_device.run(num_devices=4, tag="fig4")


if __name__ == "__main__":
    main()
