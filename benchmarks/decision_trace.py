"""Span-level cost attribution of one sharded GP-EI decision
(-> BENCH_decision_trace.json, the data the next scaling PR builds on).

The ROADMAP's top open item asks where a |L|=100k decision's ~220ms goes.
Two measurements per (|L|, mesh) point:

* ``decision_trace_L{n}_S{s}`` — the fused readout->score->argmax pipeline
  run phase-decomposed (``ShardedScorer.readout_decide_topk_phased``): the
  same program cut at its two natural barriers, each phase closed under an
  obs-tracer span with a ``block_until_ready`` sync.  The row carries the
  per-phase means (``readout`` — GP posterior re-materialization from the
  (k_obs, n) W buffer; ``score_topk`` — EIrate + per-shard top-k;
  ``gather_pick`` — cross-shard all_gather + replicated argmax), the share
  of the root ``decide`` span they attribute (**acceptance: >= 90% at
  |L|=100k**, asserted below), and the fused single-program time for
  reference (the phase split pays extra dispatches, so phases sum above
  fused — attribution is about *where*, fused is about *how fast*).

* ``decision_overhead_L{n}_S{s}`` — the cost of the instrumentation when
  tracing is OFF.  The engine's full per-decision span-site stack (event ->
  decide -> posterior/score -> pad_upload/shard_decide, all on a disabled
  tracer — each site one branch + one shared no-op context manager) is
  timed *directly* over thousands of iterations (``site_us``) and the row's
  ``overhead_pct`` is that stack cost as a share of the bare fused
  decision.  **Acceptance: < 1% at |L|=100k**, asserted below.  The paired
  bare-vs-wrapped decision timings ride along as reference fields
  (``bare_us``/``wrapped_us``) but do not gate: the difference of two
  ~100ms CPU means has multi-percent run-to-run noise and cannot resolve a
  ~1µs stack.

Mesh sizes sweep {1, 8} clipped to the visible device count; the committed
numbers are produced with ``XLA_FLAGS=--xla_force_host_platform_device_count=8
JAX_PLATFORMS=cpu`` (same protocol as BENCH_shard_scale.json — host
"devices" share cores, so S=8 validates attribution of the sharded program,
not speedup).
"""

from __future__ import annotations

import numpy as np

from . import common
from .common import FAST, emit, time_us
from .shard_scale import K_OBS, TOPK, _synthetic_state


def _mesh_sizes() -> list[int]:
    import jax
    avail = len(jax.devices())
    return [s for s in (1, 8) if s <= avail]


def _sizes() -> list[int]:
    return [2048] if FAST else [10_000, 100_000]


def _setup(n: int, shards: int):
    """Device-resident scoring state at |L|=n on a ``shards``-way mesh —
    the shard_scale protocol (pre-placed W/vectors, so timings measure the
    decision program, not host->device copies)."""
    import jax
    from jax.sharding import NamedSharding

    from repro.shardgp import ShardedScorer
    from repro.shardgp.score import P_MODELS, P_W

    rng = np.random.default_rng(0)
    num_tenants = max(8, min(256, n // 64))
    cap = ((n + shards - 1) // shards) * shards
    W, alpha, mu0, kdiag, best, member, cost, selected = _synthetic_state(
        cap, num_tenants, rng)
    sc = ShardedScorer(shards, topk=TOPK)
    sc.refresh(member, cost)
    W = jax.device_put(W, NamedSharding(sc.mesh, P_W))
    mu0 = jax.device_put(mu0, NamedSharding(sc.mesh, P_MODELS))
    kdiag = jax.device_put(kdiag, NamedSharding(sc.mesh, P_MODELS))
    selected = jax.device_put(selected, NamedSharding(sc.mesh, P_MODELS))
    return sc, (W, alpha, mu0, kdiag, best, selected)


def bench_attribution() -> None:
    from repro.obs import Tracer, aggregate_spans

    iters = 5 if FAST else 20
    for n in _sizes():
        for s in _mesh_sizes():
            sc, args = _setup(n, s)
            fused_us = time_us(sc.readout_decide_topk, *args,
                               iters=iters, warmup=2, sync=True)

            tr = Tracer(enabled=True)
            sc.tracer = tr
            for _ in range(2):              # compile all three phases
                sc.readout_decide_topk_phased(*args)
            tr.spans.clear()
            for i in range(iters):
                tr.begin_trace(i)
                with tr.span("decide"):
                    sc.readout_decide_topk_phased(*args)

            agg = aggregate_spans(tr.records())
            root_us = agg["decide"]["total_us"]
            phases = {p: agg[f"decide/{p}"]["total_us"] / iters
                      for p in ("readout", "score_topk", "gather_pick")}
            attributed = 100.0 * sum(phases.values()) * iters / root_us
            emit(f"decision_trace_L{n}_S{s}", root_us / iters,
                 live_models=n, shards=s, k_obs=K_OBS, topk=TOPK,
                 readout_us=f"{phases['readout']:.1f}",
                 score_topk_us=f"{phases['score_topk']:.1f}",
                 gather_pick_us=f"{phases['gather_pick']:.1f}",
                 fused_us=f"{fused_us:.1f}",
                 attributed_pct=f"{attributed:.2f}")
            # the tentpole acceptance bar, enforced at measurement time
            assert FAST or n < 100_000 or attributed >= 90.0, (
                f"spans attribute only {attributed:.1f}% of the "
                f"L={n} S={s} decision (need >= 90%)")


def bench_disabled_overhead() -> None:
    from repro.obs import Tracer

    iters = 10 if FAST else 30
    nt = Tracer(enabled=False)
    for n in _sizes():
        for s in _mesh_sizes():
            sc, args = _setup(n, s)

            def bare():
                return sc.readout_decide_topk(*args)

            def instrumented(call=bare):
                # the engine's per-decision span-site stack, tracer off:
                # every site is one branch + one shared no-op __enter__/__exit__
                nt.begin_trace(0)
                with nt.span("event", kind="finish"):
                    with nt.span("decide", device=0):
                        with nt.span("posterior", scorer="sharded"):
                            pass
                        with nt.span("score", scorer="sharded"):
                            with nt.span("pad_upload"):
                                pass
                            with nt.span("shard_decide", shards=s,
                                         kernel="xla"):
                                return nt.sync(call())

            bare_us = time_us(bare, iters=iters, warmup=2, sync=True)
            wrapped_us = time_us(instrumented, iters=iters, warmup=2,
                                 sync=True)
            # the gating number: the disabled stack measured alone, not as
            # the difference of two noisy ~100ms decision means
            site_us = time_us(lambda: instrumented(call=lambda: None),
                              iters=300 if FAST else 2000, warmup=50)
            overhead = 100.0 * site_us / bare_us
            emit(f"decision_overhead_L{n}_S{s}", site_us,
                 live_models=n, shards=s, bare_us=f"{bare_us:.1f}",
                 wrapped_us=f"{wrapped_us:.1f}",
                 paired_delta_pct=f"{100 * (wrapped_us - bare_us) / bare_us:.3f}",
                 overhead_pct=f"{overhead:.4f}")
            assert FAST or n < 100_000 or overhead < 1.0, (
                f"disabled-tracer overhead {overhead:.2f}% at L={n} S={s} "
                "(need < 1%)")


def main() -> None:
    bench_attribution()
    bench_disabled_overhead()


if __name__ == "__main__":
    import argparse
    import sys
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="toy shapes (same effect as BENCH_FAST=1)")
    if p.parse_args().smoke:
        common.set_fast(True)
    common.begin_suite("decision_trace")
    main()
    path = common.end_suite()
    if path is not None:
        print(f"# wrote {path}", file=sys.stderr)
