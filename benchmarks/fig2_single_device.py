"""Paper Fig. 2: single-device comparison of MDMT vs Round-Robin vs Random
on the Azure (17x8) and DeepLearning (22x8) workloads.

Figure of merit (paper Section 6.2): time to reach a given instantaneous
regret.  The paper reports MDMT reaching the same regret "up to 5x" faster
than round robin on Azure and no significant speedup on DeepLearning; we
report the geometric-mean and max per-seed speedups at two thresholds, plus
cumulative regret.

``--engine batched`` runs each seed's three policies as one
``repro.core.sim_batched`` call (identical trial sequences for the
deterministic policies; the random baseline differs per-seed only in its
PRNG stream — see DESIGN.md §6)."""

from __future__ import annotations

import numpy as np

from repro.core import (
    POLICIES,
    EpisodeSpec,
    azure_problem,
    deeplearning_problem,
    final_regret,
    regret_curves,
    simulate,
    simulate_batch,
)

from .common import FAST, emit, parse_engine_args

THRESHOLDS = {"azure": (0.03, 0.015), "deeplearning": (0.02, 0.01)}


def _gmean(xs):
    xs = np.asarray(xs, dtype=float)
    xs = xs[np.isfinite(xs) & (xs > 0)]
    return float(np.exp(np.mean(np.log(xs)))) if xs.size else float("nan")


def run(num_devices: int = 1, tag: str = "fig2", engine: str = "event",
        num_seeds: int | None = None) -> None:
    seeds = range(num_seeds if num_seeds is not None else (3 if FAST else 8))
    for ds_name, maker in (("azure", azure_problem),
                           ("deeplearning", deeplearning_problem)):
        ths = THRESHOLDS[ds_name]
        t_hit = {p: {th: [] for th in ths} for p in POLICIES}
        regret = {p: [] for p in POLICIES}
        dec_us = {p: [] for p in POLICIES}
        for seed in seeds:
            prob = maker(seed=seed)
            if engine == "batched":
                # One call per (problem, seed): unlike fig5, the ease.ml
                # generators resample the *prior* (K, mu0, cost) per seed,
                # so seeds cannot share a batch via the z_true override.
                # The jit cache is still shared across seeds (same shapes).
                batch = simulate_batch(
                    prob, [EpisodeSpec(pol, num_devices, seed)
                           for pol in POLICIES])
                per_policy = {
                    pol: batch.episode_result(i)
                    for i, pol in enumerate(POLICIES)}
                # whole-episode wall clock (incl. one-time jit compile) — NOT
                # comparable to event mode's pure per-decision latency, hence
                # the engine=batched tag on the emitted rows
                batch_us = batch.wall_seconds / len(POLICIES) * 1e6
            for pol in POLICIES:
                if engine == "batched":
                    res = per_policy[pol]
                else:
                    res = simulate(prob, pol, num_devices=num_devices, seed=seed)
                c = regret_curves(res)
                for th in ths:
                    t_hit[pol][th].append(c.time_to_instantaneous(th))
                regret[pol].append(final_regret(res))
                dec_us[pol].append(
                    batch_us if engine == "batched" else
                    res.decision_seconds / max(res.decisions, 1) * 1e6)
        for pol in POLICIES:
            derived = {"cum_regret": f"{np.mean(regret[pol]):.0f}"}
            if engine == "batched":
                derived["engine"] = "batched"  # us = wall/episode, not per-decision
            for th in ths:
                derived[f"t_reach_{th}"] = f"{np.mean(t_hit[pol][th]):.0f}"
            # batched: min over seeds = steady-state episode cost (the first
            # seed's call carries the one-time jit compile)
            us = (float(np.min(dec_us[pol])) if engine == "batched"
                  else float(np.mean(dec_us[pol])))
            if pol == "mdmt":
                for other in ("round_robin", "random"):
                    ratios = [
                        np.asarray(t_hit[other][th]) / np.asarray(t_hit["mdmt"][th])
                        for th in ths]
                    flat = np.concatenate(ratios)
                    derived[f"speedup_vs_{other}_gmean"] = f"{_gmean(flat):.2f}"
                    finite = flat[np.isfinite(flat)]
                    derived[f"speedup_vs_{other}_max"] = (
                        f"{finite.max():.2f}" if finite.size else "nan")
                derived["regret_vs_rr"] = (
                    f"{np.mean(regret['round_robin']) / np.mean(regret['mdmt']):.2f}")
            emit(f"{tag}_{ds_name}_{pol}", us, **derived)


def main() -> None:
    args = parse_engine_args()
    run(num_devices=1, tag="fig2", engine=args.engine, num_seeds=args.seeds)


if __name__ == "__main__":
    main()
