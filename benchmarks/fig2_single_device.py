"""Paper Fig. 2: single-device comparison of MDMT vs Round-Robin vs Random
on the Azure (17x8) and DeepLearning (22x8) workloads.

Figure of merit (paper Section 6.2): time to reach a given instantaneous
regret.  The paper reports MDMT reaching the same regret "up to 5x" faster
than round robin on Azure and no significant speedup on DeepLearning; we
report the geometric-mean and max per-seed speedups at two thresholds, plus
cumulative regret."""

from __future__ import annotations

import numpy as np

from repro.core import (
    POLICIES,
    azure_problem,
    deeplearning_problem,
    final_regret,
    regret_curves,
    simulate,
)

from .common import FAST, emit

THRESHOLDS = {"azure": (0.03, 0.015), "deeplearning": (0.02, 0.01)}


def _gmean(xs):
    xs = np.asarray(xs, dtype=float)
    xs = xs[np.isfinite(xs) & (xs > 0)]
    return float(np.exp(np.mean(np.log(xs)))) if xs.size else float("nan")


def run(num_devices: int = 1, tag: str = "fig2") -> None:
    seeds = range(3 if FAST else 8)
    for ds_name, maker in (("azure", azure_problem),
                           ("deeplearning", deeplearning_problem)):
        ths = THRESHOLDS[ds_name]
        t_hit = {p: {th: [] for th in ths} for p in POLICIES}
        regret = {p: [] for p in POLICIES}
        dec_us = {p: [] for p in POLICIES}
        for seed in seeds:
            prob = maker(seed=seed)
            for pol in POLICIES:
                res = simulate(prob, pol, num_devices=num_devices, seed=seed)
                c = regret_curves(res)
                for th in ths:
                    t_hit[pol][th].append(c.time_to_instantaneous(th))
                regret[pol].append(final_regret(res))
                dec_us[pol].append(
                    res.decision_seconds / max(res.decisions, 1) * 1e6)
        for pol in POLICIES:
            derived = {"cum_regret": f"{np.mean(regret[pol]):.0f}"}
            for th in ths:
                derived[f"t_reach_{th}"] = f"{np.mean(t_hit[pol][th]):.0f}"
            if pol == "mdmt":
                for other in ("round_robin", "random"):
                    ratios = [
                        np.asarray(t_hit[other][th]) / np.asarray(t_hit["mdmt"][th])
                        for th in ths]
                    flat = np.concatenate(ratios)
                    derived[f"speedup_vs_{other}_gmean"] = f"{_gmean(flat):.2f}"
                    finite = flat[np.isfinite(flat)]
                    derived[f"speedup_vs_{other}_max"] = (
                        f"{finite.max():.2f}" if finite.size else "nan")
                derived["regret_vs_rr"] = (
                    f"{np.mean(regret['round_robin']) / np.mean(regret['mdmt']):.2f}")
            emit(f"{tag}_{ds_name}_{pol}", np.mean(dec_us[pol]), **derived)


def main() -> None:
    run(num_devices=1, tag="fig2")


if __name__ == "__main__":
    main()
