"""Paper Fig. 3: impact of multiple devices on MM-GP-EI.

Figure of merit: time for the instantaneous regret to reach the threshold as
the device count grows (the paper shows the curves dropping faster with more
devices, with larger gains on DeepLearning: 14 test users vs Azure's 9).

``--engine batched`` runs each seed's whole device sweep as one
``repro.core.sim_batched`` call (see DESIGN.md §6)."""

from __future__ import annotations

import numpy as np

from repro.core import (
    EpisodeSpec,
    azure_problem,
    deeplearning_problem,
    regret_curves,
    simulate,
    simulate_batch,
)

from .common import FAST, emit, parse_engine_args

DEVICES = (1, 2, 4, 8)
THRESHOLDS = {"azure": 0.03, "deeplearning": 0.02}


def main() -> None:
    args = parse_engine_args()
    engine = args.engine
    seeds = range(args.seeds if args.seeds is not None else (2 if FAST else 5))
    for ds_name, maker in (("azure", azure_problem),
                           ("deeplearning", deeplearning_problem)):
        th = THRESHOLDS[ds_name]
        ts = {M: [] for M in DEVICES}
        dec = {M: [] for M in DEVICES}
        for seed in seeds:
            prob = maker(seed=seed)
            if engine == "batched":
                batch = simulate_batch(
                    prob, [EpisodeSpec("mdmt", M, seed) for M in DEVICES])
                tt = batch.time_to_instantaneous(th)
                # whole-episode wall clock (incl. compile), not per-decision
                # latency — rows carry engine=batched to flag that
                us = batch.wall_seconds / len(DEVICES) * 1e6
                for Mi, M in enumerate(DEVICES):
                    ts[M].append(float(tt[Mi]))
                    dec[M].append(us)
            else:
                for M in DEVICES:
                    res = simulate(prob, "mdmt", num_devices=M, seed=seed)
                    ts[M].append(regret_curves(res).time_to_instantaneous(th))
                    dec[M].append(
                        res.decision_seconds / max(res.decisions, 1) * 1e6)
        base = None
        for M in DEVICES:
            t = float(np.mean(ts[M]))
            if base is None:
                base = t
            derived = {f"t_reach_{th}": f"{t:.0f}",
                       "speedup_vs_M1": f"{base / t:.2f}",
                       "ideal": f"{M}"}
            if engine == "batched":
                derived["engine"] = "batched"
            # batched: min over seeds = steady-state episode cost (the first
            # seed's call carries the one-time jit compile)
            us = (float(np.min(dec[M])) if engine == "batched"
                  else float(np.mean(dec[M])))
            emit(f"fig3_{ds_name}_M{M}", us, **derived)


if __name__ == "__main__":
    main()
