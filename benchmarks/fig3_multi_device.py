"""Paper Fig. 3: impact of multiple devices on MM-GP-EI.

Figure of merit: time for the instantaneous regret to reach the threshold as
the device count grows (the paper shows the curves dropping faster with more
devices, with larger gains on DeepLearning: 14 test users vs Azure's 9)."""

from __future__ import annotations

import numpy as np

from repro.core import azure_problem, deeplearning_problem, regret_curves, simulate

from .common import FAST, emit

DEVICES = (1, 2, 4, 8)
THRESHOLDS = {"azure": 0.03, "deeplearning": 0.02}


def main() -> None:
    seeds = range(2 if FAST else 5)
    for ds_name, maker in (("azure", azure_problem),
                           ("deeplearning", deeplearning_problem)):
        th = THRESHOLDS[ds_name]
        base = None
        for M in DEVICES:
            ts, dec = [], []
            for seed in seeds:
                prob = maker(seed=seed)
                res = simulate(prob, "mdmt", num_devices=M, seed=seed)
                ts.append(regret_curves(res).time_to_instantaneous(th))
                dec.append(res.decision_seconds / max(res.decisions, 1) * 1e6)
            t = float(np.mean(ts))
            if base is None:
                base = t
            emit(f"fig3_{ds_name}_M{M}", float(np.mean(dec)),
                 **{f"t_reach_{th}": f"{t:.0f}",
                    "speedup_vs_M1": f"{base / t:.2f}",
                    "ideal": f"{M}"})


if __name__ == "__main__":
    main()
