"""Streaming control-plane benchmark: GP-EI under tenant churn (DESIGN.md §9).

Two measurements:

* ``stream_churn_end_to_end`` — the acceptance scenario: 200 tenant sessions
  (N >> M) arriving over time onto M = 8 slices with admission control;
  figure of merit is wall-clock events/sec and µs per scheduler decision,
  plus the service metrics (utilization, queue depth, p99 time-to-first-
  observation) from the telemetry sink.

* ``stream_decision_10k`` — decision latency at service scale: a dynamic
  ControlPlane holding |L| ~ 10k live models across 200 tenants; one EIrate
  decision (GP readout + batched scoring + argmax) on the hot loop, for all
  three scorer paths (the fused XLA dispatch, the ``kernels/ops.eirate``
  entry point — Pallas on TPU, its XLA reference here — and the sharded
  shard_map program of DESIGN.md §10).  The mesh-size/|L| sweep lives in
  ``benchmarks/shard_scale.py``.
"""

from __future__ import annotations

import numpy as np

from repro.core import ControlPlane
from repro.core.fleet import Fleet
from repro.core.tenancy import _matern_block_chol
from repro.stream import StreamEngine, poisson_churn_trace

from .common import FAST, emit, time_us, timed


def bench_end_to_end() -> None:
    sessions = 50 if FAST else 200
    trace = poisson_churn_trace(
        num_sessions=sessions, arrival_rate=1.0, seed=0,
        m_min=2, m_max=16, session_scale=25.0, num_failure_slices=2)
    eng = StreamEngine(Fleet.partition_pod(256, 8), "mdmt", seed=0,
                       max_live_models=120)
    wall, res = timed(eng.run, trace)
    s = res.telemetry.summary()
    events = trace.num_events + s["trials"]
    emit(
        "stream_churn_end_to_end",
        wall / max(events, 1) * 1e6,
        sessions=sessions,
        slices=8,
        trials=s["trials"],
        decisions=res.decisions,
        us_per_decision=f"{1e6 * res.decision_seconds / max(res.decisions, 1):.0f}",
        admitted=s["sessions_admitted"],
        queue_depth_max=s["queue_depth_max"],
        utilization=f"{s['device_utilization']:.4f}",
        ttfo_p99=f"{s['ttfo_p99']:.1f}" if s["ttfo_p99"] is not None else "na",
        wall_s=f"{wall:.2f}",
    )


def bench_decision_at_scale() -> None:
    """One EIrate decision at |L| ~ 10k live models (the service-scale bar).

    Timing goes through common.time_us (warm-up iterations + a terminal
    ``jax.block_until_ready``) on a wrapper that returns the *device
    arrays* of the decision, so the number measures kernel execution, not
    async dispatch — and the warm-up keeps one-time jit compilation out of
    the loop."""
    import jax

    from repro.core.ei import choose_next_fused
    from repro.kernels import ops as kops

    tenants = 40 if FAST else 200
    m = 50
    K_block, L = _matern_block_chol(m, 0.2, 0.04)
    rng = np.random.default_rng(0)
    for scorer in ("fused", "ops", "sharded"):
        cp = ControlPlane(np.random.default_rng(0), scorer=scorer,
                          model_capacity=tenants * m, tenant_capacity=tenants)
        for _ in range(tenants):
            cp.add_tenant(K_block, np.zeros(m), np.ones(m))
        # a realistic posterior: a few observations per tenant
        for t in range(tenants):
            for li in rng.choice(m, size=3, replace=False):
                g = t * m + int(li)
                cp.record_start(g)
                cp.record_observation(g, float(rng.uniform(0.0, 1.0)))
        n_live = tenants * m
        mu, sd = cp.gp.posterior_sd()

        if scorer == "fused":
            def decide():
                return choose_next_fused(mu, sd, cp._best_j,
                                         cp._membership_j, cp._cost_j,
                                         cp._selected_j)
        elif scorer == "ops":
            def decide():
                scores = kops.eirate(
                    mu, sd, cp._best_j, cp._membership_j, cp._cost_j,
                    cp._selected_j,
                    use_pallas=jax.default_backend() == "tpu")
                return scores.argmax()
        else:
            def decide():
                return cp._sharded.decide_topk(mu, sd, cp._best_j,
                                               cp.selected)

        us = time_us(decide, iters=10 if FAST else 30,
                     warmup=2 if FAST else 5, sync=True)
        shards = cp._sharded.num_shards if scorer == "sharded" else 1
        emit(f"stream_decision_{scorer}_L{n_live}", us,
             tenants=tenants, live_models=n_live, shards=shards)


def main() -> None:
    bench_end_to_end()
    bench_decision_at_scale()


if __name__ == "__main__":
    main()
