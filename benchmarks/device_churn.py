"""Elastic device plane benchmark: device churn + joint batched assignment
(DESIGN.md §11).

Three measurements on 2-speed-class fleets under device churn (joins,
leaves, preemptions overlaid on tenant churn):

* ``device_churn_assign_{sequential,batched}`` — decision seconds per
  policy-launched trial.  Uniform base costs synchronize completions into
  waves, so the batched path solves each k-device wave in ONE scoring pass
  (per-class top-k + greedy auction) where sequential pays k; the batched
  row must be strictly lower (acceptance criterion).

* ``device_churn_regret_{devplane,oblivious}`` — regret-at-horizon under
  scarcity (N >> M, short sessions, lognormal costs, per-trial overhead):
  the full device plane (joint batched assignment, fastest-free-first,
  queue-depth autoscale joining fast devices) vs the static speed-oblivious
  baseline (sequential per-device argmax of EI/c, stack-order placement,
  fixed fleet).  Averaged over several seeded traces; each run is
  deterministic, so the committed numbers are exactly reproducible.
  Honest finding baked into this design (DESIGN.md §11): per-decision
  *speed-aware scoring alone* is regret-neutral here — an observation
  carries the same information whichever device produced it — so the
  regret win comes from elasticity + placement, and the scoring
  generalization's win is decision *cost*, measured above.

* ``device_churn_autoscale`` — the queue-depth-driven autoscaler on the
  same scarce workload starting from a minimal fleet: how many devices it
  adds/retires and what that does to time-to-first-observation.
"""

from __future__ import annotations

import numpy as np

from repro.devplane import AutoscalePolicy, DevPlaneEngine, two_class_registry
from repro.stream import device_churn_trace

from . import common
from .common import emit, timed


def _wave_trace(sessions: int, slices: int):
    """Uniform costs => completion waves (the batched path's best case is
    also the service's common case: synchronized trial lengths)."""
    return device_churn_trace(
        num_sessions=sessions, arrival_rate=4.0, seed=0,
        initial_slices=slices, join_classes=(("fast", 16, 2.0),),
        join_rate=0.05, leave_rate=0.02, preempt_rate=0.03,
        m_min=2, m_max=16, session_scale=25.0, cost="uniform")


def bench_assign() -> None:
    fast = common.FAST
    sessions, half = (60, 4) if fast else (150, 8)
    reg = two_class_registry(2.0, overhead=0.0)

    def run(assign: str):
        eng = DevPlaneEngine(
            reg.build_fleet([("slow", half), ("fast", half)]), "mdmt",
            seed=0, registry=reg, assign=assign,
            max_live_models=200)
        res = eng.run(_wave_trace(sessions, 2 * half))
        return res, eng

    for assign in ("sequential", "batched"):
        run(assign)                       # warm the jit caches (all k's)
    for assign in ("sequential", "batched"):
        wall, (res, eng) = timed(run, assign)
        s = res.telemetry.summary()
        emit(
            f"device_churn_assign_{assign}",
            1e6 * res.decision_seconds / max(res.policy_launches, 1),
            sessions=sessions,
            slices=2 * half,
            scoring_passes=eng._scoring_passes,
            policy_launches=res.policy_launches,
            trials=s["trials"],
            preempted=s["trials_preempted"],
            devices_joined=s["devices_joined"],
            devices_left=s["devices_left"],
            wall_s=f"{wall:.2f}",
        )


def _scarce_trace(sessions: int, slices: int, seed: int = 3):
    """N >> M with short heavy-tailed sessions and lognormal costs: tenants
    depart unexplored, so scheduling quality shows up as regret."""
    return device_churn_trace(
        num_sessions=sessions, arrival_rate=3.0, seed=seed,
        initial_slices=slices, join_classes=(("fast", 16, 2.0),),
        join_rate=0.1, leave_rate=0.05, preempt_rate=0.05,
        m_min=6, m_max=30, session_scale=8.0, cost="lognormal")


def bench_regret_at_horizon() -> None:
    fast = common.FAST
    sessions, horizon, seeds = (40, 40.0, 2) if fast else (80, 60.0, 10)
    reg = two_class_registry(2.0, overhead=0.5)

    def build(name: str) -> DevPlaneEngine:
        fleet = reg.build_fleet([("slow", 2), ("fast", 2)])
        if name == "devplane":
            return DevPlaneEngine(
                fleet, "mdmt", seed=0, registry=reg, assign="batched",
                launch_order="fastest", max_live_models=100,
                autoscale=AutoscalePolicy(
                    high_backlog=6.0, low_backlog=1.0, cooldown=2.0,
                    join_class="fast", min_devices=2, max_devices=12))
        return DevPlaneEngine(
            fleet, "mdmt", seed=0, registry=reg, assign="sequential",
            launch_order="lifo", speed_oblivious=True, max_live_models=100)

    for name in ("devplane", "oblivious"):
        regrets, served, trials, joined, dec_us = [], 0, 0, 0, []
        for seed in range(seeds):
            eng = build(name)
            res = eng.run(_scarce_trace(sessions, 4, seed=seed),
                          horizon=horizon)
            s = res.telemetry.summary()
            if s["tenant_regret_mean"] is not None:
                regrets.append(s["tenant_regret_mean"])
            served += s["sessions_served"]
            trials += s["trials"]
            joined += s["devices_joined"]
            dec_us.append(1e6 * res.decision_seconds
                          / max(res.policy_launches, 1))
        emit(
            f"device_churn_regret_{name}",
            float(np.mean(dec_us)),
            horizon=horizon,
            sessions=sessions,
            seeds=seeds,
            regret_mean=(f"{np.mean(regrets):.6f}" if regrets else "na"),
            regret_max=(f"{np.max(regrets):.6f}" if regrets else "na"),
            sessions_served=served,
            trials=trials,
            devices_joined=joined,
        )


def bench_autoscale() -> None:
    fast = common.FAST
    sessions, horizon = (40, 40.0) if fast else (80, 60.0)
    reg = two_class_registry(2.0, overhead=0.5)
    configs = {
        "fixed": None,
        "autoscale": AutoscalePolicy(high_backlog=6.0, low_backlog=1.0,
                                     cooldown=2.0, join_class="fast",
                                     min_devices=2, max_devices=12),
    }
    for name, policy in configs.items():
        eng = DevPlaneEngine(
            reg.build_fleet([("slow", 1), ("fast", 1)]), "mdmt", seed=0,
            registry=reg, assign="batched", launch_order="fastest",
            autoscale=policy, max_live_models=100)
        res = eng.run(_scarce_trace(sessions, 2), horizon=horizon)
        s = res.telemetry.summary()
        emit(
            f"device_churn_autoscale_{name}",
            1e6 * res.decision_seconds / max(res.policy_launches, 1),
            devices_joined=s["devices_joined"],
            devices_left=s["devices_left"],
            trials=s["trials"],
            sessions_served=s["sessions_served"],
            ttfo_p99=(f"{s['ttfo_p99']:.2f}"
                      if s["ttfo_p99"] is not None else "na"),
            regret_mean=(f"{s['tenant_regret_mean']:.6f}"
                         if s["tenant_regret_mean"] is not None else "na"),
        )


def main() -> None:
    bench_assign()
    bench_regret_at_horizon()
    bench_autoscale()


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="toy shapes (same effect as BENCH_FAST=1)")
    if p.parse_args().smoke:
        common.set_fast(True)
    common.begin_suite("device_churn")
    main()
    path = common.end_suite()
    if path is not None:
        import sys
        print(f"# wrote {path}", file=sys.stderr)
