"""Control-plane benchmarks: the scheduler math at service scale.

Covers the two Pallas-kernel targets (EIrate scoring, GP posterior readout)
and the incremental-GP engines (dense vs block-diagonal) at |L| = 2500
(the Fig-5 synthetic scale) and |L| = 10k (service scale)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.gp import BlockIncrementalGP, IncrementalGP
from repro.kernels import ops, ref

from .common import FAST, emit


def bench_eirate(n: int, N: int) -> None:
    rng = np.random.default_rng(0)
    mu = jnp.asarray(rng.standard_normal(n), jnp.float32)
    sg = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    best = jnp.asarray(rng.standard_normal(N), jnp.float32)
    mem = jnp.asarray(rng.random((N, n)) < 0.1)
    cost = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    sel = jnp.asarray(rng.random(n) < 0.3)

    from .common import time_us
    us_ref = time_us(lambda: jax.block_until_ready(
        ref.eirate_ref(mu, sg, best, mem, cost, sel)))
    emit(f"eirate_xla_n{n}_N{N}", us_ref, bytes=f"{(N*n*4)/1e6:.1f}MB")
    # interpret-mode kernel timing is not meaningful for speed (it is a
    # Python emulation); we record it for completeness only.
    us_k = time_us(lambda: jax.block_until_ready(
        ops.eirate(mu, sg, best, mem, cost, sel, interpret=True)), iters=2, warmup=1)
    emit(f"eirate_pallas_interpret_n{n}_N{N}", us_k, note="correctness_path_only")


def bench_gp_readout(k: int, n: int) -> None:
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((k, n)) * 0.1, jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(k), jnp.float32)
    mu0 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    kd = (W * W).sum(0) + 1.0

    from .common import time_us
    us_ref = time_us(lambda: jax.block_until_ready(
        ref.gp_readout_ref(W, alpha, mu0, kd)))
    emit(f"gp_readout_xla_k{k}_n{n}", us_ref, flops=f"{2*k*n/1e6:.1f}M")


def bench_incremental_engines() -> None:
    from repro.core import synthetic_matern_problem
    import time
    prob = synthetic_matern_problem(num_users=20 if FAST else 50,
                                    num_models_per_user=50, seed=0)
    n = prob.num_models
    order = np.random.default_rng(0).permutation(n)[: n // 2]

    for name, gp in (
        ("gp_engine_dense", IncrementalGP(prob.K.astype(np.float32),
                                          prob.mu0.astype(np.float32))),
        ("gp_engine_block", BlockIncrementalGP(
            prob.K.astype(np.float32), prob.mu0.astype(np.float32),
            BlockIncrementalGP.blocks_from_membership(prob.K, prob.membership))),
    ):
        t0 = time.perf_counter()
        for i in order:
            gp.observe(int(i), float(prob.z_true[i]))
            gp.posterior()
        us = (time.perf_counter() - t0) / len(order) * 1e6
        emit(f"{name}_n{n}", us, events=len(order))


def main() -> None:
    bench_eirate(2500, 50)
    if not FAST:
        bench_eirate(10_000, 200)
    bench_gp_readout(1250, 2500)
    bench_incremental_engines()


if __name__ == "__main__":
    main()
