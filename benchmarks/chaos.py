"""Chaos plane benchmark: bounded regret degradation under injected
failures (DESIGN.md §16).

Two measurements on seeded chaos traces (hangs, poisoned losses, slice
flakes, permanent device losses overlaid on tenant churn):

* ``chaos_{twin,hardened}`` — the fully hardened DevPlaneEngine (trial
  supervision: ``timeout_factor x predicted_seconds`` deadlines, bounded
  retries with exponential backoff; device quarantine with probational
  re-admission) on each chaos trace vs the SAME engine on the trace's
  failure-free ``twin()``.  Acceptance (asserted): mean regret under
  chaos stays within ``REGRET_BOUND x twin + REGRET_SLACK`` — the
  bounded-degradation claim — and the hardened engine strands zero
  devices.  Every run is deterministic (seeded traces, seeded chaos
  overlay), so the committed numbers are exactly reproducible.

* ``chaos_unsupervised`` — the same chaos traces with supervision and
  quarantine disabled: every hang permanently strands its device, and
  the model selected on it stays selected forever (never observed, never
  re-queued).  The row records stranded devices and forever-unobserved
  launches — the failure mode the supervision plane exists to close
  (acceptance, asserted: strands at least one device where the hardened
  twin strands none).
"""

from __future__ import annotations

import numpy as np

from repro.devplane import DevPlaneEngine, QuarantinePolicy
from repro.core.fleet import Fleet
from repro.stream import chaos_trace

from . import common
from .common import emit, timed

#: bounded-degradation acceptance: hardened regret <= BOUND*twin + SLACK
REGRET_BOUND = 1.5
REGRET_SLACK = 0.05


def _fleet(n: int) -> Fleet:
    return Fleet.partition_pod(total_chips=16 * n, num_slices=n)


def _trace(sessions: int, seed: int):
    """Tenant churn plus all four chaos modes (no mesh shrink: the scorer
    stays fused so the suite needs no forced-device-count mesh)."""
    return chaos_trace(
        num_sessions=sessions, arrival_rate=1.5, seed=seed,
        initial_slices=4, hang_rate=0.25, poison_rate=0.15,
        flake_rate=0.10, loss_rate=0.03,
        m_min=2, m_max=10, session_scale=10.0)


def _engine(hardened: bool) -> DevPlaneEngine:
    kw = {}
    if hardened:
        kw = dict(timeout_factor=2.5, max_retries=2, retry_backoff=1.0,
                  quarantine=QuarantinePolicy(threshold=3, window=60.0,
                                              duration=30.0))
    return DevPlaneEngine(_fleet(4), "mdmt", seed=0, max_live_models=60,
                          **kw)


def _stranded(eng: DevPlaneEngine) -> int:
    """Devices still holding a trial after the horizon: hung launches
    nothing will ever complete (lost devices are retired, not stranded)."""
    return sum(1 for s in eng.fleet.slices
               if s.current_trial is not None and not s.retired)


def _run(hardened: bool, trace, horizon: float):
    eng = _engine(hardened)
    wall, res = timed(eng.run, trace, horizon=horizon)
    return eng, res, wall


def bench_bounded_degradation() -> None:
    fast = common.FAST
    sessions, horizon, seeds = (25, 120.0, 2) if fast else (60, 300.0, 6)

    rows = {"twin": [], "hardened": [], "unsupervised": []}
    for seed in range(seeds):
        trace = _trace(sessions, seed)
        runs = {"twin": _run(True, trace.twin(), horizon),
                "hardened": _run(True, trace, horizon),
                "unsupervised": _run(False, trace, horizon)}
        for name, (eng, res, wall) in runs.items():
            s = res.telemetry.summary()
            rows[name].append({
                "regret": s["tenant_regret_mean"],
                "served": s["sessions_served"],
                "trials": s["trials"],
                "timed_out": s["trials_timed_out"],
                "retried": s["trials_retried"],
                "quarantined": s["devices_quarantined"],
                "rejected": s["observations_rejected"],
                "stranded": _stranded(eng),
                "unobserved": sum(1 for t in eng._trials if t.z is None
                                  and t.end is None),
                "dec_us": 1e6 * res.decision_seconds
                          / max(res.policy_launches, 1),
                "wall": wall,
            })

    def regret_mean(name: str):
        vals = [r["regret"] for r in rows[name] if r["regret"] is not None]
        return float(np.mean(vals)) if vals else None

    twin_r, hard_r = regret_mean("twin"), regret_mean("hardened")
    # the acceptance criteria the committed payload certifies
    assert twin_r is not None and hard_r is not None
    assert hard_r <= REGRET_BOUND * twin_r + REGRET_SLACK, (
        f"regret degradation unbounded: {hard_r:.4f} vs twin {twin_r:.4f}")
    assert sum(r["stranded"] for r in rows["hardened"]) == 0
    assert sum(r["stranded"] for r in rows["unsupervised"]) > 0

    for name in ("twin", "hardened", "unsupervised"):
        rs = rows[name]
        r_mean = regret_mean(name)
        emit(
            f"chaos_{name}",
            float(np.mean([r["dec_us"] for r in rs])),
            sessions=sessions,
            horizon=horizon,
            seeds=seeds,
            regret_mean=(f"{r_mean:.6f}" if r_mean is not None else "na"),
            regret_bound=f"{REGRET_BOUND}x+{REGRET_SLACK}",
            regret_vs_twin=(f"{r_mean / twin_r:.3f}"
                            if r_mean is not None and twin_r else "na"),
            sessions_served=sum(r["served"] for r in rs),
            trials=sum(r["trials"] for r in rs),
            trials_timed_out=sum(r["timed_out"] for r in rs),
            trials_retried=sum(r["retried"] for r in rs),
            devices_quarantined=sum(r["quarantined"] for r in rs),
            observations_rejected=sum(r["rejected"] for r in rs),
            stranded_devices=sum(r["stranded"] for r in rs),
            wall_s=f"{sum(r['wall'] for r in rs):.2f}",
        )


def main() -> None:
    bench_bounded_degradation()


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="toy shapes (same effect as BENCH_FAST=1)")
    if p.parse_args().smoke:
        common.set_fast(True)
    common.begin_suite("chaos")
    main()
    path = common.end_suite()
    if path is not None:
        import sys
        print(f"# wrote {path}", file=sys.stderr)
