"""Pallas TPU kernel: Mamba2 SSD chunk scan (arXiv:2405.21060).

Fuses, per (batch*head, chunk):

  intra-chunk   y[t] += sum_{s<=t} (C_t.B_s) * exp(lcum_t - lcum_s) * xdt_s
  inter-chunk   y[t] += exp(lcum_t) * (C_t . state)
  state update  state  = exp(l_end) * state + sum_s exp(l_end - lcum_s) B_s (x) xdt_s

where xdt = dt * x (dt folded into the value stream upstream) and
lcum = cumsum(log a) within the chunk.  The (Q x Q) decay-masked score matrix
and the (P x N) recurrent state never leave VMEM; the XLA reference path
(repro.models.ssm) materializes the (B, Q, Q, H) decay tensor in HBM.

Grid: (B*H, chunks) with the chunk axis sequential; the state is VMEM
scratch carried across chunk steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(xdt_ref, b_ref, c_ref, la_ref, y_ref, state, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    xdt = xdt_ref[0, :, :].astype(jnp.float32)          # (Q, P)
    bmat = b_ref[0, :, :].astype(jnp.float32)           # (Q, N)
    cmat = c_ref[0, :, :].astype(jnp.float32)           # (Q, N)
    la = la_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    lcum = jnp.cumsum(la)                               # (Q,)

    # intra-chunk masked decay attention
    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # (Q,Q)
    decay = lcum[:, None] - lcum[None, :]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(s_idx <= t_idx, scores * jnp.exp(decay), 0.0)
    y = jnp.dot(w, xdt, preferred_element_type=jnp.float32)             # (Q,P)

    # inter-chunk contribution from the carried state
    y += jnp.exp(lcum)[:, None] * jnp.dot(
        cmat, state[...].T, preferred_element_type=jnp.float32)         # (Q,P)

    # state update
    l_end = lcum[chunk - 1]
    w_state = jnp.exp(l_end - lcum)                                     # (Q,)
    bx = jnp.dot((bmat * w_state[:, None]).T, xdt,
                 preferred_element_type=jnp.float32)                    # (N,P)
    state[...] = jnp.exp(l_end) * state[...] + bx.T                     # (P,N)

    y_ref[0, :, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H)  discretization step (softplus'd, clipped)
    log_a: jax.Array,    # (B, S, H)  per-step log decay (dt * A, <= 0)
    b: jax.Array,        # (B, S, N)
    c: jax.Array,        # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns the SSD mix y (B, S, H, P) (without the D*x skip term)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    # head-major: (B*H, S, ...)
    xdt_h = xdt.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    la_h = log_a.astype(jnp.float32).transpose(0, 2, 1).reshape(B * H, S, 1)

    def bc_map(g, ci):
        return (g // H, ci, 0)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, Q, N), bc_map),
            pl.BlockSpec((1, Q, N), bc_map),
            pl.BlockSpec((1, Q, 1), lambda g, ci: (g, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda g, ci: (g, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt_h, b, c, la_h)
    return out.reshape(B, H, S, P).transpose(0, 2, 1, 3)
