"""Pallas TPU kernel: fused multi-tenant EIrate scoring (eqs. 3-6).

The scheduler's hot loop evaluates, for every candidate model x and every
tenant i owning it,

    EI_i(x)   = sigma(x) * tau((mu(x) - best_i) / sigma(x)),
    score(x)  = sum_i member[i, x] * EI_i(x) / c(x),   (-inf if selected)

an (N x n) pass that is pure VPU work (erf/exp) plus a tenant-axis reduction.
At service scale (|L| ~ 10^4-10^5 models, N ~ 10^3 tenants) the naive path
materializes the (N, n) EI matrix in HBM; this kernel tiles it into VMEM
(block_users x block_models tiles, 128-lane aligned) and accumulates the
tenant sum in-register, writing only the (n,) score vector.

Grid: (models_blocks, user_blocks); the user axis is the innermost
(sequential) dimension, accumulating into the output block, with the
cost/selected epilogue applied on the final user block.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_LARGE = -1e30
_INV_SQRT2 = 0.7071067811865476
_INV_SQRT_2PI = 0.3989422804014327


def _tau_terms(u):
    """tau(u) = u * Phi(u) + phi(u) computed from erf/exp primitives."""
    cdf = 0.5 * (1.0 + jax.lax.erf(u * _INV_SQRT2))
    pdf = jnp.exp(-0.5 * u * u) * _INV_SQRT_2PI
    return u * cdf + pdf


def _ei_partial(mu_ref, sigma_ref, best_ref, member_ref):
    """One (bN, bn) tile's tenant-axis partial EI sum -> (bn,)."""
    mu = mu_ref[0, :]                       # (bn,)
    sg = sigma_ref[0, :]
    best = best_ref[:, 0]                   # (bN,)
    mem = member_ref[...]                   # (bN, bn)

    safe = jnp.where(sg > 0, sg, 1.0)
    u = (mu[None, :] - best[:, None]) / safe[None, :]
    ei = safe[None, :] * _tau_terms(u)
    ei_degenerate = jnp.maximum(mu[None, :] - best[:, None], 0.0)
    ei = jnp.where(sg[None, :] > 0, ei, ei_degenerate)
    return jnp.sum(ei * mem, axis=0)        # (bn,)


def _ei_kernel(mu_ref, sigma_ref, cost_ref, selected_ref, best_ref, member_ref,
               out_ref):
    j = pl.program_id(1)
    partial = _ei_partial(mu_ref, sigma_ref, best_ref, member_ref)

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] += partial

    @pl.when(j == pl.num_programs(1) - 1)
    def _epilogue():
        total = out_ref[0, :]
        score = total / cost_ref[0, :]
        out_ref[0, :] = jnp.where(selected_ref[0, :] > 0, NEG_LARGE, score)


def _ei_classes_kernel(mu_ref, sigma_ref, cost_ref, selected_ref, best_ref,
                       member_ref, out_ref):
    """The EIrate kernel generalized to a (C, n) *cost matrix* — one row per
    device class (DESIGN.md §11).  The tenant-axis EI sum is accumulated
    ONCE (into row 0 of the output block) and the final-tenant epilogue
    fans it out against every class's cost row, so a C-class scoring pass
    reads the (N, n) membership tile exactly as often as the 1-class one."""
    j = pl.program_id(1)
    partial = _ei_partial(mu_ref, sigma_ref, best_ref, member_ref)

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] += partial

    @pl.when(j == pl.num_programs(1) - 1)
    def _epilogue():
        total = out_ref[0, :]
        sel = selected_ref[0, :] > 0
        # row 0 holds the accumulator: write it last.  A non-finite cost
        # (memory gate) is a hard exclusion, same as the selected mask.
        for c in range(cost_ref.shape[0] - 1, -1, -1):
            row = cost_ref[c, :]
            out_ref[c, :] = jnp.where(sel | ~jnp.isfinite(row),
                                      NEG_LARGE, total / row)


def _block_topk(score_row, k: int, block_base):
    """Block-local top-k of a (1, bn) score tile, VPU-only: k unrolled
    max / min-index-at-max / mask rounds (no sort — Mosaic has no top_k).
    Equal values resolve to the lowest index, matching both ``jnp.argmax``
    and ``jax.lax.top_k`` ordering — the sharded scoring plane's exactness
    argument (DESIGN.md §10) leans on this."""
    bn = score_row.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    work = score_row
    vals, idxs = [], []
    for _ in range(k):
        m = jnp.max(work)
        idx = jnp.min(jnp.where(work == m, iota, jnp.int32(bn)))
        vals.append(m)
        idxs.append(jnp.minimum(idx, bn - 1))
        work = jnp.where(iota == idx, NEG_LARGE, work)
    return (jnp.stack(vals)[None, :],
            (jnp.stack(idxs)[None, :] + block_base).astype(jnp.int32))


def _ei_topk_kernel(mu_ref, sigma_ref, cost_ref, selected_ref, best_ref,
                    member_ref, out_ref, topv_ref, topi_ref, *, k: int):
    """The EIrate kernel with a block-local top-k epilogue: alongside the
    (n,) scores, each model block emits its k best (value, global index)
    candidates, so a sharded caller reduces (num_blocks, k) candidates
    instead of re-reading the whole score vector."""
    _ei_kernel(mu_ref, sigma_ref, cost_ref, selected_ref, best_ref,
               member_ref, out_ref)
    i = pl.program_id(0)
    j = pl.program_id(1)
    bn = out_ref.shape[1]

    @pl.when(j == pl.num_programs(1) - 1)
    def _topk_epilogue():
        vals, idxs = _block_topk(out_ref[0:1, :], k, i * bn)
        topv_ref[0, :] = vals[0]
        topi_ref[0, :] = idxs[0]


def _pad_inputs(mu, sigma, best, membership, cost, selected, bn, bN):
    n, N = mu.shape[0], best.shape[0]
    pn = math.ceil(n / bn) * bn
    pN = math.ceil(N / bN) * bN
    f32 = jnp.float32
    mu_p = jnp.zeros((1, pn), f32).at[0, :n].set(mu.astype(f32))
    sg_p = jnp.zeros((1, pn), f32).at[0, :n].set(sigma.astype(f32))
    cost_p = jnp.ones((1, pn), f32).at[0, :n].set(cost.astype(f32))
    sel_p = jnp.ones((1, pn), f32).at[0, :n].set(selected.astype(f32))
    best_p = jnp.zeros((pN, 1), f32).at[:N, 0].set(best.astype(f32))
    mem_p = jnp.zeros((pN, pn), f32).at[:N, :n].set(membership.astype(f32))
    return (mu_p, sg_p, cost_p, sel_p, best_p, mem_p), pn, pN


@functools.partial(jax.jit, static_argnames=("block_models", "block_users", "interpret"))
def eirate_pallas(
    mu: jax.Array,           # (n,)
    sigma: jax.Array,        # (n,)
    best: jax.Array,         # (N,)
    membership: jax.Array,   # (N, n) bool/float
    cost: jax.Array,         # (n,)
    selected: jax.Array,     # (n,) bool
    *,
    block_models: int = 256,
    block_users: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns (n,) EIrate scores, -1e30 at selected models."""
    n = mu.shape[0]
    N = best.shape[0]
    bn = min(block_models, max(n, 1))
    bN = min(block_users, max(N, 1))
    (mu_p, sg_p, cost_p, sel_p, best_p, mem_p), pn, pN = _pad_inputs(
        mu, sigma, best, membership, cost, selected, bn, bN)

    grid = (pn // bn, pN // bN)
    out = pl.pallas_call(
        _ei_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((bN, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bN, bn), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pn), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(mu_p, sg_p, cost_p, sel_p, best_p, mem_p)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=(
    "k", "block_models", "block_users", "interpret"))
def eirate_topk_pallas(
    mu: jax.Array,           # (n,)
    sigma: jax.Array,        # (n,)
    best: jax.Array,         # (N,)
    membership: jax.Array,   # (N, n) bool/float
    cost: jax.Array,         # (n,)
    selected: jax.Array,     # (n,) bool
    *,
    k: int = 4,
    block_models: int = 256,
    block_users: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """EIrate scoring with the block-local top-k epilogue: returns the
    global top-k as ``(values (k,), indices (k,))``, ties broken by lowest
    index (exactly ``jax.lax.top_k`` over the full score vector).  Each
    model block emits its k best candidates in VMEM; the host-side reduce
    touches only (num_blocks, k) — the shape the sharded scoring plane
    all-gathers (DESIGN.md §10)."""
    n = mu.shape[0]
    N = best.shape[0]
    bn = min(block_models, max(n, 1))
    bN = min(block_users, max(N, 1))
    kb = min(k, bn)          # a block cannot yield more candidates than bn
    (mu_p, sg_p, cost_p, sel_p, best_p, mem_p), pn, pN = _pad_inputs(
        mu, sigma, best, membership, cost, selected, bn, bN)

    grid = (pn // bn, pN // bN)
    _, topv, topi = pl.pallas_call(
        functools.partial(_ei_topk_kernel, k=kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((bN, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bN, bn), lambda i, j: (j, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, kb), lambda i, j: (i, 0)),
            pl.BlockSpec((1, kb), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, pn), jnp.float32),
            jax.ShapeDtypeStruct((pn // bn, kb), jnp.float32),
            jax.ShapeDtypeStruct((pn // bn, kb), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(mu_p, sg_p, cost_p, sel_p, best_p, mem_p)

    flatv = topv.reshape(-1)
    flati = topi.reshape(-1)
    # candidates in padding columns are inert; keep shape >= k regardless
    flatv = jnp.where(flati < n, flatv, NEG_LARGE)
    if flatv.shape[0] < k:
        pad = k - flatv.shape[0]
        flatv = jnp.concatenate([flatv, jnp.full(pad, NEG_LARGE, jnp.float32)])
        flati = jnp.concatenate([flati, jnp.zeros(pad, jnp.int32)])
    v, pos = jax.lax.top_k(flatv, k)
    return v, flati[pos]


@functools.partial(jax.jit, static_argnames=("block_models", "block_users",
                                             "interpret"))
def eirate_classes_pallas(
    mu: jax.Array,           # (n,)
    sigma: jax.Array,        # (n,)
    best: jax.Array,         # (N,)
    membership: jax.Array,   # (N, n) bool/float
    cost_matrix: jax.Array,  # (C, n) per-device-class c(x, d)
    selected: jax.Array,     # (n,) bool
    *,
    block_models: int = 256,
    block_users: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns (C, n) per-class EIrate scores, -1e30 at selected models —
    the elastic device plane's 2-D (free devices x live models) matrix in
    one kernel launch (tenant sum accumulated once, fanned out per class)."""
    n = mu.shape[0]
    N = best.shape[0]
    C = cost_matrix.shape[0]
    bn = min(block_models, max(n, 1))
    bN = min(block_users, max(N, 1))
    (mu_p, sg_p, _, sel_p, best_p, mem_p), pn, pN = _pad_inputs(
        mu, sigma, best, membership, jnp.ones_like(mu), selected, bn, bN)
    cost_p = jnp.ones((C, pn), jnp.float32).at[:, :n].set(
        cost_matrix.astype(jnp.float32))

    grid = (pn // bn, pN // bN)
    out = pl.pallas_call(
        _ei_classes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((C, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((bN, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bN, bn), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((C, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((C, pn), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(mu_p, sg_p, cost_p, sel_p, best_p, mem_p)
    return out[:, :n]
