"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately the *simplest correct* formulations (naive softmax
attention, per-timestep SSD recurrence, closed-form EI) — the kernels are
validated against them over shape/dtype sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm


# --- EIrate ----------------------------------------------------------------

def eirate_ref(mu, sigma, best, membership, cost, selected) -> jax.Array:
    """(n,) EIrate scores; -1e30 at selected models (matches kernel epilogue)."""
    mu = mu.astype(jnp.float32)
    sigma = sigma.astype(jnp.float32)
    best = best.astype(jnp.float32)
    safe = jnp.where(sigma > 0, sigma, 1.0)
    u = (mu[None, :] - best[:, None]) / safe[None, :]
    tau = u * norm.cdf(u) + norm.pdf(u)
    ei = safe[None, :] * tau
    ei0 = jnp.maximum(mu[None, :] - best[:, None], 0.0)
    ei = jnp.where(sigma[None, :] > 0, ei, ei0)
    total = jnp.sum(jnp.where(membership.astype(bool), ei, 0.0), axis=0)
    return jnp.where(selected.astype(bool), -1e30, total / cost.astype(jnp.float32))


def eirate_classes_ref(mu, sigma, best, membership, cost_matrix, selected):
    """(C, n) per-class EIrate scores; -1e30 at selected models.  The naive
    formulation: the tenant EI sum computed once, divided by every class's
    cost row (matches the class-epilogue kernel)."""
    mu = mu.astype(jnp.float32)
    sigma = sigma.astype(jnp.float32)
    best = best.astype(jnp.float32)
    safe = jnp.where(sigma > 0, sigma, 1.0)
    u = (mu[None, :] - best[:, None]) / safe[None, :]
    tau = u * norm.cdf(u) + norm.pdf(u)
    ei = safe[None, :] * tau
    ei0 = jnp.maximum(mu[None, :] - best[:, None], 0.0)
    ei = jnp.where(sigma[None, :] > 0, ei, ei0)
    total = jnp.sum(jnp.where(membership.astype(bool), ei, 0.0), axis=0)
    cm = cost_matrix.astype(jnp.float32)
    # non-finite cost (memory gate) is a hard exclusion, not score 0 —
    # matches ei.eirate_class_scores
    scores = jnp.where(jnp.isfinite(cm), total[None, :] / cm, -1e30)
    return jnp.where(selected.astype(bool)[None, :], -1e30, scores)


def eirate_topk_ref(mu, sigma, best, membership, cost, selected, *, k=4):
    """(values (k,), indices (k,)) of the EIrate top-k; short vectors pad
    with -1e30 so the shape is k regardless of n."""
    scores = eirate_ref(mu, sigma, best, membership, cost, selected)
    if scores.shape[0] < k:
        pad = k - scores.shape[0]
        scores = jnp.concatenate([scores, jnp.full(pad, -1e30, scores.dtype)])
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32)


# --- GP posterior readout ---------------------------------------------------

def gp_readout_ref(W, alpha, mu0, k_diag):
    W = W.astype(jnp.float32)
    mu = mu0.astype(jnp.float32) + W.T @ alpha.astype(jnp.float32)
    var = jnp.maximum(k_diag.astype(jnp.float32) - jnp.sum(W * W, axis=0), 0.0)
    return mu, var


# --- attention ---------------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """Naive full-matrix GQA attention. q (B,S,Hq,D), k/v (B,S,Hkv,D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, kf) / jnp.sqrt(jnp.float32(D))
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p, vf)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


# --- SSD ---------------------------------------------------------------------

def ssd_ref(x, dt, log_a, b, c):
    """Per-timestep SSD recurrence (the definitionally-correct oracle).

    x (B,S,H,P), dt/log_a (B,S,H), b/c (B,S,N) -> y (B,S,H,P) fp32,
    y_t = C_t . h_t with h_t = exp(log_a_t) h_{t-1} + dt_t B_t (x) x_t.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    def step(h, inp):
        xt, lat, bt, ct = inp                     # (B,H,P), (B,H), (B,N), (B,N)
        h = jnp.exp(lat)[..., None, None] * h + jnp.einsum(
            "bn,bhp->bhpn", bt.astype(jnp.float32), xt)
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (xdt.swapaxes(0, 1), log_a.astype(jnp.float32).swapaxes(0, 1),
         b.swapaxes(0, 1), c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)                       # (B,S,H,P)
