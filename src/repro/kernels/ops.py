"""Jit'd public entry points for the Pallas kernels.

Each op dispatches kernel-vs-reference by platform: the Pallas TPU kernels
are the target implementation; on CPU (this container) they run under
``interpret=True`` for correctness validation, while production model code
defaults to the XLA reference path (``use_pallas=False``) because Mosaic does
not lower on the CPU backend.
"""

from __future__ import annotations

import jax

from . import ref
from .ei_score import eirate_classes_pallas, eirate_pallas, eirate_topk_pallas
from .flash_attention import flash_attention_pallas
from .gp_readout import gp_readout_pallas
from .ssd import ssd_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def eirate(mu, sigma, best, membership, cost, selected, *, use_pallas=True,
           **kw):
    if not use_pallas:
        return ref.eirate_ref(mu, sigma, best, membership, cost, selected)
    kw.setdefault("interpret", _interpret_default())
    return eirate_pallas(mu, sigma, best, membership, cost, selected, **kw)


def eirate_topk(mu, sigma, best, membership, cost, selected, *, k=4,
                use_pallas=True, **kw):
    """Global EIrate top-k as (values (k,), indices (k,)), lowest-index
    tie-break — the kernel path uses the block-local top-k epilogue so only
    (num_blocks, k) candidates leave VMEM."""
    if not use_pallas:
        return ref.eirate_topk_ref(mu, sigma, best, membership, cost,
                                   selected, k=k)
    kw.setdefault("interpret", _interpret_default())
    return eirate_topk_pallas(mu, sigma, best, membership, cost, selected,
                              k=k, **kw)


def eirate_classes(mu, sigma, best, membership, cost_matrix, selected, *,
                   use_pallas=True, **kw):
    """(C, n) per-device-class EIrate scores (cost_matrix is (C, n)) — the
    elastic device plane's joint-assignment scoring pass (DESIGN.md §11).
    The kernel accumulates the tenant EI sum once and fans it out per class."""
    if not use_pallas:
        return ref.eirate_classes_ref(mu, sigma, best, membership,
                                      cost_matrix, selected)
    kw.setdefault("interpret", _interpret_default())
    return eirate_classes_pallas(mu, sigma, best, membership, cost_matrix,
                                 selected, **kw)


def gp_readout(W, alpha, mu0, k_diag, *, use_pallas=True, emit_sd=False, **kw):
    if not use_pallas:
        import jax.numpy as jnp
        mu, var = ref.gp_readout_ref(W, alpha, mu0, k_diag)
        return (mu, jnp.sqrt(var)) if emit_sd else (mu, var)
    kw.setdefault("interpret", _interpret_default())
    return gp_readout_pallas(W, alpha, mu0, k_diag, emit_sd=emit_sd, **kw)


def flash_attention(q, k, v, *, causal=True, window=None, use_pallas=True, **kw):
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    kw.setdefault("interpret", _interpret_default())
    return flash_attention_pallas(q, k, v, causal=causal, window=window, **kw)


def ssd_mix(x, dt, log_a, b, c, *, use_pallas=True, **kw):
    if not use_pallas:
        return ref.ssd_ref(x, dt, log_a, b, c)
    kw.setdefault("interpret", _interpret_default())
    return ssd_pallas(x, dt, log_a, b, c, **kw)
