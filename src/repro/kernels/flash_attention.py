"""Pallas TPU kernel: causal GQA flash attention (forward).

TPU adaptation of the paper-adjacent standard (DESIGN.md §3): block-streamed
keys/values with online softmax, block-causal *skipping* (the XLA reference
path masks but still computes all (i, j) block pairs — 2x wasted MXU work),
and optional sliding-window skipping (H2O-Danube).  Layout is head-major
(BH, S, D) so each grid step works on MXU-aligned (block_q x D) / (block_k x
D) tiles resident in VMEM.

Grid: (B*Hq, q_blocks, kv_blocks), kv innermost (sequential); accumulators
(acc, row-max m, row-sum l) live in VMEM scratch across kv steps.  GQA maps
query head h to KV head h // (Hq // Hkv) in the BlockSpec index maps.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, window: int | None):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q_first = i * block_q
    q_last = q_first + block_q - 1
    k_first = j * block_k
    k_last = k_first + block_k - 1

    needed = True
    if causal:
        needed = jnp.logical_and(needed, k_first <= q_last)
    if window is not None:
        needed = jnp.logical_and(needed, k_last > q_first - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, :].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, :, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = q_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i[:, 0], jnp.max(s, axis=1))          # (bq,)
        corr = jnp.exp(m_i[:, 0] - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_i[:, 0] = l_i[:, 0] * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_i[:, 0] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_i[:, 0], 1e-30)
        o_ref[0, :, :] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(
    q: jax.Array,            # (B, S, Hq, D)
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,            # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, S, Hq, D) attention output."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / math.sqrt(D)

    # head-major flattening: (B*Hq, S, D) / (B*Hkv, S, D)
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)

    def kv_head(b, i, j):
        return ((b // Hq) * Hkv + (b % Hq) // group, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk,
        causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), kv_head),
            pl.BlockSpec((1, bk, D), kv_head),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
