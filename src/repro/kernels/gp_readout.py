"""Pallas TPU kernel: fused incremental-GP posterior readout.

The incremental engine (repro.core.gp.IncrementalGP) maintains
  W     (k, n)  = L^{-1} K[obs, :]
  alpha (k,)    = L^{-1} (z_obs - mu0_obs)
and the scheduler needs, per decision,
  mu_post  = mu0 + W^T alpha                (matvec, MXU)
  var_post = K_diag - sum_k W[k,:]^2        (column sum-of-squares, VPU)

Reading W twice (matvec + sumsq) doubles HBM traffic on what is a purely
memory-bound O(k*n) pass; this kernel streams each (block_k x block_n) tile
of W through VMEM exactly once, producing both outputs.

Grid: (n_blocks, k_blocks), k innermost (sequential) with two VMEM
accumulators; the mu0/K_diag epilogue runs on the last k block.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _readout_kernel(W_ref, alpha_ref, mu0_ref, kdiag_ref, mu_out, var_out,
                    acc_dot, acc_sq, *, emit_sd: bool = False):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_dot[...] = jnp.zeros_like(acc_dot)
        acc_sq[...] = jnp.zeros_like(acc_sq)

    W = W_ref[...]                                  # (bk, bn)
    a = alpha_ref[:, 0]                             # (bk,)
    acc_dot[...] += jnp.dot(a[None, :], W,
                            preferred_element_type=jnp.float32)
    acc_sq[...] += jnp.sum(W * W, axis=0, keepdims=True)

    @pl.when(j == pl.num_programs(1) - 1)
    def _epilogue():
        mu_out[...] = mu0_ref[...] + acc_dot[...]
        var = jnp.maximum(kdiag_ref[...] - acc_sq[...], 0.0)
        # emit_sd: the EIrate consumer wants sigma, not variance — the sqrt
        # rides the epilogue instead of costing a second (n,) pass
        var_out[...] = jnp.sqrt(var) if emit_sd else var


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret",
                                             "emit_sd"))
def gp_readout_pallas(
    W: jax.Array,         # (k, n)
    alpha: jax.Array,     # (k,)
    mu0: jax.Array,       # (n,)
    k_diag: jax.Array,    # (n,)
    *,
    block_n: int = 512,
    block_k: int = 512,
    interpret: bool = True,
    emit_sd: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (mu_post (n,), var_post (n,)) — or (mu_post, sd_post) with
    ``emit_sd`` (the fused readout->EIrate pipeline of the sharded scoring
    plane consumes sigma directly)."""
    k, n = W.shape
    bn = min(block_n, max(n, 1))
    bk = min(block_k, max(k, 1))
    pn = math.ceil(n / bn) * bn
    pk = math.ceil(k / bk) * bk

    f32 = jnp.float32
    W_p = jnp.zeros((pk, pn), f32).at[:k, :n].set(W.astype(f32))
    a_p = jnp.zeros((pk, 1), f32).at[:k, 0].set(alpha.astype(f32))
    mu0_p = jnp.zeros((1, pn), f32).at[0, :n].set(mu0.astype(f32))
    kd_p = jnp.zeros((1, pn), f32).at[0, :n].set(k_diag.astype(f32))

    grid = (pn // bn, pk // bk)
    mu_out, var_out = pl.pallas_call(
        functools.partial(_readout_kernel, emit_sd=emit_sd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (j, i)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, pn), f32),
            jax.ShapeDtypeStruct((1, pn), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(W_p, a_p, mu0_p, kd_p)
    return mu_out[0, :n], var_out[0, :n]
