"""Logical-axis sharding rules (MaxText-style) for the model substrate.

Every parameter and activation is annotated with *logical* axis names
("embed", "heads", "mlp", "experts", "batch", ...).  An :class:`AxisRules`
table maps logical names to mesh axes ("pod", "data", "model").  This is the
single knob the perf hillclimb turns: changing a rule re-shards the whole
model with no model-code edits.

Parallelism styles expressed through rules:
  DP    batch -> ("pod", "data")
  TP    heads / kv_heads / mlp / vocab / experts_mlp -> "model"
  EP    experts -> "model"  (MoE all-to-all over the model axis)
  FSDP  embed -> "data"     (params additionally sharded over the data axis,
                             all-gathered at use; ZeRO-3 style)
  SP    kv_seq -> "data"    (long-context decode: KV/state sharded over seq)
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    rules: tuple[tuple[str, Any], ...]

    def lookup(self, name: str | None):
        if name is None:
            return None
        for key, val in self.rules:
            if key == name:
                return val
        return None

    def override(self, **kwargs) -> "AxisRules":
        new = dict(self.rules)
        new.update(kwargs)
        return AxisRules(tuple(new.items()))

    def mesh_axes(self, logical_axes: tuple[str | None, ...]) -> P:
        used: list = []
        parts = []
        for name in logical_axes:
            ax = self.lookup(name)
            # A mesh axis may appear at most once in a PartitionSpec; later
            # logical axes that map to an already-used mesh axis stay
            # replicated (standard MaxText behaviour).
            if ax is None:
                parts.append(None)
                continue
            ax_t = ax if isinstance(ax, tuple) else (ax,)
            ax_t = tuple(a for a in ax_t if a not in used)
            if not ax_t:
                parts.append(None)
            elif len(ax_t) == 1:
                parts.append(ax_t[0])
                used.append(ax_t[0])
            else:
                parts.append(ax_t)
                used.extend(ax_t)
        return P(*parts)


# Baseline rules: DP over (pod, data), TP/EP over model.  This is the
# paper-faithful production default; FSDP_RULES adds ZeRO-3 param sharding
# (used by the large MoE configs and by the hillclimb).
DEFAULT_RULES = AxisRules((
    ("batch", ("pod", "data")),
    ("seq", None),
    ("kv_seq", None),
    ("embed", None),
    ("embed_out", None),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"),
    ("expert_mlp", None),
    ("ssm_inner", "model"),
    ("ssm_state", None),
    ("ssm_heads", "model"),
    ("conv_width", None),
    ("layers", None),
    ("act_embed", None),
    ("act_heads", "model"),
    ("q_rows", None),
))

FSDP_RULES = DEFAULT_RULES.override(
    embed="data",          # shard the non-TP dim of weight matrices over data
    expert_mlp="data",
)

# Long-context decode: KV cache / attention over sequence sharded on data.
SP_DECODE_RULES = DEFAULT_RULES.override(kv_seq="data")

# Pure data-parallel + ZeRO-3 (no tensor parallelism): the batch is sharded
# over every mesh axis and parameters are fully sharded for storage
# (all-gathered at use).  No per-layer activation all-reduces at all —
# the right regime for small dense models like olmo-1b (see §Perf).
PUREDP_RULES = AxisRules((
    ("batch", ("pod", "data", "model")),
    ("seq", None), ("kv_seq", None),
    ("embed", "data"),
    ("embed_out", None),
    ("heads", "model"), ("kv_heads", "model"), ("head_dim", None),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"), ("expert_mlp", "data"),
    ("ssm_inner", "model"), ("ssm_state", None), ("ssm_heads", "model"),
    ("conv_width", None), ("layers", None),
    ("act_embed", None), ("act_heads", None), ("q_rows", None),
))

# Query-row sharded attention: for archs whose head counts don't divide the
# model axis (musicgen 24H), shard each attention chunk's query rows instead
# of heads.  Params stay TP-sharded where divisible.
QROWS_RULES = DEFAULT_RULES.override(q_rows="model", act_heads=None)

# Sharded GP-EI scoring plane (repro.shardgp, DESIGN.md §10): control-plane
# state is logically (tenants, models) / (obs, models); only the model axis
# shards — tenants ride along replicated (N ~ 10^2-10^3 is small next to
# |L| ~ 10^5-10^6) and the observation axis of the W readout buffer stays
# local so the streamed readout needs no cross-shard reduction.
SCORING_RULES = AxisRules((
    ("models", "shard"),
    ("tenants", None),
    ("obs", None),
))


@dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + dtype + logical axes (+ init scale)."""

    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"     # normal | zeros | ones | scaled
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs axes {self.logical_axes}")


def logical_to_pspec(spec: ParamSpec | tuple[str | None, ...], rules: AxisRules) -> P:
    axes = spec.logical_axes if isinstance(spec, ParamSpec) else spec
    return rules.mesh_axes(axes)


def _sanitize_pspec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop partitions whose dim isn't divisible by the mapped mesh extent
    (e.g. MQA's single KV head on a 16-way model axis -> replicate instead
    of GSPMD padding), and axes absent from this mesh (e.g. "pod" on the
    single-pod mesh)."""
    sizes = dict(mesh.shape)
    parts = []
    for i, part in enumerate(spec):
        if part is None or i >= len(shape):
            parts.append(None)
            continue
        ax_t = part if isinstance(part, tuple) else (part,)
        ax_t = tuple(a for a in ax_t if a in sizes)
        extent = 1
        for a in ax_t:
            extent *= sizes[a]
        if not ax_t or extent == 0 or shape[i] % extent != 0:
            parts.append(None)
        elif len(ax_t) == 1:
            parts.append(ax_t[0])
        else:
            parts.append(ax_t)
    return P(*parts)


def logical_sharding(
    spec: ParamSpec | tuple[str | None, ...], mesh: Mesh, rules: AxisRules
) -> NamedSharding:
    pspec = logical_to_pspec(spec, rules)
    if isinstance(spec, ParamSpec):
        pspec = _sanitize_pspec(pspec, spec.shape, mesh)
    return NamedSharding(mesh, pspec)


def shardings_for_tree(tree, mesh: Mesh, rules: AxisRules):
    """Map a pytree of ParamSpec -> pytree of NamedSharding."""
    return jax.tree.map(
        lambda s: logical_sharding(s, mesh, rules),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def shape_dtype_for_tree(tree):
    """Map a pytree of ParamSpec -> pytree of ShapeDtypeStruct (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _current_mesh():
    """The ambient mesh, across jax versions: ``get_abstract_mesh`` where it
    exists, the thread-resource physical mesh (the ``with mesh:`` context)
    on older releases.  None when no mesh is active."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        return None if mesh is None or mesh.empty else mesh
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def with_logical_constraint(x, logical_axes: tuple[str | None, ...], rules: AxisRules | None):
    """Annotate an activation with a logical sharding constraint.

    No-op outside a mesh context or when rules is None, so model code runs
    unchanged in single-device tests.
    """
    if rules is None:
        return x
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = rules.mesh_axes(logical_axes)
    spec = _sanitize_pspec(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)
