from .rules import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    FSDP_RULES,
    ParamSpec,
    logical_sharding,
    logical_to_pspec,
    shardings_for_tree,
    shape_dtype_for_tree,
    with_logical_constraint,
)
