"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; unverified]"""

from repro.models.model import ModelConfig

SLIDING_WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        num_layers=24, d_model=3840, vocab_size=32000,
        num_heads=32, num_kv_heads=8, head_dim=120,
        sliding_window=SLIDING_WINDOW,
        d_ff=10240, tie_embeddings=False,
        # SWA bounds the decode cache to the window -> long_500k applies.
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense",
        num_layers=2, d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16,
        sliding_window=64,
        d_ff=128, tie_embeddings=False, q_chunk=32, xent_chunk=32,
        supports_long_context=True,
    )
