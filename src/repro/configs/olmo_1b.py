"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304,
non-parametric LN.  [arXiv:2402.00838; hf]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        num_layers=16, d_model=2048, vocab_size=50304,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=8192, norm="nonparametric", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke", family="dense",
        num_layers=2, d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, norm="nonparametric", tie_embeddings=True,
        q_chunk=32, xent_chunk=32,
    )
