"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]"""

from repro.models.model import ModelConfig
from repro.models.ssm import SSMConfig


def config() -> ModelConfig:
    d = 2048
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        num_layers=48, d_model=d, vocab_size=50280,
        ssm=SSMConfig(d_model=d, d_inner=2 * d, headdim=64, d_state=128),
        tie_embeddings=True,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    d = 64
    return ModelConfig(
        name="mamba2-1.3b-smoke", family="ssm",
        num_layers=2, d_model=d, vocab_size=256,
        ssm=SSMConfig(d_model=d, d_inner=2 * d, headdim=32, d_state=16, chunk=32),
        tie_embeddings=True, xent_chunk=32,
        supports_long_context=True,
    )
