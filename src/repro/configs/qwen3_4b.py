"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense",
        num_layers=36, d_model=2560, vocab_size=151936,
        num_heads=32, num_kv_heads=8, head_dim=128, qk_norm=True,
        rope_theta=1_000_000.0,
        d_ff=9728, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke", family="dense",
        num_layers=2, d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, qk_norm=True,
        d_ff=128, tie_embeddings=True, q_chunk=32, xent_chunk=32,
    )
