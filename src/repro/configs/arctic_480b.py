"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    d = 7168
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=d, vocab_size=32000,
        num_heads=56, num_kv_heads=8, head_dim=128,
        d_ff=4864, dense_residual=True,
        moe=MoEConfig(d_model=d, d_ff=4864, num_experts=128, top_k=2),
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    d = 64
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        num_layers=2, d_model=d, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, dense_residual=True,
        moe=MoEConfig(d_model=d, d_ff=96, num_experts=8, top_k=2, group_size=32),
        tie_embeddings=False, q_chunk=32, xent_chunk=32,
    )
