"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module exposes ``config()`` (the exact published shape) and
``smoke_config()`` (a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "musicgen-medium",
    "zamba2-2.7b",
    "paligemma-3b",
    "mamba2-1.3b",
    "arctic-480b",
    "qwen3-moe-235b-a22b",
    "qwen3-4b",
    "qwen3-8b",
    "olmo-1b",
    "h2o-danube-3-4b",
)

_MODULES = {
    "musicgen-medium": "musicgen_medium",
    "zamba2-2.7b": "zamba2_2p7b",
    "paligemma-3b": "paligemma_3b",
    "mamba2-1.3b": "mamba2_1p3b",
    "arctic-480b": "arctic_480b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen3-4b": "qwen3_4b",
    "qwen3-8b": "qwen3_8b",
    "olmo-1b": "olmo_1b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
}

# (seq_len, global_batch, kind); kind: train | prefill | decode | long_decode
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "long_decode"),
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).config()


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke_config()


def shape_applicable(cfg, shape_name: str) -> bool:
    """long_500k only for sub-quadratic-context archs (DESIGN.md §Arch-applicability)."""
    if shape_name == "long_500k":
        return cfg.supports_long_context
    return True


# Optimized sharding-rule selection distilled from EXPERIMENTS.md §Perf:
#   qrows  — archs whose head counts don't divide the 16-way model axis
#            (attention otherwise replicates across TP; 10.2x on musicgen
#            prefill_32k)
#   puredp — small dense models where TP activation all-reduces dominate
#            (ZeRO-3 pure DP; 2.4x on olmo-1b train_4k)
#   fsdp   — very large MoE trains (16x per-device argument bytes on arctic)
#   default otherwise.
_PREFERRED: dict[tuple[str, str], str] = {}
for _shape in ("train_4k", "prefill_32k", "decode_32k"):
    _PREFERRED[("musicgen-medium", _shape)] = "qrows"
for _shape in ("train_4k", "prefill_32k"):
    _PREFERRED[("olmo-1b", _shape)] = "puredp"
    _PREFERRED[("mamba2-1.3b", _shape)] = "puredp"
_PREFERRED[("qwen3-8b", "train_4k")] = "puredp"
_PREFERRED[("arctic-480b", "train_4k")] = "fsdp"
_PREFERRED[("qwen3-moe-235b-a22b", "train_4k")] = "fsdp"


def preferred_rules_name(arch_id: str, shape_name: str) -> str:
    """The §Perf-optimized rules variant for a cell ("default" if untuned)."""
    return _PREFERRED.get((arch_id, shape_name), "default")


def cells(arch_ids=ARCH_IDS):
    """All (arch, shape) dry-run cells, with applicability filtering."""
    out = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in SHAPES:
            if shape_applicable(cfg, s):
                out.append((a, s))
    return out
