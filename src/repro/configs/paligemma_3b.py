"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216
— SigLIP + gemma backbone.  [arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per the assignment brief: ``input_specs``
provides 256 precomputed patch embeddings of width 1152 per image; the
linear projection to d_model and the gemma decoder are real.  (PaliGemma's
bidirectional prefix attention is simplified to causal; noted in DESIGN.md.)"""

from repro.models.model import ModelConfig

NUM_PATCHES = 256
PATCH_DIM = 1152


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        num_layers=18, d_model=2048, vocab_size=257216,
        num_heads=8, num_kv_heads=1, head_dim=256,
        d_ff=16384, mlp_activation="gelu",
        frontend="patches", frontend_dim=PATCH_DIM,
        num_frontend_tokens=NUM_PATCHES,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b-smoke", family="vlm",
        num_layers=2, d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, mlp_activation="gelu",
        frontend="patches", frontend_dim=48, num_frontend_tokens=16,
        tie_embeddings=True, q_chunk=32, xent_chunk=32,
    )
