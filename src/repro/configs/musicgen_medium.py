"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment brief: ``input_specs``
provides precomputed frame embeddings (sum of the 4 codebook embeddings,
delay-pattern applied upstream); the decoder and the 4 per-codebook LM heads
are real."""

from repro.models.model import ModelConfig

NUM_CODEBOOKS = 4


def config() -> ModelConfig:
    d = 1536
    return ModelConfig(
        name="musicgen-medium", family="audio",
        num_layers=48, d_model=d, vocab_size=2048,
        num_heads=24, num_kv_heads=24, head_dim=64,
        d_ff=6144,
        frontend="frames", frontend_dim=d,
        num_lm_heads=NUM_CODEBOOKS,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    d = 64
    return ModelConfig(
        name="musicgen-medium-smoke", family="audio",
        num_layers=2, d_model=d, vocab_size=128,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128,
        frontend="frames", frontend_dim=d,
        num_lm_heads=NUM_CODEBOOKS,
        tie_embeddings=False, q_chunk=32, xent_chunk=32,
    )
