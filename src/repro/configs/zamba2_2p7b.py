"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; hf]

The single shared transformer block (attention + MLP, one weight copy) is
applied every 6 Mamba2 layers; per-invocation LoRA adapters of the HF release
are omitted (noted in DESIGN.md §7)."""

from repro.models.model import ModelConfig
from repro.models.ssm import SSMConfig


def config() -> ModelConfig:
    d = 2560
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=d, vocab_size=32000,
        num_heads=32, num_kv_heads=32, head_dim=80,
        d_ff=10240, hybrid_attn_every=6,
        ssm=SSMConfig(d_model=d, d_inner=2 * d, headdim=64, d_state=64),
        tie_embeddings=True,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    d = 64
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid",
        num_layers=4, d_model=d, vocab_size=256,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, hybrid_attn_every=2,
        ssm=SSMConfig(d_model=d, d_inner=2 * d, headdim=32, d_state=16, chunk=32),
        tie_embeddings=True, q_chunk=32, xent_chunk=32,
        supports_long_context=True,
    )
