"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        num_layers=36, d_model=4096, vocab_size=151936,
        num_heads=32, num_kv_heads=8, head_dim=128, qk_norm=True,
        rope_theta=1_000_000.0,
        d_ff=12288, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        num_layers=2, d_model=96, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=24, qk_norm=True,
        d_ff=192, tie_embeddings=False, q_chunk=32, xent_chunk=32,
    )
