"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family; hf]"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    d = 4096
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=d, vocab_size=151936,
        num_heads=64, num_kv_heads=4, head_dim=128, qk_norm=True,
        rope_theta=1_000_000.0,
        d_ff=1536,
        moe=MoEConfig(d_model=d, d_ff=1536, num_experts=128, top_k=8),
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    d = 64
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke", family="moe",
        num_layers=2, d_model=d, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, qk_norm=True,
        d_ff=96,
        moe=MoEConfig(d_model=d, d_ff=96, num_experts=8, top_k=4, group_size=32),
        tie_embeddings=False, q_chunk=32, xent_chunk=32,
    )
