from .store import (  # noqa: F401
    SCHEMA_VERSION,
    CheckpointError,
    CheckpointManager,
    latest_step,
    load_arrays,
    load_checkpoint,
    save_checkpoint,
)
