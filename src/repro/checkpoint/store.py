"""Sharded checkpointing: atomic, async, retention-managed, reshard-on-load.

Layout per step:  <root>/step_<n>/
    manifest.json      tree structure + shapes/dtypes + user metadata
    arrays.npz         flattened leaves (key = flattened path)

Fault-tolerance properties:
  * atomic publish — written to step_<n>.tmp, fsync'd, then renamed, so a
    crash mid-save never yields a readable-but-corrupt checkpoint;
  * async — ``CheckpointManager.save(..., blocking=False)`` hands the host
    copy to a writer thread, keeping the train step off the critical path;
  * retention — keep the newest ``keep`` checkpoints;
  * elastic restore — ``load_checkpoint(..., shardings=...)`` device_puts
    every leaf with the *target* sharding, so a job restarted on a different
    mesh shape (elastic scaling) resumes transparently.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_SEP = "/"

# Version of the on-disk checkpoint layout (manifest + arrays.npz).  Bump on
# incompatible changes; ``load_checkpoint``/``load_arrays`` refuse snapshots
# written under a different major layout instead of mis-restoring them.
#   1: {step, keys, shapes, dtypes, metadata, schema_version}
SCHEMA_VERSION = 1


class CheckpointError(RuntimeError):
    """A snapshot on disk is unreadable: corrupted/truncated arrays, a
    missing or unparsable manifest, or a schema-version mismatch.  Distinct
    from FileNotFoundError (no snapshot at all) so recovery code can fall
    back to an older step or to log replay instead of crashing."""


def _read_manifest(path: Path) -> dict:
    mpath = path / "manifest.json"
    if not mpath.exists():
        raise CheckpointError(f"checkpoint {path} has no manifest.json")
    try:
        manifest = json.loads(mpath.read_text())
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(f"corrupt manifest at {mpath}: {e}") from e
    version = manifest.get("schema_version")
    if version != SCHEMA_VERSION:
        raise CheckpointError(
            f"checkpoint {path} has schema_version {version!r}, "
            f"this build reads {SCHEMA_VERSION}")
    return manifest


def _read_arrays(path: Path, manifest: dict) -> dict[str, np.ndarray]:
    try:
        with np.load(path / "arrays.npz") as data:
            arrays = {k: data[k] for k in data.files}
    except Exception as e:     # zipfile/OSError/ValueError: all mean corrupt
        raise CheckpointError(f"corrupt arrays.npz in {path}: {e}") from e
    missing = [k for k in manifest["keys"] if k not in arrays]
    if missing:
        raise CheckpointError(
            f"checkpoint {path} arrays missing manifest keys: {missing[:5]}")
    return arrays


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                        for k in path)
        items[key] = leaf
    return items, treedef


def save_checkpoint(root: str | os.PathLike, step: int, tree, metadata: dict | None = None):
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    items, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in items.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "schema_version": SCHEMA_VERSION,
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "metadata": metadata or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    with open(tmp / "manifest.json") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(root: str | os.PathLike) -> int | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(root: str | os.PathLike, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put each
    leaf with a (possibly different-mesh) target sharding tree."""
    path = Path(root) / f"step_{step:08d}"
    if not path.exists():
        raise FileNotFoundError(f"no checkpoint at {path}")
    manifest = _read_manifest(path)
    data = _read_arrays(path, manifest)
    items, treedef = _flatten(like_tree)
    keys = list(items)
    missing = [k for k in keys if k not in data]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]} ...")
    leaves = [data[k] for k in keys]
    if shardings is not None:
        sh_items, _ = _flatten(shardings)
        leaves = [jax.device_put(l, sh_items[k]) for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def load_arrays(root: str | os.PathLike, step: int):
    """Raw restore: ``(arrays: dict[str, np.ndarray], metadata: dict)`` without
    a ``like_tree``.  Used by snapshot consumers (the streaming engine's
    restore path) whose tree structure is data-dependent — which tenants hold
    GP blocks, how many trials have run — and therefore unknowable before the
    snapshot itself is read."""
    path = Path(root) / f"step_{step:08d}"
    if not path.exists():
        raise FileNotFoundError(f"no checkpoint at {path}")
    manifest = _read_manifest(path)
    return _read_arrays(path, manifest), manifest["metadata"]


class CheckpointManager:
    """Async save + retention.  One writer thread; ``wait()`` joins pending."""

    def __init__(self, root: str | os.PathLike, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self._pending: list[threading.Thread] = []
        self._lock = threading.Lock()
        self._writer_lock = threading.Lock()   # one writer at a time
        self._saved_steps: set[int] = set()

    def save(self, step: int, tree, metadata: dict | None = None,
             blocking: bool = True):
        with self._lock:
            if step in self._saved_steps:
                return
            self._saved_steps.add(step)
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device now

        def work():
            with self._writer_lock:
                save_checkpoint(self.root, step, host_tree, metadata)
                self._gc()

        if blocking:
            work()
        else:
            t = threading.Thread(target=work, daemon=True)
            t.start()
            with self._lock:
                self._pending.append(t)

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    def restore_latest(self, like_tree, shardings=None):
        step = latest_step(self.root)
        if step is None:
            return None
        tree, meta = load_checkpoint(self.root, step, like_tree, shardings)
        return step, tree, meta

    def _gc(self):
        steps = sorted(p for p in self.root.glob("step_*") if not p.name.endswith(".tmp"))
        for p in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(p, ignore_errors=True)
