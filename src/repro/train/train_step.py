"""The jitted training step: loss -> grad -> clip -> AdamW.

Data parallelism needs no explicit collectives: the batch is sharded over
("pod", "data"), so XLA's SPMD partitioner inserts the gradient
reduce-scatter/all-reduce automatically (hierarchical across pods when the
"pod" axis is present).  TP/EP collectives likewise come from the sharding
annotations in the model code.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, forward_loss, model_specs
from repro.sharding.rules import AxisRules
from .optimizer import OptConfig, adamw_state_specs, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt: dict


def train_state_specs(cfg: ModelConfig, opt_cfg: OptConfig):
    ps = model_specs(cfg)
    return TrainState(params=ps, opt=adamw_state_specs(ps, opt_cfg))


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, rules: AxisRules | None):
    """Returns train_step(state, batch) -> (state, metrics).  Donate state."""

    def train_step(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(
            lambda p: forward_loss(p, batch, cfg, rules))(state.params)
        new_params, new_opt, metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
