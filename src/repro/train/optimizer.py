"""AdamW + clipping + warmup-cosine schedule, implemented from scratch in JAX.

Built here (not vendored) per the assignment's "implement everything"
requirement.  Supports bf16 moment storage for the very large configs
(arctic-480b) — see ModelConfig notes in DESIGN.md §5.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: Any = jnp.float32


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_state_specs(param_specs, cfg: OptConfig):
    """ParamSpec tree for the optimizer state (moments shard like params)."""
    mom = lambda s: ParamSpec(s.shape, s.logical_axes, dtype=cfg.moment_dtype, init="zeros")
    as_spec = lambda tree: jax.tree.map(mom, tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    return {
        "mu": as_spec(param_specs),
        "nu": as_spec(param_specs),
        "step": ParamSpec((), (), dtype=jnp.int32, init="zeros"),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu32.astype(cfg.moment_dtype), nu32.astype(cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
