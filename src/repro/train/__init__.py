from .optimizer import OptConfig, adamw_init, adamw_update, global_norm, lr_at  # noqa: F401
from .train_step import TrainState, make_train_step, train_state_specs  # noqa: F401
