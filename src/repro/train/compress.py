"""Gradient compression with error feedback (distributed-optimization trick).

For cross-pod (DCI) gradient reduction the wire is ~10x slower than intra-pod
ICI, so pods exchange int8-quantized gradients.  Per-tensor symmetric
quantization with an error-feedback accumulator (Seide et al. / EF-SGD
style): the quantization residual is carried into the next step, so the
scheme is unbiased in the long run and training quality is preserved.

Usage inside a shard_map'd step (pseudo):

    q, scale, err = quantize_ef(grad + err)
    q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
    grad = dequantize(q_sum, scale_sum) / num_pods

The pure functions below are the unit; tests/test_compress.py checks the
error-feedback contraction property and end-to-end quantized-SGD convergence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, bits: int = 8) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization. Returns (int8 codes, fp32 scale)."""
    assert bits == 8, "int8 only"
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_ef(x: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback quantization: returns (codes, scale, new_err).

    new_err = (x + err) - dequantize(codes) — carried into the next step.
    """
    comp = x.astype(jnp.float32) + err
    q, scale = quantize(comp)
    new_err = comp - dequantize(q, scale)
    return q, scale, new_err


def compress_tree(grads, errs):
    """Tree-map quantize_ef; returns (codes_tree, scales_tree, new_errs)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out = [quantize_ef(g, e) for g, e in zip(flat_g, flat_e)]
    unf = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    return unf(0), unf(1), unf(2)


def decompress_tree(codes, scales):
    return jax.tree.map(dequantize, codes, scales)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes_saved(params) -> tuple[int, int]:
    """(fp32 bytes, int8 bytes) for one gradient exchange — the 4x DCI win."""
    n = sum(p.size for p in jax.tree.leaves(params))
    return 4 * n, n + 4 * len(jax.tree.leaves(params))
