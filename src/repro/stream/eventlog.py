"""Append-only event log + crash/recovery plumbing for the streaming engine.

The streaming control plane is deterministic: given a trace, a config, and a
seed, every decision, trial, and telemetry sample is a pure fold over the
event sequence.  This module makes that fold *durable* and *restartable*
(DESIGN.md §12):

* :class:`EventLog` — the append-only log.  Two streams:

    - **external** events (:class:`~repro.stream.workload.TenantArrive` /
      ``TenantDepart`` / ``SliceFail`` / ``DeviceJoin`` / ``DeviceLeave`` /
      ``DevicePreempt``), serialized losslessly (float64 arrays round-trip
      exactly through JSON's repr-based floats) — the replayable input;
    - **processed** records ``(index, t, kind, payload)`` — one per heap pop
      the engine handled, in order.  These are the *audit* stream: a restored
      engine regenerates the suffix, and any divergence from the pre-crash
      records pinpoints the first event where replay went wrong.  With
      tracing enabled the record grows a fifth field, the obs-plane trace id
      (``repro.obs.Tracer``), correlating each audit record with its span
      tree; untraced runs keep the 4-field shape.

  With a directory the log is write-through (flushed per append); without
  one it is in-memory only (every engine gets one by default).

  A third, optional stream carries the health plane's **alerts**
  (``alerts.jsonl``): structured records from ``repro.obs.HealthMonitor``,
  appended by the engine as they fire.  Alert *content* is a pure function
  of the event stream (sim-time inputs only — DESIGN.md §14), so the
  durable prefix plus a recovered run's re-emitted suffix reproduces the
  uninterrupted run's alert sequence exactly.  The file only exists for
  runs with a health monitor attached; its absence keeps old logs loading
  unchanged (no schema bump).

* :class:`FaultInjector` / :class:`SimulatedCrash` — the crash-anywhere
  hook.  The engine calls ``check(point)`` at its fault points (``before`` /
  ``after`` each event, ``mid_compact``, ``mid_launch``); the injector
  raises at the first matching point at/after ``crash_index``.  Tests sweep
  ``crash_index`` over every event of a trace (tests/test_eventlog.py).

* :func:`recover` — snapshot + replay: rebuild an engine from the latest
  checkpoint (written through ``repro.checkpoint.store``) and the log's
  external events, ready to :meth:`~repro.stream.engine.StreamEngine.resume`.
  The universal correctness property — ``snapshot + replay(suffix) ==
  uninterrupted run`` — is what every engine must satisfy.

* :func:`first_divergence` — compare two processed streams; the dict it
  returns is the replay-divergence artifact CI uploads on failure.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .workload import (
    DeviceJoin,
    DeviceLeave,
    DevicePreempt,
    Event,
    MeshShrink,
    SliceFail,
    TenantArrive,
    TenantDepart,
    TrialHang,
    TrialPoison,
)

LOG_SCHEMA_VERSION = 1


# ---- event (de)serialization ------------------------------------------------
# JSON floats are repr-round-trip exact for float64, so every array and
# timestamp survives serialize -> deserialize bit-identically — the replay
# oracle's byte-identical claim rests on this.

def serialize_event(ev: Event) -> dict:
    if not isinstance(ev, (TenantArrive, TenantDepart, SliceFail,
                           DeviceJoin, DeviceLeave, DevicePreempt,
                           TrialHang, TrialPoison, MeshShrink)):
        raise TypeError(f"unknown event {ev!r}")
    d: dict = {"type": type(ev).__name__, "at": float(ev.at)}
    if isinstance(ev, TenantArrive):
        d.update(tenant_key=int(ev.tenant_key),
                 K_block=np.asarray(ev.K_block, np.float64).tolist(),
                 mu0=np.asarray(ev.mu0, np.float64).tolist(),
                 cost=np.asarray(ev.cost, np.float64).tolist(),
                 z_true=np.asarray(ev.z_true, np.float64).tolist())
    elif isinstance(ev, TenantDepart):
        d.update(tenant_key=int(ev.tenant_key))
    elif isinstance(ev, SliceFail):
        d.update(slice_id=int(ev.slice_id), downtime=float(ev.downtime))
    elif isinstance(ev, DeviceJoin):
        d.update(chips=int(ev.chips), speed=float(ev.speed), cls=ev.cls)
    elif isinstance(ev, (DeviceLeave, DevicePreempt, TrialHang, TrialPoison)):
        d.update(slice_id=int(ev.slice_id))
    elif isinstance(ev, MeshShrink):
        d.update(num_shards=int(ev.num_shards))
    else:
        raise TypeError(f"unknown event {ev!r}")
    return d


def deserialize_event(d: dict) -> Event:
    t = d["type"]
    if t == "TenantArrive":
        return TenantArrive(
            at=d["at"], tenant_key=d["tenant_key"],
            K_block=np.asarray(d["K_block"], np.float64),
            mu0=np.asarray(d["mu0"], np.float64),
            cost=np.asarray(d["cost"], np.float64),
            z_true=np.asarray(d["z_true"], np.float64))
    if t == "TenantDepart":
        return TenantDepart(at=d["at"], tenant_key=d["tenant_key"])
    if t == "SliceFail":
        return SliceFail(at=d["at"], slice_id=d["slice_id"],
                         downtime=d["downtime"])
    if t == "DeviceJoin":
        return DeviceJoin(at=d["at"], chips=d["chips"], speed=d["speed"],
                          cls=d["cls"])
    if t == "DeviceLeave":
        return DeviceLeave(at=d["at"], slice_id=d["slice_id"])
    if t == "DevicePreempt":
        return DevicePreempt(at=d["at"], slice_id=d["slice_id"])
    if t == "TrialHang":
        return TrialHang(at=d["at"], slice_id=d["slice_id"])
    if t == "TrialPoison":
        return TrialPoison(at=d["at"], slice_id=d["slice_id"])
    if t == "MeshShrink":
        return MeshShrink(at=d["at"], num_shards=d["num_shards"])
    raise TypeError(f"unknown event type {t!r}")


# ---- the log ----------------------------------------------------------------

class EventLog:
    """Append-only external + processed event streams (module docstring).

    ``path=None`` keeps everything in memory; with a directory every append
    is written through (``external.jsonl`` / ``processed.jsonl`` /
    ``meta.json``), and :meth:`load` reads a directory back into memory.
    """

    def __init__(self, path: str | Path | None = None):
        self.path = Path(path) if path is not None else None
        self.meta: dict = {"schema_version": LOG_SCHEMA_VERSION}
        self.external: list[Event] = []
        self.processed: list[tuple[int, float, str, list]] = []
        self.alerts: list[dict] = []
        self._ext_f = self._proc_f = self._alert_f = None
        if self.path is not None:
            self.path.mkdir(parents=True, exist_ok=True)
            self._write_meta()
            self._ext_f = open(self.path / "external.jsonl", "a")
            self._proc_f = open(self.path / "processed.jsonl", "a")

    def _write_meta(self) -> None:
        if self.path is not None:
            (self.path / "meta.json").write_text(json.dumps(self.meta))

    def set_meta(self, **kw) -> None:
        self.meta.update(kw)
        self._write_meta()

    def append_external(self, ev: Event) -> None:
        self.external.append(ev)
        if self._ext_f is not None:
            self._ext_f.write(json.dumps(serialize_event(ev)) + "\n")
            self._ext_f.flush()

    def append_processed(self, index: int, t: float, kind: str,
                         data: list, trace: int | None = None) -> None:
        # ``trace`` is the obs-plane correlation key (the Tracer's trace id
        # for this event).  It is only materialized when tracing is on, so
        # untraced runs keep the 4-field record shape byte-for-byte.
        rec = ((index, float(t), kind, data) if trace is None
               else (index, float(t), kind, data, trace))
        self.processed.append(rec)
        if self._proc_f is not None:
            self._proc_f.write(json.dumps(rec) + "\n")
            self._proc_f.flush()

    def append_alert(self, record: dict) -> None:
        """Durable health-alert stream (``alerts.jsonl``), write-through
        like the others.  The file is created lazily on the first alert so
        health-less runs leave no empty stream behind."""
        self.alerts.append(record)
        if self.path is not None:
            if self._alert_f is None:
                self._alert_f = open(self.path / "alerts.jsonl", "a")
            self._alert_f.write(json.dumps(record, allow_nan=False) + "\n")
            self._alert_f.flush()

    def external_events(self) -> list[Event]:
        return list(self.external)

    def close(self) -> None:
        for f in (self._ext_f, self._proc_f, self._alert_f):
            if f is not None:
                f.close()
        self._ext_f = self._proc_f = self._alert_f = None

    @classmethod
    def load(cls, path: str | Path) -> "EventLog":
        """Read a durable log directory back into an in-memory log (the
        recovery path: the restored engine appends to its *own* fresh log,
        so the pre-crash files are never mutated)."""
        path = Path(path)
        log = cls()
        meta = json.loads((path / "meta.json").read_text())
        version = meta.get("schema_version")
        if version != LOG_SCHEMA_VERSION:
            raise ValueError(f"event log {path} has schema_version "
                             f"{version!r}, this build reads "
                             f"{LOG_SCHEMA_VERSION}")
        log.meta = meta
        ext = path / "external.jsonl"
        if ext.exists():
            with open(ext) as f:
                log.external = [deserialize_event(json.loads(line))
                                for line in f if line.strip()]
        proc = path / "processed.jsonl"
        if proc.exists():
            with open(proc) as f:
                log.processed = [tuple(json.loads(line))
                                 for line in f if line.strip()]
        al = path / "alerts.jsonl"
        if al.exists():
            with open(al) as f:
                log.alerts = [json.loads(line) for line in f
                              if line.strip()]
        return log


def first_divergence(a: list[tuple], b: list[tuple],
                     start: int = 0) -> dict | None:
    """First index where two processed streams disagree (record-by-record,
    starting at list offset ``start``), or None.  The returned dict is the
    replay-divergence artifact tests write and CI uploads on failure."""
    n = min(len(a), len(b))
    for i in range(start, n):
        ra, rb = list(a[i]), list(b[i])
        if ra != rb:
            return {"offset": i, "a": ra, "b": rb}
    if len(a) != len(b):
        i = n
        return {"offset": i,
                "a": list(a[i]) if i < len(a) else None,
                "b": list(b[i]) if i < len(b) else None,
                "len_a": len(a), "len_b": len(b)}
    return None


# ---- fault injection --------------------------------------------------------

class SimulatedCrash(RuntimeError):
    """Raised by :class:`FaultInjector` at the injected crash point.  The
    engine's in-memory state is abandoned exactly as a process kill would
    abandon it; only the durable log + snapshots survive."""


@dataclass
class FaultInjector:
    """Crash once, at the first fault point named ``point`` reached at or
    after processed-event ``crash_index``.

    Points the engine exposes:
      * ``"before"``      — after popping event ``crash_index``, before any
                            handler ran;
      * ``"after"``       — after the event's handler, launch pass, and log
                            append, before the boundary snapshot;
      * ``"mid_compact"`` — inside ``_run_compaction``, after the control
                            plane relocated blocks but before the engine
                            remapped its queues (the classic torn write);
      * ``"mid_launch"``  — inside ``_launch_on``, after ``record_start``
                            but before the trial/completion event exists.
    """
    crash_index: int
    point: str = "before"
    fired: bool = False

    def check(self, point: str, event_index: int) -> None:
        if (not self.fired and point == self.point
                and event_index >= self.crash_index):
            self.fired = True
            raise SimulatedCrash(
                f"injected crash at event {event_index} ({point})")


# ---- recovery ---------------------------------------------------------------

def recover(factory, snapshot_root: str | Path | None, log: EventLog):
    """Snapshot + replay: rebuild an engine after a crash.

    ``factory`` must build a fresh engine with the *same configuration*
    (fleet, policy, seed, scorer, compaction knobs, ...) as the crashed one
    — configuration is the caller's code, not logged state.  The newest
    readable snapshot under ``snapshot_root`` seeds the state; with none,
    the engine replays from genesis by re-ingesting the log's external
    events.  Returns ``(engine, resumed_from_event_index)`` — call
    ``engine.resume()`` to run the suffix.
    """
    from repro.checkpoint.store import (CheckpointError, latest_step,
                                        load_arrays)
    eng = factory()
    events = log.external_events()
    step = latest_step(snapshot_root) if snapshot_root is not None else None
    while step is not None:
        try:
            arrays, meta = load_arrays(snapshot_root, step)
            break
        except CheckpointError:
            # torn/corrupt snapshot: fall back toward genesis
            older = [s for s in _all_steps(snapshot_root) if s < step]
            step = max(older) if older else None
    if step is None:
        eng.begin(events, trace_name=log.meta.get("trace_name", "trace"))
        return eng, 0
    arrive_by_key = {ev.tenant_key: ev for ev in events
                     if isinstance(ev, TenantArrive)}
    eng._restore_state(arrays, meta, arrive_by_key)
    return eng, step


def _all_steps(root) -> list[int]:
    root = Path(root)
    if not root.exists():
        return []
    return [int(p.name.split("_")[1]) for p in root.glob("step_*")
            if not p.name.endswith(".tmp")]


__all__ = [
    "EventLog", "FaultInjector", "SimulatedCrash", "recover",
    "serialize_event", "deserialize_event", "first_divergence",
    "LOG_SCHEMA_VERSION",
]
