"""Service telemetry for the streaming control plane.

The engine calls the ``on_*`` hooks as events happen; the sink aggregates
them into the metrics a service operator watches:

  * per-tenant regret — ``z(x*) - z(best observed)`` at session end, plus
    the max over live tenants (the streaming analogue of the paper's
    max-over-tenants / global-happiness regret);
  * fairness — time-since-served per tenant (gap between consecutive
    observations for the same tenant), distribution + worst case;
  * device utilization — busy seconds over in-service windows, per device
    and fleet-wide, plus the *speed-weighted* fleet utilization
    (Σ busy_d·speed_d / Σ window_d·speed_d) — on a heterogeneous fleet an
    idle fast device hurts more than an idle slow one (DESIGN.md §11);
  * admission-queue depth over time (admission control backpressure);
  * time-to-first-observation per session, p50/p99.

``summary()`` returns a plain dict; ``to_json(path)`` writes it — the same
payload ``benchmarks/stream_churn.py`` records into ``BENCH_stream_churn.json``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np


@dataclass
class _TenantStats:
    arrived: float
    admitted: float | None = None
    departed: float | None = None
    first_obs: float | None = None
    last_served: float | None = None
    num_obs: int = 0
    best_z: float = -np.inf
    best_possible: float = -np.inf
    serve_gaps: list[float] = field(default_factory=list)


@dataclass
class _DeviceStats:
    joined: float
    speed: float
    left: float | None = None
    busy_seconds: float = 0.0
    trials: int = 0
    initial: bool = False    # part of the t=0 fleet (vs a runtime join)


def _pct(values, q) -> float | None:
    """Percentile over the finite entries, or an explicit None.

    Callers accumulate gaps/latencies incrementally and edge cases (tenant
    departing before its first observation, a missing sample recorded as
    None) can leave None or ±inf in the list — filter rather than let
    ``np.percentile`` fold them into NaN/-inf in ``summary()``."""
    clean = [v for v in values
             if v is not None and np.isfinite(v)]
    return float(np.percentile(clean, q)) if clean else None


class TelemetrySink:
    """Aggregates engine events into service-level metrics (module docstring)."""

    def __init__(self):
        self.tenants: dict[int, _TenantStats] = {}
        self.devices: dict[int, _DeviceStats] = {}
        self.queue_depth_samples: list[tuple[float, int]] = []
        self.busy_seconds = 0.0
        self.num_trials = 0
        self.num_failed_trials = 0
        self.num_rejected_observations = 0
        self.num_preemptions = 0
        # failure-domain lifecycle (DESIGN.md §16)
        self.num_trials_timed_out = 0
        self.num_trials_retried = 0
        self.num_trials_abandoned = 0
        self.num_devices_quarantined = 0
        self.num_poisoned_observations = 0
        self.end_time = 0.0
        self.num_slices = 0

    # ---- hooks the engine drives ------------------------------------------

    def on_arrive(self, t: float, tenant_key: int, best_possible: float) -> None:
        self.tenants[tenant_key] = _TenantStats(
            arrived=t, best_possible=best_possible)

    def on_admit(self, t: float, tenant_key: int) -> None:
        st = self.tenants[tenant_key]
        st.admitted = t
        st.last_served = t   # staleness clock starts at admission

    def on_depart(self, t: float, tenant_key: int) -> None:
        # a tenant can depart before the sink ever saw it (e.g. a trace
        # replayed from mid-stream) — ignore rather than KeyError
        st = self.tenants.get(tenant_key)
        if st is not None:
            st.departed = t

    def on_queue_depth(self, t: float, depth: int) -> None:
        self.queue_depth_samples.append((t, depth))

    def on_launch(self, t: float, tenant_key: int, model: int, device: int,
                  duration: float) -> None:
        self.num_trials += 1
        ds = self.devices.get(device)
        if ds is not None:
            ds.trials += 1

    # ---- device lifecycle (the elastic device plane, DESIGN.md §11) --------

    def on_device_join(self, t: float, device: int, speed: float,
                       initial: bool = False) -> None:
        """A slice enters service (the engine registers the initial fleet
        with ``initial=True`` at t=0; elastic joins as they land)."""
        self.devices[device] = _DeviceStats(joined=t, speed=speed,
                                            initial=initial)

    def on_device_leave(self, t: float, device: int) -> None:
        ds = self.devices.get(device)
        if ds is not None:
            ds.left = t

    def on_preemption(self, t: float, tenant_key: int, model: int,
                      busy_seconds: float, device: int | None = None) -> None:
        """A trial was evicted by a preemption (counted separately from
        failures; the occupied time still counts as busy)."""
        self.num_preemptions += 1
        self._add_busy(busy_seconds, device)

    def _add_busy(self, seconds: float, device: int | None) -> None:
        self.busy_seconds += seconds
        if device is not None:
            ds = self.devices.get(device)
            if ds is not None:
                ds.busy_seconds += seconds

    def on_observation(self, t: float, tenant_key: int, model: int,
                       z: float, duration: float,
                       device: int | None = None) -> None:
        self._add_busy(duration, device)
        st = self.tenants.get(tenant_key)
        if st is None:
            return
        if st.first_obs is None:
            st.first_obs = t
        if st.last_served is not None:
            st.serve_gaps.append(t - st.last_served)
        st.last_served = t
        st.num_obs += 1
        st.best_z = max(st.best_z, z)

    def on_trial_failed(self, t: float, tenant_key: int, model: int,
                        busy_seconds: float, device: int | None = None) -> None:
        self.num_failed_trials += 1
        self._add_busy(busy_seconds, device)   # occupied until death

    def on_rejected_observation(self, t: float, tenant_key: int,
                                duration: float,
                                device: int | None = None) -> None:
        """A trial finished after its tenant departed — result discarded,
        but the slice was busy for the full duration."""
        self.num_rejected_observations += 1
        self._add_busy(duration, device)

    # ---- failure-domain lifecycle (DESIGN.md §16) ---------------------------

    def on_trial_timeout(self, t: float, tenant_key: int, model: int,
                         busy_seconds: float, device: int | None = None,
                         retrying: bool = False) -> None:
        """Trial supervision killed a straggler at its deadline.  The device
        was occupied until the kill; ``retrying=False`` means the model's
        retry budget is exhausted — it is abandoned (never observed)."""
        self.num_trials_timed_out += 1
        if not retrying:
            self.num_trials_abandoned += 1
        self._add_busy(busy_seconds, device)

    def on_trial_retry(self, t: float, tenant_key: int, model: int,
                       attempt: int) -> None:
        """A timed-out model's backoff expired and it re-entered the launch
        queue (attempt counts from 1)."""
        self.num_trials_retried += 1

    def on_quarantine(self, t: float, device: int) -> None:
        """The device scoreboard quarantined ``device`` (strike threshold)."""
        self.num_devices_quarantined += 1

    def on_poisoned_observation(self, t: float, tenant_key: int, model: int,
                                duration: float,
                                device: int | None = None) -> None:
        """A trial returned a non-finite loss; the GP-ingest guard rejected
        it.  The slice was busy for the full duration."""
        self.num_poisoned_observations += 1
        self._add_busy(duration, device)

    def on_end(self, t: float, num_slices: int) -> None:
        self.end_time = t
        self.num_slices = num_slices

    # ---- snapshot / restore (the event-sourced engine, DESIGN.md §12) ------

    def state_dict(self) -> dict:
        """Full sink state as a JSON-able dict.  Floats survive the JSON
        round trip exactly (repr-based), including the ±inf sentinels, so a
        restored sink's aggregates are byte-identical — the crash-anywhere
        oracle compares ``summary()`` / ``per_tenant()`` outputs directly."""
        return {
            "tenants": {str(k): [st.arrived, st.admitted, st.departed,
                                 st.first_obs, st.last_served, st.num_obs,
                                 st.best_z, st.best_possible,
                                 list(st.serve_gaps)]
                        for k, st in self.tenants.items()},
            "devices": {str(k): [ds.joined, ds.speed, ds.left,
                                 ds.busy_seconds, ds.trials, ds.initial]
                        for k, ds in self.devices.items()},
            "queue_depth_samples": [[t, d]
                                    for t, d in self.queue_depth_samples],
            "busy_seconds": self.busy_seconds,
            "num_trials": self.num_trials,
            "num_failed_trials": self.num_failed_trials,
            "num_rejected_observations": self.num_rejected_observations,
            "num_preemptions": self.num_preemptions,
            "num_trials_timed_out": self.num_trials_timed_out,
            "num_trials_retried": self.num_trials_retried,
            "num_trials_abandoned": self.num_trials_abandoned,
            "num_devices_quarantined": self.num_devices_quarantined,
            "num_poisoned_observations": self.num_poisoned_observations,
            "end_time": self.end_time,
            "num_slices": self.num_slices,
        }

    def load_state(self, d: dict) -> None:
        """Overwrite this sink with :meth:`state_dict` output.  Dict
        insertion order is preserved through JSON, which keeps the order-
        sensitive float reductions in ``summary()`` byte-stable."""
        self.tenants = {
            int(k): _TenantStats(arrived=v[0], admitted=v[1], departed=v[2],
                                 first_obs=v[3], last_served=v[4],
                                 num_obs=v[5], best_z=v[6],
                                 best_possible=v[7], serve_gaps=list(v[8]))
            for k, v in d["tenants"].items()}
        self.devices = {
            int(k): _DeviceStats(joined=v[0], speed=v[1], left=v[2],
                                 busy_seconds=v[3], trials=v[4], initial=v[5])
            for k, v in d["devices"].items()}
        self.queue_depth_samples = [(t, depth)
                                    for t, depth in d["queue_depth_samples"]]
        self.busy_seconds = d["busy_seconds"]
        self.num_trials = d["num_trials"]
        self.num_failed_trials = d["num_failed_trials"]
        self.num_rejected_observations = d["num_rejected_observations"]
        self.num_preemptions = d["num_preemptions"]
        # tolerant restore: pre-supervision snapshots lack these keys
        self.num_trials_timed_out = d.get("num_trials_timed_out", 0)
        self.num_trials_retried = d.get("num_trials_retried", 0)
        self.num_trials_abandoned = d.get("num_trials_abandoned", 0)
        self.num_devices_quarantined = d.get("num_devices_quarantined", 0)
        self.num_poisoned_observations = d.get("num_poisoned_observations", 0)
        self.end_time = d["end_time"]
        self.num_slices = d["num_slices"]

    # ---- aggregation -------------------------------------------------------

    def summary(self, now: float | None = None) -> dict:
        """The roll-up.  ``now`` substitutes for ``end_time`` while a run
        is still in progress (the health plane grades SLOs mid-run at
        sim-time ``now``); the default — end-of-run shape — is untouched,
        which the replay oracle's byte-identity leans on."""
        end_time = self.end_time if now is None else max(float(now),
                                                         self.end_time)
        served = [st for st in self.tenants.values() if st.first_obs is not None]
        ttfo = [st.first_obs - st.arrived for st in served]
        gaps = [g for st in self.tenants.values() for g in st.serve_gaps
                if g is not None and np.isfinite(g)]
        # a served tenant has >=1 observation so best_z is finite, but be
        # explicit: regret stays a finite number or is excluded — summary()
        # must stay json.dumps(..., allow_nan=False)-clean
        regrets = [st.best_possible - st.best_z for st in served
                   if np.isfinite(st.best_possible)
                   and np.isfinite(st.best_z)]
        admitted = [st for st in self.tenants.values() if st.admitted is not None]
        left_queued = [st for st in self.tenants.values()
                       if st.departed is not None and st.admitted is None]
        queue_max = max((d for _, d in self.queue_depth_samples), default=0)
        elapsed = max(end_time, 1e-12)
        # device windows: joined -> left (or end of run).  With the initial
        # fleet registered at t=0 and no churn this denominator equals the
        # legacy num_slices * elapsed.
        windows = {d: max((ds.left if ds.left is not None else end_time)
                          - ds.joined, 0.0)
                   for d, ds in self.devices.items()}
        wall = sum(windows.values())
        if self.devices:
            utilization = self.busy_seconds / max(wall, 1e-12)
            speed_wall = sum(w * self.devices[d].speed
                             for d, w in windows.items())
            speed_busy = sum(ds.busy_seconds * ds.speed
                             for ds in self.devices.values())
            speed_weighted = speed_busy / max(speed_wall, 1e-12)
        else:
            utilization = (self.busy_seconds / (self.num_slices * elapsed)
                           if self.num_slices else 0.0)
            speed_weighted = None
        return {
            "sessions": len(self.tenants),
            "sessions_admitted": len(admitted),
            "sessions_served": len(served),
            "sessions_departed_while_queued": len(left_queued),
            "trials": self.num_trials,
            "trials_failed": self.num_failed_trials,
            "trials_preempted": self.num_preemptions,
            "trials_timed_out": self.num_trials_timed_out,
            "trials_retried": self.num_trials_retried,
            "trials_abandoned": self.num_trials_abandoned,
            "devices_quarantined": self.num_devices_quarantined,
            "observations_rejected": self.num_poisoned_observations,
            "observations_rejected_after_depart": self.num_rejected_observations,
            "end_time": end_time,
            "device_utilization": utilization,
            "speed_weighted_utilization": speed_weighted,
            "devices_joined": sum(1 for ds in self.devices.values()
                                  if not ds.initial),
            "devices_left": sum(1 for ds in self.devices.values()
                                if ds.left is not None),
            "queue_depth_max": queue_max,
            "ttfo_p50": _pct(ttfo, 50),
            "ttfo_p99": _pct(ttfo, 99),
            "serve_gap_p50": _pct(gaps, 50),
            "serve_gap_max": max(gaps, default=None),
            "tenant_regret_mean": float(np.mean(regrets)) if regrets else None,
            "tenant_regret_max": float(np.max(regrets)) if regrets else None,
        }

    def per_tenant(self) -> dict[int, dict]:
        out = {}
        for key, st in self.tenants.items():
            out[key] = {
                "arrived": st.arrived,
                "admitted": st.admitted,
                "departed": st.departed,
                "first_obs": st.first_obs,
                "num_obs": st.num_obs,
                "best_z": None if not np.isfinite(st.best_z) else st.best_z,
                "regret": (st.best_possible - st.best_z
                           if np.isfinite(st.best_possible)
                           and np.isfinite(st.best_z) else None),
            }
        return out

    def per_device(self) -> dict[int, dict]:
        """Per-device utilization: busy / in-service window, plus the
        speed-weighted view (busy*speed / window*speed == plain utilization
        per device; the *fleet* speed-weighted number in ``summary()`` is
        where the weights matter)."""
        out = {}
        for d, ds in self.devices.items():
            window = max((ds.left if ds.left is not None else self.end_time)
                         - ds.joined, 0.0)
            out[d] = {
                "joined": ds.joined,
                "left": ds.left,
                "speed": ds.speed,
                "trials": ds.trials,
                "busy_seconds": ds.busy_seconds,
                "utilization": ds.busy_seconds / window if window > 0 else 0.0,
            }
        return out

    def to_json(self, path: str | Path, include_tenants: bool = True,
                metrics=None, alerts=None) -> Path:
        """Write the sink payload; ``metrics`` (a
        ``repro.obs.MetricsRegistry``) rides along under a ``"metrics"``
        key in the same schema, and ``alerts`` (a list of
        ``repro.obs.Alert`` records, e.g. ``HealthMonitor.alerts`` or the
        event log's durable ``alerts`` list) under ``"alerts"``.  Both are
        ride-alongs: ``summary()``/``state_dict()`` stay untouched, so the
        replay oracle's byte-identity never sees them.  ``allow_nan=False``
        is load-bearing: the summary must contain explicit nulls, never
        NaN/±inf."""
        payload = {"summary": self.summary()}
        if self.devices:
            payload["devices"] = {str(k): v
                                  for k, v in self.per_device().items()}
        if include_tenants:
            payload["tenants"] = {str(k): v for k, v in self.per_tenant().items()}
        if metrics is not None:
            payload["metrics"] = metrics.snapshot()
        if alerts is not None:
            payload["alerts"] = [a.to_record() if hasattr(a, "to_record")
                                 else a for a in alerts]
        path = Path(path)
        path.write_text(json.dumps(payload, indent=2, sort_keys=True,
                                   allow_nan=False))
        return path
