"""Service telemetry for the streaming control plane.

The engine calls the ``on_*`` hooks as events happen; the sink aggregates
them into the metrics a service operator watches:

  * per-tenant regret — ``z(x*) - z(best observed)`` at session end, plus
    the max over live tenants (the streaming analogue of the paper's
    max-over-tenants / global-happiness regret);
  * fairness — time-since-served per tenant (gap between consecutive
    observations for the same tenant), distribution + worst case;
  * device utilization — busy seconds / (M * elapsed);
  * admission-queue depth over time (admission control backpressure);
  * time-to-first-observation per session, p50/p99.

``summary()`` returns a plain dict; ``to_json(path)`` writes it — the same
payload ``benchmarks/stream_churn.py`` records into ``BENCH_stream_churn.json``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np


@dataclass
class _TenantStats:
    arrived: float
    admitted: float | None = None
    departed: float | None = None
    first_obs: float | None = None
    last_served: float | None = None
    num_obs: int = 0
    best_z: float = -np.inf
    best_possible: float = -np.inf
    serve_gaps: list[float] = field(default_factory=list)


def _pct(values, q) -> float | None:
    return float(np.percentile(values, q)) if len(values) else None


class TelemetrySink:
    """Aggregates engine events into service-level metrics (module docstring)."""

    def __init__(self):
        self.tenants: dict[int, _TenantStats] = {}
        self.queue_depth_samples: list[tuple[float, int]] = []
        self.busy_seconds = 0.0
        self.num_trials = 0
        self.num_failed_trials = 0
        self.num_rejected_observations = 0
        self.end_time = 0.0
        self.num_slices = 0

    # ---- hooks the engine drives ------------------------------------------

    def on_arrive(self, t: float, tenant_key: int, best_possible: float) -> None:
        self.tenants[tenant_key] = _TenantStats(
            arrived=t, best_possible=best_possible)

    def on_admit(self, t: float, tenant_key: int) -> None:
        st = self.tenants[tenant_key]
        st.admitted = t
        st.last_served = t   # staleness clock starts at admission

    def on_depart(self, t: float, tenant_key: int) -> None:
        self.tenants[tenant_key].departed = t

    def on_queue_depth(self, t: float, depth: int) -> None:
        self.queue_depth_samples.append((t, depth))

    def on_launch(self, t: float, tenant_key: int, model: int, device: int,
                  duration: float) -> None:
        self.num_trials += 1

    def on_observation(self, t: float, tenant_key: int, model: int,
                       z: float, duration: float) -> None:
        self.busy_seconds += duration
        st = self.tenants.get(tenant_key)
        if st is None:
            return
        if st.first_obs is None:
            st.first_obs = t
        if st.last_served is not None:
            st.serve_gaps.append(t - st.last_served)
        st.last_served = t
        st.num_obs += 1
        st.best_z = max(st.best_z, z)

    def on_trial_failed(self, t: float, tenant_key: int, model: int,
                        busy_seconds: float) -> None:
        self.num_failed_trials += 1
        self.busy_seconds += busy_seconds   # the slice was occupied until death

    def on_rejected_observation(self, t: float, tenant_key: int,
                                duration: float) -> None:
        """A trial finished after its tenant departed — result discarded,
        but the slice was busy for the full duration."""
        self.num_rejected_observations += 1
        self.busy_seconds += duration

    def on_end(self, t: float, num_slices: int) -> None:
        self.end_time = t
        self.num_slices = num_slices

    # ---- aggregation -------------------------------------------------------

    def summary(self) -> dict:
        served = [st for st in self.tenants.values() if st.first_obs is not None]
        ttfo = [st.first_obs - st.arrived for st in served]
        gaps = [g for st in self.tenants.values() for g in st.serve_gaps]
        regrets = [st.best_possible - st.best_z for st in served
                   if np.isfinite(st.best_possible)]
        admitted = [st for st in self.tenants.values() if st.admitted is not None]
        left_queued = [st for st in self.tenants.values()
                       if st.departed is not None and st.admitted is None]
        queue_max = max((d for _, d in self.queue_depth_samples), default=0)
        elapsed = max(self.end_time, 1e-12)
        return {
            "sessions": len(self.tenants),
            "sessions_admitted": len(admitted),
            "sessions_served": len(served),
            "sessions_departed_while_queued": len(left_queued),
            "trials": self.num_trials,
            "trials_failed": self.num_failed_trials,
            "observations_rejected_after_depart": self.num_rejected_observations,
            "end_time": self.end_time,
            "device_utilization": (
                self.busy_seconds / (self.num_slices * elapsed)
                if self.num_slices else 0.0),
            "queue_depth_max": queue_max,
            "ttfo_p50": _pct(ttfo, 50),
            "ttfo_p99": _pct(ttfo, 99),
            "serve_gap_p50": _pct(gaps, 50),
            "serve_gap_max": max(gaps, default=None),
            "tenant_regret_mean": float(np.mean(regrets)) if regrets else None,
            "tenant_regret_max": float(np.max(regrets)) if regrets else None,
        }

    def per_tenant(self) -> dict[int, dict]:
        out = {}
        for key, st in self.tenants.items():
            out[key] = {
                "arrived": st.arrived,
                "admitted": st.admitted,
                "departed": st.departed,
                "first_obs": st.first_obs,
                "num_obs": st.num_obs,
                "best_z": None if not np.isfinite(st.best_z) else st.best_z,
                "regret": (st.best_possible - st.best_z
                           if np.isfinite(st.best_possible)
                           and np.isfinite(st.best_z) else None),
            }
        return out

    def to_json(self, path: str | Path, include_tenants: bool = True) -> Path:
        payload = {"summary": self.summary()}
        if include_tenants:
            payload["tenants"] = {str(k): v for k, v in self.per_tenant().items()}
        path = Path(path)
        path.write_text(json.dumps(payload, indent=2, sort_keys=True))
        return path
