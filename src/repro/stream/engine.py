"""Event-driven streaming engine: online GP-EI over a Fleet under churn.

The loop generalizes ``scheduler.simulate`` from a closed episode to an open
service.  External events come from a :class:`~repro.stream.workload.ChurnTrace`
(tenant arrivals/departures, slice failures); internal events are trial
completions and slice repairs.  All of them drive one shared
:class:`~repro.core.control_plane.ControlPlane`:

  TenantArrive  -> admission control; if admitted, ``add_tenant`` appends the
                   tenant's GP block and its warm-start trials join the queue
  TenantDepart  -> ``retire_tenant`` frees the GP block; in-flight trials run
                   to completion but their observations are discarded
  TrialDone     -> ``record_observation`` (GP fold) + fairness accounting,
                   then the freed slice launches the next EIrate argmax
  SliceFail     -> the in-flight trial dies; its model returns to the
                   unselected pool (``record_failure``); the slice rejoins
                   after ``downtime``

Admission control caps the number of *live models* (sum of candidate-set
sizes over admitted, non-departed tenants): a tenant whose block would
exceed the cap waits in a FIFO queue and is admitted as departures free
capacity — queue depth is a telemetry series.

Index space under churn (DESIGN.md §10): the ControlPlane recycles model
and tenant slots, so a reused global model id can refer to a *new* tenant's
model while an old tenant's trial is still in flight — every completion /
failure therefore resolves its owner through the trial's ``tenant_key``
(stable forever), never through the model id.  With ``compact_every`` set,
the engine periodically asks the control plane to rebalance idle tenant
blocks across shard spans and remaps its own launch queue and ownership
maps from the returned old->new id mapping (in-flight models are pinned, so
pending completion events never go stale).

Equivalence contract (tested): replaying
:func:`~repro.stream.workload.trace_from_problem` (all tenants at t=0, no
departures, no failures, no cap) reproduces ``scheduler.simulate``'s trial
sequence exactly for the deterministic policies, because both engines share
the ControlPlane decision core, the warm-start order, and the
free-device-stack pop order.  Simultaneous arrivals are therefore admitted
*before* any launch decision (matching the pre-built warm-start queue);
otherwise the engine launches greedily after every event, exactly like the
offline loop.
"""

from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass, field

import numpy as np

from repro.core.control_plane import ControlPlane, tenant_warm_models
from repro.core.fleet import DeviceSlice, Fleet
from repro.core.scheduler import POLICIES
from repro.obs import NULL_TRACER

from .eventlog import EventLog, FaultInjector
from .telemetry import TelemetrySink
from .workload import (ChurnTrace, MeshShrink, SliceFail, TenantArrive,
                       TenantDepart, TrialHang, TrialPoison)


@dataclass(frozen=True)
class StreamTrial:
    """One launched trial.  ``z is None`` means the trial died (slice
    failure) or was still in flight when the run ended."""
    model: int               # global model id in the ControlPlane's space
    tenant_key: int
    local_model: int         # index within the tenant's candidate set
    user_hint: int           # -2 warm start, -1 mdmt global, else tenant slot
    device: int
    start: float
    end: float
    z: float | None


@dataclass
class _TenantRuntime:
    key: int
    arrive: TenantArrive
    admitted_at: float | None = None
    departed: bool = False
    tenant_id: int | None = None      # ControlPlane slot once admitted
    model_start: int | None = None    # first global model id of the block


@dataclass
class StreamResult:
    trace_name: str
    policy: str
    num_devices: int
    trials: list[StreamTrial]
    end_time: float
    decisions: int
    decision_seconds: float
    telemetry: TelemetrySink
    tenants: dict[int, _TenantRuntime] = field(repr=False, default_factory=dict)
    compaction_moves: int = 0   # tenant blocks relocated by compact() passes
    policy_launches: int = 0    # launches decided by the policy (not warm
                                # start) — the decision-cost denominator

    @property
    def observations(self) -> list[tuple[float, int, float]]:
        """(finish_time, global model, z) for successful trials, time-ordered."""
        obs = [(t.end, t.model, t.z) for t in self.trials if t.z is not None]
        obs.sort()
        return obs


class StreamEngine:
    """Online multi-tenant GP-EI service over a Fleet (module docstring)."""

    LAUNCH_ORDERS = ("lifo", "fastest")

    def __init__(
        self,
        fleet: Fleet,
        policy: str = "mdmt",
        *,
        warm_start: int = 2,
        max_live_models: int | None = None,
        seed: int = 0,
        scorer: str = "fused",
        num_shards: int | None = None,
        score_kernel: str = "xla",
        compact_every: int | None = None,
        compact_imbalance: float | None = None,
        compact_max_moves: int | None = None,
        launch_order: str = "lifo",
        telemetry: TelemetrySink | None = None,
        log: EventLog | None = None,
        snapshot_root: str | None = None,
        snapshot_every: int | None = None,
        fault: FaultInjector | None = None,
        tracer=None,
        metrics=None,
        exporter=None,
        health=None,
        forensics=None,
        accounting=None,
        timeout_factor: float | None = None,
        max_retries: int = 2,
        retry_backoff: float = 1.0,
    ):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        if launch_order not in self.LAUNCH_ORDERS:
            raise ValueError(f"launch_order must be one of "
                             f"{self.LAUNCH_ORDERS}, got {launch_order!r}")
        if timeout_factor is not None and timeout_factor <= 1.0:
            raise ValueError("timeout_factor must exceed 1.0 (the deadline "
                             f"is k x predicted seconds), got {timeout_factor}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff <= 0:
            raise ValueError(f"retry_backoff must be > 0, got {retry_backoff}")
        self.fleet = fleet
        self.policy = policy
        self.launch_order = launch_order
        self.warm_start = warm_start
        # trial supervision (DESIGN.md §16): with timeout_factor set, every
        # launch schedules a deadline at t + timeout_factor * predicted
        # duration; a trial that misses it is killed, its model re-queued
        # with exponential backoff up to max_retries attempts.  None keeps
        # the unsupervised engine byte-identical (no timeout events at all).
        self.timeout_factor = timeout_factor
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.max_live_models = max_live_models
        self.compact_every = compact_every
        self.compact_imbalance = compact_imbalance
        self.compact_max_moves = compact_max_moves
        self.telemetry = telemetry or TelemetrySink()
        # event sourcing (DESIGN.md §12): every run appends its external
        # events and one processed record per handled event to the log; with
        # snapshot_root set, full-state snapshots land every snapshot_every
        # processed events through checkpoint/store.py
        self.log = log if log is not None else EventLog()
        self.snapshot_root = snapshot_root
        self.snapshot_every = snapshot_every
        self.fault = fault
        self.event_index = 0
        self.cp = ControlPlane(np.random.default_rng(seed), scorer=scorer,
                               num_shards=num_shards,
                               score_kernel=score_kernel)
        self._chooser = self.cp.chooser(policy)
        # observability (DESIGN.md §13): both planes are observation-only —
        # spans/metrics never enter snapshots or the replay oracle's
        # comparisons, and a traced run's trial sequence is byte-identical
        # to an untraced one (tested).  trace_id == event_index, so a
        # recovered run re-emits the replayed suffix's span tree exactly.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cp.set_tracer(self.tracer)
        self.metrics = metrics
        if metrics is not None:
            self._m_events = metrics.counter("engine.events")
            self._m_launches = metrics.counter("engine.launches")
            self._m_decision_s = metrics.histogram("engine.decision_seconds")
            self._m_compact_s = metrics.histogram(
                "engine.compaction_pause_seconds")
            self._m_snapshot_s = metrics.histogram("engine.snapshot_seconds")
            self._m_queue = metrics.gauge("engine.queue_depth")
        # live health plane (DESIGN.md §14): exporter/health/forensics are
        # observation-only like the tracer — none of their outputs feed the
        # decision path — but the exporter's window cursor and the health
        # monitor's detector state ride in snapshot meta so a recovered
        # run re-emits the identical export/alert suffix.  Alerts stream
        # write-through to the log's durable alerts.jsonl per event.
        self.exporter = exporter
        self.health = health
        self.forensics = forensics
        self.cp.set_forensics(forensics)
        # capacity plane (DESIGN.md §15): same discipline — gauges never
        # feed a decision; the sample cursor + projection history ride in
        # snapshot meta.  When both planes run, the exporter also renders
        # the health monitor's alert counts on its scrape surface.
        self.accounting = accounting
        if exporter is not None and health is not None \
                and exporter.health is None:
            exporter.health = health

        # mirrors scheduler.simulate's free-device stack: initial pop order is
        # slice M-1, M-2, ...; freed slices are re-pushed on top
        self._free: list[int] = [s.slice_id for s in fleet.slices if s.healthy]
        self._heap: list[tuple[float, int, str, tuple]] = []
        self._seq = 0
        # warm-start launch queue: (tenant_key, global model id) — keyed so a
        # stale entry whose slot was recycled is detected and skipped
        self._pending: list[tuple[int, int]] = []
        self._admission_queue: list[_TenantRuntime] = []
        self._live_models = 0
        self._departures = 0
        self._tenants: dict[int, _TenantRuntime] = {}
        self._owner_of_model: dict[int, _TenantRuntime] = {}
        self._trials: list[StreamTrial] = []
        self._cancelled: set[int] = set()
        # failure-domain state (DESIGN.md §16): trial indices doomed to hang
        # (never finish) or return a poisoned loss, and per-model retry
        # budgets keyed (tenant_key, local_model) — stable across slot
        # recycling and mesh re-sharding, unlike global model ids
        self._hung: set[int] = set()
        self._poisoned: set[int] = set()
        self._retry_attempts: dict[tuple[int, int], int] = {}
        self._t = 0.0
        self._decisions = 0
        self._decision_seconds = 0.0
        self._policy_launches = 0
        self._compaction_moves = 0
        self.compaction_move_counts: list[int] = []  # blocks moved per call
        self._trace_name = "trace"

    # ---- event plumbing ----------------------------------------------------

    def _fault(self, point: str) -> None:
        if self.fault is not None:
            self.fault.check(point, self.event_index)

    def _push(self, t: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    # ---- admission ---------------------------------------------------------

    def _fits(self, tr: _TenantRuntime) -> bool:
        return (self.max_live_models is None
                or self._live_models + tr.arrive.num_models <= self.max_live_models)

    def _admit(self, tr: _TenantRuntime) -> None:
        ev = tr.arrive
        handle = self.cp.add_tenant(ev.K_block, ev.mu0, ev.cost)
        tr.tenant_id = handle.tenant_id
        tr.model_start = int(handle.models[0])
        tr.admitted_at = self._t
        self._live_models += ev.num_models
        for g in handle.models:
            self._owner_of_model[int(g)] = tr
        self._pending.extend(
            (tr.key, tr.model_start + li)
            for li in tenant_warm_models(ev.cost, ev.mu0, self.warm_start))
        self.telemetry.on_admit(self._t, tr.key)

    def _drain_admission_queue(self) -> None:
        admitted = False
        while self._admission_queue and self._fits(self._admission_queue[0]):
            self._admit(self._admission_queue.pop(0))
            admitted = True
        if admitted or self._admission_queue:
            self.telemetry.on_queue_depth(self._t, len(self._admission_queue))

    # ---- event handlers ----------------------------------------------------

    def _handle_arrive(self, tr: _TenantRuntime) -> None:
        best_possible = float(np.max(tr.arrive.z_true))
        self.telemetry.on_arrive(self._t, tr.key, best_possible)
        if not self._admission_queue and self._fits(tr):
            self._admit(tr)
        else:
            self._admission_queue.append(tr)
            self.telemetry.on_queue_depth(self._t, len(self._admission_queue))

    def _handle_depart(self, key: int) -> None:
        tr = self._tenants[key]
        if tr.departed:
            return
        tr.departed = True
        self.telemetry.on_depart(self._t, key)
        if tr.tenant_id is None:
            # never admitted: drop it from the waiting line — whoever was
            # stuck behind it may fit now (FIFO head-of-line blocking).  No
            # runtime exists: nothing to retire, no live-model capacity to
            # return, no pending/ownership entries to clean.
            self._admission_queue = [q for q in self._admission_queue
                                     if q.key != key]
            self.telemetry.on_queue_depth(self._t, len(self._admission_queue))
            self._drain_admission_queue()
            return
        self.cp.retire_tenant(tr.tenant_id)
        self._live_models -= tr.arrive.num_models
        self._departures += 1
        for g in range(tr.model_start, tr.model_start + tr.arrive.num_models):
            if self._owner_of_model.get(g) is tr:
                del self._owner_of_model[g]
        self._drain_admission_queue()
        # incremental mode (compact_max_moves set) defaults to a bounded
        # pass on EVERY departure — small pauses, amortized convergence —
        # while compact_every alone keeps the periodic stop-the-world pass
        every = self.compact_every or (1 if self.compact_max_moves else None)
        if every and self._departures % every == 0:
            self._run_compaction()

    def _run_compaction(self) -> None:
        """Rebalance idle tenant blocks across shard spans and remap every
        engine-side structure that holds global model ids."""
        t0 = _time.perf_counter()
        with self.tracer.span("compaction"):
            remap = self.cp.compact(self.compact_imbalance,
                                    max_moves=self.compact_max_moves)
        if self.metrics is not None:
            self._m_compact_s.observe(_time.perf_counter() - t0)
        self.compaction_move_counts.append(len(remap))
        self._fault("mid_compact")
        if not remap:
            return
        by_tid = {tr.tenant_id: tr for tr in self._tenants.values()
                  if tr.tenant_id is not None and not tr.departed}
        gid_map: dict[int, int] = {}
        for tid, (old_ids, new_ids) in remap.items():
            tr = by_tid[tid]
            tr.model_start = int(new_ids[0])
            for og, ng in zip(old_ids.tolist(), new_ids.tolist()):
                gid_map[og] = ng
            for og in old_ids.tolist():
                if self._owner_of_model.get(og) is tr:
                    del self._owner_of_model[og]
            for ng in new_ids.tolist():
                self._owner_of_model[ng] = tr
            self._compaction_moves += 1
        self._pending = [(key, gid_map.get(g, g)) for key, g in self._pending]

    def _handle_finish(self, device: int, model: int, ti: int) -> None:
        if ti in self._cancelled:
            return
        if ti in self._hung:
            # the trial hung: its completion never materializes and the
            # device stays busy — without supervision, stranded forever
            # (the failure mode the chaos benchmark's baseline demonstrates)
            return
        t = self._trials[ti]
        # resolve the owner by tenant key, NOT by model id: with slot reuse
        # the id may already belong to a newly admitted tenant while this
        # departed tenant's trial was still in flight
        tr = self._tenants[t.tenant_key]
        if tr.departed:
            self.telemetry.on_rejected_observation(
                self._t, tr.key, t.end - t.start, device=device)
        else:
            z = float(tr.arrive.z_true[t.local_model])
            if ti in self._poisoned:
                self._poisoned.discard(ti)
                z = float("nan")
            if not np.isfinite(z):
                # poisoned-observation guard: a non-finite loss never
                # reaches the GP (it would corrupt the Cholesky).  The
                # model returns to the unselected pool like a failure.
                self.cp.record_failure(model)
                self.telemetry.on_poisoned_observation(
                    self._t, tr.key, model, t.end - t.start, device=device)
                if self.health is not None:
                    self.health.on_poisoned(self._t, self.event_index,
                                            tr.key, model)
                if self.metrics is not None:
                    self.metrics.counter("engine.observations_rejected").inc()
                if self.forensics is not None:
                    self.forensics.on_incident(
                        kind="poisoned_observation", tenant=tr.key,
                        model=model, device=device)
            else:
                self._trials[ti] = StreamTrial(
                    t.model, t.tenant_key, t.local_model, t.user_hint,
                    t.device, t.start, t.end, z)
                improved = self.cp.record_observation(model, z)
                if self.health is not None:
                    # d2 stays device-resident until a monitor asks for it —
                    # the sync is paid only on the health-enabled path
                    d2 = self.cp.gp.last_d2
                    self.health.on_observation(
                        self._t, self.event_index, tr.key, improved,
                        d2=None if d2 is None else float(d2),
                        jitter=self.cp._jitter, model=model)
                self.telemetry.on_observation(
                    self._t, tr.key, model, z, t.end - t.start, device=device)
        self.fleet.slices[device].current_trial = None
        self._device_ok(device)
        self._free.append(device)

    def _kill_trial(self, killed_ti: int, *, preempted: bool = False) -> None:
        """Shared bookkeeping for a trial dying before observation (slice
        failure, device leave, preemption): cancel its pending completion,
        rewrite the record as unobserved, and return the model to
        L \\ L(t) — it was never observed, the paper's failure rule."""
        self._hung.discard(killed_ti)
        self._poisoned.discard(killed_ti)
        self._cancelled.add(killed_ti)
        t = self._trials[killed_ti]
        self._trials[killed_ti] = StreamTrial(
            t.model, t.tenant_key, t.local_model, t.user_hint,
            t.device, t.start, self._t, None)
        owner = self._tenants[t.tenant_key]
        if not owner.departed:
            # never observed => the model returns to L \ L(t)
            self.cp.record_failure(t.model)
        if preempted:
            self.telemetry.on_preemption(
                self._t, t.tenant_key, t.model, self._t - t.start,
                device=t.device)
        else:
            self.telemetry.on_trial_failed(
                self._t, t.tenant_key, t.model, self._t - t.start,
                device=t.device)

    def _handle_slice_fail(self, slice_id: int, downtime: float) -> None:
        s = self.fleet.slices[slice_id]
        if not s.healthy:
            return                       # already down; one repair is pending
        killed_ti = self.fleet.fail(slice_id)
        if killed_ti is not None:
            self._kill_trial(killed_ti)
        elif slice_id in self._free:
            self._free.remove(slice_id)
        self._device_strike(slice_id, reason="slice_fail")
        self._push(self._t + downtime, "recover", (slice_id,))

    def _handle_recover(self, slice_id: int) -> None:
        s = self.fleet.slices[slice_id]
        if s.retired:
            return                       # left the fleet while down
        self.fleet.recover(slice_id)
        if (s.current_trial is None and slice_id not in self._free
                and not self._is_quarantined(slice_id)):
            self._free.append(slice_id)

    # ---- trial supervision + failure-domain handlers (DESIGN.md §16) -------

    def _handle_timeout(self, device: int, model: int, ti: int) -> None:
        """The deadline for trial ``ti`` fired.  A completed or cancelled
        trial makes this a logged no-op; a still-running one is a straggler:
        kill it, free the device (unless quarantine holds it), and re-queue
        the model with exponential backoff if retry budget remains.  The
        model stays SELECTED through the backoff window — the policy cannot
        re-pick it early, and the in-flight compaction pin keeps its block
        unmoved while the retry event holds its global id.  A model that
        exhausts its budget is abandoned (permanently selected, never
        observed) — deliberately NOT returned to the pool, which would
        re-pick and re-time-out it forever."""
        s = self.fleet.slices[device]
        if ti in self._cancelled or s.current_trial != ti:
            return                       # completed / killed before deadline
        self._hung.discard(ti)
        self._poisoned.discard(ti)
        self._cancelled.add(ti)
        t = self._trials[ti]
        self._trials[ti] = StreamTrial(
            t.model, t.tenant_key, t.local_model, t.user_hint,
            t.device, t.start, self._t, None)
        owner = self._tenants[t.tenant_key]
        retrying = False
        rk = (t.tenant_key, t.local_model)
        attempt = self._retry_attempts.get(rk, 0)
        if not owner.departed and attempt < self.max_retries:
            self._retry_attempts[rk] = attempt + 1
            self._push(self._t + self.retry_backoff * (2.0 ** attempt),
                       "retry", (t.tenant_key, t.model, attempt + 1))
            retrying = True
        s.current_trial = None
        s.busy_until = self._t
        quarantined = self._device_strike(device, reason="timeout")
        if not quarantined and device not in self._free:
            self._free.append(device)
        self.telemetry.on_trial_timeout(
            self._t, t.tenant_key, t.model, self._t - t.start,
            device=device, retrying=retrying or owner.departed)
        if self.health is not None:
            self.health.on_timeout(self._t, self.event_index, device,
                                   t.tenant_key,
                                   overrun=self._t - t.start)
        if self.metrics is not None:
            self.metrics.counter("engine.trials_timed_out",
                                 labels={"cls": s.cls}).inc()
        if self.forensics is not None:
            self.forensics.on_incident(
                kind="trial_timeout", tenant=t.tenant_key, model=t.model,
                device=device, attempt=attempt, retrying=retrying)

    def _handle_retry(self, key: int, model: int, attempt: int) -> None:
        """Backoff expired: deselect the model and re-queue it through the
        pending launch path (the same staleness-guarded queue warm starts
        use), so the next launch pass relaunches it deterministically."""
        owner = self._tenants.get(key)
        if (owner is None or owner.departed
                or self._owner_of_model.get(model) is not owner):
            return                       # tenant left / slot recycled meanwhile
        self.cp.record_failure(model)
        self._pending.append((key, model))
        self.telemetry.on_trial_retry(self._t, key, model, attempt)
        if self.health is not None:
            self.health.on_retry(self._t, self.event_index, key, model,
                                 attempt)
        if self.metrics is not None:
            self.metrics.counter("engine.trials_retried").inc()

    def _handle_hang(self, slice_id: int) -> None:
        """Chaos event: the trial currently on ``slice_id`` will never
        complete — mark it so its finish event becomes a no-op."""
        if slice_id >= len(self.fleet.slices):
            return
        s = self.fleet.slices[slice_id]
        ti = s.current_trial
        if (not s.healthy or s.retired or ti is None
                or ti in self._cancelled):
            return                       # nothing running to hang
        self._hung.add(ti)

    def _handle_poison(self, slice_id: int) -> None:
        """Chaos event: the trial currently on ``slice_id`` completes on
        schedule but returns NaN — mark it for the ingest guard."""
        if slice_id >= len(self.fleet.slices):
            return
        s = self.fleet.slices[slice_id]
        ti = s.current_trial
        if (not s.healthy or s.retired or ti is None
                or ti in self._cancelled):
            return
        self._poisoned.add(ti)

    def _handle_mesh_shrink(self, num_shards: int) -> None:
        """The scoring mesh lost devices: re-shard every resident posterior
        block onto a ``num_shards`` mesh through the control plane's
        checkpoint path, then remap every engine-side structure holding
        global model ids (the compaction discipline, applied to the whole
        resident set)."""
        with self.tracer.span("mesh_shrink", num_shards=num_shards):
            remap = self.cp.reshard(num_shards)
        if not remap:
            return
        for tr in self._tenants.values():
            if tr.tenant_id is not None and not tr.departed:
                tr.model_start = remap.get(tr.model_start, tr.model_start)
        self._owner_of_model = {remap.get(g, g): tr
                                for g, tr in self._owner_of_model.items()}
        self._pending = [(key, remap.get(g, g)) for key, g in self._pending]
        # in-flight trial records and their pending finish/timeout/retry
        # heap payloads carry global ids too.  Departed owners' ids are
        # absent from the remap (their blocks are already released) — their
        # handlers never dereference the model id, so passthrough is safe.
        for s in self.fleet.slices:
            ti = s.current_trial
            if ti is not None and ti not in self._cancelled:
                t = self._trials[ti]
                self._trials[ti] = StreamTrial(
                    remap.get(t.model, t.model), t.tenant_key, t.local_model,
                    t.user_hint, t.device, t.start, t.end, t.z)
        heap = []
        for t, seq, kind, payload in self._heap:
            if kind in ("finish", "timeout"):
                d, g, ti = payload
                payload = (d, remap.get(g, g), ti)
            elif kind == "retry":
                k, g, a = payload
                payload = (k, remap.get(g, g), a)
            heap.append((t, seq, kind, payload))
        # same (t, seq) arrangement => still a valid heap
        self._heap = heap
        if self.metrics is not None:
            self.metrics.counter("engine.mesh_shrinks").inc()
        if self.forensics is not None:
            self.forensics.on_incident(kind="mesh_shrink",
                                       num_shards=num_shards,
                                       slots_remapped=len(remap))

    # ---- device quarantine hooks (devplane overrides; DESIGN.md §16) -------

    def _device_strike(self, device: int, *, reason: str) -> bool:
        """Record a failure/timeout strike against ``device``.  Returns True
        when the device is (now) quarantined and must be kept out of the
        free list.  Base engine: no scoreboard, never quarantines."""
        return False

    def _device_ok(self, device: int) -> None:
        """Record a clean completion on ``device`` (probation credit)."""

    def _is_quarantined(self, device: int) -> bool:
        return False

    # ---- the launch loop (mirrors scheduler.simulate.try_launch) -----------

    def _pick_free_index(self) -> int:
        """Index into ``self._free`` of the next slice to launch on.

        ``launch_order="lifo"`` is the historical stack pop (top of stack);
        ``"fastest"`` picks the fastest free slice — ties resolve to the
        most recently freed (the stack top among the tied), so on a
        homogeneous fleet the two orders are byte-identical and the replay
        equivalence contract is untouched (tests/test_stream.py)."""
        if self.launch_order == "lifo" or len(self._free) == 1:
            return len(self._free) - 1
        speeds = [self.fleet.slices[d].speed for d in self._free]
        best = max(speeds)
        for i in range(len(self._free) - 1, -1, -1):
            if speeds[i] == best:
                return i
        raise AssertionError("unreachable: _free is non-empty")

    def _launch_on(self, i: int, model: int, hint: int) -> None:
        """Commit one launch on free-list index ``i`` (shared bookkeeping
        for the sequential and the devplane batched paths)."""
        d = self._free.pop(i)
        s = self.fleet.slices[d]
        owner = self._owner_of_model[model]
        with self.tracer.span("launch", model=model, device=d):
            dur = self._duration_on(model, s)
            end = self._t + dur
            self.cp.record_start(model)
            self._fault("mid_launch")
            ti = len(self._trials)
            s.current_trial = ti
            s.busy_until = end
            self._trials.append(StreamTrial(
                model, owner.key, model - owner.model_start, hint, d,
                self._t, end, None))
            self._push(end, "finish", (d, model, ti))
            if self.timeout_factor is not None:
                # deadline = k x predicted seconds; pushed after the finish
                # at the same heap discipline, so an on-time completion's
                # deadline pops later as a logged no-op
                self._push(self._t + self.timeout_factor * dur,
                           "timeout", (d, model, ti))
        if self.metrics is not None:
            self._m_launches.inc()
            self.metrics.counter("engine.launches_by_class",
                                 labels={"cls": s.cls}).inc()
        if self.health is not None:
            self.health.on_launch(self._t, self.event_index, owner.key,
                                  model, s.cls)
        self.telemetry.on_launch(self._t, owner.key, model, d, dur)

    def _duration_on(self, model: int, s) -> float:
        """Trial duration of ``model`` on slice ``s`` — the rank-1
        ``c(x)/speed_d``; the devplane engine overrides this with the
        registry's 2-D per-class cost (DESIGN.md §11)."""
        return float(self.cp.cost[model]) / s.speed

    def _pop_pending_launch(self) -> bool:
        """Consume exactly one warm-start queue entry: launch it on the
        ``_pick_free_index`` slice, or drop it when stale.  Returns False
        when the queue is empty.  Shared by the base and devplane launch
        loops — the batched == sequential equivalence depends on the two
        engines applying identical staleness guards."""
        if not self._pending:
            return False
        i = self._pick_free_index()
        key, model = self._pending.pop(0)
        owner = self._tenants[key]
        if owner.departed or self._owner_of_model.get(model) is not owner:
            return True                  # tenant left / slot recycled meanwhile
        if self.cp.selected[model]:
            return True                  # observed or in flight meanwhile
        self._launch_on(i, model, -2)
        return True

    def _try_launch(self, horizon: float) -> None:
        while self._free:
            if self._t >= horizon:
                return
            if self._pop_pending_launch():
                continue
            i = self._pick_free_index()
            s = self.fleet.slices[self._free[i]]
            t0 = _time.perf_counter()
            with self.tracer.span("decide", device=self._free[i]):
                pick = self._chooser(device_speed=s.speed)
            dt = _time.perf_counter() - t0
            self._decision_seconds += dt
            self._decisions += 1
            if self.metrics is not None:
                self._m_decision_s.observe(dt)
            if pick is None:
                return
            model, hint = pick
            self._policy_launches += 1
            self._launch_on(i, model, hint)

    # ---- the loop ----------------------------------------------------------

    def _ingest(self, ev) -> None:
        """Schedule one external trace event.  The devplane engine extends
        this with device lifecycle events (DeviceJoin/Leave/Preempt)."""
        if isinstance(ev, TenantArrive):
            tr = _TenantRuntime(key=ev.tenant_key, arrive=ev)
            self._tenants[ev.tenant_key] = tr
            self._push(ev.at, "arrive", (tr,))
        elif isinstance(ev, TenantDepart):
            self._push(ev.at, "depart", (ev.tenant_key,))
        elif isinstance(ev, SliceFail):
            self._push(ev.at, "slice_fail", (ev.slice_id, ev.downtime))
        elif isinstance(ev, TrialHang):
            self._push(ev.at, "hang", (ev.slice_id,))
        elif isinstance(ev, TrialPoison):
            self._push(ev.at, "poison", (ev.slice_id,))
        elif isinstance(ev, MeshShrink):
            self._push(ev.at, "mesh_shrink", (ev.num_shards,))
        else:
            raise TypeError(f"unknown trace event {ev!r}")

    def _dispatch_extra(self, kind: str, payload: tuple) -> None:
        """Handle an event kind the base engine does not know (devplane
        device lifecycle).  Base: nothing is expected to land here."""
        raise AssertionError(f"unknown event kind {kind!r}")

    def _post_event(self, kind: str) -> None:
        """Hook between event handling and the launch pass — the devplane
        engine evaluates its autoscale policy here.  Base: no-op."""

    def _capacity_extra(self) -> dict:
        """Extra scalar capacity gauges for the accounting plane — the
        devplane engine reports autoscale joins/leaves and scoring passes
        here.  Base: nothing."""
        return {}

    # ---- live health plane (DESIGN.md §14) ---------------------------------

    def _backlog(self) -> int:
        """Launchable pool size: live models neither observed nor in
        flight — the health plane's notion of pending work."""
        return int(np.count_nonzero(~self.cp.selected & self.cp.model_live))

    def _health_tick(self) -> None:
        """Feed the watchdogs once per processed event (sim-time inputs
        only — alert content must replay deterministically) and forward
        new alerts to the durable event log."""
        free_classes = tuple(sorted(
            {self.fleet.slices[d].cls for d in self._free}))
        self.health.on_event(
            self._t, self.event_index,
            queue_depth=len(self._admission_queue),
            backlog=self._backlog(),
            free_classes=free_classes,
            summary_fn=lambda: self.telemetry.summary(now=self._t))
        for a in self.health.drain_new():
            self.log.append_alert(a.to_record())

    def begin(self, events, trace_name: str = "trace") -> None:
        """Ingest all external events (appending each to the log) and
        register the initial fleet — everything ``run`` does before the
        first heap pop.  ``recover`` uses this for genesis replay."""
        self._trace_name = trace_name
        self.log.set_meta(trace_name=trace_name)
        for ev in events:
            self.log.append_external(ev)
            self._ingest(ev)
        for s in self.fleet.slices:
            self.telemetry.on_device_join(0.0, s.slice_id, s.speed,
                                          initial=True)

    def run(self, trace: ChurnTrace, horizon: float = np.inf) -> StreamResult:
        """Replay one trace to completion (or ``horizon``) and return the
        trial log + telemetry.  A fresh engine per run."""
        self.begin(trace, trace_name=trace.name)
        return self._drain(horizon)

    def resume(self, horizon: float = np.inf) -> StreamResult:
        """Continue a begun or restored engine to completion — the second
        half of ``run``.  ``recover(...)`` + ``resume()`` must reproduce the
        uninterrupted ``run`` exactly (the replay oracle)."""
        return self._drain(horizon)

    def _drain(self, horizon: float) -> StreamResult:
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t >= horizon:
                break
            self._t = t
            self.event_index += 1
            # one trace per processed event; the id IS the event index, so
            # the log's trace field and a replayed suffix's span tree both
            # correlate for free
            self.tracer.begin_trace(self.event_index)
            if self.forensics is not None:
                self.forensics.begin_event(t, self.event_index)
            self._fault("before")
            with self.tracer.span("event", kind=kind):
                if kind == "arrive":
                    self._handle_arrive(*payload)
                elif kind == "depart":
                    self._handle_depart(*payload)
                elif kind == "finish":
                    self._handle_finish(*payload)
                elif kind == "slice_fail":
                    self._handle_slice_fail(*payload)
                elif kind == "recover":
                    self._handle_recover(*payload)
                elif kind == "timeout":
                    self._handle_timeout(*payload)
                elif kind == "retry":
                    self._handle_retry(*payload)
                elif kind == "hang":
                    self._handle_hang(*payload)
                elif kind == "poison":
                    self._handle_poison(*payload)
                elif kind == "mesh_shrink":
                    self._handle_mesh_shrink(*payload)
                else:
                    self._dispatch_extra(kind, payload)
                self.log.append_processed(self.event_index, t, kind,
                                          self._encode_payload(kind, payload),
                                          trace=self.tracer.current_trace)
                self._post_event(kind)
                # simultaneous arrivals are admitted as one batch before any
                # launch — this is what makes the churn-free replay line up
                # with simulate()'s pre-built warm-start queue
                if not (kind == "arrive" and self._heap
                        and self._heap[0][0] == t
                        and self._heap[0][2] == "arrive"):
                    self._try_launch(horizon)
            if self.metrics is not None:
                self._m_events.inc()
                self._m_queue.set(len(self._admission_queue))
            # accounting before the health tick: a capacity sample may fire
            # the memory watchdog, and draining in the same event keeps the
            # alert adjacent to the sample that caused it
            if self.accounting is not None:
                self.accounting.tick(self._t, self.event_index, self)
            if self.health is not None:
                self._health_tick()
            if self.exporter is not None:
                self.exporter.tick(self._t, self.event_index)
            self._fault("after")
            self._maybe_snapshot()

        self.telemetry.on_end(self._t, self.fleet.num_devices)
        if self.metrics is not None:
            if self._decision_seconds > 0:
                self.metrics.gauge("engine.decisions_per_s").set(
                    self._decisions / self._decision_seconds)
            for d, row in self.telemetry.per_device().items():
                self.metrics.gauge(f"device.{d}.busy_fraction").set(
                    row["utilization"])
        if self.accounting is not None:
            # one closing sample so short runs still publish gauges (and
            # the exporter's final record below carries them)
            self.accounting.sample(self._t, self.event_index, self)
        if self.health is not None:
            for a in self.health.drain_new():
                self.log.append_alert(a.to_record())
        if self.exporter is not None:
            # after the end-of-run gauges so the closing record carries them
            self.exporter.final(self._t, self.event_index)
        return StreamResult(
            trace_name=self._trace_name, policy=self.policy,
            num_devices=self.fleet.num_devices, trials=self._trials,
            end_time=self._t, decisions=self._decisions,
            decision_seconds=self._decision_seconds,
            telemetry=self.telemetry, tenants=self._tenants,
            compaction_moves=self._compaction_moves,
            policy_launches=self._policy_launches)

    # ---- snapshot / restore (event sourcing, DESIGN.md §12) ----------------

    def _maybe_snapshot(self) -> None:
        if (self.snapshot_root is not None and self.snapshot_every
                and self.event_index % self.snapshot_every == 0):
            self.save_snapshot()

    def save_snapshot(self):
        """Write a full-state snapshot at the current event boundary via
        ``checkpoint.store.save_checkpoint`` (atomic publish).  Snapshot
        latency is metrics-only, deliberately NOT a span: the replay oracle
        compares span trees, and a durable run snapshots where its
        uninterrupted reference does not."""
        from repro.checkpoint.store import save_checkpoint
        t0 = _time.perf_counter()
        arrays, meta = self._snapshot_state()
        out = save_checkpoint(self.snapshot_root, self.event_index,
                              arrays, meta)
        if self.metrics is not None:
            self._m_snapshot_s.observe(_time.perf_counter() - t0)
        return out

    def _encode_payload(self, kind: str, payload: tuple) -> list:
        """JSON-able encoding of one heap payload (snapshot + processed-log
        record).  Tenant runtimes are referenced by stable tenant_key; the
        devplane engine extends this for device lifecycle kinds."""
        if kind == "arrive":
            return [payload[0].key]
        if kind in ("depart", "finish", "slice_fail", "recover",
                    "timeout", "retry", "hang", "poison", "mesh_shrink"):
            return list(payload)
        raise AssertionError(f"unknown event kind {kind!r}")

    def _decode_payload(self, kind: str, data: list) -> tuple:
        """Inverse of :meth:`_encode_payload`; runs after ``_tenants`` is
        rebuilt so arrive entries resolve to the live runtime objects."""
        if kind == "arrive":
            return (self._tenants[data[0]],)
        if kind in ("depart", "finish", "slice_fail", "recover",
                    "timeout", "retry", "hang", "poison", "mesh_shrink"):
            return tuple(data)
        raise AssertionError(f"unknown event kind {kind!r}")

    def _snapshot_extra(self) -> dict:
        """Subclass state to include in snapshots (devplane overrides)."""
        return {}

    def _restore_extra(self, extra: dict) -> None:
        """Inverse of :meth:`_snapshot_extra`."""

    def _snapshot_state(self) -> tuple[dict, dict]:
        arrays, cp_meta = self.cp.state_snapshot()
        tr = self._trials
        arrays.update({
            "trials/model": np.asarray([t.model for t in tr], np.int64),
            "trials/tenant_key": np.asarray([t.tenant_key for t in tr],
                                            np.int64),
            "trials/local_model": np.asarray([t.local_model for t in tr],
                                             np.int64),
            "trials/user_hint": np.asarray([t.user_hint for t in tr],
                                           np.int64),
            "trials/device": np.asarray([t.device for t in tr], np.int64),
            "trials/start": np.asarray([t.start for t in tr], np.float64),
            "trials/end": np.asarray([t.end for t in tr], np.float64),
            "trials/z": np.asarray([t.z if t.z is not None else 0.0
                                    for t in tr], np.float64),
            "trials/has_z": np.asarray([t.z is not None for t in tr], bool),
        })
        meta = {
            "engine": {
                "t": self._t, "seq": self._seq,
                "event_index": self.event_index,
                "trace_name": self._trace_name,
                "decisions": self._decisions,
                "decision_seconds": self._decision_seconds,
                "policy_launches": self._policy_launches,
                "compaction_moves": self._compaction_moves,
                "compaction_move_counts": list(self.compaction_move_counts),
                "departures": self._departures,
                "live_models": self._live_models,
                "free": list(self._free),
                "pending": [[k, g] for k, g in self._pending],
                "admission_queue": [q.key for q in self._admission_queue],
                "cancelled": sorted(self._cancelled),
                "hung": sorted(self._hung),
                "poisoned": sorted(self._poisoned),
                "retry_attempts": [[k, li, n] for (k, li), n
                                   in self._retry_attempts.items()],
                "heap": [[t, seq, kind, self._encode_payload(kind, payload)]
                         for t, seq, kind, payload in self._heap],
            },
            "tenants": {str(tr_.key): [tr_.admitted_at, tr_.departed,
                                       tr_.tenant_id, tr_.model_start]
                        for tr_ in self._tenants.values()},
            "fleet": [[s.slice_id, s.chips, s.speed, s.healthy, s.busy_until,
                       s.current_trial, s.cls, s.retired]
                      for s in self.fleet.slices],
            "telemetry": self.telemetry.state_dict(),
            "cp": cp_meta,
            "extra": self._snapshot_extra(),
            # live-plane cursors (DESIGN.md §14): detector state and the
            # export window cursor are pure functions of the event stream,
            # so persisting them keeps a recovered run's alert/export
            # suffix identical to the uninterrupted run.  Alert/forensics
            # RECORDS never ride here — their durable prefix lives in the
            # log's alerts.jsonl / the forensics JSONL stream.
            "obs": {
                "health": (self.health.state_dict()
                           if self.health is not None else None),
                "export": (self.exporter.state_dict()
                           if self.exporter is not None else None),
                "capacity": (self.accounting.state_dict()
                             if self.accounting is not None else None),
            },
        }
        return arrays, meta

    def _restore_state(self, arrays: dict, meta: dict,
                       arrive_by_key: dict) -> None:
        """Load a :meth:`_snapshot_state` snapshot into this freshly
        constructed, identically configured engine.  ``arrive_by_key`` maps
        tenant_key -> TenantArrive from the event log — snapshots reference
        tenants by key instead of re-storing their (large) prior blocks."""
        me = meta["engine"]
        self._t = me["t"]
        self._seq = me["seq"]
        self.event_index = me["event_index"]
        self._trace_name = me["trace_name"]
        self._decisions = me["decisions"]
        self._decision_seconds = me["decision_seconds"]
        self._policy_launches = me["policy_launches"]
        self._compaction_moves = me["compaction_moves"]
        self.compaction_move_counts = list(me["compaction_move_counts"])
        self._departures = me["departures"]
        self._live_models = me["live_models"]
        self._free = list(me["free"])
        self._pending = [(k, g) for k, g in me["pending"]]
        self._cancelled = set(me["cancelled"])
        # tolerant restore: pre-supervision snapshots lack these keys
        self._hung = set(me.get("hung", []))
        self._poisoned = set(me.get("poisoned", []))
        self._retry_attempts = {(k, li): n for k, li, n
                                in me.get("retry_attempts", [])}

        self._tenants = {}
        for key_s, (admitted_at, departed, tid, mstart) in \
                meta["tenants"].items():
            key = int(key_s)
            self._tenants[key] = _TenantRuntime(
                key=key, arrive=arrive_by_key[key], admitted_at=admitted_at,
                departed=departed, tenant_id=tid, model_start=mstart)
        self._admission_queue = [self._tenants[k]
                                 for k in me["admission_queue"]]
        self._owner_of_model = {}
        for tr in self._tenants.values():
            if tr.tenant_id is not None and not tr.departed:
                for g in range(tr.model_start,
                               tr.model_start + tr.arrive.num_models):
                    self._owner_of_model[g] = tr
        # the stored list is a valid heap; re-decoding in place preserves
        # the exact arrangement (and (t, seq) is a total order, so payloads
        # are never compared)
        self._heap = [(t, seq, kind, self._decode_payload(kind, data))
                      for t, seq, kind, data in me["heap"]]

        z = arrays["trials/z"]
        has_z = arrays["trials/has_z"]
        self._trials = [
            StreamTrial(
                model=int(arrays["trials/model"][i]),
                tenant_key=int(arrays["trials/tenant_key"][i]),
                local_model=int(arrays["trials/local_model"][i]),
                user_hint=int(arrays["trials/user_hint"][i]),
                device=int(arrays["trials/device"][i]),
                start=float(arrays["trials/start"][i]),
                end=float(arrays["trials/end"][i]),
                z=float(z[i]) if has_z[i] else None)
            for i in range(len(z))]

        self.fleet.slices[:] = [
            DeviceSlice(slice_id=sid, chips=chips, speed=speed,
                        healthy=healthy, busy_until=busy_until,
                        current_trial=current_trial, cls=cls, retired=retired)
            for sid, chips, speed, healthy, busy_until, current_trial, cls,
            retired in meta["fleet"]]

        self.telemetry.load_state(meta["telemetry"])
        self.cp.load_state(arrays, meta["cp"])
        self._restore_extra(meta["extra"])
        # tolerant restore: snapshots from health-less runs lack the key
        obs = meta.get("obs") or {}
        if self.health is not None and obs.get("health") is not None:
            self.health.load_state(obs["health"])
        if self.exporter is not None and obs.get("export") is not None:
            self.exporter.load_state(obs["export"])
        if self.accounting is not None and obs.get("capacity") is not None:
            self.accounting.load_state(obs["capacity"])
