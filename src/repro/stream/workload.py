"""Churn traces: the external event streams the streaming engine replays.

A :class:`ChurnTrace` is a time-sorted tuple of external events:

  * :class:`TenantArrive` — a tenant session starts; the event carries the
    tenant's whole TSHB block (prior covariance, prior mean, costs, and the
    ground-truth ``z`` the simulation reveals on observation);
  * :class:`TenantDepart` — the session ends (the engine retires the
    tenant's GP block and returns its unobserved models to nowhere);
  * :class:`SliceFail`   — a device slice dies for ``downtime`` seconds,
    killing its in-flight trial (the model returns to the unselected pool).

:func:`poisson_churn_trace` generates the service-provider workload the
Ease.ml setting motivates: Poisson arrivals, heavy-tailed (Pareto) session
lengths, Zipf-skewed candidate-set sizes, per-tenant Matérn-5/2 priors —
everything seeded, so traces replay bit-identically.
:func:`trace_from_problem` freezes an offline :class:`~repro.core.tenancy.Problem`
into a churn-free trace (all tenants at t=0, nobody departs) — the
equivalence bridge to ``scheduler.simulate``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.tenancy import Problem, _matern_block_chol, _matern_draw


@dataclass(frozen=True)
class TenantArrive:
    at: float
    tenant_key: int
    K_block: np.ndarray      # (m, m) prior covariance over the candidate set
    mu0: np.ndarray          # (m,) prior mean
    cost: np.ndarray         # (m,) c(x), virtual seconds
    z_true: np.ndarray       # (m,) ground truth, revealed on observation

    @property
    def num_models(self) -> int:
        return len(self.mu0)


@dataclass(frozen=True)
class TenantDepart:
    at: float
    tenant_key: int


@dataclass(frozen=True)
class SliceFail:
    at: float
    slice_id: int
    downtime: float


@dataclass(frozen=True)
class DeviceJoin:
    """A new device slice arrives at runtime (scale-up / spot grant).  The
    engine appends it to the fleet — slice ids are append-only, so the
    trace generator can predict the id of the k-th join as
    ``initial_slices + k``."""
    at: float
    chips: int = 16
    speed: float = 1.0
    cls: str = "base"


@dataclass(frozen=True)
class DeviceLeave:
    """Permanent decommission of a slice: the in-flight trial dies exactly
    like a slice failure (its model returns to the unselected pool), but
    the slice never recovers."""
    at: float
    slice_id: int


@dataclass(frozen=True)
class DevicePreempt:
    """Spot-market / priority eviction: the in-flight trial is killed and
    re-queued like a slice failure, but the slice stays healthy and is
    immediately schedulable again (no downtime)."""
    at: float
    slice_id: int


@dataclass(frozen=True)
class TrialHang:
    """The trial currently running on ``slice_id`` hangs: it will never
    produce its completion.  The device stays busy forever unless trial
    supervision (``timeout_factor``) rescues it — the failure mode the
    paper's always-returns assumption excludes."""
    at: float
    slice_id: int


@dataclass(frozen=True)
class TrialPoison:
    """The trial currently running on ``slice_id`` completes on schedule but
    returns a non-finite loss (NaN) — e.g. a diverged training run.  The
    engine's GP-ingest guard must reject it instead of corrupting the
    Cholesky."""
    at: float
    slice_id: int


@dataclass(frozen=True)
class MeshShrink:
    """The scoring mesh loses devices mid-run: re-shard resident posterior
    slots onto a ``num_shards``-device mesh through the checkpoint path
    (falling back to fused scoring at ``num_shards == 1``)."""
    at: float
    num_shards: int


Event = (TenantArrive | TenantDepart | SliceFail
         | DeviceJoin | DeviceLeave | DevicePreempt
         | TrialHang | TrialPoison | MeshShrink)

# event types a ChaosTrace's seeded overlay may inject (the .twin() filter)
CHAOS_EVENT_TYPES = (SliceFail, DeviceLeave, DevicePreempt,
                     TrialHang, TrialPoison, MeshShrink)


@dataclass(frozen=True)
class ChurnTrace:
    """Time-sorted external events plus bookkeeping for telemetry."""

    events: tuple[Event, ...]
    name: str = "trace"

    def __post_init__(self):
        ats = [e.at for e in self.events]
        if ats != sorted(ats):
            raise ValueError("trace events must be time-sorted")

    @property
    def num_sessions(self) -> int:
        return sum(1 for e in self.events if isinstance(e, TenantArrive))

    @property
    def num_events(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


def zipf_candidate_sizes(
    rng: np.random.Generator, count: int, s: float = 1.6,
    m_min: int = 2, m_max: int = 50,
) -> np.ndarray:
    """Zipf-skewed candidate-set sizes: most tenants bring a few models, a
    heavy tail brings many (clipped to [m_min, m_max])."""
    if s <= 1.0:
        raise ValueError("zipf exponent must be > 1")
    raw = rng.zipf(s, size=count)
    return np.clip(m_min * raw, m_min, m_max).astype(int)


def poisson_churn_trace(
    num_sessions: int = 200,
    arrival_rate: float = 1.0,
    seed: int = 0,
    *,
    session_scale: float = 40.0,
    pareto_alpha: float = 1.5,
    zipf_s: float = 1.6,
    m_min: int = 2,
    m_max: int = 50,
    length_scale: float = 0.2,
    kernel_variance: float = 0.04,
    cost: str = "uniform",
    num_failure_slices: int = 0,
    failure_downtime: float = 5.0,
    name: str | None = None,
) -> ChurnTrace:
    """The service-provider workload: N ≫ M tenant sessions over time.

    Arrivals are Poisson(``arrival_rate``); session lengths are Pareto
    (heavy-tailed: ``(1 + pareto(alpha)) * session_scale``); candidate-set
    sizes are Zipf-skewed; each tenant's block is a Matérn-5/2 prior with a
    ground-truth sample drawn from it (the Fig-5 generative model, per
    tenant).  ``cost`` is ``"uniform"`` (all 1) or ``"lognormal"``.
    ``num_failure_slices > 0`` sprinkles that many SliceFail events over
    slices [0, num_failure_slices) across the arrival window.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / arrival_rate, size=num_sessions)
    arrive_at = np.cumsum(gaps)
    lengths = (1.0 + rng.pareto(pareto_alpha, size=num_sessions)) * session_scale
    sizes = zipf_candidate_sizes(rng, num_sessions, zipf_s, m_min, m_max)

    # one Cholesky per distinct block size (the expensive part is shared)
    chol_cache: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    events: list[Event] = []
    for i in range(num_sessions):
        m = int(sizes[i])
        if m not in chol_cache:
            chol_cache[m] = _matern_block_chol(m, length_scale, kernel_variance)
        K_block, L = chol_cache[m]
        z = _matern_draw(rng, L)
        if cost == "uniform":
            c = np.ones(m)
        elif cost == "lognormal":
            c = rng.lognormal(mean=0.0, sigma=0.5, size=m)
        else:
            raise ValueError(cost)
        events.append(TenantArrive(
            at=float(arrive_at[i]), tenant_key=i, K_block=K_block,
            mu0=np.zeros(m), cost=c, z_true=z))
        events.append(TenantDepart(
            at=float(arrive_at[i] + lengths[i]), tenant_key=i))

    if num_failure_slices > 0:
        horizon = float(arrive_at[-1])
        for s in range(num_failure_slices):
            events.append(SliceFail(
                at=float(rng.uniform(0.0, horizon)), slice_id=s,
                downtime=failure_downtime))

    events.sort(key=lambda e: e.at)
    return ChurnTrace(
        events=tuple(events),
        name=name or f"poisson-{num_sessions}sessions-s{seed}")


def device_churn_trace(
    num_sessions: int = 200,
    arrival_rate: float = 1.0,
    seed: int = 0,
    *,
    initial_slices: int = 8,
    join_classes: tuple[tuple[str, int, float], ...] = (("base", 16, 1.0),),
    join_rate: float = 0.0,
    leave_rate: float = 0.0,
    preempt_rate: float = 0.0,
    device_seed: int | None = None,
    name: str | None = None,
    **tenant_kw,
) -> ChurnTrace:
    """Tenant churn *plus* device churn, both seeded (DESIGN.md §11).

    The tenant side is exactly :func:`poisson_churn_trace` (same seed =>
    bit-identical tenant events); the device side overlays three Poisson
    processes across the arrival window:

      * joins at ``join_rate`` — each draws a ``(cls, chips, speed)`` from
        ``join_classes`` uniformly; the k-th join will occupy slice id
        ``initial_slices + k`` (ids are append-only);
      * leaves at ``leave_rate`` — each picks a uniformly random slice that
        still exists (initial or joined, not yet left);
      * preempts at ``preempt_rate`` — each picks a uniformly random
        not-yet-left slice (the engine tolerates a preempt racing a leave).

    ``device_seed`` defaults to ``seed + 1`` so the device overlay never
    perturbs the tenant stream.
    """
    base = poisson_churn_trace(num_sessions, arrival_rate, seed, **tenant_kw)
    events: list[Event] = list(base.events)
    # span the overlay over the ARRIVAL window (same convention as the
    # SliceFail sprinkling), not the heavy-tailed depart horizon — Pareto
    # session tails would otherwise inflate device churn by orders of
    # magnitude after work has stopped arriving
    horizon = max((e.at for e in events if isinstance(e, TenantArrive)),
                  default=0.0)
    rng = np.random.default_rng(seed + 1 if device_seed is None else device_seed)

    dev_events: list[Event] = []
    for rate, kind in ((join_rate, "join"), (leave_rate, "leave"),
                       (preempt_rate, "preempt")):
        if rate <= 0:
            continue
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon:
                break
            dev_events.append((t, kind))
    dev_events.sort(key=lambda e: e[0])

    # replay the device population to give leaves/preempts valid targets
    alive = list(range(initial_slices))
    next_id = initial_slices
    out: list[Event] = []
    for t, kind in dev_events:
        if kind == "join":
            cls, chips, speed = join_classes[int(rng.integers(len(join_classes)))]
            out.append(DeviceJoin(at=t, chips=chips, speed=float(speed),
                                  cls=cls))
            alive.append(next_id)
            next_id += 1
        elif kind == "leave":
            if len(alive) <= 1:
                continue            # never drain the fleet entirely
            sid = alive.pop(int(rng.integers(len(alive))))
            out.append(DeviceLeave(at=t, slice_id=sid))
        else:
            if not alive:
                continue
            sid = alive[int(rng.integers(len(alive)))]
            out.append(DevicePreempt(at=t, slice_id=sid))

    events.extend(out)
    events.sort(key=lambda e: e.at)
    return ChurnTrace(
        events=tuple(events),
        name=name or f"devchurn-{num_sessions}sessions-s{seed}")


@dataclass(frozen=True)
class ChaosTrace(ChurnTrace):
    """A churn trace with a seeded chaos overlay (hang / poison / flake /
    device-loss / mesh-shrink schedules).  ``twin()`` strips every
    chaos-class event, recovering the failure-free trace the benchmark's
    bounded-degradation claim is measured against."""

    def twin(self, name: str | None = None) -> ChurnTrace:
        keep = tuple(e for e in self.events
                     if not isinstance(e, CHAOS_EVENT_TYPES))
        return ChurnTrace(events=keep, name=name or f"{self.name}-twin")


def chaos_trace(
    num_sessions: int = 50,
    arrival_rate: float = 1.0,
    seed: int = 0,
    *,
    initial_slices: int = 4,
    hang_rate: float = 0.0,
    poison_rate: float = 0.0,
    flake_rate: float = 0.0,
    loss_rate: float = 0.0,
    flake_downtime: float = 5.0,
    shrink_at: float | None = None,
    shrink_shards: int | None = None,
    chaos_seed: int | None = None,
    name: str | None = None,
    **tenant_kw,
) -> ChaosTrace:
    """Tenant churn plus a seeded chaos overlay (DESIGN.md §16).

    The tenant side is exactly :func:`poisson_churn_trace` (same seed =>
    bit-identical tenant events); the chaos side overlays independent
    Poisson processes across the ARRIVAL window (the ``device_churn_trace``
    convention):

      * hangs at ``hang_rate``     — ``TrialHang`` on a random alive slice;
      * poisons at ``poison_rate`` — ``TrialPoison`` on a random alive slice;
      * flakes at ``flake_rate``   — ``SliceFail`` (self-healing after
        ``flake_downtime``) on a random alive slice;
      * losses at ``loss_rate``    — ``DeviceLeave`` (permanent) on a random
        alive slice, never draining the fleet below one device.

    ``shrink_at``/``shrink_shards`` optionally schedule one deterministic
    :class:`MeshShrink`.  ``chaos_seed`` defaults to ``seed + 2`` (distinct
    from ``device_churn_trace``'s ``seed + 1``) so the overlay never
    perturbs the tenant stream and composes with device churn.
    """
    base = poisson_churn_trace(num_sessions, arrival_rate, seed, **tenant_kw)
    events: list[Event] = list(base.events)
    horizon = max((e.at for e in events if isinstance(e, TenantArrive)),
                  default=0.0)
    rng = np.random.default_rng(seed + 2 if chaos_seed is None else chaos_seed)

    chaos: list[tuple[float, str]] = []
    for rate, kind in ((hang_rate, "hang"), (poison_rate, "poison"),
                       (flake_rate, "flake"), (loss_rate, "loss")):
        if rate <= 0:
            continue
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon:
                break
            chaos.append((t, kind))
    chaos.sort(key=lambda e: e[0])

    # replay the device population so losses keep targeting slices that
    # still exist (and hangs/poisons/flakes aim at alive slices too)
    alive = list(range(initial_slices))
    out: list[Event] = []
    for t, kind in chaos:
        if not alive:
            break
        sid = alive[int(rng.integers(len(alive)))]
        if kind == "hang":
            out.append(TrialHang(at=t, slice_id=sid))
        elif kind == "poison":
            out.append(TrialPoison(at=t, slice_id=sid))
        elif kind == "flake":
            out.append(SliceFail(at=t, slice_id=sid,
                                 downtime=flake_downtime))
        else:
            if len(alive) <= 1:
                continue            # never drain the fleet entirely
            alive.remove(sid)
            out.append(DeviceLeave(at=t, slice_id=sid))
    if shrink_at is not None:
        if shrink_shards is None or shrink_shards < 1:
            raise ValueError("shrink_at requires shrink_shards >= 1")
        out.append(MeshShrink(at=float(shrink_at),
                              num_shards=int(shrink_shards)))

    events.extend(out)
    events.sort(key=lambda e: e.at)
    return ChaosTrace(
        events=tuple(events),
        name=name or f"chaos-{num_sessions}sessions-s{seed}")


def trace_from_problem(problem: Problem, at: float = 0.0) -> ChurnTrace:
    """Freeze an offline Problem into a churn-free trace: every tenant
    arrives at ``at`` in tenant order, nobody departs.  Requires disjoint
    candidate sets (every generator in ``tenancy.py`` qualifies).  Replaying
    this trace reproduces ``scheduler.simulate`` exactly (tests/test_stream.py).
    """
    mem = np.asarray(problem.membership, bool)
    if (mem.sum(axis=0) != 1).any():
        raise ValueError("trace_from_problem requires disjoint candidate sets")
    events = []
    for u in range(problem.num_users):
        ids = np.nonzero(mem[u])[0]
        events.append(TenantArrive(
            at=at, tenant_key=u,
            K_block=problem.K[np.ix_(ids, ids)],
            mu0=problem.mu0[ids], cost=problem.cost[ids],
            z_true=problem.z_true[ids]))
    return ChurnTrace(events=tuple(events), name=f"{problem.name}-frozen")
