"""Streaming control plane: online multi-device, multi-tenant GP-EI.

The offline engines (``core.scheduler``, ``core.sim_batched``) assume a
closed world — N tenants known at t=0, the episode ends when every model is
observed.  This package is the open-world counterpart the ROADMAP's
production service needs: tenants arrive and depart continuously
(``workload.py`` generates seeded churn traces), an event loop over a device
``Fleet`` admits, schedules, and observes them (``engine.py``), and a
telemetry sink records the service-level metrics — per-tenant regret, device
utilization, admission-queue depth, time-to-first-observation percentiles
(``telemetry.py``).

The per-event math is the same ``core.control_plane.ControlPlane`` the
offline simulators use; with churn disabled the engine reproduces
``scheduler.simulate``'s trial sequence exactly (tests/test_stream.py).
Long-running services recycle model/tenant slots and can run the scoring
pass across a device mesh (``scorer="sharded"``, ``repro.shardgp``) with an
identical decision sequence (tests/test_shardgp.py).  See DESIGN.md §9–§10.
The *device* side goes elastic in ``repro.devplane``: device classes,
DeviceJoin/Leave/Preempt churn, autoscale, and joint batched (device,
model) assignment — DESIGN.md §11.

The control plane is event-sourced (``eventlog.py``, DESIGN.md §12): every
run appends its external and processed events to an append-only
:class:`EventLog`, periodic full-state snapshots go through
``repro.checkpoint.store``, and ``recover(factory, snapshot_root, log)`` +
``engine.resume()`` reproduces the uninterrupted run byte-identically from
any crash point — the universal correctness property the crash-anywhere
suite (tests/test_eventlog.py) fuzzes.
"""

from .engine import StreamEngine, StreamResult, StreamTrial  # noqa: F401
from .eventlog import (  # noqa: F401
    EventLog,
    FaultInjector,
    SimulatedCrash,
    first_divergence,
    recover,
)
from .telemetry import TelemetrySink  # noqa: F401
from .workload import (  # noqa: F401
    ChaosTrace,
    ChurnTrace,
    DeviceJoin,
    DeviceLeave,
    DevicePreempt,
    MeshShrink,
    SliceFail,
    TenantArrive,
    TenantDepart,
    TrialHang,
    TrialPoison,
    chaos_trace,
    device_churn_trace,
    poisson_churn_trace,
    trace_from_problem,
)
