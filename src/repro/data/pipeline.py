"""Deterministic, host-sharded synthetic data pipeline.

Serves every arch family (tokens / patches+tokens / frames) with:

  * deterministic generation keyed by (seed, host_id, step) — a restarted or
    re-sharded job replays the exact stream (checkpoint/restart safety);
  * per-host sharding: each host draws only its slice of the global batch
    (host h owns rows [h*B/H, (h+1)*B/H));
  * background prefetch (double-buffered thread) to hide generation latency;
  * tenant-conditioned distributions (Zipf exponent per tenant) so the
    multi-tenant service's datasets genuinely differ.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from repro.models.model import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3           # tenant-specific skew
    num_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLMStream:
    """Zipf-distributed token stream with a deterministic per-step RNG."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + self.cfg.host_id) * 1_000_003 + step)

    def batch_at(self, step: int) -> dict:
        c, m = self.cfg, self.model_cfg
        rng = self._rng(step)
        B, S = c.host_batch, c.seq_len

        def zipf_tokens(shape, vocab):
            # bounded Zipf via inverse-CDF on a truncated support
            ranks = np.arange(1, vocab + 1, dtype=np.float64)
            probs = ranks ** (-c.zipf_a)
            probs /= probs.sum()
            return rng.choice(vocab, size=shape, p=probs).astype(np.int32)

        if m.frontend == "patches":
            ni = m.num_frontend_tokens
            toks = zipf_tokens((B, S - ni), m.vocab_size)
            return {
                "patches": rng.standard_normal((B, ni, m.frontend_dim)).astype(np.float32),
                "tokens": toks,
                "labels": np.roll(toks, -1, axis=1),
            }
        if m.frontend == "frames":
            return {
                "frames": rng.standard_normal((B, S, m.frontend_dim)).astype(np.float32),
                "labels": zipf_tokens((B, S, m.num_lm_heads), m.vocab_size),
            }
        toks = zipf_tokens((B, S), m.vocab_size)
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}


def make_batch_iterator(cfg: DataConfig, model_cfg: ModelConfig, start_step: int = 0):
    """Prefetching iterator; resume from ``start_step`` after a restart."""
    stream = SyntheticLMStream(cfg, model_cfg)
    q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, stream.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
