from .pipeline import DataConfig, SyntheticLMStream, make_batch_iterator  # noqa: F401
