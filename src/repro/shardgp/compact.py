"""Compaction / rebalance planner for the sharded index space.

Slot *reuse* (layout.RangeAllocator) already bounds memory; what it cannot
bound is *skew*: under heavy churn the live blocks can pile up in a few
shard spans while others sit empty, and the shard_map scoring pass runs at
the speed of the fullest shard.  ``plan_moves`` restores the load-imbalance
bound by relocating whole tenant blocks from overloaded spans into free
ranges of underloaded ones.

The planner only *plans against the layout*; the caller
(``ControlPlane.compact``) owns moving the actual state (GP block indices,
membership columns, selected/observed/cost values) and reporting the old→new
id mapping to whoever holds global model ids (the streaming engine remaps
its launch queue and ownership maps).

Only blocks the caller marked movable are touched — the control plane
excludes tenants with in-flight trials, because an in-flight trial's global
model id is baked into its completion event.

Each applied move strictly lowers the donor span's load without raising any
span above it (sum-of-squares of span loads strictly decreases), so the loop
terminates; ``max_moves`` is a belt-and-braces cap, not the stop condition.
"""

from __future__ import annotations

from .layout import ShardLayout

DEFAULT_MAX_IMBALANCE = 1.25


def plan_moves(
    layout: ShardLayout,
    movable: set[int] | frozenset[int],
    max_imbalance: float = DEFAULT_MAX_IMBALANCE,
    max_moves: int | None = None,
) -> list[tuple[int, int, int]]:
    """Relocate movable blocks until ``layout.imbalance() <= max_imbalance``
    or no improving move exists.  Mutates the layout (placements + free
    ranges) and returns ``[(key, old_start, new_start), ...]`` in the order
    applied."""
    if max_imbalance < 1.0:
        raise ValueError(f"max_imbalance must be >= 1, got {max_imbalance}")
    moves: list[tuple[int, int, int]] = []
    cap = max_moves if max_moves is not None else 4 * max(len(layout.blocks), 1)
    while layout.imbalance() > max_imbalance and len(moves) < cap:
        counts = layout.live_counts()
        donor = max(range(layout.num_shards), key=lambda s: (counts[s], -s))
        cands = sorted(
            (k for k in movable if k in layout.blocks
             and layout.shard_of(layout.blocks[k].start) == donor),
            key=lambda k: (-layout.blocks[k].length, k))
        applied = False
        for k in cands:
            m = layout.blocks[k].length
            targets = sorted(
                (s for s in range(layout.num_shards) if s != donor),
                key=lambda s: (counts[s], s))
            for t in targets:
                if counts[t] + m >= counts[donor]:
                    continue    # move would not reduce the donor's lead
                lo, hi = layout.span(t)
                start = layout.alloc.alloc(m, lo, hi)
                if start is None:
                    continue
                old = layout.relocate(k, start)
                moves.append((k, old.start, start))
                applied = True
                break
            if applied:
                break
        if not applied:
            break
    return moves
