"""Index-space layout for the sharded scoring plane (DESIGN.md §10).

Two pieces, both host-side bookkeeping (no jax):

* :class:`RangeAllocator` — a contiguous-range allocator over the model
  index space ``[0, capacity)`` with a coalescing free list.  This is what
  ends DESIGN.md §9's "append-only" index space: ``retire_tenant`` returns a
  block's slots here and the next ``add_tenant`` reuses them, so a
  long-running service's readout buffers stay O(live-model cap) instead of
  O(models ever admitted).

* :class:`ShardLayout` — partitions the index space into ``num_shards``
  contiguous spans of ``shard_capacity`` slots each (span ``s`` owns
  ``[s*C, (s+1)*C)``) and places every tenant block *entirely inside one
  span*, least-loaded span first.  The sharded scorer maps span ``s`` to
  mesh device ``s`` (``P("shard")`` over the model axis), so block locality
  here is what makes a GP observation touch exactly one device's slice.

  Growth doubles ``shard_capacity``.  Because every new span boundary
  (multiple of ``2C``) is also an old boundary (multiple of ``C``), a block
  that never straddled an old boundary never straddles a new one — existing
  global ids stay valid across growth, only their span *assignment* shifts
  (which :meth:`ShardLayout.live_counts` recomputes from the block registry).

With ``num_shards=1`` the layout degenerates to a plain first-fit allocator,
so the single-device control plane runs the identical allocation policy —
the decision-equivalence contract between ``scorer="fused"`` and
``scorer="sharded"`` depends on both seeing the same index space.
"""

from __future__ import annotations

from dataclasses import dataclass


class RangeAllocator:
    """First-fit contiguous-range allocator with a coalescing free list.

    Deterministic: ``alloc`` always returns the lowest free address that
    fits, so identical churn sequences produce identical index spaces.
    """

    def __init__(self, capacity: int = 0):
        self.capacity = 0
        self._free: list[tuple[int, int]] = []   # sorted (start, length)
        if capacity:
            self.grow(capacity)

    def grow(self, new_capacity: int) -> None:
        """Extend the address space to ``new_capacity`` slots."""
        if new_capacity <= self.capacity:
            return
        self.free(self.capacity, new_capacity - self.capacity)
        self.capacity = new_capacity

    def alloc(self, m: int, lo: int = 0, hi: int | None = None) -> int | None:
        """Lowest free range of length ``m`` inside ``[lo, hi)``; None if no
        fit.  ``lo``/``hi`` let :class:`ShardLayout` confine a block to one
        shard span."""
        if m <= 0:
            raise ValueError(f"range length must be positive, got {m}")
        hi = self.capacity if hi is None else hi
        for i, (start, length) in enumerate(self._free):
            s = max(start, lo)
            if s + m <= min(start + length, hi):
                before = (start, s - start)
                after = (s + m, start + length - (s + m))
                repl = [r for r in (before, after) if r[1] > 0]
                self._free[i:i + 1] = repl
                return s
            if start >= hi:
                break
        return None

    def free(self, start: int, m: int) -> None:
        """Return ``[start, start+m)`` to the pool, coalescing neighbours."""
        if m <= 0:
            return
        import bisect
        i = bisect.bisect_left(self._free, (start, 0))
        if i > 0 and self._free[i - 1][0] + self._free[i - 1][1] > start:
            raise ValueError(f"double free at {start}")
        if i < len(self._free) and start + m > self._free[i][0]:
            raise ValueError(f"double free at {start}")
        self._free.insert(i, (start, m))
        # coalesce with left and right neighbours
        j = max(i - 1, 0)
        while j + 1 < len(self._free):
            s0, l0 = self._free[j]
            s1, l1 = self._free[j + 1]
            if s0 + l0 == s1:
                self._free[j:j + 2] = [(s0, l0 + l1)]
            elif s1 > start + m:
                break
            else:
                j += 1

    @property
    def free_slots(self) -> int:
        return sum(l for _, l in self._free)

    @property
    def live_slots(self) -> int:
        return self.capacity - self.free_slots


@dataclass(frozen=True)
class BlockPlacement:
    """Where a tenant block lives: global start slot + length."""
    start: int
    length: int

    @property
    def stop(self) -> int:
        return self.start + self.length


class ShardLayout:
    """Shard-span-confined block placement over a RangeAllocator (module
    docstring).  The unit of placement is a tenant block; the registry maps
    an opaque key (the ControlPlane tenant slot) to its placement."""

    def __init__(self, num_shards: int = 1, shard_capacity: int = 64):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self.shard_capacity = max(1, shard_capacity)
        self.alloc = RangeAllocator(num_shards * self.shard_capacity)
        self.blocks: dict[int, BlockPlacement] = {}

    @property
    def capacity(self) -> int:
        return self.num_shards * self.shard_capacity

    def shard_of(self, slot: int) -> int:
        return slot // self.shard_capacity

    def span(self, shard: int) -> tuple[int, int]:
        return shard * self.shard_capacity, (shard + 1) * self.shard_capacity

    def live_counts(self) -> list[int]:
        """Live slots per shard span, recomputed from the block registry
        (span assignment shifts on growth)."""
        counts = [0] * self.num_shards
        for pl in self.blocks.values():
            counts[self.shard_of(pl.start)] += pl.length
        return counts

    def imbalance(self) -> float:
        """max/mean live load over shards (1.0 = perfectly balanced)."""
        counts = self.live_counts()
        total = sum(counts)
        if total == 0 or self.num_shards == 1:
            return 1.0
        return max(counts) / (total / self.num_shards)

    def occupancy(self) -> dict:
        """The capacity plane's structured view (obs/accounting.py): per-
        shard live slot counts plus the aggregate slot budget and the
        load-imbalance index, in one pass over the block registry."""
        counts = self.live_counts()
        live = sum(counts)
        return {
            "per_shard": counts,
            "num_shards": self.num_shards,
            "shard_capacity": self.shard_capacity,
            "slots_total": self.capacity,
            "slots_live": live,
            "slots_free": self.capacity - live,
            "blocks": len(self.blocks),
            "imbalance": self.imbalance(),
        }

    def _grow(self) -> None:
        self.shard_capacity *= 2
        self.alloc.grow(self.capacity)

    def place(self, key: int, m: int) -> int:
        """Place a block of ``m`` slots entirely inside one shard span,
        least-loaded span first (ties: lowest shard id).  Grows (doubling)
        until a span fits it.  Returns the global start slot."""
        if key in self.blocks:
            raise ValueError(f"block key {key} already placed")
        while True:
            counts = self.live_counts()
            order = sorted(range(self.num_shards), key=lambda s: (counts[s], s))
            for s in order:
                lo, hi = self.span(s)
                start = self.alloc.alloc(m, lo, hi)
                if start is not None:
                    self.blocks[key] = BlockPlacement(start, m)
                    return start
            self._grow()

    @classmethod
    def repartition(cls, blocks: dict[int, BlockPlacement],
                    num_shards: int) -> tuple["ShardLayout", dict[int, int]]:
        """Re-place an existing block registry onto a fresh ``num_shards``
        layout — the mesh shrink/regrow path (DESIGN.md §16).

        Blocks are placed in registry insertion order through the normal
        :meth:`place` policy (least-loaded span first, doubling growth), so
        the result is exactly the layout a restart on the new mesh would
        build by admitting the same tenants in the same order.  Returns the
        new layout plus the slot remap ``{old_global_slot: new_global_slot}``
        covering every slot of every block."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        lay = cls(num_shards=num_shards, shard_capacity=1)
        remap: dict[int, int] = {}
        for key, pl in blocks.items():
            start = lay.place(key, pl.length)
            for off in range(pl.length):
                remap[pl.start + off] = start + off
        return lay, remap

    def release(self, key: int) -> BlockPlacement:
        """Free a block's slots back to the allocator."""
        pl = self.blocks.pop(key)
        self.alloc.free(pl.start, pl.length)
        return pl

    def relocate(self, key: int, new_start: int) -> BlockPlacement:
        """Move a block to an already-allocated range at ``new_start``
        (the compaction planner allocates it; see compact.py)."""
        old = self.blocks[key]
        self.blocks[key] = BlockPlacement(new_start, old.length)
        self.alloc.free(old.start, old.length)
        return old
