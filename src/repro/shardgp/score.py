"""The sharded scoring plane: multi-device GP-EI decisions via shard_map.

One decision = GP posterior readout + batched EIrate over every live model +
argmax over the unselected pool.  Single-device, that whole pass competes
with the fleet for one chip; here the *model axis* is partitioned over a
1-D ``("shard",)`` mesh (``repro.launch.mesh.make_scoring_mesh``) and the
decision runs as one ``shard_map`` program:

  1. each shard scores its local slice of the pool — the same math as
     ``ei.choose_next_fused`` (XLA path) or the Pallas kernels
     (``kernels/ops.eirate_topk`` with the block-local top-k epilogue);
  2. each shard reduces its slice to a local top-k (values + global ids);
  3. one small ``all_gather`` of the S*k candidates, then a replicated
     global pick — max value, ties broken by *lowest global id*.

Exactness (DESIGN.md §10): the per-model scores are elementwise in the model
axis, so sharding changes no value; ``lax.top_k`` prefers lower indices on
equal values, and the gathered candidate list is ordered (shard, rank) which
is ascending in global id — so the global pick is bit-identical to
``jnp.argmax`` over the unsharded score vector, including tie-breaking.
The layout half of the contract (both scorers seeing the same index space)
lives in layout.py.

Per-shard state (membership columns, costs) is device-resident and refreshed
only on churn; per-decision inputs (mu, sd, best, selected) stream in each
call.  Shapes are capacity-padded (padding is born selected), so the jitted
program recompiles only when capacity doubles.
"""

from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.ei import NEG_INF, ei_total
from repro.obs import NULL_TRACER
from repro.sharding.rules import SCORING_RULES

# shard_map moved from jax.experimental to the jax namespace (and its
# replication-check kwarg was renamed) across releases; resolve both here so
# the pinned container jax and current releases run the same code.
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map
_SM_PARAMS = inspect.signature(shard_map).parameters
if "check_rep" in _SM_PARAMS:
    _NO_REP_CHECK = {"check_rep": False}
elif "check_vma" in _SM_PARAMS:  # pragma: no cover
    _NO_REP_CHECK = {"check_vma": False}
else:  # pragma: no cover
    _NO_REP_CHECK = {}

SCORE_KERNELS = ("xla", "pallas", "pallas_topk")

# PartitionSpecs derived from the logical-axis table (sharding/rules.py),
# not hard-coded mesh axes — the same knob the data plane turns.
P_MODELS = SCORING_RULES.mesh_axes(("models",))
P_TENANTS = SCORING_RULES.mesh_axes(("tenants",))
P_MEMBER = SCORING_RULES.mesh_axes(("tenants", "models"))
P_W = SCORING_RULES.mesh_axes(("obs", "models"))
P_OBS = SCORING_RULES.mesh_axes(("obs",))


def _global_pick(allv: jax.Array, allg: jax.Array, k: int):
    """Top-k of the gathered (S*k,) candidates.  The flat order is
    (shard, rank)-major = ascending global id at equal value, and lax.top_k
    keeps the earlier element on ties, so ties resolve to the lowest global
    id — identical to single-device argmax."""
    v, pos = jax.lax.top_k(allv, k)
    return v, allg[pos]


def _local_topk(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    # a shard's slice can be smaller than k (tiny pool, many shards):
    # lax.top_k demands k <= dimension, so clamp and pad with inert
    # candidates — same convention as the Pallas epilogue's kb guard
    kk = min(k, scores.shape[0])
    v, li = jax.lax.top_k(scores, kk)
    base = jax.lax.axis_index("shard") * scores.shape[0]
    g = base + li.astype(jnp.int32)
    if kk < k:
        v = jnp.concatenate([v, jnp.full(k - kk, NEG_INF, v.dtype)])
        g = jnp.concatenate([g, jnp.zeros(k - kk, jnp.int32)])
    return v, g


def _score_local(mu, sd, best, member, cost, selected, speed, kernel: str, k: int):
    """One shard's slice -> (k,) local best values + global ids."""
    cost = cost / speed
    if kernel == "xla":
        # bit-identical to ei.choose_next_fused on the full vector
        total = ei_total(mu, sd, best, member)
        scores = jnp.where(selected, NEG_INF, total / cost)
        return _local_topk(scores, k)
    from repro.kernels import ops
    if kernel == "pallas_topk":
        v, li = ops.eirate_topk(mu, sd, best, member, cost, selected, k=k)
        base = jax.lax.axis_index("shard") * mu.shape[0]
        return v, base + li.astype(jnp.int32)
    scores = ops.eirate(mu, sd, best, member, cost, selected)
    return _local_topk(scores, k)


@functools.partial(jax.jit, static_argnames=("mesh", "kernel", "k"))
def _decide(mu, sd, best, member, cost, selected, speed, *, mesh, kernel, k):
    # named_scope annotations land in device profiles (TensorBoard/Perfetto)
    # next to the host spans the obs tracer bridges in — same taxonomy as
    # the phase-split programs below (DESIGN.md §13)
    def local(mu, sd, best, member, cost, selected, speed):
        with jax.named_scope("score_topk"):
            v, g = _score_local(mu, sd, best, member, cost, selected, speed,
                                kernel, k)
        with jax.named_scope("all_gather"):
            allv = jax.lax.all_gather(v, "shard").reshape(-1)
            allg = jax.lax.all_gather(g, "shard").reshape(-1)
        return allv, allg
    allv, allg = shard_map(
        local, mesh=mesh,
        in_specs=(P_MODELS, P_MODELS, P_TENANTS, P_MEMBER,
                  P_MODELS, P_MODELS, P()),
        out_specs=(P(None), P(None)),
        **_NO_REP_CHECK,
    )(mu, sd, best, member, cost, selected, speed)
    with jax.named_scope("global_pick"):
        return _global_pick(allv, allg, k)


@functools.partial(jax.jit, static_argnames=("mesh", "kernel", "k"))
def _decide_classes(mu, sd, best, member, cost, selected, rates, overheads,
                    *, mesh, kernel, k):
    """Per-device-class decision in ONE shard_map program: each shard
    computes its tenant-axis EI sum once, fans it out against every class's
    cost row (``cost/rate_c + overhead_c`` — the affine 2-D cost of
    DESIGN.md §11), reduces each class row to a local top-k, and one
    all_gather serves every class's global pick.  With ``overheads == 0``
    and a single class this is bit-identical to :func:`_decide` (the
    ``+ 0.0`` and ``/ 1.0`` are IEEE identities), which is what lets the
    joint batched assignment replay sequential decisions exactly on
    homogeneous fleets."""
    C = rates.shape[0]

    def local(mu, sd, best, member, cost, selected, rates, overheads):
        cm = cost[None, :] / rates[:, None] + overheads[:, None]   # (C, nl)
        if kernel == "xla":
            total = ei_total(mu, sd, best, member)
            scores = jnp.where(selected[None, :], NEG_INF,
                               total[None, :] / cm)
        else:
            from repro.kernels import ops
            scores = ops.eirate_classes(mu, sd, best, member, cm, selected)
        per = [_local_topk(scores[c], k) for c in range(C)]
        v = jnp.stack([p[0] for p in per])       # (C, k)
        g = jnp.stack([p[1] for p in per])
        allv = jax.lax.all_gather(v, "shard")    # (S, C, k)
        allg = jax.lax.all_gather(g, "shard")
        return allv, allg

    allv, allg = shard_map(
        local, mesh=mesh,
        in_specs=(P_MODELS, P_MODELS, P_TENANTS, P_MEMBER,
                  P_MODELS, P_MODELS, P(), P()),
        out_specs=(P(None), P(None)),
        **_NO_REP_CHECK,
    )(mu, sd, best, member, cost, selected, rates, overheads)
    # (S, C, k) -> (C, S*k): per class the flat order stays (shard, rank)-
    # major = ascending global id at equal value, so top_k's keep-earlier
    # tie-break still resolves to the lowest global id
    allv = allv.transpose(1, 0, 2).reshape(C, -1)
    allg = allg.transpose(1, 0, 2).reshape(C, -1)
    v, pos = jax.lax.top_k(allv, k)
    return v, jnp.take_along_axis(allg, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("mesh", "kernel", "k"))
def _readout_decide(W, alpha, mu0, kdiag, best, member, cost, selected, speed,
                    *, mesh, kernel, k):
    """The fully fused pipeline: sharded GP readout -> EIrate -> global
    argmax in one program.  W is (k_obs, n) sharded over columns; each shard
    reads its slice of W exactly once (kernels/gp_readout streaming pass)."""
    use_pallas = kernel != "xla"

    def local(W, alpha, mu0, kdiag, best, member, cost, selected, speed):
        from repro.kernels import ops
        with jax.named_scope("gp_readout"):
            mu, sd = ops.gp_readout(W, alpha, mu0, kdiag, emit_sd=True,
                                    use_pallas=use_pallas)
        with jax.named_scope("score_topk"):
            v, g = _score_local(mu, sd, best, member, cost, selected, speed,
                                kernel, k)
        with jax.named_scope("all_gather"):
            allv = jax.lax.all_gather(v, "shard").reshape(-1)
            allg = jax.lax.all_gather(g, "shard").reshape(-1)
        return allv, allg

    allv, allg = shard_map(
        local, mesh=mesh,
        in_specs=(P_W, P_OBS, P_MODELS, P_MODELS, P_TENANTS,
                  P_MEMBER, P_MODELS, P_MODELS, P()),
        out_specs=(P(None), P(None)),
        **_NO_REP_CHECK,
    )(W, alpha, mu0, kdiag, best, member, cost, selected, speed)
    with jax.named_scope("global_pick"):
        return _global_pick(allv, allg, k)


# ---- phase-split programs (span-level cost attribution) ---------------------
# The SAME pipeline as _readout_decide, cut at its two natural barriers so a
# host span (with block_until_ready) can time each phase separately.  These
# are benchmark-only (benchmarks/decision_trace.py): the engines keep the
# fused program when tracing, so a traced run's decisions stay byte-identical
# to an untraced run's.

@functools.partial(jax.jit, static_argnames=("mesh", "kernel"))
def _readout_phase(W, alpha, mu0, kdiag, *, mesh, kernel):
    """Sharded GP posterior readout only -> (mu, sd), model-sharded."""
    use_pallas = kernel != "xla"

    def local(W, alpha, mu0, kdiag):
        from repro.kernels import ops
        with jax.named_scope("gp_readout"):
            return ops.gp_readout(W, alpha, mu0, kdiag, emit_sd=True,
                                  use_pallas=use_pallas)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P_W, P_OBS, P_MODELS, P_MODELS),
        out_specs=(P_MODELS, P_MODELS),
        **_NO_REP_CHECK,
    )(W, alpha, mu0, kdiag)


@functools.partial(jax.jit, static_argnames=("mesh", "kernel", "k"))
def _local_candidates(mu, sd, best, member, cost, selected, speed,
                      *, mesh, kernel, k):
    """Per-shard score + local top-k, candidates left shard-resident (the
    (S*k,) outputs are sharded; no cross-shard traffic yet)."""

    def local(mu, sd, best, member, cost, selected, speed):
        with jax.named_scope("score_topk"):
            return _score_local(mu, sd, best, member, cost, selected, speed,
                                kernel, k)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P_MODELS, P_MODELS, P_TENANTS, P_MEMBER,
                  P_MODELS, P_MODELS, P()),
        out_specs=(P_MODELS, P_MODELS),
        **_NO_REP_CHECK,
    )(mu, sd, best, member, cost, selected, speed)


@functools.partial(jax.jit, static_argnames=("mesh", "k"))
def _gather_pick(allv, allg, *, mesh, k):
    """Cross-shard all_gather of the S*k candidates + replicated global
    pick — the communication epilogue, isolated."""

    def local(v, g):
        with jax.named_scope("all_gather"):
            av = jax.lax.all_gather(v, "shard").reshape(-1)
            ag = jax.lax.all_gather(g, "shard").reshape(-1)
        with jax.named_scope("global_pick"):
            vv, pos = jax.lax.top_k(av, k)
            return vv, ag[pos]

    return shard_map(
        local, mesh=mesh,
        in_specs=(P_MODELS, P_MODELS),
        out_specs=(P(None), P(None)),
        **_NO_REP_CHECK,
    )(allv, allg)


class ShardedScorer:
    """Device-resident sharded mirrors + the decision entry points.

    ``num_shards`` must not exceed the jax device count; with one shard the
    program is the single-device fused path plus a trivial reduction (used
    by the tier-1 tests — the multi-shard path needs forced host devices).
    """

    def __init__(self, num_shards: int | None = None, *, topk: int = 4,
                 kernel: str = "xla", mesh=None):
        from repro.launch.mesh import make_scoring_mesh
        if kernel not in SCORE_KERNELS:
            raise ValueError(
                f"kernel must be one of {SCORE_KERNELS}, got {kernel!r}")
        if mesh is None:
            mesh = make_scoring_mesh(num_shards)
        self.mesh = mesh
        self.num_shards = mesh.devices.size
        self.topk = max(1, topk)
        self.kernel = kernel
        self.tracer = NULL_TRACER   # installed by ControlPlane.set_tracer
        self._member = None     # (N_cap, cap) device-resident, P(None, shard)
        self._cost = None       # (cap,) device-resident, P(shard)
        self._cost_host = None  # (cap,) host twin: forensics recovers
        #                         EI = score x cost without a device sync
        self._cap = 0

    # ---- sharded mirrors ---------------------------------------------------

    def _padded_cap(self, n: int) -> int:
        s = self.num_shards
        return ((n + s - 1) // s) * s

    def refresh(self, membership: np.ndarray, cost: np.ndarray) -> None:
        """Full host->device refresh of the churn-rate state (membership
        columns + costs), capacity-padded to a shard multiple."""
        n = cost.shape[0]
        cap = self._padded_cap(n)
        mem = np.zeros((membership.shape[0], cap), dtype=bool)
        mem[:, :n] = membership
        c = np.ones(cap, dtype=np.float32)
        c[:n] = cost
        self._member = jax.device_put(
            mem, NamedSharding(self.mesh, P_MEMBER))
        self._cost = jax.device_put(
            c, NamedSharding(self.mesh, P_MODELS))
        self._cost_host = c
        self._cap = cap

    def _pad(self, x, fill, dtype):
        x = np.asarray(x)
        if x.shape[0] == self._cap:
            return x.astype(dtype, copy=False)
        out = np.full(self._cap, fill, dtype=dtype)
        out[:x.shape[0]] = x
        return out

    # ---- decisions ---------------------------------------------------------

    def decide_topk(self, mu, sd, best, selected, speed: float = 1.0):
        """(values (k,), global ids (k,)) of the global EIrate top-k."""
        if self._member is None:
            raise RuntimeError("refresh() must run before decide()")
        tr = self.tracer
        with tr.span("pad_upload"):
            mu = self._pad(np.asarray(mu, dtype=np.float32), 0.0, np.float32)
            sd = self._pad(np.asarray(sd, dtype=np.float32), 0.0, np.float32)
            sel = self._pad(np.asarray(selected), True, bool)
        with tr.span("shard_decide", shards=self.num_shards,
                     kernel=self.kernel):
            return tr.sync(_decide(
                mu, sd, jnp.asarray(best, dtype=jnp.float32), self._member,
                self._cost, sel, jnp.float32(speed),
                mesh=self.mesh, kernel=self.kernel, k=self.topk))

    def decide(self, mu, sd, best, selected,
               speed: float = 1.0) -> tuple[int, float]:
        """The decision the control plane consumes: global argmax (lowest-id
        tie-break) and its score."""
        v, g = self.decide_topk(mu, sd, best, selected, speed)
        return int(g[0]), float(v[0])

    def decide_topk_classes(self, mu, sd, best, selected, rates, overheads,
                            k: int | None = None):
        """Per-device-class global EIrate top-k for the joint batched
        assignment: ``(values (C, k), global ids (C, k))``, one row per
        class in ``rates``/``overheads`` (cost row c = cost/rate_c +
        overhead_c).  ``k`` defaults to ``self.topk``; a k-device batch
        passes k = batch size so the greedy solver never runs dry."""
        if self._member is None:
            raise RuntimeError("refresh() must run before decide()")
        k = self.topk if k is None else max(1, k)
        tr = self.tracer
        with tr.span("pad_upload"):
            mu = self._pad(np.asarray(mu, dtype=np.float32), 0.0, np.float32)
            sd = self._pad(np.asarray(sd, dtype=np.float32), 0.0, np.float32)
            sel = self._pad(np.asarray(selected), True, bool)
        with tr.span("shard_decide", shards=self.num_shards,
                     kernel=self.kernel, k=k):
            return tr.sync(_decide_classes(
                mu, sd, jnp.asarray(best, dtype=jnp.float32), self._member,
                self._cost, sel, jnp.asarray(rates, dtype=jnp.float32),
                jnp.asarray(overheads, dtype=jnp.float32),
                mesh=self.mesh, kernel=self.kernel, k=k))

    def readout_decide_topk(self, W, alpha, mu0, kdiag, best, selected,
                            speed: float = 1.0):
        """Fused readout+score+pick over an explicit (k_obs, n) W buffer —
        the shard_scale benchmark's full-pipeline path.  Shapes must already
        be shard-multiples (pad upstream)."""
        if self._member is None:
            raise RuntimeError("refresh() must run before decide()")
        return _readout_decide(
            W, alpha, mu0, kdiag, jnp.asarray(best, dtype=jnp.float32),
            self._member, self._cost, jnp.asarray(selected),
            jnp.float32(speed), mesh=self.mesh, kernel=self.kernel,
            k=self.topk)

    def readout_decide_topk_phased(self, W, alpha, mu0, kdiag, best,
                                   selected, speed: float = 1.0):
        """The same pipeline as :meth:`readout_decide_topk`, run as three
        separately jitted phases — readout, local score+top-k, cross-shard
        gather+pick — each closed under a ``tracer.span`` with a
        ``block_until_ready`` sync, so the tracer attributes the decision's
        wall time phase by phase.  Benchmark-only: the extra dispatch
        boundaries forfeit fusion, so the engines never take this path."""
        if self._member is None:
            raise RuntimeError("refresh() must run before decide()")
        tr = self.tracer
        best_j = jnp.asarray(best, dtype=jnp.float32)
        sel_j = jnp.asarray(selected)
        speed_j = jnp.float32(speed)
        with tr.span("readout", shards=self.num_shards):
            mu, sd = tr.sync(_readout_phase(
                W, alpha, mu0, kdiag, mesh=self.mesh, kernel=self.kernel))
        with tr.span("score_topk", shards=self.num_shards, k=self.topk):
            v, g = tr.sync(_local_candidates(
                mu, sd, best_j, self._member, self._cost, sel_j, speed_j,
                mesh=self.mesh, kernel=self.kernel, k=self.topk))
        with tr.span("gather_pick", shards=self.num_shards, k=self.topk):
            return tr.sync(_gather_pick(v, g, mesh=self.mesh, k=self.topk))

    def phase_times(self, W, alpha, mu0, kdiag, best, selected,
                    speed: float = 1.0, *, iters: int = 10,
                    warmup: int = 2) -> dict:
        """Mean wall µs per phase of the phased pipeline — the capacity
        plane's attribution probe (obs/profile.py, benchmarks/capacity.py).
        Each phase is timed independently on materialized inputs (the
        chain's intermediates are computed once, outside the timed region),
        so the numbers decompose a decision without dispatch pipelining
        hiding one phase inside another."""
        from repro.obs.profile import time_us_blocked
        if self._member is None:
            raise RuntimeError("refresh() must run before phase_times()")
        best_j = jnp.asarray(best, dtype=jnp.float32)
        sel_j = jnp.asarray(selected)
        speed_j = jnp.float32(speed)
        mu, sd = jax.block_until_ready(_readout_phase(
            W, alpha, mu0, kdiag, mesh=self.mesh, kernel=self.kernel))
        v, g = jax.block_until_ready(_local_candidates(
            mu, sd, best_j, self._member, self._cost, sel_j, speed_j,
            mesh=self.mesh, kernel=self.kernel, k=self.topk))
        return {
            "readout_us": time_us_blocked(
                lambda: _readout_phase(W, alpha, mu0, kdiag, mesh=self.mesh,
                                       kernel=self.kernel),
                iters=iters, warmup=warmup),
            "score_us": time_us_blocked(
                lambda: _local_candidates(
                    mu, sd, best_j, self._member, self._cost, sel_j,
                    speed_j, mesh=self.mesh, kernel=self.kernel,
                    k=self.topk),
                iters=iters, warmup=warmup),
            "gather_us": time_us_blocked(
                lambda: _gather_pick(v, g, mesh=self.mesh, k=self.topk),
                iters=iters, warmup=warmup),
        }
