"""Sharded scoring plane: multi-device GP-EI decisions + index-space
compaction for long-running services (DESIGN.md §10).

  layout.py   RangeAllocator (slot reuse — ends §9's append-only index
              space) + ShardLayout (shard-span-confined block placement)
  score.py    ShardedScorer: the shard_map decision program — per-shard
              GP readout / EIrate / top-k, one cross-shard reduction to
              the exact global argmax
  compact.py  rebalance planner: relocate idle tenant blocks until shard
              loads sit within a bound

The control plane integrates all three behind ``scorer="sharded"``
(``repro.core.control_plane``); ``benchmarks/shard_scale.py`` sweeps the
decision latency over |L| x mesh size.
"""

from .compact import DEFAULT_MAX_IMBALANCE, plan_moves  # noqa: F401
from .layout import BlockPlacement, RangeAllocator, ShardLayout  # noqa: F401
from .score import SCORE_KERNELS, ShardedScorer  # noqa: F401
