"""Mixture-of-Experts layer: top-k router + GShard-style capacity dispatch.

TPU adaptation (DESIGN.md §3): dispatch/combine are expressed as one-hot
einsums over a (groups, group_size, experts, capacity) tensor — the classic
GSPMD-friendly formulation that lowers to all-to-alls when experts are
sharded (EP over the "model" mesh axis).  Group size is kept small
(default 128 tokens) so the dispatch einsum's overhead FLOPs stay a small
fraction of expert FLOPs (2*d*E*C vs 6*d*ff*k per token; see EXPERIMENTS.md
§Roofline for measured ratios).

Supports:
  * top-k routing with softmax-renormalized gates (Qwen3-MoE: k=8 of 128)
  * optional dense residual branch (Snowflake Arctic: MoE + parallel MLP)
  * auxiliary load-balance loss (Switch-style) returned for the train loss
  * capacity-factor token dropping with residual passthrough
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import AxisRules, ParamSpec, with_logical_constraint


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    group_size: int = 128
    capacity_factor: float = 2.0
    router_aux_weight: float = 0.01

    @property
    def capacity(self) -> int:
        c = self.group_size * self.top_k * self.capacity_factor / self.num_experts
        return max(int(math.ceil(c)), 1)


def moe_specs(cfg: MoEConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamSpec((d, E), ("embed", "experts"), init="fan_in"),
        "wi_gate": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"), init="fan_in"),
        "wi_up": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"), init="fan_in"),
        "wo": ParamSpec((E, f, d), ("experts", "expert_mlp", "embed"), init="fan_in"),
    }


def _route(router_w: jax.Array, x: jax.Array, cfg: MoEConfig):
    """x (G, S, d) -> gates (G, S, k), expert ids (G, S, k), aux loss scalar."""
    logits = jnp.einsum("gsd,de->gse", x, router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)           # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    E = cfg.num_experts
    me = probs.mean(axis=(0, 1))                                      # (E,)
    ce = jax.nn.one_hot(expert_ids[..., 0], E).mean(axis=(0, 1))      # fraction routed (top-1)
    aux = E * jnp.sum(me * ce)
    return gate_vals, expert_ids, aux


def moe_apply(
    p: dict,
    x: jax.Array,                 # (B, S, d)
    cfg: MoEConfig,
    rules: AxisRules | None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss scalar)."""
    Bb, S, d = x.shape
    tokens = Bb * S
    Sg = min(cfg.group_size, tokens)
    assert tokens % Sg == 0, f"tokens {tokens} must divide group size {Sg}"
    G = tokens // Sg
    E, C, K = cfg.num_experts, cfg.capacity, cfg.top_k

    xg = x.reshape(G, Sg, d)
    xg = with_logical_constraint(xg, ("batch", None, "act_embed"), rules)
    gates, ids, aux = _route(p["router"], xg, cfg)

    # Position of each (token, k) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)                  # (G,S,k,E)
    flat = onehot.reshape(G, Sg * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                   # (G,S*k,E)
    pos = (pos_in_expert * flat).sum(-1).reshape(G, Sg, K)            # (G,S,k)
    keep = pos < C                                                    # capacity drop
    gates = jnp.where(keep, gates, 0.0)

    # dispatch (G,S,E,C) in compute dtype: disp[g,s,e,c] = 1 if token s goes
    # to slot c of expert e.
    oh_e = jax.nn.one_hot(ids, E, dtype=x.dtype)                      # (G,S,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)  # (G,S,k,C)
    disp = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)                  # (G,S,E,C)
    comb = jnp.einsum("gske,gskc,gsk->gsec", oh_e, oh_c, gates.astype(x.dtype))

    xe = jnp.einsum("gsd,gsec->gecd", xg, disp)                       # (G,E,C,d)
    xe = with_logical_constraint(xe, ("batch", "experts", None, "act_embed"), rules)
    dt = x.dtype
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["wi_up"].astype(dt))
    h = with_logical_constraint(h, ("batch", "experts", None, "expert_mlp"), rules)
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    ye = with_logical_constraint(ye, ("batch", "experts", None, "act_embed"), rules)
    y = jnp.einsum("gecd,gsec->gsd", ye, comb)                        # (G,S,d)
    y = with_logical_constraint(y, ("batch", None, "act_embed"), rules)
    return y.reshape(Bb, S, d), cfg.router_aux_weight * aux


def moe_decode(p: dict, x: jax.Array, cfg: MoEConfig, rules: AxisRules | None) -> jax.Array:
    """Decode-path MoE (B tokens, S=1): same dispatch machinery, one group."""
    y, _ = moe_apply(p, x, cfg._replace(group_size=min(cfg.group_size, x.shape[0] * x.shape[1])), rules)
    return y
