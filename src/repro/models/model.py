"""Composable decoder-LM backbone covering all ten assigned architectures.

One :class:`ModelConfig` describes any member of the pool:

  dense   (qwen3-4b/8b, olmo-1b, h2o-danube-3-4b)   attn + MLP blocks
  moe     (arctic-480b, qwen3-moe-235b-a22b)        attn + MoE (+dense residual)
  ssm     (mamba2-1.3b)                             Mamba2 SSD blocks
  hybrid  (zamba2-2.7b)                             Mamba2 + shared attn block
  vlm     (paligemma-3b)                            patch-embedding frontend stub
  audio   (musicgen-medium)                         frame-embedding frontend stub

Layers are stacked and driven by ``jax.lax.scan`` (compact HLO, depth-O(1)
compile).  Parameters are pytrees of plain arrays; ``model_specs`` yields the
ParamSpec tree used for init, dry-run ShapeDtypeStructs and sharding tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.rules import AxisRules, ParamSpec, with_logical_constraint
from . import attention as attn_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .attention import AttnConfig, KVCache
from .layers import (
    apply_norm,
    embed_lookup,
    embed_specs,
    init_from_specs,
    mlp_apply,
    mlp_specs,
    rmsnorm_specs,
    scan_or_loop,
    softmax_xent_chunked,
    unembed_logits,
)
from .moe import MoEConfig
from .ssm import SSMCache, SSMConfig


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (unused for family == "ssm")
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10000.0
    # mlp / moe
    d_ff: int = 0
    mlp_activation: str = "silu"
    moe: MoEConfig | None = None
    dense_residual: bool = False  # Arctic: parallel dense MLP beside MoE
    # ssm / hybrid
    ssm: SSMConfig | None = None
    hybrid_attn_every: int = 0    # Zamba2: shared attn block every k layers
    # embeddings / heads
    norm: str = "rms"
    tie_embeddings: bool = False
    num_lm_heads: int = 1         # MusicGen: 4 codebook heads
    frontend: str | None = None   # None | "patches" | "frames"
    frontend_dim: int = 0
    num_frontend_tokens: int = 0  # VLM: image tokens prepended
    # execution knobs (perf levers — see EXPERIMENTS.md §Perf)
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: str = "full"           # none | full | dots
    q_chunk: int = 512
    xent_chunk: int = 512
    # unroll the layer stack as a Python loop instead of lax.scan — used by
    # the roofline probe (XLA cost analysis counts while bodies once).
    unroll_layers: bool = False
    # TPU path: Pallas kernels for attention / SSD (interpret=True on CPU).
    use_pallas: bool = False
    attn_logits_fp32: bool = True
    # whether long_500k applies (sub-quadratic context handling)
    supports_long_context: bool = False

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads, head_dim=self.head_dim,
            qk_norm=self.qk_norm, sliding_window=self.sliding_window,
            rope_theta=self.rope_theta, q_chunk=self.q_chunk,
            unroll=self.unroll_layers, use_pallas=self.use_pallas,
            logits_fp32=self.attn_logits_fp32)

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def num_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return self.num_layers // self.hybrid_attn_every
        return self.num_layers

    def param_count(self) -> int:
        import math
        leaves = jax.tree.leaves(
            model_specs(self), is_leaf=lambda x: isinstance(x, ParamSpec))
        return sum(math.prod(l.shape) for l in leaves)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        import math
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                model_specs(self), is_leaf=lambda x: isinstance(x, ParamSpec))[0]:
            n = math.prod(leaf.shape)
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if any(k in ("wi_gate", "wi_up", "wo") for k in keys) and "moe" in keys:
                n = n * self.moe.top_k // self.moe.num_experts
            total += n
        return total


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _stack_specs(specs, n: int):
    """Add a leading layer dim of size n to every ParamSpec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.logical_axes),
                            dtype=s.dtype, init=s.init, init_scale=s.init_scale),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _block_specs(cfg: ModelConfig) -> dict:
    """Specs for one repeated block (pre-stacking)."""
    d = cfg.d_model
    if cfg.family == "ssm":
        return {"norm": rmsnorm_specs(d), "ssm": ssm_lib.ssm_specs(cfg.ssm)}
    if cfg.family == "hybrid":
        return {"norm": rmsnorm_specs(d), "ssm": ssm_lib.ssm_specs(cfg.ssm)}
    block: dict = {
        "attn_norm": rmsnorm_specs(d) if cfg.norm == "rms" else {},
        "attn": attn_lib.attn_specs(cfg.attn_cfg),
        "mlp_norm": rmsnorm_specs(d) if cfg.norm == "rms" else {},
    }
    if cfg.moe is not None:
        block["moe"] = moe_lib.moe_specs(cfg.moe)
        if cfg.dense_residual:
            block["mlp"] = mlp_specs(d, cfg.d_ff)
    else:
        block["mlp"] = mlp_specs(d, cfg.d_ff)
    return block


def model_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs: dict = {}
    if cfg.frontend is None:
        specs["embed"] = embed_specs(cfg.vocab_size, d)
    elif cfg.frontend == "patches":
        specs["embed"] = embed_specs(cfg.vocab_size, d)
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, d), ("embed_out", "embed"), init="fan_in")
    elif cfg.frontend == "frames":
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, d), ("embed_out", "embed"), init="fan_in")
    else:
        raise ValueError(cfg.frontend)

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        groups = cfg.num_layers // k
        specs["blocks"] = _stack_specs(_stack_specs(_block_specs(cfg), k), groups)
        specs["shared_attn"] = {
            "attn_norm": rmsnorm_specs(d),
            "attn": attn_lib.attn_specs(cfg.attn_cfg),
            "mlp_norm": rmsnorm_specs(d),
            "mlp": mlp_specs(d, cfg.d_ff),
        }
    else:
        specs["blocks"] = _stack_specs(_block_specs(cfg), cfg.num_layers)

    if cfg.norm == "rms":
        specs["final_norm"] = rmsnorm_specs(d)
    if not cfg.tie_embeddings:
        if cfg.num_lm_heads == 1:
            specs["head"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"), init="fan_in")
        else:
            specs["head"] = ParamSpec(
                (cfg.num_lm_heads, d, cfg.vocab_size), (None, "embed", "vocab"),
                init="fan_in")
    return specs


def init_params(cfg: ModelConfig, key: jax.Array):
    return init_from_specs(model_specs(cfg), key, cfg.param_dtype)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _transformer_block(p, x, positions, cfg: ModelConfig, rules):
    aux = jnp.float32(0.0)
    h = apply_norm(cfg.norm, p.get("attn_norm") or None, x)
    x = x + attn_lib.attention_train(p["attn"], h, positions, cfg.attn_cfg, rules)
    h = apply_norm(cfg.norm, p.get("mlp_norm") or None, x)
    if cfg.moe is not None:
        y, aux = moe_lib.moe_apply(p["moe"], h, cfg.moe, rules)
        if cfg.dense_residual:
            y = y + mlp_apply(p["mlp"], h, rules, cfg.mlp_activation)
        x = x + y
    else:
        x = x + mlp_apply(p["mlp"], h, rules, cfg.mlp_activation)
    return x, aux


def _ssm_block(p, x, cfg: ModelConfig, rules):
    h = apply_norm(cfg.norm, p["norm"], x)
    return x + ssm_lib.ssm_train(p["ssm"], h, cfg.ssm, rules)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    raise ValueError(cfg.remat)


_scan_or_loop = scan_or_loop


def _apply_blocks_train(params, x, positions, cfg: ModelConfig, rules):
    """Scan the stacked blocks over the sequence of layers."""
    aux_total = jnp.float32(0.0)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(carry, layer_p):
            h, aux = carry
            h2, a = _transformer_block(layer_p, h, positions, cfg, rules)
            return (h2, aux + a), None
        body = _remat(body, cfg)
        (x, aux_total), _ = _scan_or_loop(body, (x, aux_total), params["blocks"], cfg.unroll_layers)
        return x, aux_total

    if cfg.family == "ssm":
        def body(h, layer_p):
            return _ssm_block(layer_p, h, cfg, rules), None
        body = _remat(body, cfg)
        x, _ = _scan_or_loop(body, x, params["blocks"], cfg.unroll_layers)
        return x, aux_total

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, group_p):
            def inner(hh, layer_p):
                return _ssm_block(layer_p, hh, cfg, rules), None
            h, _ = _scan_or_loop(_remat(inner, cfg), h, group_p, cfg.unroll_layers)
            # shared attention block (weights shared across groups)
            def shared_fn(hh):
                a = apply_norm(cfg.norm, shared["attn_norm"], hh)
                hh = hh + attn_lib.attention_train(
                    shared["attn"], a, positions, cfg.attn_cfg, rules)
                m = apply_norm(cfg.norm, shared["mlp_norm"], hh)
                return hh + mlp_apply(shared["mlp"], m, rules, cfg.mlp_activation)
            h = _remat(lambda c, _: (shared_fn(c), None), cfg)(h, None)[0]
            return h, None

        x, _ = _scan_or_loop(group_body, x, params["blocks"], cfg.unroll_layers)
        return x, aux_total

    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Training forward (loss) — inputs are a dict, see repro.launch.specs
# ---------------------------------------------------------------------------

def embed_inputs(params, batch: dict, cfg: ModelConfig, rules) -> tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), positions (S,))."""
    cd = cfg.compute_dtype
    if cfg.frontend is None:
        x = embed_lookup(params["embed"], batch["tokens"], cd)
    elif cfg.frontend == "patches":
        patches = batch["patches"].astype(cd)                     # (B, Simg, fd)
        proj = jnp.einsum("bsf,fd->bsd", patches, params["frontend_proj"].astype(cd))
        text = embed_lookup(params["embed"], batch["tokens"], cd)  # (B, Stxt, d)
        x = jnp.concatenate([proj, text], axis=1)
    elif cfg.frontend == "frames":
        frames = batch["frames"].astype(cd)                       # (B, S, fd)
        x = jnp.einsum("bsf,fd->bsd", frames, params["frontend_proj"].astype(cd))
    else:
        raise ValueError(cfg.frontend)
    x = with_logical_constraint(x, ("batch", "seq", "act_embed"), rules)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, positions


def _head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"], True
    return params["head"], False


def forward_logits_last(params, batch: dict, cfg: ModelConfig,
                        rules: AxisRules | None) -> jax.Array:
    """Logits at the final position of a full (non-cached) forward pass.

    Oracle for the prefill/decode consistency tests: must match one
    ``decode_step`` after ``prefill`` on the same prefix.
    """
    x, positions = embed_inputs(params, batch, cfg, rules)
    x, _ = _apply_blocks_train(params, x, positions, cfg, rules)
    x = apply_norm(cfg.norm, params.get("final_norm"), x)
    last = x[:, -1:, :]
    head_w, tied = _head_weight(params, cfg)
    if cfg.num_lm_heads == 1:
        return unembed_logits(head_w, last, tied)
    return jnp.stack(
        [unembed_logits(head_w[h], last, False) for h in range(cfg.num_lm_heads)],
        axis=2)


def forward_loss(params, batch: dict, cfg: ModelConfig, rules: AxisRules | None) -> jax.Array:
    """Mean-token cross entropy (+ MoE aux loss)."""
    x, positions = embed_inputs(params, batch, cfg, rules)
    x, aux = _apply_blocks_train(params, x, positions, cfg, rules)
    if cfg.norm == "rms":
        x = apply_norm(cfg.norm, params["final_norm"], x)
    else:
        x = apply_norm(cfg.norm, None, x)

    labels = batch["labels"]
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    head_w, tied = _head_weight(params, cfg)

    if cfg.num_lm_heads == 1:
        if cfg.frontend == "patches":
            # loss only over text positions (suffix)
            x = x[:, -labels.shape[1]:, :]
        loss = softmax_xent_chunked(
            x, head_w, labels, mask, tied, rules, cfg.xent_chunk,
            unroll=cfg.unroll_layers)
    else:
        # MusicGen: one head per codebook; labels (B, S, num_heads).
        losses = []
        for h in range(cfg.num_lm_heads):
            losses.append(softmax_xent_chunked(
                x, head_w[h], labels[..., h], mask[..., h], False, rules,
                cfg.xent_chunk, unroll=cfg.unroll_layers))
        loss = jnp.stack(losses).mean()
    return loss + aux.astype(loss.dtype)


# ---------------------------------------------------------------------------
# Serving: prefill + decode (see repro.serve for the step wrappers)
# ---------------------------------------------------------------------------

def make_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ParamSpec pytree for the decode cache (stacked over layers)."""
    cd = cfg.compute_dtype
    if cfg.family == "ssm":
        return {"ssm": _stack_specs(ssm_lib.ssm_cache_specs(cfg.ssm, batch, cd)._asdict(),
                                    cfg.num_layers)}
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        groups = cfg.num_layers // k
        return {
            "ssm": _stack_specs(_stack_specs(
                ssm_lib.ssm_cache_specs(cfg.ssm, batch, cd)._asdict(), k), groups),
            "attn": _stack_specs(
                attn_lib.kv_cache_specs(cfg.attn_cfg, batch, max_len, cd)._asdict(),
                groups),
        }
    return {"attn": _stack_specs(
        attn_lib.kv_cache_specs(cfg.attn_cfg, batch, max_len, cd)._asdict(),
        cfg.num_layers)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    specs = make_cache_specs(cfg, batch, max_len)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def decode_step(params, batch: dict, cache, cfg: ModelConfig, rules: AxisRules | None):
    """One new token for every sequence in the batch.

    batch: {"tokens": (B, 1)} (or frames/patch-free equivalents).
    cache: pytree from make_cache_specs / prefill.
    Returns (logits (B, 1, [heads,] V), new_cache).
    """
    cd = cfg.compute_dtype
    if cfg.frontend == "frames":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"].astype(cd),
                       params["frontend_proj"].astype(cd))
    else:
        x = embed_lookup(params["embed"], batch["tokens"], cd)

    if cfg.family == "ssm":
        def body(h, xs):
            layer_p, c = xs
            hn = apply_norm(cfg.norm, layer_p["norm"], h)
            y, c2 = ssm_lib.ssm_decode(layer_p["ssm"], hn, SSMCache(**c), cfg.ssm, rules)
            return h + y, {"state": c2.state, "conv": c2.conv, "length": c2.length}
        x, new_ssm = _scan_or_loop(body, x, (params["blocks"], cache["ssm"]), cfg.unroll_layers)
        new_cache = {"ssm": new_ssm}
    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        groups = cfg.num_layers // k
        shared = params["shared_attn"]

        def group_body(h, xs):
            group_p, ssm_c, attn_c = xs
            def inner(hh, ys):
                layer_p, c = ys
                hn = apply_norm(cfg.norm, layer_p["norm"], hh)
                y, c2 = ssm_lib.ssm_decode(layer_p["ssm"], hn, SSMCache(**c), cfg.ssm, rules)
                return hh + y, {"state": c2.state, "conv": c2.conv, "length": c2.length}
            h, new_ssm_c = jax.lax.scan(inner, h, (group_p, ssm_c))
            a = apply_norm(cfg.norm, shared["attn_norm"], h)
            y, kv2 = attn_lib.attention_decode(
                shared["attn"], a, KVCache(**attn_c), cfg.attn_cfg, rules)
            h = h + y
            m = apply_norm(cfg.norm, shared["mlp_norm"], h)
            h = h + mlp_apply(shared["mlp"], m, rules, cfg.mlp_activation)
            return h, (new_ssm_c, {"k": kv2.k, "v": kv2.v, "length": kv2.length})

        x, (new_ssm, new_attn) = _scan_or_loop(
            group_body, x, (params["blocks"], cache["ssm"], cache["attn"]), cfg.unroll_layers)
        new_cache = {"ssm": new_ssm, "attn": new_attn}
    else:
        # NOTE (§Perf iteration B3, refuted): carrying the stacked cache
        # through the scan carry and writing only the new token measured
        # WORSE on the compiled artifact (the partitioner reshards the
        # carried cache: collective 0.36ms -> 2641ms) — the ys-based copy
        # below is already buffer-aliased by XLA.  See EXPERIMENTS.md.
        def body(h, xs):
            layer_p, c = xs
            hn = apply_norm(cfg.norm, layer_p.get("attn_norm") or None, h)
            y, kv2 = attn_lib.attention_decode(
                layer_p["attn"], hn, KVCache(**c), cfg.attn_cfg, rules)
            h = h + y
            m = apply_norm(cfg.norm, layer_p.get("mlp_norm") or None, h)
            if cfg.moe is not None:
                ym = moe_lib.moe_decode(layer_p["moe"], m, cfg.moe, rules)
                if cfg.dense_residual:
                    ym = ym + mlp_apply(layer_p["mlp"], m, rules, cfg.mlp_activation)
                h = h + ym
            else:
                h = h + mlp_apply(layer_p["mlp"], m, rules, cfg.mlp_activation)
            return h, {"k": kv2.k, "v": kv2.v, "length": kv2.length}
        x, new_attn = _scan_or_loop(body, x, (params["blocks"], cache["attn"]), cfg.unroll_layers)
        new_cache = {"attn": new_attn}

    x = apply_norm(cfg.norm, params.get("final_norm"), x)
    head_w, tied = _head_weight(params, cfg)
    if cfg.num_lm_heads == 1:
        logits = unembed_logits(head_w, x, tied)
    else:
        logits = jnp.stack(
            [unembed_logits(head_w[h], x, False) for h in range(cfg.num_lm_heads)],
            axis=2)  # (B, 1, heads, V)
    return logits, new_cache


def prefill(params, batch: dict, cfg: ModelConfig, rules: AxisRules | None,
            max_len: int | None = None):
    """Score a full prompt and build the decode cache.

    Implemented as the chunked-causal forward plus per-layer cache capture.
    Returns (last_hidden (B, d), cache).
    """
    x, positions = embed_inputs(params, batch, cfg, rules)
    B, S, _ = x.shape
    max_len = max_len or S
    cd = cfg.compute_dtype

    if cfg.family == "ssm":
        def body(h, layer_p):
            hn = apply_norm(cfg.norm, layer_p["norm"], h)
            y, st = ssm_lib.ssm_train_with_state(layer_p["ssm"], hn, cfg.ssm, rules)
            return h + y, st
        x, states = _scan_or_loop(body, x, params["blocks"], cfg.unroll_layers)
        cache = {"ssm": states}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, group_p):
            def inner(hh, layer_p):
                hn = apply_norm(cfg.norm, layer_p["norm"], hh)
                y, st = ssm_lib.ssm_train_with_state(layer_p["ssm"], hn, cfg.ssm, rules)
                return hh + y, st
            h, states = _scan_or_loop(inner, h, group_p, cfg.unroll_layers)
            a = apply_norm(cfg.norm, shared["attn_norm"], h)
            y, kv = attn_lib.attention_train_with_kv(
                shared["attn"], a, positions, cfg.attn_cfg, rules, max_len)
            h = h + y
            m = apply_norm(cfg.norm, shared["mlp_norm"], h)
            h = h + mlp_apply(shared["mlp"], m, rules, cfg.mlp_activation)
            return h, (states, kv)
        x, (states, kvs) = _scan_or_loop(group_body, x, params["blocks"], cfg.unroll_layers)
        cache = {"ssm": states, "attn": kvs}
    else:
        def body(h, layer_p):
            hn = apply_norm(cfg.norm, layer_p.get("attn_norm") or None, h)
            y, kv = attn_lib.attention_train_with_kv(
                layer_p["attn"], hn, positions, cfg.attn_cfg, rules, max_len)
            h = h + y
            m = apply_norm(cfg.norm, layer_p.get("mlp_norm") or None, h)
            if cfg.moe is not None:
                ym, _ = moe_lib.moe_apply(layer_p["moe"], m, cfg.moe, rules)
                if cfg.dense_residual:
                    ym = ym + mlp_apply(layer_p["mlp"], m, rules, cfg.mlp_activation)
                h = h + ym
            else:
                h = h + mlp_apply(layer_p["mlp"], m, rules, cfg.mlp_activation)
            return h, kv
        x, kvs = _scan_or_loop(body, x, params["blocks"], cfg.unroll_layers)
        cache = {"attn": kvs}

    x = apply_norm(cfg.norm, params.get("final_norm"), x)
    return x[:, -1, :], cache
