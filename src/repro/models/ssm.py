"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm (the TPU-friendly formulation):

  per step t:  h_t = a_t * h_{t-1} + dt_t * B_t (x) x_t      a_t = exp(dt_t * A)
               y_t = C_t . h_t + D * x_t

Sequence is split into chunks of length Q.  Within a chunk the recurrence is
expanded into an attention-like masked matmul (MXU work); across chunks a
``lax.scan`` carries the (B, H, P, N) state.  Decode is the O(1) recurrence.

The intra-chunk matmul is the compute hot-spot; ``repro.kernels.ssd`` holds
the Pallas TPU kernel, this file is the XLA reference path (also the oracle).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import AxisRules, ParamSpec, with_logical_constraint
from .layers import rmsnorm, scan_or_loop


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int          # expand * d_model
    headdim: int          # P
    d_state: int          # N
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    unroll: bool = False
    use_pallas: bool = False

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.headdim


def ssm_specs(cfg: SSMConfig) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.num_heads
    conv_ch = di + 2 * N
    return {
        "in_proj_zx": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), init="fan_in"),
        "in_proj_bc": ParamSpec((d, 2 * N), ("embed", "ssm_state"), init="fan_in"),
        "in_proj_dt": ParamSpec((d, H), ("embed", "ssm_heads"), init="fan_in"),
        "conv_w": ParamSpec((cfg.conv_width, conv_ch), ("conv_width", "ssm_inner"), init="fan_in"),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "D": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "norm": {"scale": ParamSpec((di,), ("ssm_inner",), init="ones")},
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), init="fan_in"),
    }


class SSMCache(NamedTuple):
    state: jax.Array      # (B, H, P, N) fp32 recurrent state
    conv: jax.Array       # (B, W-1, conv_ch) last conv inputs
    length: jax.Array     # scalar int32


def ssm_cache_specs(cfg: SSMConfig, batch: int, dtype) -> SSMCache:
    H, P, N = cfg.num_heads, cfg.headdim, cfg.d_state
    conv_ch = cfg.d_inner + 2 * N
    return SSMCache(
        state=ParamSpec((batch, H, P, N), ("batch", "ssm_heads", None, "ssm_state"),
                        dtype=jnp.float32, init="zeros"),
        conv=ParamSpec((batch, cfg.conv_width - 1, conv_ch),
                       ("batch", None, "ssm_inner"), dtype=dtype, init="zeros"),
        length=ParamSpec((), (), dtype=jnp.int32, init="zeros"),
    )


def _split_proj(p: dict, u: jax.Array, cfg: SSMConfig):
    dt_ = u.dtype
    zx = jnp.einsum("bsd,de->bse", u, p["in_proj_zx"].astype(dt_))
    z, x = jnp.split(zx, 2, axis=-1)
    bc = jnp.einsum("bsd,de->bse", u, p["in_proj_bc"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", u, p["in_proj_dt"].astype(dt_))
    return z, x, bc, dt_raw


def _conv_mix(p: dict, xbc: jax.Array, cfg: SSMConfig) -> jax.Array:
    """Depthwise causal conv1d, width W, over (B, S, C)."""
    W = cfg.conv_width
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(W):
        out = out + pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i].astype(xbc.dtype)
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def ssm_train(p: dict, u: jax.Array, cfg: SSMConfig, rules: AxisRules | None) -> jax.Array:
    """Full-sequence SSD. u (B, S, d_model) -> (B, S, d_model)."""
    y, _ = _ssm_forward(p, u, cfg, rules)
    return y


def ssm_train_with_state(p: dict, u: jax.Array, cfg: SSMConfig,
                         rules: AxisRules | None) -> tuple[jax.Array, dict]:
    """Full-sequence SSD that also returns the decode cache (prefill path)."""
    y, cache = _ssm_forward(p, u, cfg, rules, want_state=True)
    return y, cache


def _ssm_forward(p: dict, u: jax.Array, cfg: SSMConfig, rules: AxisRules | None,
                 want_state: bool = False):
    B, S, _ = u.shape
    H, P, N, Q = cfg.num_heads, cfg.headdim, cfg.d_state, min(cfg.chunk, u.shape[1])
    if S % Q:
        Q = S               # irregular length: single chunk
    z, x, bc, dt_raw = _split_proj(p, u, cfg)
    xbc_raw = jnp.concatenate([x, bc], axis=-1)
    xbc = _conv_mix(p, xbc_raw, cfg)
    x, bc = xbc[..., : cfg.d_inner], xbc[..., cfg.d_inner :]
    Bmat, Cmat = jnp.split(bc, 2, axis=-1)                       # (B, S, N) each

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max)                    # (B, S, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    log_a = dt * A[None, None, :]                                # (B, S, H) <= 0

    xh = x.reshape(B, S, H, P)

    if cfg.use_pallas and not want_state:
        from repro.kernels.ops import ssd_mix
        y = ssd_mix(xh, dt, log_a, Bmat, Cmat, chunk=Q)
        return _ssm_epilogue(p, u, y, xh, z, cfg, rules), None

    nc = S // Q

    def chunk_view(t, shape):
        return t.reshape(B, nc, Q, *shape).swapaxes(0, 1)        # (nc, B, Q, ...)

    xc = chunk_view(xh, (H, P))
    bC = chunk_view(Bmat, (N,))
    cC = chunk_view(Cmat, (N,))
    dtc = chunk_view(dt, (H,))
    lac = chunk_view(log_a, (H,))

    def chunk_body(state, inp):
        xq, bq, cq, dtq, laq = inp                               # (B,Q,...)
        lcum = jnp.cumsum(laq, axis=1)                           # (B,Q,H) inclusive
        # intra-chunk: M[t,s] = (C_t.B_s) * exp(lcum_t - lcum_s) * dt_s, s<=t
        scores = jnp.einsum("btn,bsn->bts", cq, bq)              # (B,Q,Q)
        decay = lcum[:, :, None, :] - lcum[:, None, :, :]        # (B,t,s,H)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        m = jnp.where(causal[None, :, :, None], jnp.exp(decay), 0.0)
        w = scores[..., None] * m * dtq[:, None, :, :]           # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w.astype(xq.dtype), xq)
        # inter-chunk: y_inter[t] = exp(lcum_t) * (C_t . state_carried)
        y_inter = jnp.einsum("btn,bhpn->bthp", cq.astype(jnp.float32), state)
        y_inter = y_inter * jnp.exp(lcum)[:, :, :, None]         # (B,Q,H,P)
        # state update: new_state = exp(l_end)*state + sum_s exp(l_end - l_s) dt_s B_s (x) x_s
        l_end = lcum[:, -1, :]                                   # (B,H)
        carry_decay = jnp.exp(l_end)[:, :, None, None]           # (B,H,1,1)
        w_state = jnp.exp(l_end[:, None, :] - lcum) * dtq        # (B,Q,H)
        bx = jnp.einsum("bqh,bqn,bqhp->bhpn",
                        w_state, bq.astype(jnp.float32), xq.astype(jnp.float32))
        new_state = carry_decay * state + bx
        y = y_intra.astype(jnp.float32) + y_inter
        return new_state, y

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    final_state, ys = scan_or_loop(chunk_body, state0, (xc, bC, cC, dtc, lac), cfg.unroll)
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    out = _ssm_epilogue(p, u, y, xh, z, cfg, rules)
    if not want_state:
        return out, None
    cache = {
        "state": final_state,
        "conv": xbc_raw[:, S - (cfg.conv_width - 1):, :],
        "length": jnp.int32(S),
    }
    return out, cache


def _ssm_epilogue(p, u, y, xh, z, cfg: SSMConfig, rules):
    """D-skip, gating, norm, out-projection shared by XLA and Pallas paths."""
    B, S, _ = u.shape
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    y = with_logical_constraint(y, ("batch", "seq", "ssm_inner"), rules)
    return jnp.einsum("be,ed->bd", y.reshape(-1, cfg.d_inner),
                      p["out_proj"].astype(u.dtype)).reshape(B, S, cfg.d_model)


def ssm_decode(p: dict, u: jax.Array, cache: SSMCache, cfg: SSMConfig,
               rules: AxisRules | None) -> tuple[jax.Array, SSMCache]:
    """One-token recurrence. u (B, 1, d_model)."""
    B = u.shape[0]
    H, P, N = cfg.num_heads, cfg.headdim, cfg.d_state
    z, x, bc, dt_raw = _split_proj(p, u, cfg)
    xbc = jnp.concatenate([x, bc], axis=-1)[:, 0, :]             # (B, C)
    conv_in = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, W, C)
    mixed = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
    mixed = jax.nn.silu(mixed + p["conv_b"].astype(jnp.float32)).astype(u.dtype)
    x1, bc1 = mixed[..., : cfg.d_inner], mixed[..., cfg.d_inner :]
    Bv, Cv = jnp.split(bc1, 2, axis=-1)                          # (B, N)

    dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max)                    # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                                 # (B, H)

    xh = x1.reshape(B, H, P).astype(jnp.float32)
    bx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv.astype(jnp.float32), xh)
    new_state = a[:, :, None, None] * cache.state + bx
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(jnp.float32), new_state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, cfg.d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(u.dtype))
    new_conv = conv_in[:, 1:, :]
    return out, SSMCache(state=new_state, conv=new_conv, length=cache.length + 1)
