"""Shared neural-net building blocks for all assigned architectures.

Pure-functional style: every component is a pair of functions

  *_specs(cfg)  -> pytree of ParamSpec   (shapes + dtypes + logical axes)
  *_apply(p, x) -> activations

so the same code path serves initialization, dry-run ShapeDtypeStructs,
sharding tables, and execution.  No Flax/Haiku — parameters are plain nested
dicts of jax.Arrays.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.rules import AxisRules, ParamSpec, with_logical_constraint


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def scan_or_loop(body, carry, xs, unroll: bool, length: int | None = None):
    """lax.scan, or an unrolled Python loop (roofline-probe path: XLA cost
    analysis counts while-loop bodies once, so the probe unrolls)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        stacked = None
    return carry, stacked


def init_from_specs(specs, key: jax.Array, param_dtype=jnp.float32):
    """Materialize a ParamSpec pytree into parameter arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))

    def one(spec: ParamSpec, k):
        dtype = spec.dtype if spec.dtype is not None else param_dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "normal":
            return (jax.random.normal(k, spec.shape) * spec.init_scale).astype(dtype)
        if spec.init == "fan_in":
            fan_in = spec.shape[0] if len(spec.shape) == 1 else math.prod(spec.shape[:-1])
            return (jax.random.normal(k, spec.shape) / math.sqrt(max(fan_in, 1))).astype(dtype)
        raise ValueError(spec.init)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(dim: int, *, axis_name: str = "embed") -> dict:
    return {"scale": ParamSpec((dim,), (axis_name,), init="ones")}


def rmsnorm(p: dict | None, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm; with p=None it is OLMo's non-parametric LayerNorm variant
    (no scale / no bias), computed in fp32 for stability."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if p is not None:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


def nonparametric_layernorm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo: LayerNorm without elementwise affine (arXiv:2402.00838)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dtype)


def apply_norm(kind: str, p: dict | None, x: jax.Array) -> jax.Array:
    if kind == "rms":
        return rmsnorm(p, x)
    if kind == "nonparametric":
        return nonparametric_layernorm(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embedding / output head
# ---------------------------------------------------------------------------

def embed_specs(vocab: int, dim: int) -> dict:
    return {"table": ParamSpec((vocab, dim), ("vocab", "embed"), init="normal")}


def embed_lookup(p: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["table"].astype(compute_dtype)[tokens]


def unembed_logits(table_or_w: jax.Array, x: jax.Array, transpose: bool) -> jax.Array:
    """x (..., d) -> logits (..., V).  transpose=True for tied embeddings."""
    w = table_or_w.astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, w) if transpose else jnp.einsum("...d,dv->...v", x, w)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_specs(dim: int, hidden: int) -> dict:
    return {
        "wi_gate": ParamSpec((dim, hidden), ("embed", "mlp"), init="fan_in"),
        "wi_up": ParamSpec((dim, hidden), ("embed", "mlp"), init="fan_in"),
        "wo": ParamSpec((hidden, dim), ("mlp", "embed"), init="fan_in"),
    }


def mlp_apply(p: dict, x: jax.Array, rules: AxisRules | None,
              activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    dt = x.dtype
    gate = jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(dt))
    up = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(dt))
    h = act(gate) * up
    h = with_logical_constraint(h, ("batch", "seq", "mlp"), rules)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply rotary embeddings.  x (..., S, H, D), positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angle = positions[..., :, None, None].astype(jnp.float32) * freq  # (..., S, 1, half)
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (never materializes full (B,S,V) logits)
# ---------------------------------------------------------------------------

def softmax_xent_chunked(
    x: jax.Array,            # (B, S, d) final hidden states
    head_w: jax.Array,       # (d, V) or (V, d) if tied
    labels: jax.Array,       # (B, S) int32
    mask: jax.Array | None,  # (B, S) bool or None
    tied: bool,
    rules: AxisRules | None,
    chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Mean token cross-entropy with seq-chunked logits (O(B*chunk*V) peak)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else (
            jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad))))
    elif mask is None:
        mask = jnp.ones((B, S), bool)
    nchunks = x.shape[1] // chunk
    xc = x.reshape(B, nchunks, chunk, d).swapaxes(0, 1)          # (n, B, c, d)
    lc = labels.reshape(B, nchunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nchunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        loss_sum, count = carry
        xb, lb, mb = inp
        logits = unembed_logits(head_w, xb, tied)                # (B, c, V)
        logits = with_logical_constraint(logits, ("batch", "seq", "vocab"), rules)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mb
        return (loss_sum + nll.sum(), count + mb.sum()), None

    (loss_sum, count), _ = scan_or_loop(
        body, (jnp.float32(0), jnp.float32(0)), (xc, lc, mc), unroll)
    return loss_sum / jnp.maximum(count, 1.0)
