"""Grouped-query attention: chunked-causal training/prefill + KV-cache decode.

Design notes (TPU adaptation, see DESIGN.md §3):

* Training/prefill never materializes the full (S, S) score matrix — queries
  are processed in blocks of ``q_chunk`` under ``lax.scan`` with a causal
  (and optionally sliding-window) mask against the full key prefix.  Peak
  memory is O(B * H * q_chunk * S).  This is the XLA reference path; the
  Pallas flash kernel (``repro.kernels.flash_attention``) is the TPU path
  that additionally skips fully-masked key blocks.
* Decode is a single fused step against a (B, S_cache, Hkv, D) cache; for
  `long_500k` the cache's sequence axis is sharded over the data axis
  (SP_DECODE_RULES) and XLA turns the softmax reductions into collectives.
* Optional per-head RMS q/k-norm (Qwen3) and sliding-window masking
  (H2O-Danube3).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import AxisRules, ParamSpec, with_logical_constraint
from .layers import rmsnorm, rope, scan_or_loop


class AttnConfig(NamedTuple):
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10000.0
    q_chunk: int = 512
    causal: bool = True
    unroll: bool = False
    use_pallas: bool = False
    logits_fp32: bool = True   # perf lever: bf16 softmax halves attention bytes


def attn_specs(cfg: AttnConfig) -> dict:
    d, H, Hkv, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, H, D), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": ParamSpec((d, Hkv, D), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wv": ParamSpec((d, Hkv, D), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wo": ParamSpec((H, D, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.qk_norm:
        specs["q_norm"] = {"scale": ParamSpec((D,), ("head_dim",), init="ones")}
        specs["k_norm"] = {"scale": ParamSpec((D,), ("head_dim",), init="ones")}
    return specs


def _project_qkv(p: dict, x: jax.Array, cfg: AttnConfig, positions: jax.Array,
                 rules: AxisRules | None):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = with_logical_constraint(q, ("batch", "seq", "act_heads", None), rules)
    k = with_logical_constraint(k, ("batch", "kv_seq", "act_heads", None), rules)
    v = with_logical_constraint(v, ("batch", "kv_seq", "act_heads", None), rules)
    return q, k, v


def _gqa_scores_and_mix(q_blk, k, v, cfg: AttnConfig, q_pos, k_pos,
                        rules: AxisRules | None = None):
    """q_blk (B,Qb,H,D), k/v (B,S,Hkv,D) -> (B,Qb,H,D)."""
    B, Qb, H, D = q_blk.shape
    Hkv = k.shape[2]
    G = H // Hkv
    # Perf lever ("q_rows" logical axis): shard the query rows of each chunk
    # over the model axis.  This is how archs whose head counts do not divide
    # the 16-way model axis (musicgen 24H, zamba2 32kv at 80dim) still get
    # model-parallel attention compute instead of full replication.
    q_blk = with_logical_constraint(q_blk, ("batch", "q_rows", None, None), rules)
    qg = q_blk.reshape(B, Qb, Hkv, G, D)
    acc_t = jnp.float32 if cfg.logits_fp32 else q_blk.dtype
    scale = jnp.asarray(1.0 / jnp.sqrt(jnp.float32(D)), acc_t)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, k).astype(acc_t) * scale
    mask = jnp.ones((Qb, k.shape[1]), dtype=bool)
    if cfg.causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if cfg.sliding_window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - cfg.sliding_window
    neg = jnp.asarray(-1e30 if acc_t == jnp.float32 else -3e38, acc_t)
    logits = jnp.where(mask[None, None, None], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(q_blk.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs, v)
    return out.reshape(B, Qb, H, D)


def attention_train(
    p: dict,
    x: jax.Array,              # (B, S, d)
    positions: jax.Array,      # (S,) absolute positions
    cfg: AttnConfig,
    rules: AxisRules | None,
) -> jax.Array:
    """Chunked-causal self-attention for training / prefill scoring."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, rules)
    if cfg.use_pallas:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=cfg.causal, window=cfg.sliding_window)
        out = with_logical_constraint(out, ("batch", "seq", "act_heads", None), rules)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    Qb = min(cfg.q_chunk, S)
    if S % Qb:
        Qb = S              # irregular length: single query block
    nb = S // Qb
    q_blocks = q.reshape(B, nb, Qb, cfg.num_heads, cfg.head_dim).swapaxes(0, 1)
    pos_blocks = positions.reshape(nb, Qb)

    def body(_, inp):
        qb, qpos = inp
        out = _gqa_scores_and_mix(qb, k, v, cfg, qpos, positions, rules)
        return None, out

    _, out_blocks = scan_or_loop(body, None, (q_blocks, pos_blocks), cfg.unroll)
    out = out_blocks.swapaxes(0, 1).reshape(B, S, cfg.num_heads, cfg.head_dim)
    out = with_logical_constraint(out, ("batch", "seq", "act_heads", None), rules)
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def attention_train_with_kv(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: AttnConfig,
    rules: AxisRules | None,
    max_len: int,
) -> tuple[jax.Array, dict]:
    """Prefill path: chunked-causal attention that also emits the decode cache.

    The cache is laid out ring-buffer style (position p at slot p % size) so
    subsequent ``attention_decode`` writes continue seamlessly — for
    sliding-window configs size == window and only the last window of keys is
    retained.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, rules)
    Qb = min(cfg.q_chunk, S)
    if S % Qb:
        Qb = S              # irregular length: single query block
    nb = S // Qb
    q_blocks = q.reshape(B, nb, Qb, cfg.num_heads, cfg.head_dim).swapaxes(0, 1)
    pos_blocks = positions.reshape(nb, Qb)

    def body(_, inp):
        qb, qpos = inp
        return None, _gqa_scores_and_mix(qb, k, v, cfg, qpos, positions, rules)

    _, out_blocks = scan_or_loop(body, None, (q_blocks, pos_blocks), cfg.unroll)
    out = out_blocks.swapaxes(0, 1).reshape(B, S, cfg.num_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))

    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if S >= size:
        # keep last `size` positions, rotated so position p sits at slot p%size
        k_c = jnp.roll(k[:, S - size:], S % size, axis=1)
        v_c = jnp.roll(v[:, S - size:], S % size, axis=1)
    else:
        pad = size - S
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_c, "v": v_c, "length": jnp.int32(S)}
    return y, cache


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, S_cache, Hkv, D) — ring buffer if sliding window
    v: jax.Array
    length: jax.Array     # scalar int32: total tokens written so far


def kv_cache_specs(cfg: AttnConfig, batch: int, max_len: int, dtype) -> KVCache:
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    axes = ("batch", "kv_seq", "kv_heads", "head_dim")
    return KVCache(
        k=ParamSpec(shape, axes, dtype=dtype, init="zeros"),
        v=ParamSpec(shape, axes, dtype=dtype, init="zeros"),
        length=ParamSpec((), (), dtype=jnp.int32, init="zeros"),
    )


def attention_decode(
    p: dict,
    x: jax.Array,              # (B, 1, d)
    cache: KVCache,
    cfg: AttnConfig,
    rules: AxisRules | None,
    write_back: bool = True,
) -> tuple[jax.Array, KVCache]:
    """One decode step: append to cache, attend over valid prefix.

    With ``write_back=False`` the returned cache carries only the new-token
    projections (k/v of shape (B,1,Hkv,D)); the caller performs the in-place
    stacked-cache write (decode cache-in-carry path, §Perf B3).
    """
    B = x.shape[0]
    pos = cache.length
    positions = pos[None].astype(jnp.int32)  # (1,)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, rules)
    size = cache.k.shape[1]
    slot = (pos % size).astype(jnp.int32)
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    acc_t = jnp.float32 if cfg.logits_fp32 else cache.k.dtype
    scale = jnp.asarray(1.0 / jnp.sqrt(jnp.float32(D)), acc_t)
    neg = jnp.asarray(-1e30 if acc_t == jnp.float32 else -3e38, acc_t)
    idx = jnp.arange(size)

    if write_back:
        k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
        # Pin the updated cache to its declared layout: without this the SPMD
        # partitioner materializes a kv-heads-sharded copy inside the attention
        # pipeline and all-gathers the ENTIRE cache back every decode step
        # (measured 68GB/device/step on qwen3-8b decode_32k — §Perf iter B1).
        cache_axes = ("batch", "kv_seq", "kv_heads", "head_dim")
        k = with_logical_constraint(k, cache_axes, rules)
        v = with_logical_constraint(v, cache_axes, rules)
        logits = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(acc_t) * scale
        written = jnp.where(pos + 1 < size, idx <= slot, jnp.ones((size,), bool))
        logits = jnp.where(written[None, None, None, :], logits, neg)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhgs,bshd->bhgd", probs, v).reshape(B, 1, H, D)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        return y, KVCache(k=k, v=v, length=pos + 1)

    # §Perf iteration B3 (cache-in-carry): attend over the STALE cache with
    # the slot masked out, fold the new token's logit in separately — the
    # full-size cache is read once and never copied; only the (B,1,Hkv,D)
    # new-token projections are written back by the caller.
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, cache.k.astype(qg.dtype)).astype(acc_t) * scale
    written = jnp.where(pos < size, idx < slot + jnp.int32(pos >= size) * size,
                        jnp.ones((size,), bool))
    written = jnp.where(pos >= size, idx != slot, written)
    logits = jnp.where(written[None, None, None, :], logits, neg)
    logit_new = (jnp.einsum("bhgd,bshd->bhgs", qg, k_new.astype(qg.dtype))
                 .astype(acc_t) * scale)                       # (B,Hkv,G,1)
    full = jnp.concatenate([logits, logit_new], axis=-1)
    probs = jax.nn.softmax(full, axis=-1).astype(x.dtype)
    p_cache, p_new = probs[..., :-1], probs[..., -1:]
    out = jnp.einsum("bhgs,bshd->bhgd", p_cache, cache.v.astype(x.dtype))
    out = out + jnp.einsum("bhgs,bshd->bhgd", p_new, v_new.astype(x.dtype))
    out = out.reshape(B, 1, H, D)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, KVCache(k=k_new, v=v_new, length=pos + 1)
