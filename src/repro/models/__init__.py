from .model import (  # noqa: F401
    ModelConfig,
    decode_step,
    forward_logits_last,
    forward_loss,
    init_cache,
    init_params,
    make_cache_specs,
    model_specs,
    prefill,
)
