"""Capacity accounting: the resource half of the observability story.

The third observation plane (DESIGN.md §15, after §13's flight recorder and
§14's live health plane).  The service's dominant state is the per-tenant
GP posterior — preallocated (m, m) Cholesky/readout buffers whose *active*
share grows O(obs·m) per tenant (O(obs²) when the block tracks its observed
set) — and the sharded index space that decides how M devices split the
scoring work.  Neither was measurable before this plane: memory grew
invisibly and BENCH_shard_scale.json's weak-scaling collapse had no metric
naming a cause.

:class:`CapacityAccountant` samples both, from inside the engine pop loops:

* **posterior accounting** — ``ControlPlane.capacity_stats()`` introspects
  every live tenant block through ``BlockIncrementalGP.resource_stats()``
  (analytic byte formulas, no device syncs) and the accountant publishes
  aggregate gauges (``capacity.gp_alloc_bytes``, ``capacity.gp_obs`` ...)
  plus per-tenant labeled gauges (``capacity.tenant_bytes{tenant="3"}``).
* **shard occupancy** — ``ShardLayout.occupancy()`` gives per-shard live
  slot counts and the max/mean load-imbalance index
  (``capacity.shard_slots{shard="0"}``, ``capacity.load_imbalance``).
* **projection** — a least-squares slope over the recent byte samples
  projects total posterior bytes ``horizon`` sim-seconds ahead
  (``capacity.gp_bytes_projected``); the health plane's ``memory_runaway``
  watchdog consumes it, so the alert fires *before* the budget is blown.
* **fleet composition** — live device counts per class
  (``capacity.devices{cls="fast"}``) and whatever the engine's
  ``_capacity_extra()`` hook adds (the devplane engine reports autoscale
  joins/leaves and scoring passes).

Discipline (the same as every other plane): observation-only — gauges never
feed a decision, a run with the accountant attached is byte-identical to a
bare twin — and replay-stable — samples fire at sim-time window boundaries
(a pure function of the event stream), the sample cursor + projection
history ride in the engine snapshot under ``meta["obs"]["capacity"]``, so
a crash-recovered run re-emits the identical gauge/alert suffix.
"""

from __future__ import annotations

ACCOUNTING_SCHEMA_VERSION = 1


def _fit_slope(samples: list[tuple[float, float]]) -> float:
    """Least-squares d(bytes)/d(sim-second) over ``(t, bytes)`` samples;
    0.0 when under-determined (fewer than 2 distinct times)."""
    if len(samples) < 2:
        return 0.0
    n = len(samples)
    mt = sum(t for t, _ in samples) / n
    mb = sum(b for _, b in samples) / n
    den = sum((t - mt) ** 2 for t, _ in samples)
    if den <= 0.0:
        return 0.0
    num = sum((t - mt) * (b - mb) for t, b in samples)
    return num / den


class CapacityAccountant:
    """Windowed capacity sampler fed once per processed event.

    ``tick(t, event_index, engine)`` is the engine pop-loop site: the first
    event whose sim-time crosses a ``window``-second boundary takes one
    sample (so idle windows cost nothing and sampling is deterministic);
    the end-of-run path calls :meth:`sample` directly so short runs still
    publish gauges.  Construct with the run's ``MetricsRegistry`` and hand
    to ``StreamEngine(accounting=...)``.

    ``horizon`` is the projection lookahead in sim-seconds;
    ``history`` bounds the projection fit window (samples, not seconds).
    """

    def __init__(self, metrics, *, window: float = 10.0,
                 horizon: float = 60.0, history: int = 8):
        if window <= 0:
            raise ValueError("window must be positive")
        if history < 2:
            raise ValueError("history must be >= 2 (projection needs a fit)")
        self.metrics = metrics
        self.window = float(window)
        self.horizon = float(horizon)
        self.history = int(history)
        self.samples: list[dict] = []
        self._last_window = -1
        self._byte_hist: list[tuple[float, float]] = []

    # -- the engine feed ---------------------------------------------------

    def tick(self, t: float, event_index: int, engine) -> None:
        w = int(t // self.window)
        if w <= self._last_window:
            return
        self._last_window = w
        self.sample(t, event_index, engine)

    def sample(self, t: float, event_index: int, engine) -> dict:
        """Take one capacity sample: introspect, publish gauges, project,
        and feed the health plane's memory watchdog.  Returns the sample
        record (also appended to ``self.samples`` for the report plane)."""
        stats = engine.cp.capacity_stats()
        gp, layout = stats.get("gp"), stats.get("layout")
        rec = {"schema_version": ACCOUNTING_SCHEMA_VERSION,
               "t": float(t), "event_index": int(event_index)}
        g = self.metrics.gauge if self.metrics is not None else None

        total_bytes = 0.0
        if gp is not None:
            alloc = gp.get("alloc_bytes", 0)
            readout = gp.get("readout_bytes", 0)
            total_bytes = float(alloc + readout)
            rec.update(gp_blocks=gp.get("num_blocks", 1),
                       gp_obs=gp.get("obs_total", gp.get("obs", 0)),
                       gp_alloc_bytes=int(alloc),
                       gp_active_bytes=int(gp.get("active_bytes", 0)),
                       gp_readout_bytes=int(readout),
                       gp_bytes=int(total_bytes))
            if g is not None:
                g("capacity.gp_blocks").set(rec["gp_blocks"])
                g("capacity.gp_obs").set(rec["gp_obs"])
                g("capacity.gp_alloc_bytes").set(rec["gp_alloc_bytes"])
                g("capacity.gp_active_bytes").set(rec["gp_active_bytes"])
                g("capacity.gp_readout_bytes").set(rec["gp_readout_bytes"])
                g("capacity.gp_bytes").set(rec["gp_bytes"])
                for tid, bstat in (gp.get("tenants") or {}).items():
                    labels = {"tenant": str(tid)}
                    g("capacity.tenant_bytes", labels).set(
                        bstat["alloc_bytes"])
                    g("capacity.tenant_obs", labels).set(bstat["obs"])

        if layout is not None:
            rec.update(slots_total=layout["slots_total"],
                       slots_live=layout["slots_live"],
                       slots_free=layout["slots_free"],
                       shard_slots=list(layout["per_shard"]),
                       load_imbalance=float(layout["imbalance"]))
            if g is not None:
                g("capacity.slots_total").set(layout["slots_total"])
                g("capacity.slots_live").set(layout["slots_live"])
                g("capacity.slots_free").set(layout["slots_free"])
                g("capacity.load_imbalance").set(float(layout["imbalance"]))
                for s, live in enumerate(layout["per_shard"]):
                    g("capacity.shard_slots", {"shard": str(s)}).set(live)

        by_cls: dict[str, int] = {}
        for sl in engine.fleet.slices:
            if not sl.retired:
                by_cls[sl.cls] = by_cls.get(sl.cls, 0) + 1
        rec["devices"] = dict(sorted(by_cls.items()))
        if g is not None:
            for cls, n in sorted(by_cls.items()):
                g("capacity.devices", {"cls": cls}).set(n)

        extra = engine._capacity_extra()
        for key, val in sorted(extra.items()):
            rec[key] = val
            if g is not None:
                g(f"capacity.{key}").set(val)

        # projection: bytes-at-horizon from the recent sample slope.  The
        # history is (t, bytes) pairs only — small, JSON-able, snapshot-safe.
        self._byte_hist.append((float(t), total_bytes))
        del self._byte_hist[:-self.history]
        slope = _fit_slope(self._byte_hist)
        projected = total_bytes + slope * self.horizon
        rec["gp_bytes_projected"] = int(max(projected, 0.0))
        rec["gp_bytes_slope"] = float(slope)
        if g is not None:
            g("capacity.gp_bytes_projected").set(rec["gp_bytes_projected"])

        if getattr(engine, "health", None) is not None:
            engine.health.on_capacity(
                t, event_index, bytes_now=total_bytes,
                projected_bytes=float(max(projected, 0.0)))

        self.samples.append(rec)
        return rec

    def latest(self) -> dict | None:
        """The most recent sample (the report plane's capacity section)."""
        return self.samples[-1] if self.samples else None

    # -- persistence (rides in the engine snapshot) ------------------------

    def state_dict(self) -> dict:
        return {"schema_version": ACCOUNTING_SCHEMA_VERSION,
                "last_window": self._last_window,
                "byte_hist": [[t, b] for t, b in self._byte_hist]}

    def load_state(self, state: dict) -> None:
        self._last_window = int(state["last_window"])
        self._byte_hist = [(float(t), float(b))
                           for t, b in state["byte_hist"]]
        # samples are NOT restored: like alerts, a resumed run re-emits
        # only its suffix — the cursor above keeps the timing identical
        self.samples = []


__all__ = ["CapacityAccountant", "ACCOUNTING_SCHEMA_VERSION"]
