"""Device-time attribution: capture windows, shard skew, dispatch cost.

The capacity plane's timing half (DESIGN.md §15).  ``obs/accounting.py``
answers *where the bytes are*; this module answers *where the device time
goes* — specifically, why BENCH_shard_scale.json's weak scaling collapses
(efficiency 0.16 at 8 shards).  Three probes, composed by
``benchmarks/capacity.py`` into BENCH_capacity.json rows that decompose
the weak-scaling gap into named causes:

* :func:`capture` — a ``jax.profiler`` capture-window context manager
  around any region; the resulting TensorBoard/Perfetto trace carries the
  ``jax.named_scope`` phase annotations the scoring programs already emit
  (``gp_readout`` / ``score_topk`` / ``all_gather``).  Degrades to a no-op
  when the profiler (or jax) is unavailable, so call sites never gate.
* :func:`per_shard_skew` — runs one caller-built thunk pinned to each
  device of a scoring mesh (single-device sub-meshes) and reports the
  per-device timing spread.  On forced host-platform devices the "devices"
  share physical cores, so the spread measures exactly the contention +
  imbalance a real multi-chip mesh hides inside its slowest-shard barrier.
* :func:`dispatch_overhead_us` — times a trivially small ``shard_map``
  program on the real mesh: all compute rounds to zero, what remains is
  the per-call dispatch + partitioning overhead that one fused decision
  pays regardless of |L|.

Everything here is host-side benchmarking machinery: nothing is wired into
the engines, nothing feeds a decision, and jax is imported lazily so the
obs package keeps its zero-dependency envelope.
"""

from __future__ import annotations

import contextlib
import time as _time

PROFILE_SCHEMA_VERSION = 1


def profiler_available() -> bool:
    """True when ``jax.profiler`` trace capture is importable."""
    try:
        from jax import profiler  # noqa: F401
        return hasattr(profiler, "start_trace")
    except Exception:
        return False


@contextlib.contextmanager
def capture(logdir: str | None = None):
    """``jax.profiler`` capture window: everything inside the ``with``
    lands in a TensorBoard/Perfetto trace under ``logdir``.  Yields True
    when a capture is actually running, False when ``logdir`` is None or
    the profiler is unavailable — callers need no gating of their own."""
    if logdir is None:
        yield False
        return
    try:
        from jax import profiler
        profiler.start_trace(str(logdir))
    except Exception:
        yield False
        return
    try:
        yield True
    finally:
        try:
            profiler.stop_trace()
        except Exception:
            pass


def time_us_blocked(fn, *, iters: int = 10, warmup: int = 2) -> float:
    """Mean wall µs per call with a ``block_until_ready`` barrier after
    every call — async dispatch must not let timings overlap."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = _time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (_time.perf_counter() - t0) / iters * 1e6


def single_device_mesh(device):
    """A one-device ``("shard",)`` mesh pinned to ``device`` — the same
    axis name the scoring programs expect, so a thunk built against it runs
    the genuine single-shard program on exactly that device."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray([device]), ("shard",))


def per_shard_skew(make_thunk, devices=None, *, iters: int = 10,
                   warmup: int = 2) -> dict:
    """Per-device timing spread of one shard's workload.

    ``make_thunk(shard_index, mesh)`` builds a zero-arg callable running
    that shard's slice of work on the given single-device mesh (state
    construction happens inside the builder, outside the timed region).
    Returns the per-device µs plus the same max/mean skew index the layout
    plane uses for slots (``ShardLayout.imbalance``), so byte imbalance
    and time imbalance read on one scale.
    """
    import jax
    if devices is None:
        devices = jax.devices()
    per: list[float] = []
    for s, dev in enumerate(devices):
        thunk = make_thunk(s, single_device_mesh(dev))
        per.append(time_us_blocked(thunk, iters=iters, warmup=warmup))
    mean = sum(per) / len(per)
    return {"schema_version": PROFILE_SCHEMA_VERSION,
            "per_shard_us": per,
            "mean_us": mean, "max_us": max(per), "min_us": min(per),
            "skew": max(per) / mean if mean > 0 else 1.0}


def dispatch_overhead_us(mesh, *, iters: int = 50, warmup: int = 5) -> float:
    """Per-call overhead of dispatching a ``shard_map`` program on ``mesh``:
    the program's compute (one add over S floats) rounds to zero, so the
    measured time is partitioning + launch + the cross-device sync — the
    fixed cost every fused decision pays before any real work."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.shardgp.score import _NO_REP_CHECK, shard_map

    @jax.jit
    def trivial(x):
        def local(x):
            return x + 1.0
        return shard_map(local, mesh=mesh, in_specs=(P("shard"),),
                         out_specs=P("shard"), **_NO_REP_CHECK)(x)

    x = jax.device_put(jnp.zeros(mesh.devices.size, jnp.float32),
                       NamedSharding(mesh, P("shard")))
    return time_us_blocked(lambda: trivial(x), iters=iters, warmup=warmup)


__all__ = ["capture", "profiler_available", "time_us_blocked",
           "single_device_mesh", "per_shard_skew", "dispatch_overhead_us",
           "PROFILE_SCHEMA_VERSION"]
