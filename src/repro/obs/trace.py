"""Decision-path tracing: nestable spans with deterministic ids.

A :class:`Tracer` records *spans* — named, attributed, monotonic-clock
intervals — arranged in trees by nesting.  The design constraints come from
the control plane it instruments (DESIGN.md §13):

* **Deterministic ids.**  ``trace_id`` is set by the caller (the streaming
  engine uses the processed-event index, ``begin_trace(event_index)``) and
  ``span_id`` counts from 0 *within* each trace.  Ids therefore depend only
  on the code path taken, never on wall clock or randomness — which is what
  lets the crash-anywhere replay oracle assert that a recovered run
  re-emits the identical span tree for the replayed suffix, and what makes
  the trace id threaded into each EventLog processed record a stable
  correlation key.

* **Device-aware timing.**  JAX dispatch is async: the wall time of the
  Python call that *launches* a program says nothing about the program's
  cost.  ``tracer.sync(x)`` calls ``jax.block_until_ready`` when tracing is
  enabled — so the enclosing span measures execution, not dispatch (the
  same primitive ``benchmarks/common.time_us(sync=True)`` uses) — and is a
  pass-through when disabled, preserving the untraced pipeline's async
  behavior exactly.

* **Near-zero cost when off.**  ``span()`` on a disabled tracer returns a
  shared no-op context manager: one branch + one ``with`` per site.
  BENCH_decision_trace.json carries the measured overhead row (<1% of a
  |L|=100k decision is the acceptance bar).

* **Profiler bridge.**  ``Tracer(profiler=True)`` additionally enters a
  ``jax.profiler.TraceAnnotation`` per span, so host spans land in
  TensorBoard/Perfetto device profiles alongside the ``jax.named_scope``
  annotations compiled into the sharded decision program
  (``shardgp/score.py``).

Span records are plain dicts (``records()`` / ``to_json(path)``); the
structural view for equality testing is ``signature()`` — (trace, span,
parent, name, attrs) tuples with all timing stripped.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

TRACE_SCHEMA_VERSION = 1

ROOT_TRACE = -1   # trace id of spans opened before any begin_trace()


def block_ready(x):
    """``jax.block_until_ready`` if jax is importable, else identity — the
    one timing primitive shared by spans and the benchmark harness."""
    try:
        import jax
    except ImportError:      # pragma: no cover - jax is a core dependency
        return x
    return jax.block_until_ready(x)


class _NullSpan:
    """Shared no-op context manager returned by disabled tracers.  One
    instance, no per-call allocation."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One open span; closes (and records itself) on ``__exit__``."""

    __slots__ = ("tracer", "name", "attrs", "trace_id", "span_id",
                 "parent_id", "t0", "_annotation")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self._annotation = None

    def __enter__(self):
        tr = self.tracer
        self.trace_id = tr._trace_id
        self.span_id = tr._next_span
        tr._next_span += 1
        self.parent_id = tr._stack[-1].span_id if tr._stack else None
        tr._stack.append(self)
        if tr.profiler:
            self._annotation = tr._annotation(self.name)
            if self._annotation is not None:
                self._annotation.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
        tr = self.tracer
        # a crash inside a child may unwind out of order; pop to this span
        while tr._stack and tr._stack[-1] is not self:
            tr._stack.pop()
        if tr._stack:
            tr._stack.pop()
        tr.spans.append({
            "trace": self.trace_id,
            "span": self.span_id,
            "parent": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "dur_us": (t1 - self.t0) * 1e6,
            "attrs": self.attrs,
        })
        return False


class Tracer:
    """Span collector with deterministic ids (module docstring).

    ``enabled=False`` (the engines' default) makes every method a cheap
    no-op; flip at construction, not mid-run — span ids are only meaningful
    for a consistent setting.
    """

    def __init__(self, enabled: bool = True, *, profiler: bool = False):
        self.enabled = enabled
        self.profiler = profiler and enabled
        self.spans: list[dict] = []
        self._trace_id: int = ROOT_TRACE
        self._next_span: int = 0
        self._stack: list[_Span] = []

    # ---- recording ---------------------------------------------------------

    def begin_trace(self, trace_id: int) -> None:
        """Start a new trace: subsequent spans carry ``trace_id`` and span
        ids restart from 0.  The engine calls this with the processed-event
        index, which is what makes replayed suffixes re-emit identical
        ids."""
        if not self.enabled:
            return
        self._trace_id = trace_id
        self._next_span = 0
        self._stack.clear()

    def span(self, name: str, **attrs):
        """Context manager for one span.  Attrs must be deterministic
        (model ids, shard counts, event kinds — never wall-clock values):
        they are part of the replay-equality signature."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def sync(self, x):
        """Block on device work before the enclosing span closes (enabled),
        or pass through untouched (disabled).  Values are identical either
        way — tracing never changes a decision."""
        if self.enabled:
            return block_ready(x)
        return x

    @property
    def current_trace(self) -> int | None:
        """The trace id stamped into EventLog processed records (None when
        disabled — records keep their untraced 4-field shape)."""
        return self._trace_id if self.enabled else None

    def _annotation(self, name: str):
        try:  # pragma: no cover - exercised only with jax present (always)
            from jax.profiler import TraceAnnotation
        except ImportError:  # pragma: no cover
            return None
        return TraceAnnotation(name)

    # ---- export ------------------------------------------------------------

    def records(self) -> list[dict]:
        """Finished spans, in completion order (children before parents)."""
        return list(self.spans)

    def signature(self, min_trace: int | None = None) -> list[tuple]:
        """Structural view for equality tests: (trace, span, parent, name,
        sorted attr items), timing stripped.  ``min_trace`` keeps only
        traces with id >= it — the replayed-suffix comparison."""
        out = []
        for s in self.spans:
            if min_trace is not None and s["trace"] < min_trace:
                continue
            out.append((s["trace"], s["span"], s["parent"], s["name"],
                        tuple(sorted(s["attrs"].items()))))
        return out

    def to_json(self, path: str | Path) -> Path:
        path = Path(path)
        path.write_text(json.dumps(
            {"schema_version": TRACE_SCHEMA_VERSION, "spans": self.spans},
            indent=2, sort_keys=True, allow_nan=False))
        return path


NULL_TRACER = Tracer(enabled=False)

__all__ = ["Tracer", "NULL_TRACER", "ROOT_TRACE", "block_ready",
           "TRACE_SCHEMA_VERSION"]
