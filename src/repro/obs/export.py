"""Streaming metrics export: windowed JSONL + Prometheus text rendering.

The passive registry (``obs/metrics.py``) only surfaces at end of run —
this module samples it *live* from inside the engine pop loops
(DESIGN.md §14).  :class:`MetricsExporter` is ticked once per processed
event with the engine's **sim-time** clock; whenever the event stream
crosses a window boundary it appends one snapshot record to an append-only
JSONL stream.  Export *timing* is therefore a pure function of the event
log — a crash-recovered run re-emits windows for the replayed suffix at
exactly the sim-times the uninterrupted run used (the exporter's window
cursor rides in the engine snapshot).  Export *content* includes
wall-clock histograms (decision latency), which is fine: nothing consumes
exports back into the decision path, and the replay oracle never compares
them (same observation-only discipline as spans, §13).

``prometheus_text`` renders a registry snapshot in the Prometheus
exposition format — labeled series produced by
``MetricsRegistry.counter(name, labels=...)`` already carry
``name{k="v"}`` flat keys, so the rendering is mostly name sanitization
plus histogram summary expansion (``_count``/``_sum``/quantile series).
The capacity plane's gauges (``capacity.gp_bytes``,
``capacity.shard_slots{shard="0"}`` ... — obs/accounting.py) flow through
unchanged; health-plane alert *counts* are not registry metrics, so the
exporter renders them itself (``health_alerts_total{kind="..."}``) when a
``HealthMonitor`` is attached — alerts previously reached only
alerts.jsonl and the report, never the scrape surface.
"""

from __future__ import annotations

import json
import re


def _split_key(key: str) -> tuple[str, str]:
    """``name{k="v"}`` -> (name, ``{k="v"}``); bare names get ``""``."""
    if "{" in key:
        name, rest = key.split("{", 1)
        return name, "{" + rest
    return key, ""


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


def _prom_val(v) -> str:
    if v is None:
        return "NaN"
    return repr(float(v)) if isinstance(v, float) else str(v)


def prometheus_text(snapshot: dict) -> str:
    """Render a ``MetricsRegistry.snapshot()`` in Prometheus exposition
    format.  Deterministic: snapshot dicts are sorted, label items are
    sorted at key-construction time."""
    lines: list[str] = []
    seen_type: set[str] = set()

    def typed(name: str, kind: str) -> None:
        if name not in seen_type:
            seen_type.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for key, v in snapshot.get("counters", {}).items():
        name, labels = _split_key(key)
        name = _prom_name(name) + "_total"
        typed(name, "counter")
        lines.append(f"{name}{labels} {_prom_val(v)}")
    for key, g in snapshot.get("gauges", {}).items():
        name, labels = _split_key(key)
        name = _prom_name(name)
        typed(name, "gauge")
        lines.append(f"{name}{labels} {_prom_val(g['value'])}")
        typed(name + "_max", "gauge")
        lines.append(f"{name}_max{labels} {_prom_val(g['max'])}")
    for key, s in snapshot.get("histograms", {}).items():
        name, labels = _split_key(key)
        name = _prom_name(name)
        typed(name, "summary")
        for q, field in (("0.5", "p50"), ("0.99", "p99")):
            qlab = (labels[:-1] + f',quantile="{q}"}}' if labels
                    else f'{{quantile="{q}"}}')
            lines.append(f"{name}{qlab} {_prom_val(s[field])}")
        lines.append(f"{name}_sum{labels} {_prom_val(s['sum'])}")
        lines.append(f"{name}_count{labels} {_prom_val(s['count'])}")
    return "\n".join(lines) + "\n"


EXPORT_SCHEMA_VERSION = 1


class MetricsExporter:
    """Sim-time-windowed registry sampler.

    ``tick(t, event_index)`` is called once per processed event; the first
    event whose sim-time lands in a new ``window``-second window emits one
    snapshot record (so idle windows emit nothing and emission is a
    deterministic function of the event stream).  Records accumulate
    in-memory and — when ``path`` is given — stream write-through to
    append-only JSONL, one object per line.

    The only mutable cursor (``last window emitted``) has
    ``state_dict``/``load_state`` hooks; engines persist it in their
    snapshots so a recovered run's suffix emits the identical windows.

    ``health`` (a ``HealthMonitor``, attached by the engine when both
    planes run) folds per-kind alert counts into every snapshot record and
    into the Prometheus rendering as ``health_alerts_total{kind="..."}``.
    Alert counts are a pure function of the event stream (health.py), so
    the records stay replay-stable.
    """

    def __init__(self, metrics, path: str | None = None,
                 window: float = 10.0, health=None):
        if window <= 0:
            raise ValueError("window must be positive")
        self.metrics = metrics
        self.window = float(window)
        self.health = health
        self.records: list[dict] = []
        self._last_window = -1
        self._fh = open(path, "a", encoding="utf-8") if path else None

    def _emit(self, rec: dict) -> None:
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, allow_nan=False) + "\n")
            self._fh.flush()

    def _alert_counts(self) -> dict[str, int] | None:
        if self.health is None:
            return None
        counts: dict[str, int] = {}
        for a in self.health.alerts:
            counts[a.kind] = counts.get(a.kind, 0) + 1
        return dict(sorted(counts.items()))

    def _record(self, t: float, event_index: int, **extra) -> dict:
        rec = {"schema_version": EXPORT_SCHEMA_VERSION,
               "window": int(t // self.window), "t": float(t),
               "event_index": int(event_index), **extra,
               "metrics": self.metrics.snapshot()}
        alerts = self._alert_counts()
        if alerts is not None:
            rec["alerts"] = alerts
        return rec

    def tick(self, t: float, event_index: int) -> None:
        w = int(t // self.window)
        if w <= self._last_window:
            return
        self._last_window = w
        self._emit(self._record(t, event_index))

    def final(self, t: float, event_index: int) -> None:
        """End-of-run flush: one closing record regardless of window
        position (both the uninterrupted run and a resumed run end at the
        same sim-time, so this too replays stably)."""
        self._emit(self._record(t, event_index, final=True))

    def prometheus(self) -> str:
        text = prometheus_text(self.metrics.snapshot())
        alerts = self._alert_counts()
        if alerts is None:
            return text
        lines = ["# TYPE health_alerts_total counter"]
        for kind, n in alerts.items():
            lines.append(f'health_alerts_total{{kind="{kind}"}} {n}')
        return text + "\n".join(lines) + "\n"

    def state_dict(self) -> dict:
        return {"last_window": self._last_window}

    def load_state(self, state: dict) -> None:
        self._last_window = int(state["last_window"])

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


__all__ = ["MetricsExporter", "prometheus_text", "EXPORT_SCHEMA_VERSION"]
