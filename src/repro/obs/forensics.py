"""Per-decision forensics: WHY did GP-EI pick this (model, tenant) pair?

The decision path reduces the whole live pool to one argmax and throws the
rest away; when a tenant asks "why is my trial not running", the operator
has nothing.  :class:`ForensicsRecorder` captures, for every policy
decision, the attribution the scoring program already materializes
(DESIGN.md §14):

* the winner and runner-up with their EIrate scores and the argmax
  *margin* between them;
* the μ/σ/cost decomposition of each top-k candidate (EIrate = EI/cost,
  so EI recovers as ``score × effective_cost`` — no extra scoring pass);
* a uniform-cost counterfactual: who would win if every trial cost the
  same — i.e. is this pick EI-driven or cheapness-driven?  (For the
  sharded scorer the counterfactual argmax is taken *within* the
  materialized top-k — exact whenever the uniform-cost winner's EIrate
  also reaches the top-k, which is the overwhelmingly common case; the
  fused path scores the full pool so its counterfactual is exact.)

Recording is observation-only: the engines' decision path is unchanged
(the sharded ``decide()`` is literally the head of ``decide_topk()``, so
forensics just keeps the k values the decision already computed), records
never enter engine snapshots, and every field is derived from sim-time/
decision state — a crash-recovered run re-emits byte-identical records
for its replayed suffix (tests/test_eventlog.py).  Records are keyed by
``(event_index, seq)`` — ``seq`` separates the multiple per-class
decisions of one batched devplane wave.
"""

from __future__ import annotations

import json
import math

FORENSICS_SCHEMA_VERSION = 1


def _f(v) -> float | None:
    """JSON-safe float: allow_nan=False streams reject inf/nan."""
    v = float(v)
    return v if math.isfinite(v) else None


class ForensicsRecorder:
    """Append-only per-decision attribution stream.

    Hand to ``StreamEngine(forensics=...)``; the engine threads it into
    ``ControlPlane.set_forensics`` and calls :meth:`begin_event` once per
    processed event so records carry (event_index, seq) keys.  With
    ``path`` set, records stream write-through to JSONL.
    """

    def __init__(self, path: str | None = None):
        self.records: list[dict] = []
        self._fh = open(path, "a", encoding="utf-8") if path else None
        self._t = 0.0
        self._event_index = -1
        self._seq = 0

    def begin_event(self, t: float, event_index: int) -> None:
        self._t = float(t)
        self._event_index = int(event_index)
        self._seq = 0

    def _candidate(self, model: int, score: float, eff_cost: float,
                   mu: float | None, sd: float | None) -> dict:
        ei = score * eff_cost if math.isfinite(score) else float("-inf")
        return {"model": int(model), "eirate": _f(score), "ei": _f(ei),
                "mu": _f(mu) if mu is not None else None,
                "sd": _f(sd) if sd is not None else None,
                "cost": _f(eff_cost)}

    def on_decision(self, *, scorer: str, values, gids, eff_costs,
                    mu=None, sd=None, speed: float = 1.0,
                    device_class: str | None = None) -> dict:
        """Record one scoring decision from its materialized top-k.

        ``values``/``gids``/``eff_costs`` are aligned (k,) sequences of
        EIrate scores, global model ids, and the *effective* per-candidate
        costs the scores were divided by (cost/speed, or the class's
        affine cost row).  ``mu``/``sd`` are optional aligned posterior
        slices for the decomposition.
        """
        cands = []
        for j in range(len(values)):
            v = float(values[j])
            if not math.isfinite(v) or v <= -1e29:
                break           # padded / inert tail of the top-k
            cands.append(self._candidate(
                int(gids[j]), v, float(eff_costs[j]),
                None if mu is None else float(mu[j]),
                None if sd is None else float(sd[j])))
        winner = cands[0] if cands else None
        runner = cands[1] if len(cands) > 1 else None
        margin = (winner["eirate"] - runner["eirate"]
                  if winner and runner and winner["eirate"] is not None
                  and runner["eirate"] is not None else None)
        # uniform-cost counterfactual: argmax of EI alone over the top-k
        # (ties to the lowest model id, matching the decision tie-break)
        cf = None
        if cands:
            best = max(c["ei"] for c in cands if c["ei"] is not None)
            cf_model = min(c["model"] for c in cands if c["ei"] == best)
            cf = {"model": cf_model,
                  "changes_pick": bool(cf_model != winner["model"])}
        rec = {"schema_version": FORENSICS_SCHEMA_VERSION,
               "t": self._t, "event_index": self._event_index,
               "seq": self._seq, "scorer": scorer, "speed": _f(speed),
               "device_class": device_class,
               "winner": winner, "runner_up": runner, "margin": _f(margin)
               if margin is not None else None,
               "uniform_cost": cf, "topk": cands}
        self._seq += 1
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, allow_nan=False) + "\n")
            self._fh.flush()
        return rec

    def on_incident(self, *, kind: str, **detail) -> dict:
        """Record one failure-domain incident (DESIGN.md §16): a trial
        timeout, a poisoned observation, a device quarantine, a mesh
        shrink.  Incident records share the decision stream (and its
        (event_index, seq) keying) but carry ``"record": "incident"`` so
        report tooling can split them; every float is sanitized for the
        allow_nan=False stream."""
        clean = {k: (_f(v) if isinstance(v, float) else v)
                 for k, v in detail.items()}
        rec = {"schema_version": FORENSICS_SCHEMA_VERSION,
               "record": "incident",
               "t": self._t, "event_index": self._event_index,
               "seq": self._seq, "kind": kind, "detail": clean}
        self._seq += 1
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, allow_nan=False) + "\n")
            self._fh.flush()
        return rec

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


__all__ = ["ForensicsRecorder", "FORENSICS_SCHEMA_VERSION"]
