"""Service metrics registry: counters, gauges, fixed-bucket histograms.

The lightweight, zero-dependency registry the streaming engines feed
(DESIGN.md §13): decision counts and latency, admission-queue depth,
compaction pause, snapshot latency, per-device busy fraction.  Everything
is a plain Python accumulator — no locks (the engines are single-threaded
event loops), no background threads, no exporters.  ``snapshot()`` returns
a JSON-able dict that rides along in the telemetry sink's payload
(``TelemetrySink.to_json(metrics=...)``) and the per-run report
(``obs/report.py``).

Metrics are observation-only by construction: they never enter engine
snapshots and the crash-anywhere replay oracle never compares them, so
wall-clock-valued histograms cannot break byte-identical replay.

Histograms use fixed bucket upper bounds (default: 5 buckets per decade
from 1µs to 100s — trial durations and decision latencies both fit).
``percentile(q)`` interpolates linearly inside the located bucket and
clamps to the observed min/max, so p50/p99 are bucket-resolution estimates,
not exact order statistics — the right trade for an always-on hot-path
counter.  Observations above the last finite bound land in an explicit
``+inf`` overflow bucket; percentiles falling there interpolate between the
top bound and the observed max, and ``summary()`` reports ``saturated``
so readers know the tail estimate is max-clamped rather than
bucket-resolved.

Counters and gauges accept optional ``labels`` (per-device-class,
per-priority, ...): each label set is its own time series, snapshot under
the Prometheus-style flat key ``name{k="v",...}`` (label items sorted, so
keys are deterministic).  Unlabeled metrics keep their bare names —
``snapshot()``'s schema is backward compatible.  ``series(name)`` returns
the (labels, metric) pairs of a labeled family so export and health rules
never parse mangled metric keys.
"""

from __future__ import annotations

import math


def _default_time_buckets() -> tuple[float, ...]:
    # 5 per decade, 1e-6s .. 1e2s: 41 finite bounds + implicit overflow
    return tuple(10.0 ** (-6 + i / 5) for i in range(41))


DEFAULT_TIME_BUCKETS = _default_time_buckets()


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value, plus the max ever set (queue-depth style series
    often only need "current" and "worst")."""

    __slots__ = ("value", "max")

    def __init__(self):
        self.value = None
        self.max = None

    def set(self, v: float) -> None:
        self.value = v
        self.max = v if self.max is None else max(self.max, v)


class Histogram:
    """Fixed-bucket histogram with p50/p99 snapshot estimates.

    ``bounds`` are ascending finite upper bounds; values above the last
    bound land in the explicit ``+inf`` overflow bucket
    (``counts[len(bounds)]``) — never silently attributed to the last
    finite bucket.  ``saturated`` is True once that bucket is non-empty:
    percentile estimates that land there are max-clamped interpolations,
    not bucket-resolved.  Non-finite observations are counted separately
    (``dropped``) instead of poisoning the stats.
    """

    __slots__ = ("bounds", "counts", "count", "total", "min", "max",
                 "dropped")

    def __init__(self, bounds: tuple[float, ...] = DEFAULT_TIME_BUCKETS):
        if list(bounds) != sorted(bounds) or len(bounds) == 0:
            raise ValueError("bounds must be non-empty and ascending")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # + explicit +inf bucket
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.dropped = 0

    def observe(self, v: float) -> None:
        if v is None or not math.isfinite(v):
            self.dropped += 1
            return
        # linear scan is fine: bucket lists are ~40 long and observe() is
        # called once per *decision*, not per model
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> float | None:
        """Bucket-interpolated q-th percentile (q in [0, 100]); None when
        empty."""
        if self.count == 0:
            return None
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else (
                    self.min if self.min is not None else 0.0)
                hi = self.bounds[i] if i < len(self.bounds) else (
                    self.max if self.max is not None else lo)
                frac = (target - cum) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return float(min(max(est, self.min), self.max))
            cum += c
        return float(self.max)   # pragma: no cover - cum==count handled above

    @property
    def saturated(self) -> bool:
        """True once any observation exceeded the top finite bound (mass
        sits in the ``+inf`` bucket; tail percentiles are max-clamped)."""
        return self.counts[len(self.bounds)] > 0

    def summary(self) -> dict:
        mean = self.total / self.count if self.count else None
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "mean": mean,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "dropped_non_finite": self.dropped,
            "saturated": self.saturated,
        }


def _labeled_key(name: str, labels: dict | None) -> str:
    """Prometheus-style flat series key: ``name{k="v",...}`` with label
    items sorted so the key is deterministic; bare ``name`` when
    unlabeled."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Named metric store with get-or-create accessors.  Asking for an
    existing name with the same kind (and labels) returns the same object
    (engines cache handles at construction; ad-hoc callers just look up by
    name).  Labeled series share one *family* name — the whole family must
    be one kind."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._kinds: dict[str, dict] = {}     # family name -> owning store
        self._labels: dict[str, dict] = {}    # series key -> labels dict

    def _check_free(self, name: str, own: dict) -> None:
        store = self._kinds.setdefault(name, own)
        if store is not own:
            raise ValueError(f"metric {name!r} already registered "
                             "with a different kind")

    def counter(self, name: str, labels: dict | None = None) -> Counter:
        self._check_free(name, self._counters)
        key = _labeled_key(name, labels)
        if labels:
            self._labels[key] = dict(labels)
        return self._counters.setdefault(key, Counter())

    def gauge(self, name: str, labels: dict | None = None) -> Gauge:
        self._check_free(name, self._gauges)
        key = _labeled_key(name, labels)
        if labels:
            self._labels[key] = dict(labels)
        return self._gauges.setdefault(key, Gauge())

    def histogram(self, name: str,
                  bounds: tuple[float, ...] | None = None) -> Histogram:
        self._check_free(name, self._histograms)
        h = self._histograms.get(name)
        if h is None:
            h = Histogram(bounds or DEFAULT_TIME_BUCKETS)
            self._histograms[name] = h
        return h

    def series(self, name: str) -> list:
        """All series of the family ``name`` as ``(labels, metric)`` pairs
        (labels ``{}`` for the unlabeled series) — the structured view
        export and health rules use instead of parsing flat keys."""
        store = self._kinds.get(name)
        if store is None:
            return []
        out = []
        for key, m in store.items():
            if key == name or key.startswith(name + "{"):
                out.append((self._labels.get(key, {}), m))
        return out

    def snapshot(self) -> dict:
        """JSON-able dump of every metric — the payload that rides in the
        telemetry sink's ``to_json`` and the per-run report."""
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: {"value": g.value, "max": g.max}
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_TIME_BUCKETS"]
