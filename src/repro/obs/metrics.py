"""Service metrics registry: counters, gauges, fixed-bucket histograms.

The lightweight, zero-dependency registry the streaming engines feed
(DESIGN.md §13): decision counts and latency, admission-queue depth,
compaction pause, snapshot latency, per-device busy fraction.  Everything
is a plain Python accumulator — no locks (the engines are single-threaded
event loops), no background threads, no exporters.  ``snapshot()`` returns
a JSON-able dict that rides along in the telemetry sink's payload
(``TelemetrySink.to_json(metrics=...)``) and the per-run report
(``obs/report.py``).

Metrics are observation-only by construction: they never enter engine
snapshots and the crash-anywhere replay oracle never compares them, so
wall-clock-valued histograms cannot break byte-identical replay.

Histograms use fixed bucket upper bounds (default: 5 buckets per decade
from 1µs to 100s — trial durations and decision latencies both fit).
``percentile(q)`` interpolates linearly inside the located bucket and
clamps to the observed min/max, so p50/p99 are bucket-resolution estimates,
not exact order statistics — the right trade for an always-on hot-path
counter.
"""

from __future__ import annotations

import math


def _default_time_buckets() -> tuple[float, ...]:
    # 5 per decade, 1e-6s .. 1e2s: 41 finite bounds + implicit overflow
    return tuple(10.0 ** (-6 + i / 5) for i in range(41))


DEFAULT_TIME_BUCKETS = _default_time_buckets()


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value, plus the max ever set (queue-depth style series
    often only need "current" and "worst")."""

    __slots__ = ("value", "max")

    def __init__(self):
        self.value = None
        self.max = None

    def set(self, v: float) -> None:
        self.value = v
        self.max = v if self.max is None else max(self.max, v)


class Histogram:
    """Fixed-bucket histogram with p50/p99 snapshot estimates.

    ``bounds`` are ascending finite upper bounds; values above the last
    bound land in an implicit overflow bucket.  Non-finite observations are
    counted separately (``dropped``) instead of poisoning the stats.
    """

    __slots__ = ("bounds", "counts", "count", "total", "min", "max",
                 "dropped")

    def __init__(self, bounds: tuple[float, ...] = DEFAULT_TIME_BUCKETS):
        if list(bounds) != sorted(bounds) or len(bounds) == 0:
            raise ValueError("bounds must be non-empty and ascending")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # + overflow
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.dropped = 0

    def observe(self, v: float) -> None:
        if v is None or not math.isfinite(v):
            self.dropped += 1
            return
        # linear scan is fine: bucket lists are ~40 long and observe() is
        # called once per *decision*, not per model
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> float | None:
        """Bucket-interpolated q-th percentile (q in [0, 100]); None when
        empty."""
        if self.count == 0:
            return None
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else (
                    self.min if self.min is not None else 0.0)
                hi = self.bounds[i] if i < len(self.bounds) else (
                    self.max if self.max is not None else lo)
                frac = (target - cum) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return float(min(max(est, self.min), self.max))
            cum += c
        return float(self.max)   # pragma: no cover - cum==count handled above

    def summary(self) -> dict:
        mean = self.total / self.count if self.count else None
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "mean": mean,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "dropped_non_finite": self.dropped,
        }


class MetricsRegistry:
    """Named metric store with get-or-create accessors.  Asking for an
    existing name with the same kind returns the same object (engines cache
    handles at construction; ad-hoc callers just look up by name)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def _check_free(self, name: str, own: dict) -> None:
        for kind in (self._counters, self._gauges, self._histograms):
            if kind is not own and name in kind:
                raise ValueError(f"metric {name!r} already registered "
                                 "with a different kind")

    def counter(self, name: str) -> Counter:
        self._check_free(name, self._counters)
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        self._check_free(name, self._gauges)
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str,
                  bounds: tuple[float, ...] | None = None) -> Histogram:
        self._check_free(name, self._histograms)
        h = self._histograms.get(name)
        if h is None:
            h = Histogram(bounds or DEFAULT_TIME_BUCKETS)
            self._histograms[name] = h
        return h

    def snapshot(self) -> dict:
        """JSON-able dump of every metric — the payload that rides in the
        telemetry sink's ``to_json`` and the per-run report."""
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: {"value": g.value, "max": g.max}
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_TIME_BUCKETS"]
