"""SLO burn-rate alerts and anomaly watchdogs over the streaming engines.

The live half of the health story (DESIGN.md §14): where ``obs/report.py``
grades SLO attainment after the run, :class:`HealthMonitor` watches it
*during* the run and emits structured :class:`Alert` records the moment a
budget starts burning or a pathology pattern fires.

Every detector input is **sim-time-derived** — queue depth, launch/
observation events, telemetry summaries (themselves computed from sim
timestamps), and the GP Cholesky pivot ``d²`` (a pure function of the
folded observations).  Wall-clock series (decision latency histograms)
are deliberately *not* inputs: alert content must be a pure function of
the event stream so a crash-recovered run re-emits the identical alert
sequence for its replayed suffix.  Detector state (stall counters, armed
flags, window cursors) has ``state_dict``/``load_state`` and rides in the
engine snapshot; emitted alerts stream to the event log's durable
``alerts.jsonl``, so ``prefix-from-log + suffix-from-resume`` equals the
uninterrupted run's alert list exactly (tests/test_eventlog.py).

Detectors:

* **slo_burn** — at every ``window``-second sim-time boundary, grade the
  telemetry summary against the ``meta["slo"]`` targets (utilization
  targets are floors, latency/regret targets are ceilings — the
  ``report.py`` semantics) and track the violating-window fraction over
  the last ``burn_windows`` windows; alert when it reaches
  ``burn_threshold`` (severity ``page`` when *every* window burned).
* **regret_stall** — a tenant whose incumbent has not improved for
  ``stall_k`` consecutive observations while its trials keep burning
  budget; re-arms on the next improvement.
* **queue_runaway** — admission-queue depth crosses ``queue_limit`` while
  rising; re-arms once depth drains below half the limit.
* **class_starvation** — a device class with free capacity and a nonempty
  backlog that has not launched a trial for ``starvation_window``
  sim-seconds; re-arms on its next launch.
* **gp_conditioning** — the incremental Cholesky pivot ``d²`` for a fold
  dropped to within ``conditioning_scale`` of the jitter floor: the
  posterior update is numerically degenerate (near-duplicate model under
  the kernel), deduped to one alert per tenant per window.
* **memory_runaway** — the capacity plane's projected posterior bytes at
  its horizon (``obs/accounting.py``, a pure function of the event
  stream) crossed ``memory_budget_bytes``: the admission rate is
  outrunning the memory budget and will blow it *before* it actually
  does.  Severity ``page`` once current bytes already exceed the budget;
  re-arms when the projection drops back under 80% of it.

Failure-domain detectors (DESIGN.md §16), fed by trial supervision and
the device quarantine scoreboard:

* **straggler** — trial supervision killed a trial at its deadline; the
  device is producing overruns.  Deduped to one alert per device per
  sim-time window.
* **retry_storm** — ``retry_storm_k`` or more backoff re-queues landed
  inside one sliding ``window``: the fleet is thrashing on retries
  instead of making progress (severity ``page``); re-arms once the
  windowed count drains to half the threshold.
* **quarantine_flap** — the same device got quarantined twice within
  ``flap_window`` sim-seconds: probation keeps re-admitting a device
  that keeps failing (severity ``page``), deduped per device per window.
* **poisoned_observation** — the GP-ingest guard rejected a non-finite
  loss (every occurrence alerts: poisoned losses are rare and each one
  is a diverged training run someone should look at).
"""

from __future__ import annotations

from dataclasses import dataclass, field

HEALTH_SCHEMA_VERSION = 1

#: alert kinds, in severity-report order
ALERT_KINDS = ("slo_burn", "regret_stall", "queue_runaway",
               "class_starvation", "gp_conditioning", "memory_runaway",
               "straggler", "retry_storm", "quarantine_flap",
               "poisoned_observation")


@dataclass(frozen=True)
class Alert:
    """One structured health event — JSON-able via :meth:`to_record`."""

    t: float
    event_index: int
    kind: str           # one of ALERT_KINDS
    severity: str       # "warn" | "page"
    subject: str        # tenant key / slo key / device class
    detail: dict = field(default_factory=dict)

    def to_record(self) -> dict:
        return {"schema_version": HEALTH_SCHEMA_VERSION,
                "t": self.t, "event_index": self.event_index,
                "kind": self.kind, "severity": self.severity,
                "subject": self.subject, "detail": self.detail}

    @classmethod
    def from_record(cls, rec: dict) -> "Alert":
        return cls(t=rec["t"], event_index=rec["event_index"],
                   kind=rec["kind"], severity=rec["severity"],
                   subject=rec["subject"], detail=dict(rec["detail"]))


def _slo_ok(key: str, val: float, target: float) -> bool:
    # report.py::_slo_section semantics: utilization targets are floors,
    # latency/regret targets are ceilings
    return val >= target if "utilization" in key else val <= target


class HealthMonitor:
    """Rule-based watchdog fed per-event by the engine pop loops.

    Construct with the run's SLO table (same shape as the report plane's
    ``meta["slo"]``) and hand to ``StreamEngine(health=...)``.  All
    thresholds are sim-time/count-valued so alerting is deterministic.
    """

    def __init__(self, slo: dict | None = None, *, window: float = 20.0,
                 burn_windows: int = 3, burn_threshold: float = 0.75,
                 stall_k: int = 12, queue_limit: int = 16,
                 starvation_window: float = 30.0,
                 conditioning_scale: float = 10.0,
                 memory_budget_bytes: float | None = None,
                 retry_storm_k: int = 4,
                 flap_window: float | None = None):
        if window <= 0:
            raise ValueError("window must be positive")
        self.slo = dict(slo or {})
        self.window = float(window)
        self.burn_windows = int(burn_windows)
        self.burn_threshold = float(burn_threshold)
        self.stall_k = int(stall_k)
        self.queue_limit = int(queue_limit)
        self.starvation_window = float(starvation_window)
        self.conditioning_scale = float(conditioning_scale)
        self.memory_budget_bytes = (None if memory_budget_bytes is None
                                    else float(memory_budget_bytes))
        self.retry_storm_k = int(retry_storm_k)
        self.flap_window = (10.0 * self.window if flap_window is None
                            else float(flap_window))

        self.alerts: list[Alert] = []
        self._drained = 0
        # detector state — everything here must round-trip state_dict()
        self._last_window = -1
        self._slo_hist: dict[str, list[int]] = {}   # key -> recent 0/1 fails
        self._slo_armed: dict[str, bool] = {}
        self._stall: dict[str, int] = {}            # tenant -> obs since improve
        self._stall_armed: dict[str, bool] = {}
        self._queue_prev = 0
        self._queue_armed = True
        self._class_last: dict[str, float] = {}     # cls -> last launch/seen t
        self._class_armed: dict[str, bool] = {}
        self._cond_last_window: dict[str, int] = {}  # tenant -> window
        self._mem_armed = True
        # failure-domain detector state (DESIGN.md §16)
        self._straggler_last_window: dict[str, int] = {}  # device -> window
        self._retry_times: list[float] = []          # retries inside window
        self._retry_armed = True
        self._flap_times: dict[str, list[float]] = {}   # device -> quarantines
        self._flap_last_window: dict[str, int] = {}

    # -- emission ---------------------------------------------------------

    def _alert(self, t: float, event_index: int, kind: str, severity: str,
               subject: str, **detail) -> None:
        self.alerts.append(Alert(float(t), int(event_index), kind, severity,
                                 str(subject), detail))

    def drain_new(self) -> list[Alert]:
        """Alerts appended since the last drain — the engine forwards these
        to the durable event log."""
        new = self.alerts[self._drained:]
        self._drained = len(self.alerts)
        return new

    # -- engine feeds -----------------------------------------------------

    def on_launch(self, t: float, event_index: int, tenant, model: int,
                  cls: str) -> None:
        self._class_last[cls] = float(t)
        self._class_armed[cls] = True

    def on_observation(self, t: float, event_index: int, tenant,
                       improved: bool, d2: float | None = None,
                       jitter: float | None = None,
                       model: int = -1) -> None:
        key = str(tenant)
        if improved:
            self._stall[key] = 0
            self._stall_armed[key] = True
        else:
            n = self._stall.get(key, 0) + 1
            self._stall[key] = n
            if (n >= self.stall_k
                    and self._stall_armed.setdefault(key, True)):
                self._stall_armed[key] = False
                self._alert(t, event_index, "regret_stall", "warn", key,
                            observations_since_improvement=n)
        if d2 is not None and jitter is not None:
            if d2 <= self.conditioning_scale * jitter:
                w = int(t // self.window)
                if self._cond_last_window.get(key) != w:
                    self._cond_last_window[key] = w
                    self._alert(t, event_index, "gp_conditioning", "warn",
                                key, model=int(model), d2=float(d2),
                                jitter=float(jitter))

    # -- failure-domain feeds (DESIGN.md §16) ------------------------------

    def on_timeout(self, t: float, event_index: int, device, tenant,
                   overrun: float = 0.0) -> None:
        """Trial supervision killed a straggler on ``device`` — one
        ``straggler`` alert per device per sim-time window."""
        key = str(device)
        w = int(t // self.window)
        if self._straggler_last_window.get(key) != w:
            self._straggler_last_window[key] = w
            self._alert(t, event_index, "straggler", "warn", key,
                        tenant=str(tenant), overrun_seconds=float(overrun))

    def on_retry(self, t: float, event_index: int, tenant, model: int,
                 attempt: int) -> None:
        """A backoff re-queue landed; ``retry_storm_k`` of them inside one
        sliding window pages (the fleet is thrashing, not progressing)."""
        self._retry_times.append(float(t))
        self._retry_times = [x for x in self._retry_times
                             if t - x <= self.window]
        n = len(self._retry_times)
        if n >= self.retry_storm_k and self._retry_armed:
            self._retry_armed = False
            self._alert(t, event_index, "retry_storm", "page", "fleet",
                        retries_in_window=int(n), window=self.window,
                        limit=self.retry_storm_k)
        elif n <= self.retry_storm_k // 2:
            self._retry_armed = True

    def on_quarantine(self, t: float, event_index: int, device,
                      count: int = 1) -> None:
        """The scoreboard quarantined ``device``; a second quarantine of the
        same device within ``flap_window`` means probation keeps re-admitting
        a bad device — the flap alert, deduped per device per window."""
        key = str(device)
        times = self._flap_times.setdefault(key, [])
        times.append(float(t))
        self._flap_times[key] = times = [x for x in times
                                         if t - x <= self.flap_window]
        if len(times) >= 2:
            w = int(t // self.window)
            if self._flap_last_window.get(key) != w:
                self._flap_last_window[key] = w
                self._alert(t, event_index, "quarantine_flap", "page", key,
                            quarantines_in_window=len(times),
                            flap_window=self.flap_window,
                            total_quarantines=int(count))

    def on_poisoned(self, t: float, event_index: int, tenant,
                    model: int) -> None:
        """The GP-ingest guard rejected a non-finite loss."""
        self._alert(t, event_index, "poisoned_observation", "warn",
                    str(tenant), model=int(model))

    def on_capacity(self, t: float, event_index: int, *, bytes_now: float,
                    projected_bytes: float) -> None:
        """Fed by the capacity accountant at its sample boundaries (so the
        input cadence — and thus the alert sequence — is a pure function of
        the event stream).  No-op without a configured budget."""
        budget = self.memory_budget_bytes
        if budget is None:
            return
        if projected_bytes >= budget:
            if self._mem_armed:
                self._mem_armed = False
                self._alert(t, event_index, "memory_runaway",
                            "page" if bytes_now >= budget else "warn",
                            "gp_posterior",
                            bytes_now=float(bytes_now),
                            projected_bytes=float(projected_bytes),
                            budget_bytes=float(budget))
        elif projected_bytes <= 0.8 * budget:
            self._mem_armed = True

    def on_event(self, t: float, event_index: int, *, queue_depth: int,
                 backlog: int, free_classes: tuple[str, ...] = (),
                 summary_fn=None) -> None:
        """Once per processed event, after the engine's own bookkeeping."""
        # queue runaway: depth crossing the limit while rising
        if (queue_depth >= self.queue_limit
                and queue_depth > self._queue_prev and self._queue_armed):
            self._queue_armed = False
            self._alert(t, event_index, "queue_runaway", "page", "admission",
                        depth=int(queue_depth), limit=self.queue_limit)
        elif queue_depth <= self.queue_limit // 2:
            self._queue_armed = True
        self._queue_prev = int(queue_depth)

        # device-class starvation: free capacity + backlog, but no launch
        # on this class for a full starvation window.  With no backlog the
        # class is idle by lack of demand, not starvation — the clock
        # restarts, so ``idle_for`` only accumulates demand-present time
        # (as observed at event ticks).
        if backlog > 0:
            for cls in free_classes:
                last = self._class_last.setdefault(cls, float(t))
                if (t - last >= self.starvation_window
                        and self._class_armed.setdefault(cls, True)):
                    self._class_armed[cls] = False
                    self._alert(t, event_index, "class_starvation", "warn",
                                cls, idle_for=float(t - last),
                                backlog=int(backlog))
        else:
            for cls in free_classes:
                self._class_last[cls] = float(t)

        # SLO burn rate, evaluated at window boundaries only
        w = int(t // self.window)
        if w > self._last_window and self.slo and summary_fn is not None:
            self._last_window = w
            summary = summary_fn()
            for key, target in self.slo.items():
                if target is None:
                    continue
                val = summary.get(key)
                if val is None:
                    continue
                hist = self._slo_hist.setdefault(key, [])
                hist.append(0 if _slo_ok(key, val, target) else 1)
                del hist[:-self.burn_windows]
                burn = sum(hist) / len(hist)
                if hist[-1] == 0:
                    self._slo_armed[key] = True
                elif (len(hist) >= self.burn_windows
                        and burn >= self.burn_threshold
                        and self._slo_armed.setdefault(key, True)):
                    self._slo_armed[key] = False
                    self._alert(t, event_index, "slo_burn",
                                "page" if burn >= 1.0 else "warn", key,
                                burn_rate=float(burn), value=float(val),
                                target=float(target))

    # -- persistence (rides in the engine snapshot) -----------------------

    def state_dict(self) -> dict:
        return {
            "schema_version": HEALTH_SCHEMA_VERSION,
            "last_window": self._last_window,
            "slo_hist": {k: list(v) for k, v in self._slo_hist.items()},
            "slo_armed": dict(self._slo_armed),
            "stall": dict(self._stall),
            "stall_armed": dict(self._stall_armed),
            "queue_prev": self._queue_prev,
            "queue_armed": self._queue_armed,
            "class_last": dict(self._class_last),
            "class_armed": dict(self._class_armed),
            "cond_last_window": dict(self._cond_last_window),
            "mem_armed": self._mem_armed,
            "straggler_last_window": dict(self._straggler_last_window),
            "retry_times": list(self._retry_times),
            "retry_armed": self._retry_armed,
            "flap_times": {k: list(v) for k, v in self._flap_times.items()},
            "flap_last_window": dict(self._flap_last_window),
        }

    def load_state(self, state: dict) -> None:
        self._last_window = int(state["last_window"])
        self._slo_hist = {k: list(v) for k, v in state["slo_hist"].items()}
        self._slo_armed = {k: bool(v)
                           for k, v in state["slo_armed"].items()}
        self._stall = {k: int(v) for k, v in state["stall"].items()}
        self._stall_armed = {k: bool(v)
                             for k, v in state["stall_armed"].items()}
        self._queue_prev = int(state["queue_prev"])
        self._queue_armed = bool(state["queue_armed"])
        self._class_last = {k: float(v)
                            for k, v in state["class_last"].items()}
        self._class_armed = {k: bool(v)
                             for k, v in state["class_armed"].items()}
        self._cond_last_window = {k: int(v) for k, v
                                  in state["cond_last_window"].items()}
        # tolerant of pre-capacity-plane snapshots (no mem_armed key)
        self._mem_armed = bool(state.get("mem_armed", True))
        # tolerant of pre-supervision snapshots (no failure-domain keys)
        self._straggler_last_window = {
            k: int(v) for k, v
            in state.get("straggler_last_window", {}).items()}
        self._retry_times = [float(x) for x in state.get("retry_times", [])]
        self._retry_armed = bool(state.get("retry_armed", True))
        self._flap_times = {k: [float(x) for x in v] for k, v
                            in state.get("flap_times", {}).items()}
        self._flap_last_window = {k: int(v) for k, v
                                  in state.get("flap_last_window", {}).items()}
        # alerts are NOT restored: the durable prefix lives in the event
        # log's alerts.jsonl; a resumed run re-emits only its suffix
        self.alerts = []
        self._drained = 0


__all__ = ["Alert", "HealthMonitor", "ALERT_KINDS",
           "HEALTH_SCHEMA_VERSION"]
