"""Zero-dependency observability layer: tracing, metrics, reports.

The service's innermost hot path — one sharded GP-EI decision — is also its
scaling ceiling (BENCH_shard_scale.json: ~220ms at |L|=100k, weak-scaling
efficiency 0.16 at 8 shards).  You cannot tune what you cannot observe, so
this package gives the control plane three observation planes (DESIGN.md
§13):

  trace.py    :class:`Tracer` — nestable monotonic-clock spans with
              deterministic (trace, span) ids, a ``block_until_ready``-aware
              sync so device work is attributed to the span that launched
              it, and an optional ``jax.profiler`` trace-annotation bridge
              (spans show up in TensorBoard/Perfetto device profiles).
              Disabled tracers cost one branch per span site (<1% of a
              decision, measured in BENCH_decision_trace.json).

  metrics.py  :class:`MetricsRegistry` — counters, gauges, and fixed-bucket
              histograms with p50/p99 snapshots.  The streaming engines feed
              it (decisions, decision latency, queue depth, compaction
              pause, snapshot latency, per-device busy fraction) and the
              snapshot exports through the existing telemetry JSON sink.

  report.py   :func:`write_report` — one experiment directory per run
              (``reports/<run_id>/`` with ``summary.json``,
              ``timeline.csv``, a self-contained ``report.html`` and the
              raw ``trace.json``), rendered from telemetry + trace + metrics
              payloads plus the live planes' alerts and forensics records.
              The multi-tenant operator view.

The *active* layer on top (DESIGN.md §14) turns the flight recorder into a
monitoring system:

  export.py     :class:`MetricsExporter` — sim-time-windowed registry
                snapshots streamed to append-only JSONL from inside the
                engine pop loops, plus a Prometheus text rendering.
  health.py     :class:`HealthMonitor` — SLO burn-rate alerts against the
                run's ``meta["slo"]`` targets and rule-based watchdogs
                (regret-stall, queue runaway, device-class starvation, GP
                conditioning), emitting structured :class:`Alert` records
                into telemetry and the durable event log.
  forensics.py  :class:`ForensicsRecorder` — per-decision attribution
                (winner/runner-up EIrate, μ/σ/cost decomposition, argmax
                margin, uniform-cost counterfactual) from the top-k the
                scoring program already materializes.

The *capacity* layer (DESIGN.md §15) accounts for the resources both of
the above spend:

  accounting.py :class:`CapacityAccountant` — per-tenant GP posterior byte
                accounting, shard slot occupancy + load imbalance, fleet
                composition, and a projected-bytes-at-horizon feed for the
                health plane's memory watchdog; published as labeled
                ``capacity.*`` gauges through the registry/exporter.
  profile.py    device-time attribution: ``jax.profiler`` capture windows,
                per-shard timing-skew probes, and a shard_map dispatch-
                overhead probe — the machinery behind BENCH_capacity.json's
                weak-scaling-gap decomposition.

Everything here is observation-only: a traced run's trial sequence is
byte-identical to an untraced run's (CI asserts it), spans/metrics never
enter engine snapshots, and trace ids are derived from processed-event
indices so a crash-recovered run re-emits the identical span tree for the
replayed suffix (tests/test_obs.py).
"""

from .accounting import CapacityAccountant  # noqa: F401
from .export import MetricsExporter, prometheus_text  # noqa: F401
from .forensics import ForensicsRecorder  # noqa: F401
from .health import ALERT_KINDS, Alert, HealthMonitor  # noqa: F401
from .metrics import Counter, Gauge, Histogram, MetricsRegistry  # noqa: F401
from .profile import capture, profiler_available  # noqa: F401
from .report import aggregate_spans, write_report  # noqa: F401
from .trace import NULL_TRACER, Tracer  # noqa: F401
