"""The report plane: one experiment directory per run.

:func:`write_report` turns a run's observability payloads — the telemetry
sink, the tracer's span records, the metrics registry, optionally the
``StreamResult`` trial log — into ``<out_dir>/<run_id>/`` (DESIGN.md §13):

  summary.json   machine-readable roll-up: telemetry summary, metrics
                 snapshot, span aggregation by path, run metadata
  timeline.csv   the run as a flat time series (trial launches and
                 observations, queue-depth samples) for ad-hoc plotting
  report.html    self-contained operator view: flamegraph-style span
                 breakdown bars, SLO / regret / utilization tables —
                 zero external assets, opens from a CI artifact
  trace.json     raw span dump (only when a tracer with spans is given)
  alerts.jsonl   health-plane alert records (only when alerts are given)
  forensics.jsonl per-decision attribution stream (only when given); the
                 html tabulates the smallest-margin decisions and counts
                 uniform-cost counterfactual flips

Everything is stdlib-rendered (json/csv/html): the report plane must run
in the same zero-dependency envelope as the engines it observes.  The
layout follows the per-run ``reports/`` + ``experiments/`` convention of
the pyotest framework the ROADMAP points at: a run id names a directory,
and every artifact inside is self-describing.
"""

from __future__ import annotations

import csv
import html
import json
from pathlib import Path

REPORT_SCHEMA_VERSION = 1


def aggregate_spans(records: list[dict]) -> dict[str, dict]:
    """Fold span records into a flamegraph-style path aggregation.

    A span's *path* is the '/'-joined name chain from its trace's root
    (``decide/posterior``), so identical code paths across traces land in
    one row.  Each row carries call count, total time, and *self* time
    (total minus direct children — the unattributed share lives in the
    parent's self time).  Rows come back sorted by total time, descending.
    """
    by_key = {(s["trace"], s["span"]): s for s in records}
    paths: dict[tuple, str] = {}

    def path_of(s: dict) -> str:
        key = (s["trace"], s["span"])
        got = paths.get(key)
        if got is None:
            if s["parent"] is None:
                got = s["name"]
            else:
                parent = by_key.get((s["trace"], s["parent"]))
                got = (f"{path_of(parent)}/{s['name']}"
                       if parent is not None else s["name"])
            paths[key] = got
        return got

    agg: dict[str, dict] = {}
    for s in records:
        row = agg.setdefault(path_of(s), {"count": 0, "total_us": 0.0,
                                          "self_us": 0.0})
        row["count"] += 1
        row["total_us"] += s["dur_us"]
        row["self_us"] += s["dur_us"]
    for s in records:           # subtract children from their parent's self
        if s["parent"] is None:
            continue
        parent = by_key.get((s["trace"], s["parent"]))
        if parent is not None:
            agg[path_of(parent)]["self_us"] -= s["dur_us"]
    for row in agg.values():
        row["mean_us"] = row["total_us"] / row["count"]
    return dict(sorted(agg.items(),
                       key=lambda kv: -kv[1]["total_us"]))


def _timeline_rows(telemetry, result) -> list[list]:
    rows: list[list] = []    # kind, t, tenant, model, device, value
    if result is not None:
        for t in result.trials:
            rows.append(["launch", t.start, t.tenant_key, t.model,
                         t.device, t.end - t.start])
            if t.z is not None:
                rows.append(["observation", t.end, t.tenant_key, t.model,
                             t.device, t.z])
    if telemetry is not None:
        for t, depth in telemetry.queue_depth_samples:
            rows.append(["queue_depth", t, "", "", "", depth])
    rows.sort(key=lambda r: (r[1], r[0]))
    return rows


# ---- HTML rendering ---------------------------------------------------------

_CSS = """
body { font: 14px/1.45 system-ui, sans-serif; margin: 2em auto;
       max-width: 72em; color: #1a1a2e; padding: 0 1em; }
h1 { font-size: 1.4em; } h2 { font-size: 1.1em; margin-top: 2em; }
table { border-collapse: collapse; margin: 0.5em 0; }
th, td { border: 1px solid #d5d5e0; padding: 0.25em 0.7em;
         text-align: right; font-variant-numeric: tabular-nums; }
th { background: #f0f0f6; } td.l, th.l { text-align: left; }
.bar { display: inline-block; height: 0.85em; background: #5470c6;
       vertical-align: baseline; min-width: 1px; }
.bar.self { background: #91cc75; }
.muted { color: #777; } code { background: #f4f4f8; padding: 0 0.25em; }
"""


def _fmt(v, digits=3):
    if v is None:
        return "–"
    if isinstance(v, float):
        return f"{v:.{digits}f}"
    return str(v)


def _table(headers: list[str], rows: list[list], left: set[int]) -> str:
    out = ["<table><tr>"]
    for i, h in enumerate(headers):
        cls = ' class="l"' if i in left else ""
        out.append(f"<th{cls}>{html.escape(h)}</th>")
    out.append("</tr>")
    for row in rows:
        out.append("<tr>")
        for i, cell in enumerate(row):
            cls = ' class="l"' if i in left else ""
            out.append(f"<td{cls}>{cell}</td>")
        out.append("</tr>")
    out.append("</table>")
    return "".join(out)


def _span_section(span_agg: dict[str, dict]) -> str:
    if not span_agg:
        return ("<p class='muted'>No spans recorded — run with tracing "
                "enabled (<code>Tracer(enabled=True)</code>) for the "
                "decision-path breakdown.</p>")
    total = max((r["total_us"] for r in span_agg.values()), default=0.0)
    rows = []
    for path, r in span_agg.items():
        depth = path.count("/")
        share = r["total_us"] / total if total > 0 else 0.0
        self_share = max(r["self_us"], 0.0) / total if total > 0 else 0.0
        label = ("&nbsp;" * (2 * depth)) + html.escape(path.split("/")[-1])
        bar = (f'<span class="bar" style="width:{share * 28:.2f}em"></span>'
               f'<span class="bar self" '
               f'style="width:{self_share * 28:.2f}em"></span>')
        rows.append([label, r["count"], f"{r['total_us']:.1f}",
                     f"{max(r['self_us'], 0.0):.1f}",
                     f"{r['mean_us']:.1f}", f"{100 * share:.1f}%",
                     f'<span class="l">{bar}</span>'])
    legend = ("<p class='muted'>bars: <span class='bar' "
              "style='width:1.2em'></span> total &nbsp; <span class='bar "
              "self' style='width:1.2em'></span> self (excl. children); "
              "widths share one scale (largest total)</p>")
    return legend + _table(
        ["span path", "count", "total µs", "self µs", "mean µs", "share",
         ""], rows, left={0, 6})


def _alerts_section(alerts: list[dict]) -> str:
    if not alerts:
        return ("<p class='muted'>No alerts fired — run with a "
                "<code>HealthMonitor</code> attached for SLO burn-rate "
                "and watchdog coverage.</p>")
    rows = []
    for a in alerts:
        detail = ", ".join(f"{k}={_fmt(v)}"
                           for k, v in sorted(a.get("detail", {}).items()))
        rows.append([_fmt(a["t"], 2), a["event_index"],
                     html.escape(a["kind"]), html.escape(a["severity"]),
                     html.escape(str(a["subject"])), html.escape(detail)])
    return _table(["t", "event", "kind", "severity", "subject", "detail"],
                  rows, left={2, 3, 4, 5})


def _forensics_section(records: list[dict], limit: int = 30) -> str:
    if not records:
        return ("<p class='muted'>No forensics recorded — run with a "
                "<code>ForensicsRecorder</code> attached for per-decision "
                "attribution.</p>")
    cf_flips = sum(1 for r in records
                   if (r.get("uniform_cost") or {}).get("changes_pick"))
    # smallest-margin decisions are the interesting ones: the pick was
    # nearly something else
    ranked = sorted((r for r in records if r.get("margin") is not None),
                    key=lambda r: r["margin"])[:limit]
    rows = []
    for r in ranked:
        w, ru = r["winner"], r["runner_up"]
        cf = r.get("uniform_cost") or {}
        rows.append([
            _fmt(r["t"], 2), r["event_index"], r["seq"],
            html.escape(str(r.get("device_class") or "–")),
            w["model"], _fmt(w["eirate"], 5), _fmt(w["ei"], 5),
            _fmt(w["cost"], 3),
            ru["model"] if ru else "–",
            _fmt(r["margin"], 6),
            ("flips&rarr;" + str(cf.get("model"))
             if cf.get("changes_pick") else "no"),
        ])
    head = (f"<p class='muted'>{len(records)} decisions recorded; "
            f"{cf_flips} would flip under uniform cost "
            f"(cheapness-driven picks); showing the {len(rows)} "
            f"smallest-margin decisions.</p>")
    return head + _table(
        ["t", "event", "seq", "class", "winner", "EIrate", "EI", "cost",
         "runner-up", "margin", "uniform-cost"], rows, left={3, 10})


def _capacity_section(sample: dict | None) -> str:
    if not sample:
        return ("<p class='muted'>No capacity samples — run with a "
                "<code>CapacityAccountant</code> attached for posterior "
                "byte accounting and shard occupancy.</p>")
    scalar_rows = []
    for key in ("gp_blocks", "gp_obs", "gp_alloc_bytes", "gp_active_bytes",
                "gp_readout_bytes", "gp_bytes", "gp_bytes_projected",
                "slots_total", "slots_live", "slots_free", "load_imbalance",
                "autoscale_joins", "autoscale_leaves", "scoring_passes"):
        if key in sample:
            scalar_rows.append([html.escape(key), _fmt(sample[key])])
    for cls, n in sorted((sample.get("devices") or {}).items()):
        scalar_rows.append([f"devices[{html.escape(cls)}]", n])
    head = (f"<p class='muted'>final sample at t={_fmt(sample['t'], 2)} "
            f"(event {sample['event_index']}); projected bytes use the "
            f"accountant's horizon slope fit.</p>")
    out = head + _table(["capacity metric", "value"], scalar_rows, left={0})
    shard_slots = sample.get("shard_slots")
    if shard_slots:
        out += _table(["shard", "live slots"],
                      [[s, n] for s, n in enumerate(shard_slots)],
                      left=set())
    return out


def _slo_section(summary: dict, slo: dict) -> str:
    rows = []
    for key in ("ttfo_p50", "ttfo_p99", "serve_gap_p50", "serve_gap_max",
                "tenant_regret_mean", "tenant_regret_max",
                "device_utilization", "speed_weighted_utilization"):
        val = summary.get(key)
        target = slo.get(key)
        if target is None:
            att = "–"
        elif val is None:
            att = "no data"
        else:
            # utilization SLOs are floors, latency/regret SLOs are ceilings
            ok = (val >= target if "utilization" in key else val <= target)
            att = "met" if ok else "MISSED"
        rows.append([html.escape(key), _fmt(val), _fmt(target), att])
    return _table(["metric", "value", "target", "attainment"], rows,
                  left={0, 3})


def _render_html(run_id: str, meta: dict, summary: dict,
                 span_agg: dict[str, dict], metrics: dict | None,
                 per_tenant: dict | None, per_device: dict | None,
                 alerts: list[dict] | None = None,
                 forensics: list[dict] | None = None,
                 capacity: dict | None = None) -> str:
    parts = [f"<!doctype html><html><head><meta charset='utf-8'>"
             f"<title>run {html.escape(run_id)}</title>"
             f"<style>{_CSS}</style></head><body>"]
    parts.append(f"<h1>Run report — <code>{html.escape(run_id)}</code></h1>")
    if meta:
        items = ", ".join(f"{html.escape(str(k))}={html.escape(str(v))}"
                          for k, v in sorted(meta.items()) if k != "slo")
        parts.append(f"<p class='muted'>{items}</p>")

    parts.append("<h2>Decision-path span breakdown</h2>")
    parts.append(_span_section(span_agg))

    parts.append("<h2>SLO attainment</h2>")
    parts.append(_slo_section(summary, dict(meta.get("slo") or {})))

    parts.append("<h2>Health alerts</h2>")
    parts.append(_alerts_section(list(alerts or [])))

    parts.append("<h2>Capacity</h2>")
    parts.append(_capacity_section(capacity))

    parts.append("<h2>Decision forensics</h2>")
    parts.append(_forensics_section(list(forensics or [])))

    parts.append("<h2>Service summary</h2>")
    parts.append(_table(
        ["metric", "value"],
        [[html.escape(k), _fmt(v)] for k, v in sorted(summary.items())],
        left={0}))

    if metrics:
        hrows = [[html.escape(name), h["count"], _fmt(h["mean"], 6),
                  _fmt(h["p50"], 6), _fmt(h["p99"], 6), _fmt(h["max"], 6)]
                 for name, h in sorted(metrics["histograms"].items())]
        crows = [[html.escape(k), v]
                 for k, v in sorted(metrics["counters"].items())]
        grows = [[html.escape(k), _fmt(v["value"]), _fmt(v["max"])]
                 for k, v in sorted(metrics["gauges"].items())]
        parts.append("<h2>Metrics registry</h2>")
        if hrows:
            parts.append(_table(["histogram", "count", "mean", "p50",
                                 "p99", "max"], hrows, left={0}))
        if crows:
            parts.append(_table(["counter", "value"], crows, left={0}))
        if grows:
            parts.append(_table(["gauge", "value", "max"], grows, left={0}))

    if per_tenant:
        ranked = sorted(per_tenant.items(),
                        key=lambda kv: -(kv[1].get("regret") or 0.0))[:25]
        parts.append("<h2>Per-tenant regret (worst 25)</h2>")
        parts.append(_table(
            ["tenant", "arrived", "admitted", "departed", "obs", "best z",
             "regret"],
            [[k, _fmt(v["arrived"], 2), _fmt(v["admitted"], 2),
              _fmt(v["departed"], 2), v["num_obs"], _fmt(v["best_z"]),
              _fmt(v["regret"], 5)] for k, v in ranked], left=set()))

    if per_device:
        parts.append("<h2>Per-device utilization</h2>")
        parts.append(_table(
            ["device", "speed", "joined", "left", "trials", "busy s",
             "busy fraction"],
            [[d, _fmt(v["speed"], 1), _fmt(v["joined"], 2),
              _fmt(v["left"], 2), v["trials"], _fmt(v["busy_seconds"], 2),
              _fmt(v["utilization"])]
             for d, v in sorted(per_device.items())], left=set()))

    parts.append("</body></html>")
    return "".join(parts)


# ---- the entry point --------------------------------------------------------

def write_report(out_dir: str | Path, run_id: str, *, telemetry=None,
                 tracer=None, metrics=None, result=None,
                 meta: dict | None = None, alerts=None,
                 forensics=None, accounting=None) -> Path:
    """Render one per-run experiment directory and return its path.

    Args:
      out_dir:   reports root; the run directory is ``out_dir / run_id``.
      run_id:    directory name — caller-chosen (trace name, seed, ...).
      telemetry: a ``TelemetrySink`` (summary + per-tenant/per-device
                 tables); optional.
      tracer:    a ``Tracer`` whose spans feed the breakdown; optional.
      metrics:   a ``MetricsRegistry``; optional.
      result:    a ``StreamResult`` for the trial timeline; optional.
      meta:      run metadata echoed into summary.json and the report
                 header.  ``meta["slo"]`` (metric name -> target) drives
                 the SLO-attainment column.
      alerts:    health-plane alert records (``Alert`` objects or their
                 ``to_record()`` dicts — e.g. ``HealthMonitor.alerts`` or
                 ``EventLog.alerts``); rendered as the alert table and
                 re-emitted to ``alerts.jsonl`` in the run dir.
      forensics: per-decision attribution records
                 (``ForensicsRecorder.records``); the smallest-margin
                 decisions are tabulated and the raw stream lands in
                 ``forensics.jsonl``.
      accounting: a ``CapacityAccountant`` (its final sample feeds the
                 capacity section and ``summary.json["capacity"]``).
    """
    meta = dict(meta or {})
    alert_recs = [a.to_record() if hasattr(a, "to_record") else a
                  for a in (alerts or [])]
    forensic_recs = list(forensics or [])
    run_dir = Path(out_dir) / run_id
    run_dir.mkdir(parents=True, exist_ok=True)

    summary = telemetry.summary() if telemetry is not None else {}
    per_tenant = telemetry.per_tenant() if telemetry is not None else None
    per_device = (telemetry.per_device()
                  if telemetry is not None and telemetry.devices else None)
    records = tracer.records() if tracer is not None else []
    span_agg = aggregate_spans(records)
    metric_snap = metrics.snapshot() if metrics is not None else None

    cf_flips = sum(1 for r in forensic_recs
                   if (r.get("uniform_cost") or {}).get("changes_pick"))
    payload = {
        "schema_version": REPORT_SCHEMA_VERSION,
        "run_id": run_id,
        "meta": meta,
        "telemetry": summary,
        "metrics": metric_snap,
        "spans": span_agg,
        "num_spans": len(records),
        "alerts": {
            "total": len(alert_recs),
            "by_kind": {k: sum(1 for a in alert_recs if a["kind"] == k)
                        for k in sorted({a["kind"] for a in alert_recs})},
        },
        "forensics": {
            "decisions": len(forensic_recs),
            "uniform_cost_flips": cf_flips,
        },
        "capacity": (accounting.latest()
                     if accounting is not None else None),
    }
    (run_dir / "summary.json").write_text(
        json.dumps(payload, indent=2, sort_keys=True, allow_nan=False))

    with open(run_dir / "timeline.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["kind", "t", "tenant", "model", "device", "value"])
        w.writerows(_timeline_rows(telemetry, result))

    (run_dir / "report.html").write_text(_render_html(
        run_id, meta, summary, span_agg, metric_snap, per_tenant,
        per_device, alerts=alert_recs, forensics=forensic_recs,
        capacity=accounting.latest() if accounting is not None else None))

    if alert_recs:
        with open(run_dir / "alerts.jsonl", "w") as f:
            for a in alert_recs:
                f.write(json.dumps(a, allow_nan=False) + "\n")
    if forensic_recs:
        with open(run_dir / "forensics.jsonl", "w") as f:
            for r in forensic_recs:
                f.write(json.dumps(r, allow_nan=False) + "\n")

    if records:
        tracer.to_json(run_dir / "trace.json")
    return run_dir


__all__ = ["write_report", "aggregate_spans", "REPORT_SCHEMA_VERSION"]
