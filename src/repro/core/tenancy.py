"""Tenants, candidate sets, and problem instances (Section 3.1 + Section 6.1).

A :class:`Problem` is the full TSHB instance the scheduler consumes:

  * ``K``           (n, n) prior covariance over all models in L
  * ``mu0``         (n,)   prior mean
  * ``z_true``      (n,)   ground-truth performance (revealed on observation)
  * ``cost``        (n,)   run cost c(x) in (virtual) seconds
  * ``membership``  (N, n) bool — tenant i has model x in L_i

The paper's two real workloads (ease.ml traces) are not public, so
:func:`azure_problem` / :func:`deeplearning_problem` regenerate matrices
faithful to every statistic the paper publishes (tenant/model counts,
per-tenant accuracy std 0.12 / 0.04, 8 held-out prior-fitting tenants, two
fastest models as warm start) with fixed seeds.  :func:`synthetic_matern_problem`
reproduces the Fig-5 setup exactly as specified (50 tenants x 50 models,
Matérn nu=5/2, zero mean, samples shifted non-negative).

In the ease.ml setting a "model" is an (algorithm, dataset) pair — running
algorithm j for tenant i is its own arm with its own accuracy — so candidate
sets of distinct tenants are disjoint and K is block-diagonal across tenants,
with the within-tenant block estimated from the held-out tenants.  Cross-
tenant coupling in the scheduler comes from the shared device pool, exactly
as in the paper.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class Problem:
    K: np.ndarray
    mu0: np.ndarray
    z_true: np.ndarray
    cost: np.ndarray
    membership: np.ndarray  # (N, n) bool
    name: str = "problem"
    model_names: tuple[str, ...] = ()
    user_names: tuple[str, ...] = ()

    @property
    def num_users(self) -> int:
        return self.membership.shape[0]

    @property
    def num_models(self) -> int:
        return self.membership.shape[1]

    def best_per_user(self) -> np.ndarray:
        """z(x_i^*) for every tenant — ground-truth optima."""
        masked = np.where(self.membership, self.z_true[None, :], -np.inf)
        return masked.max(axis=1)

    def validate(self) -> None:
        n = self.num_models
        assert self.K.shape == (n, n)
        assert self.mu0.shape == (n,)
        assert self.z_true.shape == (n,)
        assert self.cost.shape == (n,)
        assert (self.cost > 0).all(), "costs must be positive"
        assert self.membership.any(axis=0).all(), "every model belongs to a tenant"
        assert self.membership.any(axis=1).all(), "every tenant has a model"
        # K must be symmetric PSD (up to tolerance).
        assert np.allclose(self.K, self.K.T, atol=1e-8)
        w = np.linalg.eigvalsh(self.K)
        assert w.min() > -1e-6, f"K not PSD: min eig {w.min()}"


# ---------------------------------------------------------------------------
# Matérn 5/2 kernel (Fig 5 synthetic setup)
# ---------------------------------------------------------------------------

def matern52(X: np.ndarray, Y: np.ndarray, length_scale: float = 0.2,
             variance: float = 1.0) -> np.ndarray:
    """Matérn nu=5/2 kernel on 1-D or d-dim inputs. X (a, d), Y (b, d)."""
    X = np.asarray(X, dtype=np.float64)
    Y = np.asarray(Y, dtype=np.float64)
    if X.ndim == 1:
        X = X[:, None]
    if Y.ndim == 1:
        Y = Y[:, None]
    d2 = ((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1)
    r = np.sqrt(np.maximum(d2, 0.0)) / length_scale
    s5 = np.sqrt(5.0) * r
    return variance * (1.0 + s5 + 5.0 * r * r / 3.0) * np.exp(-s5)


def _matern_block_chol(
    m: int, length_scale: float, kernel_variance: float
) -> tuple[np.ndarray, np.ndarray]:
    """(K_block, cholesky(K_block)) for one tenant's m-point Matérn prior."""
    xs = np.linspace(0.0, 1.0, m)[:, None]
    K_block = matern52(xs, xs, length_scale, kernel_variance)
    K_block += 1e-10 * np.eye(m)
    return K_block, np.linalg.cholesky(K_block)


def _matern_draw(rng: np.random.Generator, L: np.ndarray) -> np.ndarray:
    """One tenant's GP sample, "shifted upwards to be non-negative"."""
    sample = L @ rng.standard_normal(L.shape[0])
    return sample - sample.min()


def synthetic_matern_z(
    num_users: int = 50,
    num_models_per_user: int = 50,
    seed: int = 0,
    length_scale: float = 0.2,
    kernel_variance: float = 0.04,
) -> np.ndarray:
    """Just the (n,) ground-truth draw of :func:`synthetic_matern_problem`.

    Bit-identical to the z_true that ``synthetic_matern_problem`` produces
    for the same arguments (both go through ``_matern_draw`` with the same
    RNG stream), but skips the O(n^2) prior assembly — many-seed batched
    sweeps only need fresh samples over a shared prior
    (``EpisodeSpec(z_true=...)``).
    """
    rng = np.random.default_rng(seed)
    m = num_models_per_user
    _, L = _matern_block_chol(m, length_scale, kernel_variance)
    z = np.zeros(num_users * m)
    for i in range(num_users):
        z[i * m:(i + 1) * m] = _matern_draw(rng, L)
    return z


def synthetic_matern_problem(
    num_users: int = 50,
    num_models_per_user: int = 50,
    seed: int = 0,
    length_scale: float = 0.2,
    kernel_variance: float = 0.04,
    cost: str | np.ndarray = "uniform",
) -> Problem:
    """Fig-5 synthetic workload: per-tenant GP samples from a Matérn-5/2 prior,
    shifted upward to be non-negative, unit costs."""
    rng = np.random.default_rng(seed)
    m = num_models_per_user
    K_block, L = _matern_block_chol(m, length_scale, kernel_variance)

    n = num_users * m
    K = np.zeros((n, n))
    z = np.zeros(n)
    membership = np.zeros((num_users, n), dtype=bool)
    for i in range(num_users):
        sl = slice(i * m, (i + 1) * m)
        K[sl, sl] = K_block
        z[sl] = _matern_draw(rng, L)
        membership[i, sl] = True

    if isinstance(cost, str):
        if cost == "uniform":
            c = np.ones(n)
        elif cost == "lognormal":
            c = rng.lognormal(mean=0.0, sigma=0.5, size=n)
        else:
            raise ValueError(cost)
    else:
        c = np.asarray(cost, dtype=np.float64)

    return Problem(
        K=K, mu0=np.zeros(n), z_true=z, cost=c, membership=membership,
        name=f"synthetic-matern-{num_users}x{m}",
        model_names=tuple(f"u{i}/m{j}" for i in range(num_users) for j in range(m)),
        user_names=tuple(f"user{i}" for i in range(num_users)),
    )


# ---------------------------------------------------------------------------
# ease.ml-style workloads (Fig 2-4): Azure and DeepLearning
# ---------------------------------------------------------------------------

AZURE_MODELS = (
    "AveragedPerceptron", "BayesPointMachine", "BoostedDecisionTree",
    "DecisionForest", "DecisionJungle", "LogisticRegression",
    "NeuralNetwork", "SVM",
)
DEEPLEARNING_MODELS = (
    "NIN", "GoogLeNet", "ResNet-50", "AlexNet", "BNAlexNet", "ResNet-18",
    "VGG-16", "SqueezeNet",
)


def _ease_ml_matrix(
    rng: np.random.Generator,
    num_users: int,
    model_names: tuple[str, ...],
    acc_std: float,
    base_low: float,
    base_high: float,
    cost_range: tuple[float, float],
) -> tuple[np.ndarray, np.ndarray]:
    """Accuracy matrix (users x models) + per-(algorithm) cost vector.

    Generative model: each dataset has a difficulty level; each algorithm has
    a skill offset plus dataset-algorithm interaction.  The interaction std is
    calibrated so the *per-user across-model accuracy std* matches the
    figure the paper reports (0.12 Azure / 0.04 DeepLearning).
    """
    k = len(model_names)
    difficulty = rng.uniform(base_low, base_high, size=num_users)
    # Algorithm cost: log-uniform over the plausible range, shared across
    # datasets up to a per-dataset size factor.
    algo_cost = np.exp(rng.uniform(np.log(cost_range[0]), np.log(cost_range[1]), size=k))
    # Skill correlates mildly with cost (bigger/slower models tend to be
    # better) — matches the real zoos behind both ease.ml workloads and makes
    # the cheap-models warm start leave a genuine accuracy gap to search.
    logc = np.log(algo_cost)
    logc = (logc - logc.mean()) / max(logc.std(), 1e-9)
    skill = 0.6 * acc_std * logc + rng.normal(0.0, acc_std * 0.7, size=k)
    interaction = rng.normal(0.0, acc_std * 0.7, size=(num_users, k))
    acc = difficulty[:, None] + skill[None, :] + interaction
    acc = np.clip(acc, 0.02, 0.995)
    return acc, algo_cost


def _matrix_to_problem(
    acc: np.ndarray,
    algo_cost: np.ndarray,
    rng: np.random.Generator,
    name: str,
    model_names: tuple[str, ...],
    num_prior_users: int = 8,
) -> Problem:
    """Split users into prior-fitting and test sets, build block-diagonal K.

    Follows the paper's protocol: "randomly select 8 users which we will
    isolate and use to estimate the mean and the covariance matrix of the
    prior ... test using the remaining users."
    """
    num_users_total, k = acc.shape
    perm = rng.permutation(num_users_total)
    prior_users, test_users = perm[:num_prior_users], perm[num_prior_users:]
    prior_acc = acc[prior_users]  # (8, k)
    mu_algo = prior_acc.mean(axis=0)
    K_algo = np.cov(prior_acc, rowvar=False)  # (k, k) across-algorithm covariance
    K_algo += 1e-6 * np.trace(K_algo) / k * np.eye(k)

    N = len(test_users)
    n = N * k
    K = np.zeros((n, n))
    mu0 = np.zeros(n)
    z = np.zeros(n)
    cost = np.zeros(n)
    membership = np.zeros((N, n), dtype=bool)
    size_factor = rng.uniform(0.5, 2.0, size=N)  # per-dataset size scaling
    for i, u in enumerate(test_users):
        sl = slice(i * k, (i + 1) * k)
        K[sl, sl] = K_algo
        mu0[sl] = mu_algo
        z[sl] = acc[u]
        cost[sl] = algo_cost * size_factor[i]
        membership[i, sl] = True

    return Problem(
        K=K, mu0=mu0, z_true=z, cost=cost, membership=membership, name=name,
        model_names=tuple(f"u{i}/{m}" for i in range(N) for m in model_names),
        user_names=tuple(f"user{u}" for u in test_users),
    )


def azure_problem(seed: int = 0) -> Problem:
    """Azure workload: 17 tenants x 8 binary classifiers, per-tenant accuracy
    std 0.12, 8 prior-fitting tenants -> 9 test tenants."""
    rng = np.random.default_rng(1000 + seed)
    acc, cost = _ease_ml_matrix(
        rng, num_users=17, model_names=AZURE_MODELS, acc_std=0.12,
        base_low=0.55, base_high=0.9, cost_range=(30.0, 1200.0))
    return _matrix_to_problem(acc, cost, rng, f"azure-s{seed}", AZURE_MODELS)


def deeplearning_problem(seed: int = 0) -> Problem:
    """DeepLearning workload: 22 tenants x 8 CNN architectures, per-tenant
    accuracy std 0.04, 8 prior-fitting tenants -> 14 test tenants."""
    rng = np.random.default_rng(2000 + seed)
    acc, cost = _ease_ml_matrix(
        rng, num_users=22, model_names=DEEPLEARNING_MODELS, acc_std=0.04,
        base_low=0.6, base_high=0.92, cost_range=(600.0, 21600.0))
    return _matrix_to_problem(acc, cost, rng, f"deeplearning-s{seed}", DEEPLEARNING_MODELS)
