"""The paper's contribution: multi-device, multi-tenant GP-EI scheduling.

Control-plane stack (see DESIGN.md for the full design rationale):
  gp.py            zero-noise GP posterior (masked one-shot + incremental +
                   block-diagonal engines with runtime block add/retire;
                   jitter choice in DESIGN.md §3.3)
  ei.py            tau / EI / multi-tenant EI / EIrate (eqs. 3-6, Lemma 1)
  miu.py           Maximum Incremental Uncertainty (Section 5.1)
  tenancy.py       TSHB problem instances (Azure / DeepLearning / Matérn synthetic)
  control_plane.py the per-event decision core (GP fold + EIrate pick),
                   shared by every engine; closed-world (from_problem) and
                   open-world (tenant churn) construction — DESIGN.md §9;
                   slot reuse + the multi-device sharded scorer live in
                   repro.shardgp (scorer="sharded") — DESIGN.md §10
  scheduler.py     event-driven MM-GP-EI + round-robin/random baselines
                   (one episode, host event loop; failures + horizons supported)
  sim_batched.py   batched synchronous-slot engine: many episodes as one
                   vmap(lax.scan) accelerator call (DESIGN.md §6) — use for
                   large (policy x tenants x devices x seed) sweeps
  regret.py        cumulative + instantaneous global-happiness regret
  cost_model.py    roofline-derived c(x) (bridges to the data plane)
  service.py       real-executor multi-tenant service loop

Three episode engines, one contract: for deterministic policies and
identical seeds, ``sim_batched.simulate_batch`` and (with churn disabled)
``repro.stream.StreamEngine`` both reproduce ``scheduler.simulate``'s trial
sequence exactly (tests/test_sim_batched.py, tests/test_stream.py).
"""

from .control_plane import (  # noqa: F401
    ControlPlane,
    TenantHandle,
    no_obs_floor,
    tenant_warm_models,
    warm_start_queue,
)
from .ei import (  # noqa: F401
    choose_next,
    choose_topk_classes,
    ei_matrix,
    ei_total,
    eirate_class_scores,
    eirate_scores,
    expected_improvement,
    tau,
)
from .gp import BlockIncrementalGP, IncrementalGP, make_gp, posterior_masked  # noqa: F401
from .miu import (  # noqa: F401
    miu_cumulative_exact,
    miu_diag_paper_bound,
    miu_diag_upper_bound,
    miu_greedy,
    miu_s_exact,
)
from .regret import RegretCurves, final_regret, regret_curves, speedup_to_threshold  # noqa: F401
from .scheduler import POLICIES, FailureEvent, SimResult, TrialRecord, simulate  # noqa: F401
from .sim_batched import BatchResult, EpisodeSpec, simulate_batch  # noqa: F401
from .tenancy import (  # noqa: F401
    Problem,
    azure_problem,
    deeplearning_problem,
    matern52,
    synthetic_matern_problem,
    synthetic_matern_z,
)
