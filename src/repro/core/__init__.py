"""The paper's contribution: multi-device, multi-tenant GP-EI scheduling.

Control-plane stack:
  gp.py         zero-noise GP posterior (masked one-shot + incremental)
  ei.py         tau / EI / multi-tenant EI / EIrate (eqs. 3-6, Lemma 1)
  miu.py        Maximum Incremental Uncertainty (Section 5.1)
  tenancy.py    TSHB problem instances (Azure / DeepLearning / Matérn synthetic)
  scheduler.py  event-driven MM-GP-EI + round-robin/random baselines
  regret.py     cumulative + instantaneous global-happiness regret
  cost_model.py roofline-derived c(x) (bridges to the data plane)
  service.py    real-executor multi-tenant service loop
"""

from .ei import (  # noqa: F401
    choose_next,
    ei_matrix,
    ei_total,
    eirate_scores,
    expected_improvement,
    tau,
)
from .gp import BlockIncrementalGP, IncrementalGP, make_gp, posterior_masked  # noqa: F401
from .miu import (  # noqa: F401
    miu_cumulative_exact,
    miu_diag_paper_bound,
    miu_diag_upper_bound,
    miu_greedy,
    miu_s_exact,
)
from .regret import RegretCurves, final_regret, regret_curves, speedup_to_threshold  # noqa: F401
from .scheduler import POLICIES, FailureEvent, SimResult, TrialRecord, simulate  # noqa: F401
from .tenancy import (  # noqa: F401
    Problem,
    azure_problem,
    deeplearning_problem,
    matern52,
    synthetic_matern_problem,
)
