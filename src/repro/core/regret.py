"""Regret metrics for cumulative global happiness (Section 3.2 + Section 6.1).

  Regret_T            = sum_i  integral_0^T ( z(x_i^*) - z(x_i^*(t)) ) dt
  instantaneous(T)    = mean_i ( z(x_i^*) - z(x_i^*(T)) )

Both are step functions of the observation log, so we integrate exactly
between observation events.  Before a tenant's first observation their gap is
undefined in the paper; following the ease.ml convention we clamp it to
``initial_gap`` = z(x_i^*) - min_{x in L_i} z(x) (the worst the tenant could
be doing), which only shifts all policies by the same warm-up constant.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .scheduler import SimResult


@dataclass(frozen=True)
class RegretCurves:
    times: np.ndarray        # event times, ascending, starting at 0
    instantaneous: np.ndarray  # mean per-user gap right after each time
    cumulative: np.ndarray   # Regret_t at each time
    per_user_best: np.ndarray  # (num_events+1, N) best-so-far trace

    def cumulative_at(self, T: float) -> float:
        """Exact Regret_T for any T >= 0 (step-function integration)."""
        i = int(np.searchsorted(self.times, T, side="right") - 1)
        i = max(i, 0)
        base = self.cumulative[i]
        rate = self.instantaneous[i] * self.per_user_best.shape[1]
        return float(base + rate * (T - self.times[i]))

    def time_to_instantaneous(self, threshold: float) -> float:
        """First time the mean per-user gap drops to <= threshold (inf if never)."""
        hit = np.nonzero(self.instantaneous <= threshold)[0]
        return float(self.times[hit[0]]) if hit.size else float("inf")


def regret_curves(result: SimResult) -> RegretCurves:
    problem = result.problem
    N = problem.num_users
    z_star = problem.best_per_user()
    worst = np.where(problem.membership, problem.z_true[None, :], np.inf).min(axis=1)
    best = worst.copy()  # pessimistic start: clamp pre-observation gap

    obs = result.observations
    times = [0.0]
    inst = [float(np.mean(z_star - best))]
    cum = [0.0]
    traces = [best.copy()]

    t_prev = 0.0
    running = 0.0
    for t, model, z in obs:
        running += float(np.sum(z_star - best)) * (t - t_prev)
        users = np.nonzero(problem.membership[:, model])[0]
        for u in users:
            if z > best[u]:
                best[u] = z
        times.append(t)
        inst.append(float(np.mean(z_star - best)))
        cum.append(running)
        traces.append(best.copy())
        t_prev = t

    return RegretCurves(
        times=np.asarray(times),
        instantaneous=np.asarray(inst),
        cumulative=np.asarray(cum),
        per_user_best=np.stack(traces),
    )


def final_regret(result: SimResult, T: float | None = None) -> float:
    curves = regret_curves(result)
    if T is None:
        T = result.end_time
    return curves.cumulative_at(T)


def speedup_to_threshold(
    baseline: SimResult, ours: SimResult, threshold: float
) -> float:
    """time(baseline reaches threshold) / time(ours reaches threshold)."""
    tb = regret_curves(baseline).time_to_instantaneous(threshold)
    to = regret_curves(ours).time_to_instantaneous(threshold)
    return tb / to
