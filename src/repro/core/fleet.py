"""Device fleet: the paper's M atomic devices = disjoint mesh slices.

A production pod (16x16) is partitioned into M equal slices (e.g. 16 slices
of 4x4 = 16 chips); each slice is the atomic unit a tenant trial occupies,
exactly the paper's device abstraction.  The fleet tracks health: a failed
slice kills its in-flight trial (the scheduler re-queues the model — it was
never observed, so it simply returns to L \\ L(t)) and rejoins after repair.

Heterogeneity: per-slice ``speed`` scales effective c(x); the MDMT policy is
device-aware through EIrate = EI(x) / (c(x)/speed_d) (a strict generalization
of eq. 5, see scheduler.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class DeviceSlice:
    slice_id: int
    chips: int
    speed: float = 1.0
    healthy: bool = True
    busy_until: float = 0.0
    current_trial: int | None = None


@dataclass
class Fleet:
    slices: list[DeviceSlice]

    @classmethod
    def partition_pod(cls, total_chips: int = 256, num_slices: int = 8,
                      speeds: list[float] | None = None) -> "Fleet":
        assert total_chips % num_slices == 0
        chips = total_chips // num_slices
        speeds = speeds or [1.0] * num_slices
        return cls([DeviceSlice(i, chips, speeds[i]) for i in range(num_slices)])

    @property
    def num_devices(self) -> int:
        return len(self.slices)

    def free_at(self, t: float) -> list[DeviceSlice]:
        return [s for s in self.slices
                if s.healthy and s.current_trial is None and s.busy_until <= t]

    def fail(self, slice_id: int) -> int | None:
        """Mark slice failed; returns the killed trial id (to re-queue).

        The killed trial's reservation dies with it: ``busy_until`` is reset
        so a slice repaired before the old reservation would have expired is
        immediately schedulable."""
        s = self.slices[slice_id]
        s.healthy = False
        s.busy_until = 0.0
        killed, s.current_trial = s.current_trial, None
        return killed

    def recover(self, slice_id: int):
        self.slices[slice_id].healthy = True
