"""Device fleet: the paper's M atomic devices = disjoint mesh slices.

A production pod (16x16) is partitioned into M equal slices (e.g. 16 slices
of 4x4 = 16 chips); each slice is the atomic unit a tenant trial occupies,
exactly the paper's device abstraction.  The fleet tracks health: a failed
slice kills its in-flight trial (the scheduler re-queues the model — it was
never observed, so it simply returns to L \\ L(t)) and rejoins after repair.

Heterogeneity: per-slice ``speed`` scales effective c(x); the MDMT policy is
device-aware through EIrate = EI(x) / (c(x)/speed_d) (a strict generalization
of eq. 5, see scheduler.py).  ``cls`` names the slice's *device class* in a
:class:`repro.devplane.DeviceClassRegistry` — the registry routes per-class
trial costs through the roofline cost model, making the cost genuinely 2-D
over (device, model) instead of the rank-1 ``c(x)/speed_d`` (DESIGN.md §11).

Elasticity: slices can :meth:`join` (a new device arrives at runtime),
:meth:`leave` (permanently decommissioned — the in-flight trial dies like a
failure, but the slice never repairs), and be :meth:`preempt`-ed (the trial
is evicted, the slice is immediately schedulable again).  The streaming
device plane (``repro.devplane``) drives all three from trace events.
"""

from __future__ import annotations

from dataclasses import dataclass, field

DEFAULT_CLASS = "base"


@dataclass
class DeviceSlice:
    slice_id: int
    chips: int
    speed: float = 1.0
    healthy: bool = True
    busy_until: float = 0.0
    current_trial: int | None = None
    cls: str = DEFAULT_CLASS       # device-class name (devplane registry key)
    retired: bool = False          # left the fleet for good (never recovers)


@dataclass
class Fleet:
    slices: list[DeviceSlice]

    @classmethod
    def partition_pod(cls, total_chips: int = 256, num_slices: int = 8,
                      speeds: list[float] | None = None) -> "Fleet":
        assert total_chips % num_slices == 0
        chips = total_chips // num_slices
        speeds = speeds or [1.0] * num_slices
        return cls([DeviceSlice(i, chips, speeds[i]) for i in range(num_slices)])

    @property
    def num_devices(self) -> int:
        """Devices currently in the fleet (retired slices keep their ids but
        no longer count — a joined replacement gets a fresh id)."""
        return sum(1 for s in self.slices if not s.retired)

    def free_at(self, t: float) -> list[DeviceSlice]:
        return [s for s in self.slices
                if s.healthy and not s.retired
                and s.current_trial is None and s.busy_until <= t]

    def fail(self, slice_id: int) -> int | None:
        """Mark slice failed; returns the killed trial id (to re-queue).

        The killed trial's reservation dies with it: ``busy_until`` is reset
        so a slice repaired before the old reservation would have expired is
        immediately schedulable."""
        s = self.slices[slice_id]
        s.healthy = False
        s.busy_until = 0.0
        killed, s.current_trial = s.current_trial, None
        return killed

    def recover(self, slice_id: int):
        self.slices[slice_id].healthy = True

    # ---- elasticity (the device plane's lifecycle verbs) --------------------

    def join(self, chips: int, speed: float = 1.0,
             cls: str = DEFAULT_CLASS) -> DeviceSlice:
        """A new slice arrives at runtime (cluster scale-up, a spot device
        granted).  Slice ids are append-only — a retired id is never reused,
        so pending completion events can never alias a new device."""
        s = DeviceSlice(len(self.slices), chips, speed, cls=cls)
        self.slices.append(s)
        return s

    def leave(self, slice_id: int) -> int | None:
        """Permanent decommission: the in-flight trial dies exactly like a
        slice failure (returns the killed trial id), but the slice is marked
        retired and never recovers."""
        killed = self.fail(slice_id)
        self.slices[slice_id].retired = True
        return killed

    def preempt(self, slice_id: int) -> int | None:
        """Evict the in-flight trial (returns its id to re-queue) but keep
        the slice healthy and immediately schedulable — the spot-market /
        higher-priority-work eviction, distinct from a failure's downtime."""
        s = self.slices[slice_id]
        s.busy_until = 0.0
        killed, s.current_trial = s.current_trial, None
        return killed
