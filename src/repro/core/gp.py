"""Gaussian-process posterior over a finite model set.

The paper (Supplemental A) conditions a GP prior ``GP(mu(x), k(x, x'))`` on
noise-free observations of a growing set of models.  Two engines are provided:

* :func:`posterior_masked` — one-shot, fixed-shape, fully jittable posterior
  over *all* models given an observation mask.  O(n^3); used for tests, small
  problems and as the oracle for the incremental engine.

* :class:`IncrementalGP` — event-driven engine used by the scheduler.  It
  maintains a Cholesky factor of the observed-set kernel and the matrix
  ``W = L^{-1} K[obs, :]`` so that appending one observation costs O(k * n)
  and the full posterior mean/variance over all n models is always available
  in O(1) extra work.  All buffers are preallocated at size n so every
  append is a fixed-shape jitted step (no recompilation as observations grow).

Observation noise is zero in the paper's setting (each model is run once);
``jitter`` keeps the Cholesky numerically PSD and is chosen far below any
kernel scale of interest (see DESIGN.md §3.3).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp


DEFAULT_JITTER = 1e-6


def posterior_masked(
    K: jax.Array,
    mu0: jax.Array,
    z: jax.Array,
    mask: jax.Array,
    jitter: float = DEFAULT_JITTER,
) -> tuple[jax.Array, jax.Array]:
    """Posterior mean/variance over all n models given masked observations.

    Uses the identity-padding trick: rows/cols of unobserved models are
    replaced by identity rows, so the Cholesky of the padded matrix contains
    the Cholesky of ``K[obs, obs]`` embedded in the observed rows and the
    identity rows are inert (their RHS entries are zeroed).

    Args:
      K:    (n, n) prior covariance.
      mu0:  (n,) prior mean.
      z:    (n,) observed values; entries where ``mask`` is False are ignored.
      mask: (n,) bool, True where observed.
      jitter: diagonal jitter added to observed rows.

    Returns:
      (mu_post, var_post), each (n,).  For observed models the posterior mean
      equals z and the variance is ~0.
    """
    n = K.shape[0]
    m = mask.astype(K.dtype)
    eye = jnp.eye(n, dtype=K.dtype)
    A = K * (m[:, None] * m[None, :]) + eye * (1.0 - m) + eye * (jitter * m)
    L = jnp.linalg.cholesky(A)
    rhs = m * (z - mu0)
    alpha = jax.scipy.linalg.cho_solve((L, True), rhs)
    V = m[:, None] * K  # (n, n): column x holds K[obs, x] with unobserved rows zeroed
    W = jax.scipy.linalg.solve_triangular(L, V, lower=True)
    mu_post = mu0 + V.T @ alpha
    var_post = jnp.diag(K) - jnp.sum(W * W, axis=0)
    return mu_post, jnp.maximum(var_post, 0.0)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _append_step(
    W: jax.Array,
    alpha: jax.Array,
    diag_acc: jax.Array,
    K_row: jax.Array,
    idx: jax.Array,
    z_val: jax.Array,
    mu0_val: jax.Array,
    k: jax.Array,
    jitter: jax.Array,
):
    """One fixed-shape incremental Cholesky/posterior update.

    W:        (n, n) buffer; rows [0, k) hold L^{-1} K[obs, :].
    alpha:    (n,) buffer; entries [0, k) hold L^{-1} (z_obs - mu0_obs).
    diag_acc: (n,) running sum of W^2 over observed rows (= prior_var - post_var).
    K_row:    (n,) row of the prior kernel for the new model.
    idx:      scalar int, index of the new model.

    Also returns the pivot ``d2`` (the Schur complement of the new row):
    when it sits at the jitter floor the factorization is numerically
    degenerate — the health plane's conditioning watchdog consumes it
    (DESIGN.md §14).  The extra output changes no numerics: W/alpha/
    diag_acc are computed exactly as before.
    """
    # l = L^{-1} K[obs, new] is exactly column `idx` of W (rows >= k are zero).
    l = W[:, idx]
    d2 = K_row[idx] + jitter - jnp.dot(l, l)
    d = jnp.sqrt(jnp.maximum(d2, jitter))
    w_new = (K_row - l @ W) / d
    a_new = (z_val - mu0_val - jnp.dot(l, alpha)) / d
    W = jax.lax.dynamic_update_index_in_dim(W, w_new, k, axis=0)
    alpha = alpha.at[k].set(a_new)
    diag_acc = diag_acc + w_new * w_new
    return W, alpha, diag_acc, d2


@jax.jit
def _readout(W, alpha, mu0, kdiag, diag_acc):
    # alpha @ W (not W.T @ alpha): keeps the (n, n) buffer row-major and
    # avoids an eager 25MB transpose copy per scheduler decision.
    mu = mu0 + alpha @ W
    var = jnp.maximum(kdiag - diag_acc, 0.0)
    return mu, var


class IncrementalGP:
    """Incremental zero-noise GP posterior over a fixed finite model set."""

    def __init__(self, K, mu0, jitter: float = DEFAULT_JITTER):
        self.K = jnp.asarray(K)
        self.mu0 = jnp.asarray(mu0, dtype=self.K.dtype)
        n = self.K.shape[0]
        if self.K.shape != (n, n):
            raise ValueError(f"K must be square, got {self.K.shape}")
        if self.mu0.shape != (n,):
            raise ValueError(f"mu0 must be ({n},), got {self.mu0.shape}")
        self.n = n
        self.jitter = jnp.asarray(jitter, dtype=self.K.dtype)
        dtype = self.K.dtype
        self._W = jnp.zeros((n, n), dtype=dtype)
        self._alpha = jnp.zeros((n,), dtype=dtype)
        self._diag_acc = jnp.zeros((n,), dtype=dtype)
        self._k = 0
        self._kdiag = None
        self.observed: list[int] = []
        self._z = {}
        # pivot d² of the most recent fold, device-resident (never synced
        # unless a health monitor asks — the disabled path stays async)
        self.last_d2 = None

    def observe(self, idx: int, z_val: float) -> None:
        """Condition on z(model idx) = z_val.  O(n^2) fixed-shape jitted step."""
        if idx in self._z:
            raise ValueError(f"model {idx} already observed")
        import math
        if not math.isfinite(z_val):
            # poisoned-observation guard (DESIGN.md §16): a NaN/±inf fold
            # would silently corrupt every later posterior readout
            raise ValueError(f"non-finite observation {z_val!r} for "
                             f"model {idx}")
        self._W, self._alpha, self._diag_acc, self.last_d2 = _append_step(
            self._W,
            self._alpha,
            self._diag_acc,
            self.K[idx],
            jnp.asarray(idx),
            jnp.asarray(z_val, dtype=self.K.dtype),
            self.mu0[idx],
            jnp.asarray(self._k),
            self.jitter,
        )
        self._k += 1
        self.observed.append(idx)
        self._z[idx] = float(z_val)

    @property
    def num_observed(self) -> int:
        return self._k

    def resource_stats(self) -> dict:
        """Analytic byte/observation accounting of this engine's buffers
        (obs/accounting.py introspects through here, never through the
        private attributes).  ``alloc_bytes`` is the preallocated footprint
        — W (n,n) + K (n,n) + alpha/diag_acc/mu0 (n,) each; ``active_bytes``
        is the Cholesky-occupied share, the O(k·n) rows [0, k) of W plus k
        entries of alpha — the part that grows O(obs²) when n tracks the
        observed set."""
        item = self.K.dtype.itemsize
        n, k = self.n, self._k
        return {
            "models": n,
            "obs": k,
            "alloc_bytes": (2 * n * n + 3 * n) * item,
            "active_bytes": (k * n + k) * item,
            "dtype_bytes": item,
        }

    def posterior(self) -> tuple[jax.Array, jax.Array]:
        """(mu, var) over all n models, O(n^2) readout (jitted, row-major)."""
        if self._kdiag is None:
            self._kdiag = jnp.diag(self.K)
        return _readout(self._W, self._alpha, self.mu0, self._kdiag,
                        self._diag_acc)

    def posterior_sd(self) -> tuple[jax.Array, jax.Array]:
        mu, var = self.posterior()
        return mu, jnp.sqrt(var)


class BlockIncrementalGP:
    """Incremental GP specialized to block-diagonal priors.

    In the paper's experimental setting each "model" is an (algorithm,
    dataset) pair, so tenants' candidate sets are disjoint and K is block
    diagonal — observations for one tenant never move another tenant's
    posterior.  Exploiting that turns the per-event cost from O(n^2) to
    O(m^2) (m = block size, n = N*m total), a ~N x control-plane speedup
    measured in benchmarks/control_plane.py.  Same interface as
    :class:`IncrementalGP`; equivalence is tested in tests/test_gp.py.

    Blocks are also the unit of tenant churn (DESIGN.md §9): because each
    block owns an independent Cholesky factor, a tenant's covariance block
    can be appended (:meth:`add_block`) or retired (:meth:`retire_block`)
    at runtime without refactorizing any other tenant's state.  Retired
    entries keep their last posterior values in the cached readout; callers
    mask them (the streaming control plane marks them selected).
    """

    def __init__(self, K=None, mu0=None, blocks: list | None = None,
                 jitter: float = DEFAULT_JITTER):
        import numpy as np
        self._jitter = jitter
        self.n = 0
        self._blocks: dict[int, np.ndarray] = {}
        self._engines: dict[int, IncrementalGP] = {}
        self._next_block_id = 0
        self._local: dict[int, tuple[int, int]] = {}
        self._mu = np.zeros(0, np.float32)
        self._var = np.zeros(0, np.float32)
        self._dirty: set[int] = set()
        self.observed: list[int] = []
        self._z = {}
        self.last_d2 = None     # pivot d² of the most recent fold
        if K is not None:
            K = np.asarray(K)
            mu0 = np.asarray(mu0, dtype=K.dtype)
            n = K.shape[0]
            assert blocks is not None, "static construction requires blocks"
            idx = [np.asarray(b, dtype=np.int64) for b in blocks]
            seen = np.concatenate(idx)
            assert len(seen) == n and len(set(seen.tolist())) == n, \
                "blocks must partition the model set"
            for b in idx:
                self.add_block(b, K[np.ix_(b, b)], mu0[b])
            assert self.n == n

    @classmethod
    def empty(cls, jitter: float = DEFAULT_JITTER) -> "BlockIncrementalGP":
        """A dynamic instance with no tenants yet (streaming control plane)."""
        return cls(jitter=jitter)

    # ---- tenant churn: block lifecycle ------------------------------------

    def ensure_capacity(self, n_cap: int) -> None:
        """Grow the cached posterior readout to ``n_cap`` entries (padding:
        mu 0, var 0 — callers mask indices that belong to no block)."""
        import numpy as np
        if n_cap <= self.n:
            return
        grow = n_cap - self.n
        self._mu = np.concatenate([self._mu, np.zeros(grow, np.float32)])
        self._var = np.concatenate([self._var, np.zeros(grow, np.float32)])
        self.n = n_cap

    def add_block(self, indices, K_block, mu0_block) -> int:
        """Register one tenant's covariance block at the given global model
        indices.  O(m) setup; no other block is touched.  Returns a block id
        for :meth:`retire_block`."""
        import numpy as np
        b = np.asarray(indices, dtype=np.int64)
        K_block = np.asarray(K_block)
        mu0_block = np.asarray(mu0_block, dtype=K_block.dtype)
        m = len(b)
        assert K_block.shape == (m, m) and mu0_block.shape == (m,)
        clash = [int(g) for g in b if int(g) in self._local]
        assert not clash, f"indices already owned by a live block: {clash}"
        bid = self._next_block_id
        self._next_block_id += 1
        self.ensure_capacity(int(b.max()) + 1)
        self._blocks[bid] = b
        self._engines[bid] = IncrementalGP(K_block, mu0_block, self._jitter)
        for li, g in enumerate(b.tolist()):
            self._local[int(g)] = (bid, li)
        self._mu[b] = mu0_block.astype(np.float32)
        self._var[b] = np.clip(np.diag(K_block), 0, None).astype(np.float32)
        self._dirty.discard(bid)
        return bid

    def retire_block(self, block_id: int) -> None:
        """Drop one tenant's block: its Cholesky factor is freed and its
        models stop accepting observations.  Other blocks are untouched
        (no refactorization).  Cached readout entries go stale — mask them."""
        b = self._blocks.pop(block_id)
        self._engines.pop(block_id)
        self._dirty.discard(block_id)
        for g in b.tolist():
            del self._local[int(g)]

    def relocate_block(self, block_id: int, new_indices) -> None:
        """Move a live block to new global indices (index-space compaction,
        DESIGN.md §10).  The Cholesky factor and every observation are
        position-independent (they live in block-local coordinates), so this
        is O(m) bookkeeping: remap the global->local index, move the cached
        readout values, and leave the vacated entries inert (mu 0, var 0 —
        the padding convention)."""
        import numpy as np
        old = self._blocks[block_id]
        new = np.asarray(new_indices, dtype=np.int64)
        assert new.shape == old.shape, "relocation must preserve block size"
        own = set(old.tolist())
        clash = [int(g) for g in new
                 if int(g) in self._local and int(g) not in own]
        assert not clash, f"target indices owned by a live block: {clash}"
        self.ensure_capacity(int(new.max()) + 1)
        for g in old.tolist():
            del self._local[int(g)]
        for li, g in enumerate(new.tolist()):
            self._local[int(g)] = (block_id, li)
        mu_b, var_b = self._mu[old].copy(), self._var[old].copy()
        self._mu[old] = 0.0
        self._var[old] = 0.0
        self._mu[new] = mu_b
        self._var[new] = var_b
        self._blocks[block_id] = new

    @staticmethod
    def blocks_from_membership(K, membership, atol: float = 0.0) -> list | None:
        """Tenant partition if candidate sets are disjoint and K has no
        cross-block mass; None if the structure doesn't hold."""
        import numpy as np
        membership = np.asarray(membership, bool)
        if (membership.sum(axis=0) != 1).any():
            return None
        blocks = [np.nonzero(membership[i])[0] for i in range(membership.shape[0])]
        K = np.asarray(K)
        mask = np.zeros_like(K, dtype=bool)
        for b in blocks:
            mask[np.ix_(b, b)] = True
        if np.abs(K[~mask]).max(initial=0.0) > atol:
            return None
        return blocks

    def observe(self, idx: int, z_val: float) -> None:
        import math
        if not math.isfinite(z_val):
            # poisoned-observation guard at the block boundary too: callers
            # that bypass ControlPlane.record_observation get the same wall
            raise ValueError(f"non-finite observation {z_val!r} for "
                             f"model {idx}")
        if idx not in self._local:
            raise KeyError(f"model {idx} belongs to no live block")
        bi, li = self._local[idx]
        self._engines[bi].observe(li, z_val)
        self.last_d2 = self._engines[bi].last_d2
        self._dirty.add(bi)
        self.observed.append(idx)
        self._z[idx] = float(z_val)

    @property
    def num_observed(self) -> int:
        return len(self.observed)

    def resource_stats(self) -> dict:
        """Per-block + aggregate resource accounting (obs/accounting.py).

        ``blocks`` maps block id -> the owning :class:`IncrementalGP`'s
        :meth:`~IncrementalGP.resource_stats`; the aggregate adds the host
        readout caches (``_mu``/``_var``, float32 over the full capacity).
        Pure host-side introspection: no device syncs, so the accounting
        plane's disabled-path cost discipline holds."""
        blocks = {bid: eng.resource_stats()
                  for bid, eng in sorted(self._engines.items())}
        readout = 2 * self.n * 4          # _mu + _var, float32 each
        return {
            "blocks": blocks,
            "num_blocks": len(blocks),
            "capacity": self.n,
            "obs_total": sum(b["obs"] for b in blocks.values()),
            "alloc_bytes": sum(b["alloc_bytes"] for b in blocks.values()),
            "active_bytes": sum(b["active_bytes"] for b in blocks.values()),
            "readout_bytes": readout,
        }

    def _flush(self) -> None:
        import numpy as np
        for bi in self._dirty:
            mu_b, var_b = self._engines[bi].posterior()
            b = self._blocks[bi]
            self._mu[b] = np.asarray(mu_b)
            self._var[b] = np.asarray(var_b)
        self._dirty.clear()

    def posterior(self):
        self._flush()
        return jnp.asarray(self._mu), jnp.asarray(self._var)

    def posterior_host(self):
        """(mu, var) as the engine's own host numpy buffers (read-only by
        convention — callers must not mutate).  The sharded scorer consumes
        these directly: wrapping them in device arrays here only to convert
        back before the sharded upload would round-trip every decision."""
        self._flush()
        return self._mu, self._var

    def posterior_sd(self):
        mu, var = self.posterior()
        return mu, jnp.sqrt(var)


def make_gp(K, mu0, membership=None, jitter: float = DEFAULT_JITTER):
    """Pick the block engine when the tenant structure allows it."""
    if membership is not None:
        blocks = BlockIncrementalGP.blocks_from_membership(K, membership)
        if blocks is not None and len(blocks) > 1:
            return BlockIncrementalGP(K, mu0, blocks, jitter)
    return IncrementalGP(K, mu0, jitter)
