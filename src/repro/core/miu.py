"""Maximum Incremental Uncertainty (MIU) — Section 5.1 of the paper.

  MIU_s(K) = max_{S' subset S, |S|=s, |S'|=s-1} sqrt(det(K_S) / det(K_S'))

By the Schur-complement identity (Lemma 5), det(K_S)/det(K_S') is the
*conditional variance* of the element added to S' — so

  MIU_s(K) = max_{|S'| = s-1, x not in S'} Var(z_x | z_S')^{1/2}

which is how we compute it (an (s-1)-subset enumeration plus a rank-|S'|
solve, instead of an s-subset enumeration with two determinants — same value,
one fewer combinatorial level and numerically far stabler for near-singular
K_S').

Exact enumeration is exponential; it is intended for the test/analysis regime
(n <= ~14).  For larger matrices use :func:`miu_diag_upper_bound` (the bound
used in the paper's convergence discussion) or :func:`miu_greedy` (a lower
bound via greedy subset growth).
"""

from __future__ import annotations

import itertools

import numpy as np


def _cond_var(K: np.ndarray, x: int, subset: tuple[int, ...]) -> float:
    """Var(z_x | z_subset) with zero observation noise."""
    if not subset:
        return float(K[x, x])
    S = list(subset)
    Kss = K[np.ix_(S, S)]
    kxs = K[S, x]
    try:
        sol = np.linalg.solve(Kss, kxs)
    except np.linalg.LinAlgError:
        sol, *_ = np.linalg.lstsq(Kss, kxs, rcond=None)
    return float(max(K[x, x] - kxs @ sol, 0.0))


def miu_s_exact(K: np.ndarray, s: int) -> float:
    """MIU_s(K) by exhaustive enumeration.  1 <= s <= n."""
    K = np.asarray(K, dtype=np.float64)
    n = K.shape[0]
    if not 1 <= s <= n:
        raise ValueError(f"s must be in [1, {n}], got {s}")
    best = 0.0
    for subset in itertools.combinations(range(n), s - 1):
        in_subset = set(subset)
        for x in range(n):
            if x in in_subset:
                continue
            best = max(best, _cond_var(K, x, subset))
    return float(np.sqrt(best))


def miu_cumulative_exact(K: np.ndarray, num_observed: int) -> float:
    """MIU(T, K) = sum_{s=2}^{|L(t)|} MIU_s(K) (Theorem 2), exact."""
    return float(sum(miu_s_exact(K, s) for s in range(2, num_observed + 1)))


def miu_greedy(K: np.ndarray, s: int) -> float:
    """Greedy lower bound on MIU_s: grow S' by repeatedly keeping the subset
    that leaves the *largest* maximal conditional variance."""
    K = np.asarray(K, dtype=np.float64)
    n = K.shape[0]
    subset: tuple[int, ...] = ()
    for _ in range(s - 1):
        # add the element whose removal from the candidate pool hurts least:
        # heuristically, the element most predictable from the current subset.
        remaining = [x for x in range(n) if x not in subset]
        scores = [(_cond_var(K, x, subset), x) for x in remaining]
        subset = subset + (min(scores)[1],)
    remaining = [x for x in range(n) if x not in subset]
    if not remaining:
        return 0.0
    return float(np.sqrt(max(_cond_var(K, x, subset) for x in remaining)))


def miu_diag_paper_bound(K: np.ndarray, num_observed: int) -> float:
    """The bound as *stated* in the paper (Section 5.2):
    MIU(T,K) <= sum of the top |L(t)| values of sqrt(K_ii).

    NOTE (reproduction finding, see EXPERIMENTS.md §Findings): this claim is
    FALSE in general.  Counterexample: variances (1, eps, eps), all
    independent -> MIU_2 = MIU_3 = 1, so MIU(T) = 2, but the top-3 diagonal
    sum is 1 + 2*sqrt(eps) < 2 for small eps.  The issue is that the max in
    MIU_s may select the *same* high-variance variable for every s (any
    subset S' not containing it leaves its conditional variance untouched).
    Kept for reference; use :func:`miu_diag_upper_bound` for a bound that
    actually holds.
    """
    K = np.asarray(K, dtype=np.float64)
    d = np.sqrt(np.clip(np.diag(K), 0.0, None))
    top = np.sort(d)[::-1][:num_observed]
    return float(top.sum())


def miu_diag_upper_bound(K: np.ndarray, num_observed: int) -> float:
    """A correct diagonal bound: MIU_s(K) <= max_i sqrt(K_ii) for every s
    (conditioning cannot raise a marginal variance), hence
    MIU(T,K) = sum_{s=2}^{|L(t)|} MIU_s(K) <= (|L(t)|-1) * max_i sqrt(K_ii).

    All of the paper's convergence corollaries survive with this bound: it
    is O(T) in general (the "not converge" independent case is tight), and
    whenever MIU_s decays (correlated models) MIU(T,K) = o(T) and the
    average regret converges, exactly as discussed in Section 5.2.
    """
    K = np.asarray(K, dtype=np.float64)
    d = np.sqrt(np.clip(np.diag(K), 0.0, None))
    return float(max(num_observed - 1, 0) * d.max()) if d.size else 0.0
