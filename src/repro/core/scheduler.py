"""Event-driven multi-device, multi-tenant schedulers (Algorithm 1 + baselines).

Implements the paper's policy loop: *as long as there is a device available,
select a model to run on this device*.  The simulator is a discrete-event
engine over virtual time; the per-event decision core (GP update + EIrate
pick) lives in ``control_plane.ControlPlane`` and is shared with the
streaming engine (``repro.stream``) — the event bookkeeping here is host
Python, exactly the split a real service has (control decisions on the
coordinator, math on an accelerator).

Policies
--------
* ``mdmt``        — MM-GP-EI (the paper): global argmax of EIrate (eq. 6).
* ``round_robin`` — each tenant runs their own GP-EI; tenants served cyclically.
* ``random``      — each tenant runs their own GP-EI; tenant chosen uniformly.

All policies share the experimental protocol of Section 6.1: a warm start
that trains the two fastest models of every tenant first, then the policy
takes over.

Beyond-paper (service-grade) features, all default-off:
* device failures — a failed trial's model returns to the unselected pool and
  is eligible for re-issue (checkpoint/restart of long trainings is handled a
  layer down, see ``repro.checkpoint``);
* heterogeneous device speeds — EIrate becomes device-aware,
  ``EI(x) / (c(x)/speed_d)``, a strict generalization of eq. (5);
* scheduler-decision accounting for control-plane benchmarks.
"""

from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass, field

import numpy as np

from .control_plane import (  # noqa: F401  (re-exported: sim_batched + tests)
    ControlPlane,
    _fastest_models,
    no_obs_floor,
    warm_start_queue,
)
from .tenancy import Problem

POLICIES = ("mdmt", "round_robin", "random")


@dataclass(frozen=True)
class TrialRecord:
    model: int
    user_hint: int          # tenant that motivated the launch (-1 for mdmt global)
    device: int
    start: float
    end: float
    z: float | None         # None => trial failed (device died)


@dataclass(frozen=True)
class FailureEvent:
    device: int
    at: float
    downtime: float


@dataclass
class SimResult:
    problem: Problem
    policy: str
    num_devices: int
    trials: list[TrialRecord]
    end_time: float
    decisions: int
    decision_seconds: float  # host+accelerator time inside policy decisions

    @property
    def observations(self) -> list[tuple[float, int, float]]:
        """(finish_time, model, z) for successful trials, time-ordered."""
        obs = [(t.end, t.model, t.z) for t in self.trials if t.z is not None]
        obs.sort()
        return obs


def simulate(
    problem: Problem,
    policy: str,
    num_devices: int,
    seed: int = 0,
    horizon: float = np.inf,
    warm_start: int = 2,
    device_speeds: np.ndarray | None = None,
    failures: list[FailureEvent] | None = None,
) -> SimResult:
    """Run one TSHB episode and return the full trial log.

    The loop mirrors Algorithm 1: whenever a device frees (or at t=0), refresh
    the posterior with all observations, then launch the policy's pick.
    ``warm_start`` is the number of fastest models per tenant trained before
    the policy takes over (Section 6.1 protocol uses 2; pass 0 to start with
    the pure algorithm, whose line 1 initialization is the prior-mean argmax).
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    problem.validate()
    rng = np.random.default_rng(seed)
    state = ControlPlane.from_problem(problem, rng)
    speeds = np.ones(num_devices) if device_speeds is None else np.asarray(device_speeds, float)
    assert speeds.shape == (num_devices,)

    fail_sched: dict[int, list[FailureEvent]] = {d: [] for d in range(num_devices)}
    for f in failures or []:
        fail_sched[f.device].append(f)
    for evs in fail_sched.values():
        evs.sort(key=lambda f: f.at)

    pending = warm_start_queue(problem, warm_start)

    heap: list[tuple[float, int, str, tuple]] = []  # (time, seq, kind, payload)
    seq = 0

    def push(t: float, kind: str, payload: tuple) -> None:
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    trials: list[TrialRecord] = []
    decisions = 0
    decision_seconds = 0.0
    free = list(range(num_devices))
    t_now = 0.0

    chooser = state.chooser(policy)

    def try_launch() -> None:
        nonlocal decisions, decision_seconds
        while free:
            if t_now >= horizon:
                return
            d = free[-1]
            if pending:
                model, user_hint = pending.pop(0), -2
                if state.selected[model]:
                    continue
            else:
                t0 = _time.perf_counter()
                pick = chooser(device_speed=speeds[d])
                decision_seconds += _time.perf_counter() - t0
                decisions += 1
                if pick is None:
                    return
                model, user_hint = pick
            free.pop()
            dur = float(problem.cost[model]) / speeds[d]
            end = t_now + dur
            state.record_start(model)
            # Device-failure check: does a scheduled failure interrupt this trial?
            fut = [f for f in fail_sched[d] if t_now <= f.at < end]
            if fut:
                f = fut[0]
                fail_sched[d].remove(f)
                trials.append(TrialRecord(model, user_hint, d, t_now, f.at, None))
                push(f.at, "fail", (d, model, f.downtime))
            else:
                trials.append(TrialRecord(model, user_hint, d, t_now, end, None))
                push(end, "finish", (d, model, len(trials) - 1))

    try_launch()
    while heap:
        t_now, _, kind, payload = heapq.heappop(heap)
        if kind == "finish":
            d, model, ti = payload
            z = float(problem.z_true[model])
            trials[ti] = TrialRecord(
                trials[ti].model, trials[ti].user_hint, d,
                trials[ti].start, trials[ti].end, z)
            state.record_observation(model, z)
            free.append(d)
        elif kind == "fail":
            d, model, downtime = payload
            state.record_failure(model)
            push(t_now + downtime, "recover", (d,))
        elif kind == "recover":
            (d,) = payload
            free.append(d)
        if t_now < horizon:
            try_launch()

    return SimResult(
        problem=problem, policy=policy, num_devices=num_devices,
        trials=trials, end_time=t_now, decisions=decisions,
        decision_seconds=decision_seconds)
