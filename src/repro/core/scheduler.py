"""Event-driven multi-device, multi-tenant schedulers (Algorithm 1 + baselines).

Implements the paper's policy loop: *as long as there is a device available,
select a model to run on this device*.  The simulator is a discrete-event
engine over virtual time; all GP/EI math is JAX (see ``gp.py`` / ``ei.py``),
the event bookkeeping is host Python — exactly the split a real service has
(control decisions on the coordinator, math on an accelerator).

Policies
--------
* ``mdmt``        — MM-GP-EI (the paper): global argmax of EIrate (eq. 6).
* ``round_robin`` — each tenant runs their own GP-EI; tenants served cyclically.
* ``random``      — each tenant runs their own GP-EI; tenant chosen uniformly.

All policies share the experimental protocol of Section 6.1: a warm start
that trains the two fastest models of every tenant first, then the policy
takes over.

Beyond-paper (service-grade) features, all default-off:
* device failures — a failed trial's model returns to the unselected pool and
  is eligible for re-issue (checkpoint/restart of long trainings is handled a
  layer down, see ``repro.checkpoint``);
* heterogeneous device speeds — EIrate becomes device-aware,
  ``EI(x) / (c(x)/speed_d)``, a strict generalization of eq. (5);
* scheduler-decision accounting for control-plane benchmarks.
"""

from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .ei import choose_next_fused, single_tenant_ei_scores
from .gp import make_gp
from .tenancy import Problem

POLICIES = ("mdmt", "round_robin", "random")


@dataclass(frozen=True)
class TrialRecord:
    model: int
    user_hint: int          # tenant that motivated the launch (-1 for mdmt global)
    device: int
    start: float
    end: float
    z: float | None         # None => trial failed (device died)


@dataclass(frozen=True)
class FailureEvent:
    device: int
    at: float
    downtime: float


@dataclass
class SimResult:
    problem: Problem
    policy: str
    num_devices: int
    trials: list[TrialRecord]
    end_time: float
    decisions: int
    decision_seconds: float  # host+accelerator time inside policy decisions

    @property
    def observations(self) -> list[tuple[float, int, float]]:
        """(finish_time, model, z) for successful trials, time-ordered."""
        obs = [(t.end, t.model, t.z) for t in self.trials if t.z is not None]
        obs.sort()
        return obs


def _fastest_models(problem: Problem, user: int, count: int) -> list[int]:
    idx = np.nonzero(problem.membership[user])[0]
    order = idx[np.argsort(problem.cost[idx], kind="stable")]
    return list(order[:count])


def no_obs_floor(problem: Problem) -> float:
    """Finite stand-in for "no observation yet": far below any plausible z,
    so unserved tenants dominate the EI sum (see DESIGN.md §7).  Shared by
    both episode engines — the equivalence contract depends on it."""
    prior_sd = float(np.sqrt(np.clip(np.diag(problem.K), 0, None).max()))
    return float(problem.mu0.min()) - 5.0 * max(prior_sd, 1e-3)


def warm_start_queue(problem: Problem, warm_start: int) -> list[int]:
    """The initial launch queue: user-major, ``warm_start`` fastest models
    each, deduplicated keeping first occurrence (Section 6.1 protocol).
    ``warm_start=0`` yields Algorithm 1 line 1-2's prior-mean argmax per
    tenant instead.  Shared by both episode engines."""
    pending: list[int] = []
    seen: set[int] = set()
    for u in range(problem.num_users):
        for m in _fastest_models(problem, u, warm_start):
            if m not in seen:
                seen.add(m)
                pending.append(m)
    if warm_start == 0:
        for u in range(problem.num_users):
            idx = np.nonzero(problem.membership[u])[0]
            m = int(idx[np.argmax(problem.mu0[idx])])
            if m not in seen:
                seen.add(m)
                pending.append(m)
    return pending


class _PolicyState:
    """Shared mutable state the policies read."""

    def __init__(self, problem: Problem, rng: np.random.Generator):
        self.problem = problem
        self.rng = rng
        n, N = problem.num_models, problem.num_users
        self.gp = make_gp(problem.K, problem.mu0, problem.membership)
        self.selected = np.zeros(n, dtype=bool)   # observed OR in flight
        self.observed = np.zeros(n, dtype=bool)
        self.best = np.full(N, -np.inf)           # z(x_i^*(t)), observed best
        self._no_obs_floor = no_obs_floor(problem)
        self._membership_j = jnp.asarray(problem.membership)
        self._cost_j = jnp.asarray(problem.cost.astype(np.float32))
        # device-resident mirrors updated incrementally (one .at[] per event
        # instead of a full host->device copy per decision) — §Perf iteration 3
        self._selected_j = jnp.zeros(n, bool)
        self._best_j = jnp.full(N, self._no_obs_floor, jnp.float32)
        self.rr_pointer = 0

    def best_effective(self) -> np.ndarray:
        return np.where(np.isfinite(self.best), self.best, self._no_obs_floor)

    def record_start(self, model: int) -> None:
        self.selected[model] = True
        self._selected_j = self._selected_j.at[model].set(True)

    def record_failure(self, model: int) -> None:
        # Paper's abstraction makes failure handling trivial: the model was
        # never observed, so it simply returns to L \ L(t).
        self.selected[model] = False
        self._selected_j = self._selected_j.at[model].set(False)

    def record_observation(self, model: int, z: float) -> None:
        self.observed[model] = True
        self.gp.observe(model, z)
        users = np.nonzero(self.problem.membership[:, model])[0]
        for u in users:
            if z > self.best[u] or not np.isfinite(self.best[u]):
                self.best[u] = max(z, self.best[u]) if np.isfinite(self.best[u]) else z
                self._best_j = self._best_j.at[u].set(self.best[u])

    # ---- policy decisions -------------------------------------------------

    def choose_mdmt(self, device_speed: float = 1.0) -> tuple[int, int] | None:
        if self.selected.all():
            return None
        mu, sd = self.gp.posterior_sd()
        cost = self._cost_j if device_speed == 1.0 else self._cost_j / device_speed
        idx, score = choose_next_fused(
            mu, sd, self._best_j, self._membership_j, cost, self._selected_j)
        score = float(score)
        if not np.isfinite(score) or score <= -1e29:
            return None
        return int(idx), -1

    def _users_with_work(self) -> np.ndarray:
        has_work = (self.problem.membership & ~self.selected[None, :]).any(axis=1)
        return np.nonzero(has_work)[0]

    def _own_gp_ei(self, user: int) -> int | None:
        mu, sd = self.gp.posterior_sd()
        best = self.best[user] if np.isfinite(self.best[user]) else self._no_obs_floor
        scores = single_tenant_ei_scores(
            mu, sd, jnp.asarray(best),
            self._membership_j[user], jnp.asarray(self.selected))
        idx = int(jnp.argmax(scores))
        if not np.isfinite(float(scores[idx])):
            return None
        return idx

    def choose_random(self, device_speed: float = 1.0) -> tuple[int, int] | None:
        users = self._users_with_work()
        if users.size == 0:
            return None
        u = int(self.rng.choice(users))
        m = self._own_gp_ei(u)
        return (m, u) if m is not None else None

    def choose_round_robin(self, device_speed: float = 1.0) -> tuple[int, int] | None:
        users = self._users_with_work()
        if users.size == 0:
            return None
        N = self.problem.num_users
        for step in range(N):
            u = (self.rr_pointer + step) % N
            if u in users:
                self.rr_pointer = (u + 1) % N
                m = self._own_gp_ei(u)
                if m is not None:
                    return m, u
        return None


def simulate(
    problem: Problem,
    policy: str,
    num_devices: int,
    seed: int = 0,
    horizon: float = np.inf,
    warm_start: int = 2,
    device_speeds: np.ndarray | None = None,
    failures: list[FailureEvent] | None = None,
) -> SimResult:
    """Run one TSHB episode and return the full trial log.

    The loop mirrors Algorithm 1: whenever a device frees (or at t=0), refresh
    the posterior with all observations, then launch the policy's pick.
    ``warm_start`` is the number of fastest models per tenant trained before
    the policy takes over (Section 6.1 protocol uses 2; pass 0 to start with
    the pure algorithm, whose line 1 initialization is the prior-mean argmax).
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    problem.validate()
    rng = np.random.default_rng(seed)
    state = _PolicyState(problem, rng)
    speeds = np.ones(num_devices) if device_speeds is None else np.asarray(device_speeds, float)
    assert speeds.shape == (num_devices,)

    fail_sched: dict[int, list[FailureEvent]] = {d: [] for d in range(num_devices)}
    for f in failures or []:
        fail_sched[f.device].append(f)
    for evs in fail_sched.values():
        evs.sort(key=lambda f: f.at)

    pending = warm_start_queue(problem, warm_start)

    heap: list[tuple[float, int, str, tuple]] = []  # (time, seq, kind, payload)
    seq = 0

    def push(t: float, kind: str, payload: tuple) -> None:
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    trials: list[TrialRecord] = []
    decisions = 0
    decision_seconds = 0.0
    free = list(range(num_devices))
    t_now = 0.0

    chooser = {
        "mdmt": state.choose_mdmt,
        "random": state.choose_random,
        "round_robin": state.choose_round_robin,
    }[policy]

    def try_launch() -> None:
        nonlocal decisions, decision_seconds
        while free:
            if t_now >= horizon:
                return
            d = free[-1]
            if pending:
                model, user_hint = pending.pop(0), -2
                if state.selected[model]:
                    continue
            else:
                t0 = _time.perf_counter()
                pick = chooser(device_speed=speeds[d])
                decision_seconds += _time.perf_counter() - t0
                decisions += 1
                if pick is None:
                    return
                model, user_hint = pick
            free.pop()
            dur = float(problem.cost[model]) / speeds[d]
            end = t_now + dur
            state.record_start(model)
            # Device-failure check: does a scheduled failure interrupt this trial?
            fut = [f for f in fail_sched[d] if t_now <= f.at < end]
            if fut:
                f = fut[0]
                fail_sched[d].remove(f)
                trials.append(TrialRecord(model, user_hint, d, t_now, f.at, None))
                push(f.at, "fail", (d, model, f.downtime))
            else:
                trials.append(TrialRecord(model, user_hint, d, t_now, end, None))
                push(end, "finish", (d, model, len(trials) - 1))

    try_launch()
    while heap:
        t_now, _, kind, payload = heapq.heappop(heap)
        if kind == "finish":
            d, model, ti = payload
            z = float(problem.z_true[model])
            trials[ti] = TrialRecord(
                trials[ti].model, trials[ti].user_hint, d,
                trials[ti].start, trials[ti].end, z)
            state.record_observation(model, z)
            free.append(d)
        elif kind == "fail":
            d, model, downtime = payload
            state.record_failure(model)
            push(t_now + downtime, "recover", (d,))
        elif kind == "recover":
            (d,) = payload
            free.append(d)
        if t_now < horizon:
            try_launch()

    return SimResult(
        problem=problem, policy=policy, num_devices=num_devices,
        trials=trials, end_time=t_now, decisions=decisions,
        decision_seconds=decision_seconds)
