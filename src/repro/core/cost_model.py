"""Roofline-derived trial cost c(x) — the paper's Remark 1 made concrete.

The paper assumes run cost c(x) is "easy to estimate [from] the dataset size,
the computational hardware parameters, historical data".  Here that estimate
is literally the dry-run roofline: for a trial = (arch config, input shape,
slice of `chips` chips, `steps` steps),

  c(x) = steps * max(compute_term, memory_term, collective_term)

with the three terms taken from the probe JSON when one exists for the
(arch, shape) cell (experiments/dryrun/...), else from an analytic model on
the same hardware constants.  A measured-update hook blends in observed
durations (historical data), which the service uses after every completed
trial.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
REFERENCE_CHIPS = 256        # probes are taken on the 16x16 mesh


@dataclass
class CostModel:
    mfu_assumption: float = 0.4      # analytic-path efficiency guess
    measured_blend: float = 0.5      # EMA weight for observed durations
    _measured: dict = field(default_factory=dict)
    _probe_cache: dict = field(default_factory=dict)

    # -- probe-backed path ---------------------------------------------------

    def _probe(self, arch: str, shape: str, mesh: str = "pod16x16",
               rules: str = "default"):
        key = (arch, shape, mesh, rules)
        if key not in self._probe_cache:
            path = DRYRUN_DIR / mesh / f"{arch}__{shape}__{rules}__probe.json"
            self._probe_cache[key] = json.loads(path.read_text()) if path.exists() else None
        return self._probe_cache[key]

    def step_seconds(self, arch: str, shape: str, chips: int = REFERENCE_CHIPS,
                     cfg=None) -> float:
        """Roofline step time for one (arch, shape) on a `chips`-chip slice."""
        probe = self._probe(arch, shape)
        if probe is not None:
            scale = REFERENCE_CHIPS / max(chips, 1)   # fewer chips => more per-chip work
            return max(probe["compute_seconds"], probe["memory_seconds"],
                       probe["collective_seconds"]) * scale
        if cfg is None:
            from repro.configs import get_config
            cfg = get_config(arch)
        return self._analytic(cfg, shape, chips)

    def _analytic(self, cfg, shape: str, chips: int) -> float:
        from repro.configs import SHAPES
        S, B, kind = SHAPES[shape]
        n_active = cfg.active_param_count()
        factor = 6.0 if kind == "train" else 2.0
        tokens = S * B if kind in ("train", "prefill") else B
        compute = factor * n_active * tokens / (chips * PEAK_FLOPS * self.mfu_assumption)
        # memory term: params + optimizer traffic per step
        param_bytes = cfg.param_count() * 4.0 * (3.0 if kind == "train" else 0.5)
        memory = param_bytes / (chips * HBM_BW)
        return max(compute, memory)

    # -- trial-level costs ---------------------------------------------------

    def trial_seconds(self, arch: str, shape: str, steps: int,
                      chips: int = REFERENCE_CHIPS, overhead: float = 30.0,
                      cfg=None) -> float:
        """c(x) for a `steps`-step trial (+ fixed setup/compile overhead)."""
        key = (arch, shape, chips)
        est = overhead + steps * self.step_seconds(arch, shape, chips, cfg)
        if key in self._measured:
            est = (1 - self.measured_blend) * est + self.measured_blend * self._measured[key]
        return est

    def class_trial_seconds(self, arch: str, shape: str, steps: int, *,
                            chips: int, speed: float = 1.0,
                            overhead: float = 30.0, cfg=None) -> float:
        """c(x, d) — the Remark-1 estimate specialized to one *device class*
        (``repro.devplane.DeviceClass``): the roofline step time at the
        class's chip count, scaled by the class's clock-speed multiplier,
        plus the fixed per-trial overhead.  The overhead does NOT scale with
        speed (setup/compile is host-bound), which is exactly what makes the
        (device-class x model) cost matrix genuinely 2-D — an affine map of
        the base cost, not the rank-1 ``c(x)/speed_d`` (DESIGN.md §11)."""
        if speed <= 0:
            raise ValueError(f"speed must be positive, got {speed}")
        return overhead + steps * self.step_seconds(arch, shape, chips, cfg) / speed

    def observe(self, arch: str, shape: str, chips: int, measured_seconds: float):
        """Historical-data update (Remark 1): EMA of observed trial durations."""
        key = (arch, shape, chips)
        prev = self._measured.get(key, measured_seconds)
        self._measured[key] = 0.5 * prev + 0.5 * measured_seconds
