"""Batched synchronous-slot episode engine: many TSHB episodes in one XLA call.

The event-driven simulator in ``scheduler.py`` runs one episode through a
host-Python heap loop — perfect for exactness, terrible for sweeps.  This
module reformulates Algorithm 1 as a fixed-shape ``lax.scan`` in which one
scan step processes exactly one device *slot* (the next device to free), and
a batch of episodes is a single ``jax.vmap`` over per-episode specs
(seed, policy, device count, device-speed vector, optional per-episode
``z_true``).  Thousands of (policy x N x M x seed) scenarios then run as one
accelerator dispatch instead of an overnight host loop.

Exactness (DESIGN.md §6): for the deterministic policies (``mdmt``,
``round_robin``) the engine replays the event-driven simulator's trial
sequence *exactly* — same models, same devices, same launch order — because
each scan step mirrors one heap pop: the device with the minimal
(finish-time, launch-sequence) key is processed, its observation is folded
into the incremental GP (the same ``_append_step`` recurrence as
``gp.IncrementalGP``, block-local), and the policy's pick is launched.  The
``random`` baseline uses a JAX PRNG stream, so it matches the event engine
in distribution but not per-seed.

Structural requirement: tenant candidate sets must be disjoint, equal-sized
and laid out tenant-major (model ``g`` belongs to tenant ``g // m``), with a
block-diagonal prior ``K`` — exactly the structure every problem generator
in ``tenancy.py`` produces, and the same structure ``gp.BlockIncrementalGP``
exploits.  ``simulate_batch`` raises ``ValueError`` otherwise.

Not supported (use ``scheduler.simulate``): device failures, finite
``horizon``.  Both are control-flow features of the host engine that a
fixed-shape scan would have to over-approximate; see DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
import functools
import time as _time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .ei import expected_improvement
from .gp import DEFAULT_JITTER
from .scheduler import POLICIES, SimResult, TrialRecord, no_obs_floor, warm_start_queue
from .tenancy import Problem

_IDLE_SEQ = np.iinfo(np.int32).max
_POLICY_ID = {p: i for i, p in enumerate(POLICIES)}  # mdmt=0, rr=1, random=2


@dataclass(frozen=True, eq=False)
class EpisodeSpec:
    """One episode of a batched sweep.

    ``device_speeds`` defaults to all-ones; ``z_true`` (length ``n``)
    overrides the problem's ground truth, which is how many-seed synthetic
    sweeps (fresh GP sample per seed, shared prior) batch into one call.
    (``eq=False``: the ndarray field would make the generated ``__eq__`` /
    ``__hash__`` raise; identity semantics are what callers need anyway.)
    """

    policy: str = "mdmt"
    num_devices: int = 1
    seed: int = 0
    device_speeds: tuple[float, ...] | None = None
    z_true: np.ndarray | None = None

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {self.policy!r}")
        if self.num_devices < 1:
            raise ValueError("num_devices must be >= 1")
        if self.device_speeds is not None and len(self.device_speeds) != self.num_devices:
            raise ValueError("device_speeds must have num_devices entries")


@dataclass
class BatchResult:
    """Per-episode trial logs + regret curves for a batch of B episodes.

    Trial arrays are in launch order (the same order ``scheduler.simulate``
    appends trials); step arrays are in event-time order (one row per scan
    step; ``obs_model < 0`` marks steps that observed nothing).
    """

    problem: Problem
    specs: tuple[EpisodeSpec, ...]
    warm_start: int
    # (B, n) launch-ordered trial logs
    trial_model: np.ndarray
    trial_user: np.ndarray      # user hint: -2 warm start, -1 mdmt global, else tenant
    trial_device: np.ndarray
    trial_start: np.ndarray
    trial_end: np.ndarray
    trial_z: np.ndarray
    # (B, T) event-ordered step logs
    obs_model: np.ndarray
    obs_time: np.ndarray
    inst_regret: np.ndarray     # mean per-user gap right after each step
    cum_regret: np.ndarray      # Regret_t at each observation step
    # (B,) accounting
    decisions: np.ndarray
    end_time: np.ndarray
    inst0: np.ndarray = None    # (B,) t=0 mean per-user gap (regret clamp)
    wall_seconds: float = 0.0   # total batch wall clock (incl. compile)

    @property
    def num_episodes(self) -> int:
        return self.trial_model.shape[0]

    def episode_result(self, i: int) -> SimResult:
        """Convert episode ``i`` to a :class:`scheduler.SimResult` so the
        exact host-side metrics in ``regret.py`` apply unchanged.

        When the spec overrides ``z_true``, the returned result carries a
        problem rebuilt around that override, so ``regret.py``'s
        ``z_star``/``worst`` are consistent with the logged observations.
        """
        spec = self.specs[i]
        problem = self.problem
        if spec.z_true is not None:
            problem = dataclasses.replace(
                problem, z_true=np.asarray(spec.z_true, problem.z_true.dtype))
        trials = [
            TrialRecord(
                model=int(self.trial_model[i, j]),
                user_hint=int(self.trial_user[i, j]),
                device=int(self.trial_device[i, j]),
                start=float(self.trial_start[i, j]),
                end=float(self.trial_end[i, j]),
                z=float(self.trial_z[i, j]),
            )
            for j in range(self.trial_model.shape[1])
            if self.trial_model[i, j] >= 0
        ]
        return SimResult(
            problem=problem, policy=spec.policy,
            num_devices=spec.num_devices, trials=trials,
            end_time=float(self.end_time[i]), decisions=int(self.decisions[i]),
            decision_seconds=0.0)

    def time_to_instantaneous(self, threshold: float) -> np.ndarray:
        """(B,) first event time the mean per-user gap drops to <= threshold
        (matches ``RegretCurves.time_to_instantaneous``; inf if never)."""
        B = self.num_episodes
        out = np.full(B, np.inf)
        valid = self.obs_model >= 0
        hit = (self.inst_regret <= threshold) & valid
        for i in range(B):
            idx = np.nonzero(hit[i])[0]
            if idx.size:
                out[i] = float(self.obs_time[i, idx[0]])
        # the t=0 point (pre-observation gap) can already satisfy the bar
        out[self.inst0 <= threshold] = 0.0
        return out


# ---------------------------------------------------------------------------
# host-side structure checks + warm-start queue
# ---------------------------------------------------------------------------

def _block_shape(problem: Problem) -> tuple[int, int]:
    """(N, m) if the problem is tenant-major block structured, else raise."""
    mem = np.asarray(problem.membership, bool)
    N, n = mem.shape
    if (mem.sum(axis=0) != 1).any():
        raise ValueError(
            "simulate_batch requires disjoint tenant candidate sets "
            "(every model owned by exactly one tenant)")
    sizes = mem.sum(axis=1)
    if (sizes != sizes[0]).any():
        raise ValueError("simulate_batch requires equal-sized candidate sets")
    m = int(sizes[0])
    for i in range(N):
        if not mem[i, i * m:(i + 1) * m].all():
            raise ValueError(
                "simulate_batch requires tenant-major model layout "
                "(model g owned by tenant g // m)")
    K = np.asarray(problem.K)
    off = K.copy()
    for i in range(N):
        off[i * m:(i + 1) * m, i * m:(i + 1) * m] = 0.0
    if np.abs(off).max(initial=0.0) != 0.0:
        raise ValueError("simulate_batch requires a block-diagonal prior K")
    return N, m


# ---------------------------------------------------------------------------
# the scan engine
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("N", "m", "Mmax", "T", "warm_len"))
def _run_batch(
    Kb, kdiag_b, mu0_b, cost, pending, floor, jitter,
    policy_id, num_devices, seeds, speeds, z_true_b, z_star_b, worst_b,
    *, N: int, m: int, Mmax: int, T: int, warm_len: int,
):
    """vmap-ed scan over episodes.  Shapes:

      Kb (N, m, m), kdiag_b/mu0_b (N, m), cost (n,), pending (warm_len,)
      policy_id/num_devices/seeds (B,), speeds (B, Mmax)
      z_true_b (B, n), z_star_b/worst_b (B, N)
    """
    n = N * m
    owner = jnp.repeat(jnp.arange(N, dtype=jnp.int32), m)
    mu0 = mu0_b.reshape(n)
    kdiag = kdiag_b.reshape(n)

    def episode(pid, nd, seed, speed, z_true, z_star, worst):
        dev_ids = jnp.arange(Mmax, dtype=jnp.int32)
        alive = dev_ids < nd
        state = dict(
            # device slots: finish time, running model, launch-seq tiebreak
            dev_end=jnp.where(alive, 0.0, jnp.inf).astype(jnp.float32),
            dev_model=jnp.full((Mmax,), -1, jnp.int32),
            # t=0 fill order is the free-stack pop order M-1, M-2, ..., 0
            dev_seq=jnp.where(alive, -1 - dev_ids, _IDLE_SEQ).astype(jnp.int32),
            # incremental GP (block-local _append_step buffers)
            W=jnp.zeros((N, m, m), jnp.float32),
            alpha=jnp.zeros((N, m), jnp.float32),
            diag_acc=jnp.zeros((N, m), jnp.float32),
            kcount=jnp.zeros((N,), jnp.int32),
            postmu=mu0.astype(jnp.float32),
            postvar=jnp.maximum(kdiag, 0.0).astype(jnp.float32),
            # policy state
            selected=jnp.zeros((n,), bool),
            best_raw=jnp.full((N,), -jnp.inf, jnp.float32),
            has_obs=jnp.zeros((N,), bool),
            rr_ptr=jnp.int32(0),
            key=jax.random.PRNGKey(seed),
            pend_ptr=jnp.int32(0),
            # trial log + accounting
            counter=jnp.int32(0),
            decisions=jnp.int32(0),
            tr_model=jnp.full((n,), -1, jnp.int32),
            tr_user=jnp.full((n,), -2, jnp.int32),
            tr_dev=jnp.full((n,), -1, jnp.int32),
            tr_start=jnp.zeros((n,), jnp.float32),
            tr_end=jnp.zeros((n,), jnp.float32),
            # regret integration (regret.py convention: pre-observation best
            # clamped to the worst in-set value)
            best_true=worst.astype(jnp.float32),
            t_prev=jnp.float32(0.0),
            cum=jnp.float32(0.0),
        )

        def step(s, _):
            # -- 1. pop the next event: min (finish time, launch seq) --------
            end = s["dev_end"]
            emin = jnp.min(end)
            active = jnp.isfinite(emin)
            tied = end == emin
            d = jnp.argmin(jnp.where(tied, s["dev_seq"], _IDLE_SEQ))
            t = jnp.where(active, emin, s["t_prev"])
            model = s["dev_model"][d]
            do_obs = active & (model >= 0)
            mi = jnp.maximum(model, 0)          # safe index when idle
            b, li = owner[mi], mi % m
            z = z_true[mi]

            # -- 2. regret integral up to t (integrand constant between obs) -
            gapsum = jnp.sum(z_star - s["best_true"])
            cum = s["cum"] + jnp.where(active, gapsum * (t - s["t_prev"]), 0.0)
            t_prev = jnp.where(active, t, s["t_prev"])

            # -- 3. fold the observation into the block-local incremental GP -
            Wb, ab = s["W"][b], s["alpha"][b]
            k_b = s["kcount"][b]
            K_row = Kb[b, li]
            l = Wb[:, li]
            d2 = K_row[li] + jitter - jnp.dot(l, l)
            dchol = jnp.sqrt(jnp.maximum(d2, jitter))
            w_new = (K_row - l @ Wb) / dchol
            a_new = (z - mu0_b[b, li] - jnp.dot(l, ab)) / dchol
            Wb2 = jax.lax.dynamic_update_index_in_dim(Wb, w_new, k_b, axis=0)
            ab2 = ab.at[k_b].set(a_new)
            dacc2 = s["diag_acc"][b] + w_new * w_new
            mu_blk = mu0_b[b] + ab2 @ Wb2
            var_blk = jnp.maximum(kdiag_b[b] - dacc2, 0.0)

            W = s["W"].at[b].set(jnp.where(do_obs, Wb2, Wb))
            alpha = s["alpha"].at[b].set(jnp.where(do_obs, ab2, ab))
            diag_acc = s["diag_acc"].at[b].set(
                jnp.where(do_obs, dacc2, s["diag_acc"][b]))
            kcount = s["kcount"].at[b].set(jnp.where(do_obs, k_b + 1, k_b))
            old_mu = jax.lax.dynamic_slice(s["postmu"], (b * m,), (m,))
            old_var = jax.lax.dynamic_slice(s["postvar"], (b * m,), (m,))
            postmu = jax.lax.dynamic_update_slice(
                s["postmu"], jnp.where(do_obs, mu_blk, old_mu), (b * m,))
            postvar = jax.lax.dynamic_update_slice(
                s["postvar"], jnp.where(do_obs, var_blk, old_var), (b * m,))

            best_raw = s["best_raw"].at[b].set(
                jnp.where(do_obs, jnp.maximum(s["best_raw"][b], z),
                          s["best_raw"][b]))
            has_obs = s["has_obs"].at[b].set(s["has_obs"][b] | do_obs)
            best_true = s["best_true"].at[b].set(
                jnp.where(do_obs, jnp.maximum(s["best_true"][b], z),
                          s["best_true"][b]))
            inst = jnp.sum(z_star - best_true) / N

            # -- 4. decide what to launch on the freed device ----------------
            selected = s["selected"]
            any_left = ~jnp.all(selected)
            if warm_len > 0:
                use_pending = s["pend_ptr"] < warm_len
                pend_model = pending[jnp.minimum(s["pend_ptr"], warm_len - 1)]
            else:
                use_pending = jnp.bool_(False)
                pend_model = jnp.int32(0)

            sd = jnp.sqrt(postvar)
            best_eff = jnp.where(has_obs, best_raw, floor)
            # With disjoint candidate sets the multi-tenant EI sum (eq. 4)
            # degenerates to the owner-tenant EI, so one (n,) pass serves
            # both the global EIrate argmax and the per-tenant baselines.
            ei_all = expected_improvement(postmu, sd, best_eff[owner])
            scores = jnp.where(selected, -jnp.inf, ei_all / cost)
            pick_mdmt = jnp.argmax(scores).astype(jnp.int32)

            has_work = (~selected).reshape(N, m).any(axis=1)
            order = (s["rr_ptr"] + jnp.arange(N, dtype=jnp.int32)) % N
            u_rr = order[jnp.argmax(has_work[order])]
            key, sub = jax.random.split(s["key"])
            logits = jnp.where(has_work, 0.0, -jnp.inf)
            u_rand = jnp.where(
                any_left, jax.random.categorical(sub, logits), 0
            ).astype(jnp.int32)
            u_sel = jnp.where(pid == _POLICY_ID["round_robin"], u_rr, u_rand)
            ei_u = jax.lax.dynamic_slice(ei_all, (u_sel * m,), (m,))
            sel_u = jax.lax.dynamic_slice(selected, (u_sel * m,), (m,))
            pick_st = (u_sel * m +
                       jnp.argmax(jnp.where(~sel_u, ei_u, -jnp.inf))
                       ).astype(jnp.int32)

            is_mdmt = pid == _POLICY_ID["mdmt"]
            pick = jnp.where(is_mdmt, pick_mdmt, pick_st)
            hint = jnp.where(is_mdmt, -1, u_sel)
            model_next = jnp.where(use_pending, pend_model, pick)
            hint = jnp.where(use_pending, -2, hint)
            launch = active & any_left

            # -- 5. launch (or retire the device slot) -----------------------
            dur = cost[model_next] / speed[d]
            dev_end = s["dev_end"].at[d].set(
                jnp.where(launch, t + dur,
                          jnp.where(active, jnp.inf, s["dev_end"][d])))
            dev_model = s["dev_model"].at[d].set(
                jnp.where(active, jnp.where(launch, model_next, -1),
                          s["dev_model"][d]))
            dev_seq = s["dev_seq"].at[d].set(
                jnp.where(launch, s["counter"],
                          jnp.where(active, _IDLE_SEQ, s["dev_seq"][d])))
            selected = selected.at[model_next].set(
                selected[model_next] | launch)
            ci = jnp.minimum(s["counter"], n - 1)
            tr_model = s["tr_model"].at[ci].set(
                jnp.where(launch, model_next, s["tr_model"][ci]))
            tr_user = s["tr_user"].at[ci].set(
                jnp.where(launch, hint, s["tr_user"][ci]))
            tr_dev = s["tr_dev"].at[ci].set(
                jnp.where(launch, d.astype(jnp.int32), s["tr_dev"][ci]))
            tr_start = s["tr_start"].at[ci].set(
                jnp.where(launch, t, s["tr_start"][ci]))
            tr_end = s["tr_end"].at[ci].set(
                jnp.where(launch, t + dur, s["tr_end"][ci]))

            s2 = dict(
                dev_end=dev_end, dev_model=dev_model, dev_seq=dev_seq,
                W=W, alpha=alpha, diag_acc=diag_acc, kcount=kcount,
                postmu=postmu, postvar=postvar,
                selected=selected, best_raw=best_raw, has_obs=has_obs,
                rr_ptr=jnp.where(
                    launch & ~use_pending & (pid == _POLICY_ID["round_robin"]),
                    (u_rr + 1) % N, s["rr_ptr"]),
                key=key,
                pend_ptr=s["pend_ptr"] + (use_pending & launch),
                counter=s["counter"] + launch,
                decisions=s["decisions"] + (active & ~use_pending),
                tr_model=tr_model, tr_user=tr_user, tr_dev=tr_dev,
                tr_start=tr_start, tr_end=tr_end,
                best_true=best_true, t_prev=t_prev, cum=cum,
            )
            emit = dict(
                obs_model=jnp.where(do_obs, model, -1),
                obs_time=t,
                inst=inst,
                cum=cum,
            )
            return s2, emit

        final, steps = jax.lax.scan(step, state, None, length=T)
        return dict(
            trial_model=final["tr_model"], trial_user=final["tr_user"],
            trial_device=final["tr_dev"], trial_start=final["tr_start"],
            trial_end=final["tr_end"],
            obs_model=steps["obs_model"], obs_time=steps["obs_time"],
            inst=steps["inst"], cum=steps["cum"],
            decisions=final["decisions"], end_time=final["t_prev"],
        )

    return jax.vmap(episode)(
        policy_id, num_devices, seeds, speeds, z_true_b, z_star_b, worst_b)


def simulate_batch(
    problem: Problem,
    specs,
    warm_start: int = 2,
    jitter: float = DEFAULT_JITTER,
) -> BatchResult:
    """Run a batch of TSHB episodes as one jitted ``vmap(scan)`` call.

    Args:
      problem: a tenant-major block-structured :class:`Problem` (all three
        generators in ``tenancy.py`` qualify).
      specs: sequence of :class:`EpisodeSpec`.
      warm_start: fastest-models-per-tenant warm start (Section 6.1; same
        semantics as ``scheduler.simulate``, shared by the whole batch).

    Returns:
      :class:`BatchResult` with launch-ordered trial logs, event-ordered
      regret curves, and per-episode accounting.
    """
    specs = tuple(specs)
    if not specs:
        raise ValueError("specs must be non-empty")
    problem.validate()
    N, m = _block_shape(problem)
    n = N * m
    B = len(specs)
    Mmax = max(s.num_devices for s in specs)
    T = n + Mmax

    K = np.asarray(problem.K, np.float32)
    Kb = np.stack([K[i * m:(i + 1) * m, i * m:(i + 1) * m] for i in range(N)])
    kdiag_b = np.stack([np.diag(Kb[i]) for i in range(N)])
    mu0_b = np.asarray(problem.mu0, np.float32).reshape(N, m)
    cost = np.asarray(problem.cost, np.float32)
    pending = np.asarray(warm_start_queue(problem, warm_start), np.int32)
    floor = no_obs_floor(problem)

    policy_id = np.asarray([_POLICY_ID[s.policy] for s in specs], np.int32)
    num_devices = np.asarray([s.num_devices for s in specs], np.int32)
    seeds = np.asarray([s.seed for s in specs], np.uint32)
    speeds = np.ones((B, Mmax), np.float32)
    for i, s in enumerate(specs):
        if s.device_speeds is not None:
            speeds[i, :s.num_devices] = np.asarray(s.device_speeds, np.float32)
    z_true_b = np.stack([
        np.asarray(s.z_true if s.z_true is not None else problem.z_true,
                   np.float32)
        for s in specs])
    if z_true_b.shape != (B, n):
        raise ValueError(f"per-episode z_true must have shape ({n},)")
    mem = np.asarray(problem.membership, bool)
    z_star_b = np.where(mem[None], z_true_b[:, None, :], -np.inf).max(-1)
    worst_b = np.where(mem[None], z_true_b[:, None, :], np.inf).min(-1)

    t0 = _time.perf_counter()
    out = _run_batch(
        jnp.asarray(Kb), jnp.asarray(kdiag_b), jnp.asarray(mu0_b),
        jnp.asarray(cost), jnp.asarray(pending),
        jnp.float32(floor), jnp.float32(jitter),
        jnp.asarray(policy_id), jnp.asarray(num_devices), jnp.asarray(seeds),
        jnp.asarray(speeds), jnp.asarray(z_true_b),
        jnp.asarray(z_star_b, jnp.float32), jnp.asarray(worst_b, jnp.float32),
        N=N, m=m, Mmax=Mmax, T=T, warm_len=int(pending.size))
    out = jax.tree.map(np.asarray, jax.block_until_ready(out))
    wall = _time.perf_counter() - t0

    tm = out["trial_model"]
    z_log = np.where(
        tm >= 0,
        np.take_along_axis(z_true_b, np.maximum(tm, 0), axis=1),
        np.nan)
    return BatchResult(
        problem=problem, specs=specs, warm_start=warm_start,
        trial_model=tm, trial_user=out["trial_user"],
        trial_device=out["trial_device"], trial_start=out["trial_start"],
        trial_end=out["trial_end"], trial_z=z_log,
        obs_model=out["obs_model"], obs_time=out["obs_time"],
        inst_regret=out["inst"], cum_regret=out["cum"],
        decisions=out["decisions"], end_time=out["end_time"],
        inst0=(z_star_b - worst_b).mean(axis=1),
        wall_seconds=wall)
