"""Expected improvement, multi-tenant EI aggregation, and EIrate.

Implements Lemma 1 and equations (3)-(6) of the paper:

  tau(u)        = u * Phi(u) + phi(u)
  EI_{i,t}(x)   = sigma_t(x) * tau((mu_t(x) - z(x_i*(t))) / sigma_t(x))
  EI_t(x)       = sum_i 1(x in L_i) * EI_{i,t}(x)
  EIrate_t(x)   = EI_t(x) / c(x)
  x_next        = argmax_{x not selected} EIrate_t(x)

All functions are shape-stable and jittable; ``membership`` is an (N, n)
bool matrix (tenant i "has" model x).  ``selected`` marks models that are
observed *or currently running* — both are excluded from the argmax (eq. 6
takes the argmax over L \\ L(t) where L(t) includes in-flight models).

A Pallas TPU kernel for the (N, n) EI pass lives in
``repro.kernels.ei_kernel``; these jnp implementations are its oracle and the
default path on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm


NEG_INF = -jnp.inf


def tau(u: jax.Array) -> jax.Array:
    """tau(u) = u*Phi(u) + phi(u); the EI shape function of Lemma 1."""
    return u * norm.cdf(u) + norm.pdf(u)


def expected_improvement(mu: jax.Array, sigma: jax.Array, best: jax.Array) -> jax.Array:
    """E[max(X - best, 0)] for X ~ N(mu, sigma^2), elementwise.

    Handles sigma == 0 exactly: EI degenerates to max(mu - best, 0).
    Shapes broadcast (use mu (n,), sigma (n,), best (N, 1) for the tenant grid).
    """
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    u = (mu - best) / safe_sigma
    ei = safe_sigma * tau(u)
    return jnp.where(sigma > 0, ei, jnp.maximum(mu - best, 0.0))


def ei_matrix(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
) -> jax.Array:
    """(N, n) matrix of 1(x in L_i) * EI_{i,t}(x)."""
    ei = expected_improvement(mu[None, :], sigma[None, :], best_per_user[:, None])
    return jnp.where(membership, ei, 0.0)


def ei_total(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
) -> jax.Array:
    """(n,) total EI over tenants — eq. (4)."""
    return ei_matrix(mu, sigma, best_per_user, membership).sum(axis=0)


@jax.jit
def eirate_scores(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
    cost: jax.Array,
    selected: jax.Array,
) -> jax.Array:
    """(n,) EIrate with selected models masked to -inf — eqs. (5)-(6)."""
    total = ei_total(mu, sigma, best_per_user, membership)
    scores = total / cost
    return jnp.where(selected, NEG_INF, scores)


def choose_next(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
    cost: jax.Array,
    selected: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Returns (argmax index, its EIrate score)."""
    scores = eirate_scores(mu, sigma, best_per_user, membership, cost, selected)
    idx = jnp.argmax(scores)
    return idx, scores[idx]


@jax.jit
def choose_next_fused(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
    cost: jax.Array,
    selected: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single-dispatch decision: EIrate + argmax + gather in one XLA call.

    §Perf control-plane iteration 3: collapses ~6 host->device dispatches per
    scheduler decision into one fused executable.
    """
    total = ei_total(mu, sigma, best_per_user, membership)
    scores = jnp.where(selected, NEG_INF, total / cost)
    idx = jnp.argmax(scores)
    return idx, scores[idx]


@jax.jit
def eirate_class_scores(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
    cost_matrix: jax.Array,
    selected: jax.Array,
) -> jax.Array:
    """(C, n) EIrate over (device class x model) — the 2-D generalization of
    eqs. (5)-(6) the elastic device plane scores (DESIGN.md §11).

    ``cost_matrix[c, x]`` is c(x, d) for a device of class c; the EI sum over
    tenants is computed ONCE and broadcast against every class's cost row,
    so a k-device joint assignment costs one scoring pass, not k.

    A non-finite cost (the registry's memory gate emits +inf for a model
    that does not fit a class) is a hard exclusion: the score is -inf, not
    the 0 that a naive division would produce (0 could still win a row
    whose every fitting candidate has zero EI).
    """
    total = ei_total(mu, sigma, best_per_user, membership)
    scores = jnp.where(jnp.isfinite(cost_matrix),
                       total[None, :] / cost_matrix, NEG_INF)
    return jnp.where(selected[None, :], NEG_INF, scores)


def topk_rows_padded(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Per-row top-k of a (C, n) score matrix, padded with (-inf, id 0)
    entries when n < k so the shape is always (C, k) — one definition of
    the pad convention, shared by every class-axis scorer."""
    kk = min(k, scores.shape[1])
    v, i = jax.lax.top_k(scores, kk)
    if kk < k:
        pad = k - kk
        v = jnp.concatenate(
            [v, jnp.full((v.shape[0], pad), NEG_INF, v.dtype)], axis=1)
        i = jnp.concatenate(
            [i, jnp.zeros((i.shape[0], pad), i.dtype)], axis=1)
    return v, i


@functools.partial(jax.jit, static_argnames=("k",))
def choose_topk_classes(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
    cost_matrix: jax.Array,
    selected: jax.Array,
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Per-class EIrate top-k in one dispatch: (values (C, k), ids (C, k)).

    Row c's candidates feed the greedy device<->model assignment solver
    (``devplane.assign``); ``lax.top_k`` keeps the earlier element on ties,
    so each row's order matches sequential ``jnp.argmax``-with-masking
    exactly — the batched == sequential equivalence leans on this.
    """
    scores = eirate_class_scores(mu, sigma, best_per_user, membership,
                                 cost_matrix, selected)
    return topk_rows_padded(scores, k)


@functools.partial(jax.jit, static_argnames=("k",))
def eirate_topk_fused(
    mu: jax.Array,
    sigma: jax.Array,
    best_per_user: jax.Array,
    membership: jax.Array,
    cost: jax.Array,
    selected: jax.Array,
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Forensics companion to :func:`choose_next_fused`: the same masked
    EIrate vector reduced to its top-k ``(values, ids)``.  Run *in
    addition to* the decision program, only when forensics is enabled —
    the decision path itself is untouched.  ``lax.top_k`` keeps the
    earlier element on ties, so ``ids[0]`` always equals
    ``choose_next_fused``'s argmax."""
    total = ei_total(mu, sigma, best_per_user, membership)
    scores = jnp.where(selected, NEG_INF, total / cost)
    kk = min(k, scores.shape[0])
    return jax.lax.top_k(scores, kk)


@jax.jit
def single_tenant_ei_scores(
    mu: jax.Array,
    sigma: jax.Array,
    best: jax.Array,
    member_row: jax.Array,
    selected: jax.Array,
) -> jax.Array:
    """Per-tenant plain GP-EI scores (baselines: each user runs own GP-EI).

    ``best`` is the scalar best-observed value for this tenant; models outside
    the tenant's candidate set or already selected score -inf.
    """
    ei = expected_improvement(mu, sigma, best)
    return jnp.where(member_row & ~selected, ei, NEG_INF)
