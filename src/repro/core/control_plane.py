"""The per-event decision core shared by every episode engine (Algorithm 1).

``ControlPlane`` owns exactly the state Algorithm 1's loop body needs — the
GP posterior, the selected/observed masks, the per-tenant incumbents — and
exposes it as a stepping API:

  * ``record_start(x)`` / ``record_failure(x)`` / ``record_observation(x, z)``
    fold one scheduler event into the state;
  * ``choose_mdmt`` / ``choose_round_robin`` / ``choose_random`` score the
    unselected pool and return the next launch (the EIrate argmax of eq. 6
    for the paper's policy).

Two construction modes, one implementation:

  * :meth:`ControlPlane.from_problem` — the closed-world mode used by the
    offline simulators (``scheduler.simulate``): every tenant is known up
    front, shapes are exact, behavior is bit-identical to the pre-refactor
    ``_PolicyState``.
  * ``ControlPlane(...)`` with no problem — the open-world mode used by the
    streaming engine (``repro.stream.engine``): tenants arrive and depart at
    runtime via :meth:`add_tenant` / :meth:`retire_tenant`.  Buffers are
    capacity-allocated (doubling growth) so the jitted scoring path keeps a
    stable shape across churn events; a tenant's GP block is appended or
    retired without refactorizing the others (``gp.BlockIncrementalGP``).

Scoring is always the batched multi-tenant EIrate pass over the whole pool:
``scorer="fused"`` (default) is the single-dispatch XLA path
(``ei.choose_next_fused``); ``scorer="ops"`` routes through the
``repro.kernels.ops.eirate`` entry point — the Pallas kernel on TPU, its XLA
reference elsewhere — so the streaming hot loop exercises the same code the
kernel benchmarks measure; ``scorer="sharded"`` partitions the model axis
over a device mesh and runs the decision as one ``shard_map`` program
(``repro.shardgp``, DESIGN.md §10) — decision-equivalent to ``fused``
including tie-breaking, provided both planes use the same ``num_shards``
(the index-space layout is part of the tie-break order).

Index space (dynamic mode): model slots and tenant slots are *recycled* —
``retire_tenant`` returns them to a free pool (``shardgp.layout``) and later
admissions reuse them, so buffers grow with the live-model cap, not with
total models ever admitted.  ``compact()`` additionally relocates idle
tenant blocks between shard spans to keep the sharded scorer's load
imbalance bounded.
"""

from __future__ import annotations

from dataclasses import dataclass
from heapq import heappop, heappush

import jax
import jax.numpy as jnp
import numpy as np

from .ei import (
    choose_next_fused,
    choose_topk_classes,
    eirate_topk_fused,
    single_tenant_ei_scores,
    topk_rows_padded,
)
from .gp import DEFAULT_JITTER, BlockIncrementalGP, make_gp
from .tenancy import Problem
from repro.obs import NULL_TRACER

SCORERS = ("fused", "ops", "sharded")

#: candidates kept per forensics record on the fused/ops paths (the
#: sharded path keeps its scorer's own top-k)
FORENSICS_TOPK = 4

_FLOOR_SDS = 5.0  # "no observation yet" sits this many prior sds below mu0


def _fastest_models(problem: Problem, user: int, count: int) -> list[int]:
    idx = np.nonzero(problem.membership[user])[0]
    order = idx[np.argsort(problem.cost[idx], kind="stable")]
    return list(order[:count])


def no_obs_floor(problem: Problem) -> float:
    """Finite stand-in for "no observation yet": far below any plausible z,
    so unserved tenants dominate the EI sum (see DESIGN.md §7).  Shared by
    all episode engines — the equivalence contract depends on it."""
    prior_sd = float(np.sqrt(np.clip(np.diag(problem.K), 0, None).max()))
    return float(problem.mu0.min()) - _FLOOR_SDS * max(prior_sd, 1e-3)


def warm_start_queue(problem: Problem, warm_start: int) -> list[int]:
    """The initial launch queue: user-major, ``warm_start`` fastest models
    each, deduplicated keeping first occurrence (Section 6.1 protocol).
    ``warm_start=0`` yields Algorithm 1 line 1-2's prior-mean argmax per
    tenant instead.  Shared by all episode engines."""
    pending: list[int] = []
    seen: set[int] = set()
    for u in range(problem.num_users):
        for m in _fastest_models(problem, u, warm_start):
            if m not in seen:
                seen.add(m)
                pending.append(m)
    if warm_start == 0:
        for u in range(problem.num_users):
            idx = np.nonzero(problem.membership[u])[0]
            m = int(idx[np.argmax(problem.mu0[idx])])
            if m not in seen:
                seen.add(m)
                pending.append(m)
    return pending


def tenant_warm_models(cost_block: np.ndarray, mu0_block: np.ndarray,
                       warm_start: int) -> list[int]:
    """Per-tenant warm-start picks (local indices): the ``warm_start``
    cheapest models, or the prior-mean argmax when ``warm_start == 0``.
    Concatenating these tenant-major over disjoint candidate sets reproduces
    :func:`warm_start_queue` exactly — the churn-free equivalence relies on
    it."""
    if warm_start > 0:
        order = np.argsort(np.asarray(cost_block), kind="stable")
        return [int(i) for i in order[:warm_start]]
    return [int(np.argmax(np.asarray(mu0_block)))]


@dataclass(frozen=True)
class TenantHandle:
    """What :meth:`ControlPlane.add_tenant` returns: the tenant's slot and
    the global model ids its block occupies."""
    tenant_id: int
    models: np.ndarray  # (m,) global model indices


class ControlPlane:
    """GP update + EIrate pick, as a reusable stepping API (module docstring)."""

    def __init__(
        self,
        rng: np.random.Generator | None = None,
        *,
        jitter: float = DEFAULT_JITTER,
        scorer: str = "fused",
        model_capacity: int = 64,
        tenant_capacity: int = 8,
        num_shards: int | None = None,
        shard_topk: int = 4,
        score_kernel: str = "xla",
    ):
        if scorer not in SCORERS:
            raise ValueError(f"scorer must be one of {SCORERS}, got {scorer!r}")
        from repro.shardgp import ShardedScorer, ShardLayout
        self.rng = rng or np.random.default_rng(0)
        self.scorer = scorer
        self._jitter = jitter
        self._dynamic = True
        self._num_models = 0        # count of LIVE models
        self._num_tenants = 0       # high-water mark of tenant slots
        self._free_tenant_slots: list[int] = []   # min-heap of retired slots
        self._sharded = (ShardedScorer(num_shards, topk=shard_topk,
                                       kernel=score_kernel)
                         if scorer == "sharded" else None)
        shards = (self._sharded.num_shards if self._sharded is not None
                  else (num_shards or 1))
        cap_n = max(1, model_capacity)
        # every tenant block lives inside one shard span; slot reuse +
        # compaction keep this space O(live cap) under churn (DESIGN.md §10)
        self._layout = ShardLayout(
            num_shards=shards, shard_capacity=-(-cap_n // shards))
        cap_n = self._layout.capacity
        cap_N = max(1, tenant_capacity)
        # padding entries are born selected so every chooser masks them
        self.selected = np.ones(cap_n, dtype=bool)
        self.observed = np.zeros(cap_n, dtype=bool)
        self.cost = np.ones(cap_n, dtype=np.float64)
        self.membership = np.zeros((cap_N, cap_n), dtype=bool)
        self.best = np.full(cap_N, -np.inf)
        self.tenant_live = np.zeros(cap_N, dtype=bool)
        self.model_live = np.zeros(cap_n, dtype=bool)
        self._tenant_floor_stats: dict[int, tuple[float, float]] = {}
        self._block_ids: dict[int, int] = {}
        self._no_obs_floor = 0.0
        self.gp = BlockIncrementalGP.empty(jitter)
        self.gp.ensure_capacity(cap_n)
        self.rr_pointer = 0
        self.tracer = NULL_TRACER
        self._forensics = None
        self._rebuild_mirrors()

    @classmethod
    def from_problem(
        cls,
        problem: Problem,
        rng: np.random.Generator | None = None,
        *,
        jitter: float = DEFAULT_JITTER,
        scorer: str = "fused",
        num_shards: int | None = None,
        shard_topk: int = 4,
        score_kernel: str = "xla",
    ) -> "ControlPlane":
        """Closed-world construction: all tenants at t=0, exact shapes.

        Supports arbitrary (also overlapping) candidate sets — the GP engine
        falls back to the dense incremental factorization when the prior is
        not block-diagonal (``gp.make_gp``).  Churn methods are disabled."""
        n, N = problem.num_models, problem.num_users
        cp = cls.__new__(cls)
        cp.rng = rng or np.random.default_rng(0)
        if scorer not in SCORERS:
            raise ValueError(f"scorer must be one of {SCORERS}, got {scorer!r}")
        cp.scorer = scorer
        cp._jitter = jitter
        cp._dynamic = False
        cp._num_models = n
        cp._num_tenants = N
        cp._free_tenant_slots = []
        cp._layout = None           # closed world: no churn, no reuse
        if scorer == "sharded":
            from repro.shardgp import ShardedScorer
            # pads n to a shard multiple internally
            cp._sharded = ShardedScorer(num_shards, topk=shard_topk,
                                        kernel=score_kernel)
        else:
            cp._sharded = None
        cp.selected = np.zeros(n, dtype=bool)
        cp.observed = np.zeros(n, dtype=bool)
        cp.cost = np.asarray(problem.cost, dtype=np.float64).copy()
        cp.membership = np.asarray(problem.membership, dtype=bool).copy()
        cp.best = np.full(N, -np.inf)
        cp.tenant_live = np.ones(N, dtype=bool)
        cp.model_live = np.ones(n, dtype=bool)
        cp._tenant_floor_stats = {}
        cp._block_ids = {}
        cp._no_obs_floor = no_obs_floor(problem)
        cp.gp = make_gp(problem.K, problem.mu0, problem.membership, jitter)
        cp.rr_pointer = 0
        cp.tracer = NULL_TRACER
        cp._forensics = None
        cp._rebuild_mirrors()
        return cp

    # ---- capacity + device-resident mirrors -------------------------------

    @property
    def num_models(self) -> int:
        """Live models (dynamic mode recycles slots, so this is a count of
        the current pool, not an allocation high-water mark)."""
        return self._num_models

    @property
    def num_tenants(self) -> int:
        return self._num_tenants

    @property
    def capacity(self) -> int:
        return len(self.selected)

    def _rebuild_mirrors(self) -> None:
        """Full host->device refresh; called at construction and on churn
        events (rare relative to decisions, which update incrementally)."""
        self._membership_j = jnp.asarray(self.membership)
        self._cost_j = jnp.asarray(self.cost.astype(np.float32))
        self._selected_j = jnp.asarray(self.selected)
        self._best_j = jnp.asarray(
            np.where(np.isfinite(self.best), self.best,
                     self._no_obs_floor).astype(np.float32))
        if self._sharded is not None:
            self._sharded.refresh(self.membership, self.cost)

    def _grow(self, need_models: int, need_tenants: int) -> None:
        cap_n, cap_N = self.capacity, self.membership.shape[0]
        new_n = cap_n
        while new_n < need_models:
            new_n *= 2
        new_N = cap_N
        while new_N < need_tenants:
            new_N *= 2
        if new_n == cap_n and new_N == cap_N:
            return
        pad_n, pad_N = new_n - cap_n, new_N - cap_N
        self.selected = np.concatenate([self.selected, np.ones(pad_n, bool)])
        self.observed = np.concatenate([self.observed, np.zeros(pad_n, bool)])
        self.cost = np.concatenate([self.cost, np.ones(pad_n)])
        self.model_live = np.concatenate([self.model_live, np.zeros(pad_n, bool)])
        grown = np.zeros((new_N, new_n), dtype=bool)
        grown[:cap_N, :cap_n] = self.membership
        self.membership = grown
        self.best = np.concatenate([self.best, np.full(pad_N, -np.inf)])
        self.tenant_live = np.concatenate(
            [self.tenant_live, np.zeros(pad_N, bool)])
        self.gp.ensure_capacity(new_n)

    def _recompute_floor(self) -> None:
        stats = [self._tenant_floor_stats[t]
                 for t in np.nonzero(self.tenant_live)[0]
                 if t in self._tenant_floor_stats]
        if not stats:
            self._no_obs_floor = 0.0
            return
        mu_min = min(s[0] for s in stats)
        sd_max = max(s[1] for s in stats)
        self._no_obs_floor = mu_min - _FLOOR_SDS * max(sd_max, 1e-3)

    # ---- tenant churn ------------------------------------------------------

    def add_tenant(self, K_block, mu0_block, cost_block) -> TenantHandle:
        """Admit one tenant: its GP block, candidate models, and tenant slot
        come from the free pools when churn left any (slot reuse, DESIGN.md
        §10), else extend the space.  O(m) plus a mirror refresh; no other
        tenant's GP state is touched.  The block always lands inside one
        shard span of the layout."""
        if not self._dynamic:
            raise RuntimeError("churn is only supported on dynamic "
                               "ControlPlanes (not from_problem)")
        K_block = np.asarray(K_block, dtype=np.float64)
        mu0_block = np.asarray(mu0_block, dtype=np.float64)
        cost_block = np.asarray(cost_block, dtype=np.float64)
        m = len(mu0_block)
        if K_block.shape != (m, m) or cost_block.shape != (m,):
            raise ValueError("block shapes disagree")
        if (cost_block <= 0).any():
            raise ValueError("costs must be positive")
        tid = (heappop(self._free_tenant_slots) if self._free_tenant_slots
               else self._num_tenants)
        start = self._layout.place(tid, m)
        self._grow(self._layout.capacity, tid + 1)
        self._num_tenants = max(self._num_tenants, tid + 1)
        self._num_models += m
        ids = np.arange(start, start + m, dtype=np.int64)
        self._block_ids[tid] = self.gp.add_block(ids, K_block, mu0_block)
        self.selected[ids] = False
        self.observed[ids] = False
        self.cost[ids] = cost_block
        self.model_live[ids] = True
        self.membership[tid, ids] = True
        self.best[tid] = -np.inf
        self.tenant_live[tid] = True
        self._tenant_floor_stats[tid] = (
            float(mu0_block.min()),
            float(np.sqrt(np.clip(np.diag(K_block), 0, None).max())))
        self._recompute_floor()
        self._rebuild_mirrors()
        return TenantHandle(tenant_id=tid, models=ids)

    def retire_tenant(self, tenant_id: int) -> None:
        """Depart one tenant: its GP block is freed, its models leave the
        pool (masked selected) and their slots return to the free pool for
        the next admission, its tenant slot likewise.  In-flight models of
        the tenant stay selected — the caller decides whether their
        completions are folded (they cannot be: the block is gone)."""
        if not self._dynamic:
            raise RuntimeError("churn is only supported on dynamic "
                               "ControlPlanes (not from_problem)")
        if not self.tenant_live[tenant_id]:
            raise ValueError(f"tenant {tenant_id} is not live")
        ids = np.nonzero(self.membership[tenant_id])[0]
        self.gp.retire_block(self._block_ids.pop(tenant_id))
        self.membership[tenant_id, :] = False
        self.selected[ids] = True
        self.observed[ids] = False
        self.cost[ids] = 1.0
        self.model_live[ids] = False
        self.tenant_live[tenant_id] = False
        self.best[tenant_id] = -np.inf
        del self._tenant_floor_stats[tenant_id]
        self._layout.release(tenant_id)
        heappush(self._free_tenant_slots, tenant_id)
        self._num_models -= len(ids)
        self._recompute_floor()
        self._rebuild_mirrors()

    def in_flight_mask(self) -> np.ndarray:
        """Models launched but not yet observed (their global ids are baked
        into pending completion events — compaction must not move them)."""
        return self.selected & ~self.observed & self.model_live

    def compact(self, max_imbalance: float | None = None,
                max_moves: int | None = None) -> dict[int, tuple]:
        """Rebalance live tenant blocks across shard spans until the load
        imbalance sits within ``max_imbalance`` (shardgp.compact).  Tenants
        with in-flight trials are pinned.  Returns ``{tenant_id: (old_ids,
        new_ids)}`` so callers holding global model ids (the streaming
        engine's launch queue / ownership maps) can remap.  With one shard
        this is a no-op.

        ``max_moves`` bounds the relocations of one call — the incremental
        mode (DESIGN.md §12): each call does at most that much work (a
        bounded pause) and later calls continue toward the imbalance target,
        amortizing a full stop-the-world pass across many events."""
        if not self._dynamic:
            raise RuntimeError("compaction is only supported on dynamic "
                               "ControlPlanes (not from_problem)")
        from repro.shardgp import compact as _compact
        if max_imbalance is None:
            max_imbalance = _compact.DEFAULT_MAX_IMBALANCE
        in_flight = self.in_flight_mask()
        movable = {
            int(t) for t in np.nonzero(self.tenant_live)[0]
            if not in_flight[self.membership[t]].any()}
        moves = _compact.plan_moves(self._layout, movable, max_imbalance,
                                    max_moves)
        first_old: dict[int, np.ndarray] = {}
        for tid, old_start, new_start in moves:
            m = self._layout.blocks[tid].length
            old_ids = np.arange(old_start, old_start + m, dtype=np.int64)
            new_ids = np.arange(new_start, new_start + m, dtype=np.int64)
            self.gp.relocate_block(self._block_ids[tid], new_ids)
            for arr, fill in ((self.selected, True), (self.observed, False),
                              (self.cost, 1.0), (self.model_live, False)):
                vals = arr[old_ids].copy()
                arr[old_ids] = fill
                arr[new_ids] = vals
            self.membership[tid, old_ids] = False
            self.membership[tid, new_ids] = True
            first_old.setdefault(tid, old_ids)
        if moves:
            self._rebuild_mirrors()
        # compose per-tenant hops: a block can move more than once in one
        # pass, and callers hold the ORIGINAL ids — map them to the final
        # placement, not an intermediate one
        remap: dict[int, tuple] = {}
        for tid, old_ids in first_old.items():
            pl = self._layout.blocks[tid]
            remap[tid] = (old_ids,
                          np.arange(pl.start, pl.stop, dtype=np.int64))
        return remap

    # ---- snapshot / restore (the event-sourced engine, DESIGN.md §12) ------

    def state_snapshot(self) -> tuple[dict, dict]:
        """Full dynamic-mode state as ``(arrays, meta)`` for
        ``checkpoint.store.save_checkpoint``.

        The GP is captured *by construction recipe*, not by weights: per
        live tenant we store its prior block and the block-local observation
        sequence, because ``IncrementalGP``'s jitted append is bit-
        deterministic — replaying the same observations on the same machine
        rebuilds ``W``/``alpha`` exactly.  The float32 readout cache is
        stored verbatim (plus the dirty set), so even entries of retired
        blocks — stale, always masked, but part of byte-level state — are
        restored exactly."""
        if not self._dynamic:
            raise RuntimeError("state_snapshot is only supported on dynamic "
                               "ControlPlanes (not from_problem)")
        arrays = {
            "cp/selected": self.selected.copy(),
            "cp/observed": self.observed.copy(),
            "cp/cost": self.cost.copy(),
            "cp/membership": self.membership.copy(),
            "cp/best": self.best.copy(),
            "cp/tenant_live": self.tenant_live.copy(),
            "cp/model_live": self.model_live.copy(),
            "cp/gp_mu": self.gp._mu.copy(),
            "cp/gp_var": self.gp._var.copy(),
        }
        bid_to_tid = {bid: tid for tid, bid in self._block_ids.items()}
        for tid, bid in self._block_ids.items():
            eng = self.gp._engines[bid]
            arrays[f"gp/{tid}/K"] = np.asarray(eng.K)
            arrays[f"gp/{tid}/mu0"] = np.asarray(eng.mu0)
            arrays[f"gp/{tid}/obs_idx"] = np.asarray(eng.observed, np.int64)
            arrays[f"gp/{tid}/obs_z"] = np.asarray(
                [eng._z[li] for li in eng.observed], np.float64)
        lay = self._layout
        meta = {
            "num_models": self._num_models,
            "num_tenants": self._num_tenants,
            "free_tenant_slots": list(self._free_tenant_slots),
            "rr_pointer": self.rr_pointer,
            "no_obs_floor": self._no_obs_floor,
            "floor_stats": {str(t): [mn, sd] for t, (mn, sd)
                            in self._tenant_floor_stats.items()},
            "rng_state": self.rng.bit_generator.state,
            "layout": {
                "num_shards": lay.num_shards,
                "shard_capacity": lay.shard_capacity,
                "alloc_capacity": lay.alloc.capacity,
                "free": [[s, l] for s, l in lay.alloc._free],
                "blocks": {str(k): [pl.start, pl.length]
                           for k, pl in lay.blocks.items()},
            },
            "gp_dirty": sorted(bid_to_tid[b] for b in self.gp._dirty),
            "gp_n": self.gp.n,
        }
        return arrays, meta

    def load_state(self, arrays: dict, meta: dict) -> None:
        """Overwrite this (dynamic, same-config) plane with
        :meth:`state_snapshot` output, in place — callers holding references
        (the engine's bound chooser) keep working."""
        from repro.shardgp import ShardLayout
        if not self._dynamic:
            raise RuntimeError("load_state is only supported on dynamic "
                               "ControlPlanes (not from_problem)")
        self.selected = np.array(arrays["cp/selected"], dtype=bool)
        self.observed = np.array(arrays["cp/observed"], dtype=bool)
        self.cost = np.array(arrays["cp/cost"], dtype=np.float64)
        self.membership = np.array(arrays["cp/membership"], dtype=bool)
        self.best = np.array(arrays["cp/best"], dtype=np.float64)
        self.tenant_live = np.array(arrays["cp/tenant_live"], dtype=bool)
        self.model_live = np.array(arrays["cp/model_live"], dtype=bool)
        self._num_models = meta["num_models"]
        self._num_tenants = meta["num_tenants"]
        self._free_tenant_slots = list(meta["free_tenant_slots"])
        self.rr_pointer = meta["rr_pointer"]
        self._no_obs_floor = meta["no_obs_floor"]
        self._tenant_floor_stats = {int(t): (mn, sd) for t, (mn, sd)
                                    in meta["floor_stats"].items()}
        self.rng.bit_generator.state = meta["rng_state"]

        ml = meta["layout"]
        lay = ShardLayout(num_shards=ml["num_shards"], shard_capacity=1)
        lay.shard_capacity = ml["shard_capacity"]
        lay.alloc.capacity = ml["alloc_capacity"]
        lay.alloc._free = [(s, l) for s, l in ml["free"]]
        from repro.shardgp.layout import BlockPlacement
        lay.blocks = {int(k): BlockPlacement(start, length)
                      for k, (start, length) in ml["blocks"].items()}
        self._layout = lay

        self.gp = BlockIncrementalGP.empty(self._jitter)
        self._block_ids = {}
        for k in ml["blocks"]:          # serialized insertion order
            tid = int(k)
            pl = lay.blocks[tid]
            ids = np.arange(pl.start, pl.stop, dtype=np.int64)
            bid = self.gp.add_block(ids, arrays[f"gp/{tid}/K"],
                                    arrays[f"gp/{tid}/mu0"])
            self._block_ids[tid] = bid
            for li, z in zip(arrays[f"gp/{tid}/obs_idx"].tolist(),
                             arrays[f"gp/{tid}/obs_z"].tolist()):
                self.gp.observe(int(ids[li]), float(z))
        self.gp.ensure_capacity(meta["gp_n"])
        # exact cache bytes (incl. stale masked entries of retired blocks),
        # and the dirty set as of the snapshot — the next flush recomputes
        # exactly what the uninterrupted run would have
        self.gp._mu = np.array(arrays["cp/gp_mu"], dtype=np.float32)
        self.gp._var = np.array(arrays["cp/gp_var"], dtype=np.float32)
        self.gp._dirty = {self._block_ids[t] for t in meta["gp_dirty"]}
        self._rebuild_mirrors()

    # ---- mesh shrink / regrow (DESIGN.md §16) ------------------------------

    def reshard(self, num_shards: int) -> dict[int, int]:
        """Re-shard every resident posterior block onto a ``num_shards``
        scoring mesh *through the checkpoint path*: snapshot the full state,
        repartition the layout (``ShardLayout.repartition``), scatter the
        per-slot arrays through the slot remap, and restore via
        :meth:`load_state` — the same recipe crash recovery exercises, so
        no hand-rolled array surgery can drift from it.  The GP is rebuilt
        by replaying each block's local observation sequence (bit-
        deterministic), and retired blocks' stale readout-cache entries are
        dropped (the new mesh starts from deterministic fresh padding).

        When the plane scores sharded, the scorer is rebuilt for the new
        mesh first; at ``num_shards == 1`` it falls back to the fused
        scorer — exact by the fused == sharded decision-equivalence
        contract, so the fallback changes no decision.

        Returns ``{old_global_model_id: new_global_model_id}`` over every
        live block slot (empty = no-op) so the caller can remap its queues,
        ownership maps, and pending completion events."""
        if not self._dynamic:
            raise RuntimeError("reshard is only supported on dynamic "
                               "ControlPlanes (not from_problem)")
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        from repro.shardgp import ShardedScorer, ShardLayout
        if num_shards == self._layout.num_shards:
            return {}
        arrays, meta = self.state_snapshot()
        lay, remap = ShardLayout.repartition(self._layout.blocks, num_shards)
        new_cap = lay.capacity
        cap_N = self.membership.shape[0]
        old = np.fromiter(remap.keys(), np.int64, len(remap))
        new = np.fromiter(remap.values(), np.int64, len(remap))

        def scatter(src, fill, dtype):
            out = np.full(new_cap, fill, dtype=dtype)
            if len(old):
                out[new] = src[old]
            return out

        # padding conventions match construction: born selected, unobserved,
        # unit cost, not live, zeroed readout cache
        arrays["cp/selected"] = scatter(arrays["cp/selected"], True, bool)
        arrays["cp/observed"] = scatter(arrays["cp/observed"], False, bool)
        arrays["cp/cost"] = scatter(arrays["cp/cost"], 1.0, np.float64)
        arrays["cp/model_live"] = scatter(arrays["cp/model_live"], False,
                                          bool)
        arrays["cp/gp_mu"] = scatter(arrays["cp/gp_mu"], 0.0, np.float32)
        arrays["cp/gp_var"] = scatter(arrays["cp/gp_var"], 0.0, np.float32)
        mem = np.zeros((cap_N, new_cap), dtype=bool)
        if len(old):
            mem[:, new] = arrays["cp/membership"][:, old]
        arrays["cp/membership"] = mem
        # preserve registry insertion order — load_state rebuilds the GP in
        # this order, and the uninterrupted-vs-restart equivalence needs it
        meta["layout"] = {
            "num_shards": lay.num_shards,
            "shard_capacity": lay.shard_capacity,
            "alloc_capacity": lay.alloc.capacity,
            "free": [[s, l] for s, l in lay.alloc._free],
            "blocks": {str(k): [pl.start, pl.length]
                       for k, pl in lay.blocks.items()},
        }
        meta["gp_n"] = new_cap
        if self.scorer == "sharded":
            if num_shards == 1:
                self.scorer = "fused"
                self._sharded = None
            elif num_shards != self._sharded.num_shards:
                self._sharded = ShardedScorer(
                    num_shards, topk=self._sharded.topk,
                    kernel=self._sharded.kernel)
        self.load_state(arrays, meta)
        return remap

    # ---- observability (DESIGN.md §13) -------------------------------------

    def set_tracer(self, tracer) -> None:
        """Install a ``repro.obs.Tracer`` on the decision path (and on the
        sharded scorer, which opens its own pad/dispatch spans).  Tracing is
        observation-only: spans never change a decision and never enter
        :meth:`state_snapshot`."""
        self.tracer = tracer
        if self._sharded is not None:
            self._sharded.tracer = tracer

    def capacity_stats(self) -> dict:
        """Host-side resource accounting of the posterior + index space —
        the capacity plane's one-stop introspection point
        (``obs/accounting.py``).  GP stats come from
        :meth:`BlockIncrementalGP.resource_stats` keyed back to *tenant*
        slots (block ids are internal); layout occupancy is per shard span.
        Closed-world instances (``from_problem``) have no layout and a
        possibly non-block GP — both degrade to None rather than faking
        numbers.  No device syncs anywhere on this path."""
        gp_stats = None
        if hasattr(self.gp, "resource_stats"):
            gp_stats = self.gp.resource_stats()
            if "blocks" in gp_stats:
                bid_to_tid = {bid: tid for tid, bid in self._block_ids.items()}
                # closed-world blocks (from_problem) have no tenant slot
                # mapping — fall back to the block id itself
                gp_stats["tenants"] = {
                    bid_to_tid.get(bid, bid): stats
                    for bid, stats in gp_stats.pop("blocks").items()}
        layout = (self._layout.occupancy()
                  if self._layout is not None else None)
        return {"gp": gp_stats, "layout": layout}

    def set_forensics(self, recorder) -> None:
        """Install a ``repro.obs.ForensicsRecorder`` on the decision path.
        Observation-only: when enabled, the sharded path keeps the top-k the
        decision already materializes (``decide()`` is the head of
        ``decide_topk()``) and the fused/ops paths run one *additional*
        jitted top-k program — the decision itself is computed by the same
        program either way."""
        self._forensics = recorder

    def _base_cost(self, g: int) -> float:
        """Host-side cost of one candidate, valid across the sharded
        scorer's padded capacity (padding cost is 1.0 by convention)."""
        if self._sharded is not None and self._sharded._cost_host is not None:
            ch = self._sharded._cost_host
            if g < len(ch):
                return float(ch[g])
        return float(self.cost[g]) if g < len(self.cost) else 1.0

    def _record_forensics(self, values, gids, mu, sd, *,
                          speed: float = 1.0, overhead: float = 0.0,
                          device_class: str | None = None) -> None:
        """Feed one materialized top-k into the forensics recorder, with the
        host-side μ/σ/cost decomposition aligned to the candidates."""
        values = np.asarray(values)
        gids = np.asarray(gids)
        mu = np.asarray(mu)
        sd = np.asarray(sd)
        n = mu.shape[0]
        eff, mu_k, sd_k = [], [], []
        for gi in gids:
            gi = int(gi)
            eff.append(self._base_cost(gi) / speed + overhead)
            mu_k.append(float(mu[gi]) if gi < n else 0.0)
            sd_k.append(float(sd[gi]) if gi < n else 0.0)
        self._forensics.on_decision(
            scorer=self.scorer, values=values, gids=gids, eff_costs=eff,
            mu=mu_k, sd=sd_k, speed=speed, device_class=device_class)

    def _record_batch_forensics(self, v, g, mu, sd, rates, overheads,
                                class_names) -> None:
        """One forensics record per class row of a batched decision (the
        (C, k) top-k the greedy assignment consumes)."""
        if self._forensics is None:
            return
        rates = np.asarray(rates, dtype=np.float64)
        overheads = np.asarray(overheads, dtype=np.float64)
        for c in range(v.shape[0]):
            name = (str(class_names[c]) if class_names is not None
                    else f"class{c}")
            self._record_forensics(v[c], g[c], mu, sd,
                                   speed=float(rates[c]),
                                   overhead=float(overheads[c]),
                                   device_class=name)

    # ---- event steps -------------------------------------------------------

    def best_effective(self) -> np.ndarray:
        return np.where(np.isfinite(self.best), self.best, self._no_obs_floor)

    def record_start(self, model: int) -> None:
        self.selected[model] = True
        self._selected_j = self._selected_j.at[model].set(True)

    def record_failure(self, model: int) -> None:
        # Paper's abstraction makes failure handling trivial: the model was
        # never observed, so it simply returns to L \ L(t).
        self.selected[model] = False
        self._selected_j = self._selected_j.at[model].set(False)

    def record_observation(self, model: int, z: float) -> bool:
        """Fold one observation; returns True when it improved at least one
        member tenant's incumbent (the health plane's regret-stall signal —
        callers that predate the health plane ignore the return).

        Non-finite ``z`` is rejected loudly (DESIGN.md §16): a NaN here
        corrupts the incremental Cholesky and every later decision.  The
        engines check upstream and route poisoned losses through
        ``record_failure`` instead; this raise is the hard boundary for
        callers that don't."""
        if not np.isfinite(z):
            raise ValueError(f"non-finite observation {z!r} for model "
                             f"{model}; poisoned losses must not reach the "
                             f"GP (use record_failure)")
        self.observed[model] = True
        with self.tracer.span("gp_fold", model=model):
            self.gp.observe(model, z)
        users = np.nonzero(self.membership[:, model])[0]
        improved = False
        for u in users:
            if z > self.best[u] or not np.isfinite(self.best[u]):
                self.best[u] = max(z, self.best[u]) if np.isfinite(self.best[u]) else z
                self._best_j = self._best_j.at[u].set(self.best[u])
                improved = True
        return improved

    # ---- policy decisions --------------------------------------------------

    def choose_mdmt(self, device_speed: float = 1.0) -> tuple[int, int] | None:
        if self.selected.all():
            return None
        tr = self.tracer
        if self.scorer == "sharded":
            # stay on host buffers until the sharded upload: the block
            # engine's cache is numpy, and float32 sqrt is bit-deterministic,
            # so this matches the fused path's jnp sqrt exactly
            with tr.span("posterior", scorer="sharded"):
                if hasattr(self.gp, "posterior_host"):
                    mu, var = self.gp.posterior_host()
                    sd = np.sqrt(var)
                else:
                    mu, sd = tr.sync(self.gp.posterior_sd())
            with tr.span("score", scorer="sharded"):
                if self._forensics is None:
                    idx, score = self._sharded.decide(
                        mu, sd, self._best_j, self.selected, device_speed)
                else:
                    # decide() is literally the head of decide_topk(), so
                    # keeping the k candidates changes no decision — it
                    # just stops discarding what the program materialized
                    v, g = self._sharded.decide_topk(
                        mu, sd, self._best_j, self.selected, device_speed)
                    idx, score = int(g[0]), float(v[0])
                    self._record_forensics(v, g, mu, sd, speed=device_speed)
            if not np.isfinite(score) or score <= -1e29:
                return None
            return idx, -1
        with tr.span("posterior", scorer=self.scorer):
            mu, sd = tr.sync(self.gp.posterior_sd())
        cost = self._cost_j if device_speed == 1.0 else self._cost_j / device_speed
        with tr.span("score", scorer=self.scorer):
            if self.scorer == "ops":
                from repro.kernels import ops
                scores = ops.eirate(
                    mu, sd, self._best_j, self._membership_j, cost,
                    self._selected_j,
                    use_pallas=jax.default_backend() == "tpu")
                idx = jnp.argmax(scores)
                idx, score = int(idx), float(scores[idx])
            else:
                idx, score = choose_next_fused(
                    mu, sd, self._best_j, self._membership_j, cost,
                    self._selected_j)
                idx, score = int(idx), float(score)
        if self._forensics is not None:
            # one additional jitted top-k over the same masked EIrate
            # vector; its head equals the decision's argmax (keep-earlier
            # tie-break), the decision above is untouched
            v, g = eirate_topk_fused(
                mu, sd, self._best_j, self._membership_j, cost,
                self._selected_j, k=FORENSICS_TOPK)
            self._record_forensics(v, g, mu, sd, speed=device_speed)
        if not np.isfinite(score) or score <= -1e29:
            return None
        return idx, -1

    def choose_mdmt_batch(self, rates, overheads, k: int, *,
                          class_names=None) -> tuple[np.ndarray, np.ndarray]:
        """One scoring pass for a k-device joint assignment (DESIGN.md §11).

        ``rates``/``overheads`` carry one entry per *device class* present
        in the batch; class c's cost row is ``cost / rates[c] +
        overheads[c]``.  Returns per-class EIrate top-k over the unselected
        pool as numpy ``(values (C, k), global ids (C, k))`` — the greedy
        device<->model solver (``devplane.assign``) consumes them.  With a
        single class at rate 1 / overhead 0, row 0's head is bit-identical
        to :meth:`choose_mdmt`'s pick (the ``/ 1.0`` and ``+ 0.0`` are IEEE
        identities), which is the batched == sequential contract.

        ``class_names`` (optional, len C) labels the per-class forensics
        records when a recorder is installed; it never affects scoring.
        """
        rates_j = jnp.asarray(np.asarray(rates, np.float32))
        over_j = jnp.asarray(np.asarray(overheads, np.float32))
        if self.selected.all():
            # same early-out as choose_mdmt: an empty pool must not pay a
            # scoring pass (dry passes dominate idle stretches)
            C = rates_j.shape[0]
            return (np.full((C, k), -np.inf, np.float32),
                    np.zeros((C, k), np.int64))
        tr = self.tracer
        if self.scorer == "sharded":
            with tr.span("posterior", scorer="sharded"):
                if hasattr(self.gp, "posterior_host"):
                    mu, var = self.gp.posterior_host()
                    sd = np.sqrt(var)
                else:
                    mu, sd = tr.sync(self.gp.posterior_sd())
            with tr.span("score_topk", scorer="sharded", k=k):
                v, g = self._sharded.decide_topk_classes(
                    mu, sd, self._best_j, self.selected, rates_j, over_j, k=k)
                v, g = np.asarray(v), np.asarray(g)
                self._record_batch_forensics(v, g, mu, sd, rates, overheads,
                                             class_names)
                return v, g
        with tr.span("posterior", scorer=self.scorer):
            mu, sd = tr.sync(self.gp.posterior_sd())
        cm = self._cost_j[None, :] / rates_j[:, None] + over_j[:, None]
        with tr.span("score_topk", scorer=self.scorer, k=k):
            if self.scorer == "ops":
                from repro.kernels import ops
                scores = ops.eirate_classes(
                    mu, sd, self._best_j, self._membership_j, cm,
                    self._selected_j,
                    use_pallas=jax.default_backend() == "tpu")
                v, i = topk_rows_padded(scores, k)
            else:
                v, i = choose_topk_classes(
                    mu, sd, self._best_j, self._membership_j, cm,
                    self._selected_j, k=k)
            v, i = np.asarray(v), np.asarray(i)
            self._record_batch_forensics(v, i, mu, sd, rates, overheads,
                                         class_names)
            return v, i

    def _users_with_work(self) -> np.ndarray:
        has_work = (self.membership & ~self.selected[None, :]).any(axis=1)
        return np.nonzero(has_work)[0]

    def _own_gp_ei(self, user: int) -> int | None:
        mu, sd = self.gp.posterior_sd()
        best = self.best[user] if np.isfinite(self.best[user]) else self._no_obs_floor
        scores = single_tenant_ei_scores(
            mu, sd, jnp.asarray(best),
            self._membership_j[user], jnp.asarray(self.selected))
        idx = int(jnp.argmax(scores))
        if not np.isfinite(float(scores[idx])):
            return None
        return idx

    def choose_random(self, device_speed: float = 1.0) -> tuple[int, int] | None:
        users = self._users_with_work()
        if users.size == 0:
            return None
        u = int(self.rng.choice(users))
        m = self._own_gp_ei(u)
        return (m, u) if m is not None else None

    def choose_round_robin(self, device_speed: float = 1.0) -> tuple[int, int] | None:
        users = self._users_with_work()
        if users.size == 0:
            return None
        N = self._num_tenants
        for step in range(N):
            u = (self.rr_pointer + step) % N
            if u in users:
                self.rr_pointer = (u + 1) % N
                m = self._own_gp_ei(u)
                if m is not None:
                    return m, u
        return None

    def chooser(self, policy: str):
        """The decision callable for a policy name (``POLICIES``)."""
        return {
            "mdmt": self.choose_mdmt,
            "random": self.choose_random,
            "round_robin": self.choose_round_robin,
        }[policy]
