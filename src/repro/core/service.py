"""Real-executor multi-tenant AutoML service — the paper's system, end to end.

Unlike the simulator (scheduler.py), here z(x) is genuinely unknown until a
trial *actually trains*: each model x = (tenant, architecture) is a reduced
config from the assigned pool trained on that tenant's synthetic dataset, and
z is an accuracy-like score exp(-val_loss).  The control plane is identical —
GP posterior + multi-tenant EIrate (Algorithm 1) — and c(x) comes from the
roofline cost model (Remark 1), updated with measured durations.

Fault tolerance: the service checkpoints its control state (observations,
in-flight set) as JSON after every event; on restart, in-flight trials are
re-queued (their models were never observed — the TSHB abstraction makes
recovery trivial).  Fleet slice failures likewise just return the model to
the unselected pool.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from .cost_model import CostModel
from .ei import choose_next, single_tenant_ei_scores
from .fleet import Fleet
from .gp import IncrementalGP


@dataclass(frozen=True)
class TenantSpec:
    tenant_id: int
    data_seed: int
    zipf_a: float            # dataset "difficulty" knob


@dataclass
class ServiceConfig:
    steps_per_trial: int = 30
    eval_steps: int = 4
    seq_len: int = 128
    batch: int = 8
    lr: float = 1e-3
    policy: str = "mdmt"     # mdmt | round_robin | random


class RealExecutor:
    """Trains a reduced-config model on the tenant's synthetic dataset."""

    def __init__(self, svc: ServiceConfig):
        self.svc = svc

    def run(self, tenant: TenantSpec, arch: str) -> tuple[float, float]:
        from repro.data.pipeline import DataConfig, SyntheticLMStream
        from repro.models import init_params
        from repro.models.model import forward_loss
        from repro.train.optimizer import OptConfig, adamw_init, adamw_update

        t0 = time.perf_counter()
        cfg = get_smoke_config(arch)
        svc = self.svc
        dcfg = DataConfig(seq_len=svc.seq_len, global_batch=svc.batch,
                          seed=tenant.data_seed, zipf_a=tenant.zipf_a)
        stream = SyntheticLMStream(dcfg, cfg)
        params = init_params(cfg, jax.random.PRNGKey(tenant.data_seed))
        opt_cfg = OptConfig(lr=svc.lr, warmup_steps=5,
                            total_steps=svc.steps_per_trial, weight_decay=0.0)
        opt = adamw_init(params, opt_cfg)

        @jax.jit
        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(
                lambda p: forward_loss(p, batch, cfg, None))(params)
            params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
            return params, opt, loss

        @jax.jit
        def eval_loss(params, batch):
            return forward_loss(params, batch, cfg, None)

        for s in range(svc.steps_per_trial):
            batch = jax.tree.map(jnp.asarray, stream.batch_at(s))
            params, opt, _ = step(params, opt, batch)
        losses = [float(eval_loss(params, jax.tree.map(
            jnp.asarray, stream.batch_at(10_000 + s))))
            for s in range(svc.eval_steps)]
        val = float(np.mean(losses))
        z = float(np.exp(-val))                  # accuracy-like, in (0, 1]
        return z, time.perf_counter() - t0


@dataclass
class ServiceTrial:
    model: int
    tenant: int
    arch: str
    slice_id: int
    t_start: float
    t_end: float | None = None
    z: float | None = None


class AutoMLService:
    """Event-driven service over a Fleet, MM-GP-EI scheduled."""

    def __init__(
        self,
        tenants: list[TenantSpec],
        archs: list[str],
        fleet: Fleet,
        executor,
        svc_cfg: ServiceConfig | None = None,
        prior: tuple[np.ndarray, np.ndarray] | None = None,
        cost_model: CostModel | None = None,
        checkpoint_path: str | None = None,
        seed: int = 0,
    ):
        self.tenants, self.archs, self.fleet = tenants, archs, fleet
        self.executor = executor
        self.svc = svc_cfg or ServiceConfig()
        self.cost_model = cost_model or CostModel()
        self.checkpoint_path = Path(checkpoint_path) if checkpoint_path else None
        self.rng = np.random.default_rng(seed)

        N, A = len(tenants), len(archs)
        self.n = N * A
        mu_a, K_a = prior if prior is not None else (
            np.full(A, 0.5), 0.05 * np.eye(A) + 0.01)
        self.mu0 = np.tile(mu_a, N)
        K = np.zeros((self.n, self.n))
        for i in range(N):
            K[i * A:(i + 1) * A, i * A:(i + 1) * A] = K_a
        self.K = K + 1e-8 * np.eye(self.n)
        self.membership = np.zeros((N, self.n), dtype=bool)
        for i in range(N):
            self.membership[i, i * A:(i + 1) * A] = True

        self.cost = np.array([
            self.cost_model.trial_seconds(
                archs[x % A] + "", "train_4k",
                steps=self.svc.steps_per_trial,
                chips=fleet.slices[0].chips,
                cfg=get_smoke_config(archs[x % A]))
            for x in range(self.n)])

        self.gp = IncrementalGP(self.K, self.mu0)
        self.selected = np.zeros(self.n, bool)
        self.best = np.full(N, -np.inf)
        self.trials: list[ServiceTrial] = []
        self.rr_pointer = 0
        self.t = 0.0

    # -- policies (same math as scheduler.py, unknown z) ----------------------

    def _choose(self) -> int | None:
        if self.selected.all():
            return None
        mu, sd = self.gp.posterior_sd()
        best = np.where(np.isfinite(self.best), self.best, float(self.mu0.min()) - 1.0)
        if self.svc.policy == "mdmt":
            idx, score = choose_next(
                mu, sd, jnp.asarray(best), jnp.asarray(self.membership),
                jnp.asarray(self.cost), jnp.asarray(self.selected))
            return int(idx) if np.isfinite(float(score)) else None
        users = np.nonzero((self.membership & ~self.selected[None, :]).any(1))[0]
        if users.size == 0:
            return None
        if self.svc.policy == "random":
            u = int(self.rng.choice(users))
        else:  # round_robin
            u = int(users[np.searchsorted(users, self.rr_pointer % len(self.tenants)) % users.size])
            self.rr_pointer = u + 1
        scores = single_tenant_ei_scores(
            mu, sd, jnp.asarray(best[u]), jnp.asarray(self.membership[u]),
            jnp.asarray(self.selected))
        m = int(jnp.argmax(scores))
        return m if np.isfinite(float(scores[m])) else None

    # -- event loop ------------------------------------------------------------

    def run(self, max_trials: int | None = None) -> list[ServiceTrial]:
        A = len(self.archs)
        budget = max_trials if max_trials is not None else self.n
        launched = 0
        inflight: list[ServiceTrial] = []
        while launched < budget or inflight:
            for s in self.fleet.free_at(self.t):
                if launched >= budget:
                    break
                m = self._choose()
                if m is None:
                    break
                tenant, arch = self.tenants[m // A], self.archs[m % A]
                z, wall = self.executor.run(tenant, arch)
                dur = wall / s.speed
                tr = ServiceTrial(m, tenant.tenant_id, arch, s.slice_id,
                                  self.t, self.t + dur, z)
                self.selected[m] = True
                s.current_trial = len(self.trials)
                s.busy_until = self.t + dur
                self.trials.append(tr)
                inflight.append(tr)
                launched += 1
                self.cost_model.observe(arch, "train_4k", s.chips, wall)
            if not inflight:
                break
            # advance to next completion
            inflight.sort(key=lambda tr: tr.t_end)
            tr = inflight.pop(0)
            self.t = tr.t_end
            self.gp.observe(tr.model, tr.z)
            u = tr.model // A
            self.best[u] = max(self.best[u], tr.z) if np.isfinite(self.best[u]) else tr.z
            self.fleet.slices[tr.slice_id].current_trial = None
            self._checkpoint()
        return self.trials

    # -- fault tolerance --------------------------------------------------------

    def _checkpoint(self):
        if self.checkpoint_path is None:
            return
        state = {
            "t": self.t,
            "observations": {str(i): self.gp._z[i] for i in self.gp.observed},
            "selected": self.selected.tolist(),
        }
        tmp = self.checkpoint_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(state))
        tmp.rename(self.checkpoint_path)

    def restore(self):
        """Re-apply observations; un-select in-flight (never-observed) models."""
        if self.checkpoint_path is None or not self.checkpoint_path.exists():
            return False
        state = json.loads(self.checkpoint_path.read_text())
        A = len(self.archs)
        self.t = state["t"]
        for k, z in state["observations"].items():
            m = int(k)
            self.gp.observe(m, z)
            self.selected[m] = True
            u = m // A
            self.best[u] = max(self.best[u], z) if np.isfinite(self.best[u]) else z
        # anything selected-but-not-observed was in flight during the crash
        observed = set(self.gp.observed)
        for m, was in enumerate(state["selected"]):
            if was and m not in observed:
                self.selected[m] = False   # re-queue
        return True


def estimate_prior(archs: list[str], prior_tenants: list[TenantSpec],
                   executor) -> tuple[np.ndarray, np.ndarray]:
    """The paper's protocol: isolate a few tenants, fit prior mean/cov."""
    rows = []
    for t in prior_tenants:
        rows.append([executor.run(t, a)[0] for a in archs])
    acc = np.asarray(rows)
    mu = acc.mean(axis=0)
    K = np.cov(acc, rowvar=False) if len(rows) > 1 else 0.05 * np.eye(len(archs))
    K = K + 1e-4 * np.eye(len(archs))
    return mu, K
