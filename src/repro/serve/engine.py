"""Batched serving engine over the prefill/decode substrate.

Wave-based static batching (the scheme the decode_32k dry-run cells lower):
requests are grouped into fixed-size waves, right-padded to a common prompt
length, prefilled once, then decoded lock-step with per-request stopping.
Finished requests exit the wave; the engine reports per-wave utilization so
the multi-tenant service can cost serving trials the same way it costs
training trials.

(Continuous batching needs per-slot cache lengths — a ragged-cache layout —
which the ring-buffer cache doesn't support; noted as future work in
DESIGN.md.  Static waves are what the 32k/500k dry-run shapes model.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig, decode_step, prefill


@dataclass
class Request:
    request_id: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    pad_id: int = 0


class StaticBatchEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig | None = None,
                 rules=None):
        self.cfg = cfg
        self.params = params
        self.serve = serve_cfg or ServeConfig()
        self.rules = rules
        self.queue: list[Request] = []
        self.stats = {"waves": 0, "decode_steps": 0, "slot_steps_used": 0,
                      "slot_steps_total": 0, "wall": 0.0}
        self._decode = jax.jit(
            lambda p, b, c: decode_step(p, b, c, self.cfg, self.rules))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _next_wave(self) -> list[Request]:
        wave, self.queue = (self.queue[: self.serve.batch_slots],
                            self.queue[self.serve.batch_slots:])
        return wave

    def run(self) -> list[Request]:
        done: list[Request] = []
        while self.queue:
            done.extend(self._run_wave(self._next_wave()))
        return done

    def _run_wave(self, wave: list[Request]) -> list[Request]:
        t0 = time.perf_counter()
        B = len(wave)
        plen = max(len(r.tokens) for r in wave)
        toks = np.full((B, plen), self.serve.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.tokens):] = r.tokens   # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        max_new = max(r.max_new_tokens for r in wave)
        _, cache = prefill(self.params, batch, self.cfg, self.rules,
                           max_len=min(plen + max_new + 8, self.serve.max_len))

        last = jnp.asarray(toks[:, -1:])
        active = np.ones(B, bool)
        for step in range(max_new):
            logits, cache = self._decode(self.params, {"tokens": last}, cache)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
            self.stats["decode_steps"] += 1
            self.stats["slot_steps_total"] += B
            self.stats["slot_steps_used"] += int(active.sum())
            for i, r in enumerate(wave):
                if not active[i]:
                    continue
                r.output.append(int(nxt[i]))
                if (r.eos_id is not None and nxt[i] == r.eos_id) or \
                        len(r.output) >= r.max_new_tokens:
                    r.done = True
                    active[i] = False
            if not active.any():
                break
            last = jnp.asarray(nxt[:, None])
        for r in wave:
            r.done = True
        self.stats["waves"] += 1
        self.stats["wall"] += time.perf_counter() - t0
        return wave

    @property
    def slot_utilization(self) -> float:
        tot = self.stats["slot_steps_total"]
        return self.stats["slot_steps_used"] / tot if tot else 1.0
