from .engine import Request, ServeConfig, StaticBatchEngine  # noqa: F401
