"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable, zero allocation.  One function builds everything the dry-run (and
the real launcher) needs to stage a cell:

  build_cell(cfg, shape_name, mesh, rules) ->
      CellSpec(fn, args_sds, in_shardings, out_shardings, donate_argnums)

Step kinds per shape (see repro.configs.SHAPES):
  train        jit(train_step)(state, batch)
  prefill      jit(prefill)(params, batch)
  decode       jit(decode_step)(params, batch, cache)
  long_decode  decode with a 500k-token context (SSM state / SWA window /
               sequence-sharded KV, per DESIGN.md §Arch-applicability)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.models.model import (
    ModelConfig,
    decode_step,
    make_cache_specs,
    model_specs,
    prefill,
)
from repro.sharding.rules import (
    AxisRules,
    DEFAULT_RULES,
    ParamSpec,
    shardings_for_tree,
    shape_dtype_for_tree,
)
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainState, make_train_step, train_state_specs

DECODE_MARGIN = 128  # decode cache capacity beyond the prefilled context


def batch_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ParamSpec tree for the input batch of a given shape."""
    S, B, kind = SHAPES[shape_name]
    tok = lambda shape: ParamSpec(shape, ("batch", "seq"), dtype=jnp.int32, init="zeros")
    if kind in ("train", "prefill"):
        if cfg.frontend == "patches":
            ni = cfg.num_frontend_tokens
            specs = {
                "patches": ParamSpec((B, ni, cfg.frontend_dim),
                                     ("batch", "seq", None), dtype=jnp.float32),
                "tokens": tok((B, S - ni)),
            }
            if kind == "train":
                specs["labels"] = tok((B, S - ni))
            return specs
        if cfg.frontend == "frames":
            specs = {
                "frames": ParamSpec((B, S, cfg.frontend_dim),
                                    ("batch", "seq", None), dtype=jnp.float32),
            }
            if kind == "train":
                specs["labels"] = ParamSpec((B, S, cfg.num_lm_heads),
                                            ("batch", "seq", None),
                                            dtype=jnp.int32, init="zeros")
            return specs
        specs = {"tokens": tok((B, S))}
        if kind == "train":
            specs["labels"] = tok((B, S))
        return specs
    # decode kinds: one new token per sequence
    if cfg.frontend == "frames":
        return {"frames": ParamSpec((B, 1, cfg.frontend_dim),
                                    ("batch", "seq", None), dtype=jnp.float32)}
    return {"tokens": tok((B, 1))}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStructs for the batch of one cell (no allocation)."""
    return shape_dtype_for_tree(batch_specs(cfg, shape_name))


def rules_for_shape(cfg: ModelConfig, shape_name: str, base: AxisRules) -> AxisRules:
    S, B, kind = SHAPES[shape_name]
    if kind == "long_decode":
        # batch=1 cannot shard; shard the KV sequence instead (SP).
        return base.override(batch=None, kv_seq="data")
    return base


@dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args_sds: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    static_notes: dict


def build_cell(cfg: ModelConfig, shape_name: str, mesh, rules: AxisRules | None = None,
               opt_cfg: OptConfig | None = None) -> CellSpec:
    rules = rules_for_shape(cfg, shape_name, rules or DEFAULT_RULES)
    S, B, kind = SHAPES[shape_name]
    opt_cfg = opt_cfg or OptConfig()

    b_specs = batch_specs(cfg, shape_name)
    b_sds = shape_dtype_for_tree(b_specs)
    b_sh = shardings_for_tree(b_specs, mesh, rules)

    if kind == "train":
        st_specs = train_state_specs(cfg, opt_cfg)
        st_sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                              st_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        st_sh = shardings_for_tree(st_specs, mesh, rules)
        fn = make_train_step(cfg, opt_cfg, rules)
        return CellSpec(
            arch=cfg.name, shape=shape_name, kind=kind, fn=fn,
            args_sds=(st_sds, b_sds),
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
            static_notes={"seq": S, "batch": B})

    p_specs = model_specs(cfg)
    p_sds = shape_dtype_for_tree(p_specs)
    p_sh = shardings_for_tree(p_specs, mesh, rules)

    if kind == "prefill":
        fn = lambda params, batch: prefill(params, batch, cfg, rules, max_len=S + DECODE_MARGIN)
        return CellSpec(
            arch=cfg.name, shape=shape_name, kind=kind, fn=fn,
            args_sds=(p_sds, b_sds),
            in_shardings=(p_sh, b_sh),
            out_shardings=None,
            donate_argnums=(),
            static_notes={"seq": S, "batch": B})

    # decode / long_decode: serve_step against an S-token context
    cache_specs = make_cache_specs(cfg, batch=B, max_len=S + DECODE_MARGIN)
    c_sds = shape_dtype_for_tree(cache_specs)
    c_sh = shardings_for_tree(cache_specs, mesh, rules)
    fn = lambda params, batch, cache: decode_step(params, batch, cache, cfg, rules)
    return CellSpec(
        arch=cfg.name, shape=shape_name, kind=kind, fn=fn,
        args_sds=(p_sds, b_sds, c_sds),
        in_shardings=(p_sh, b_sh, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
        static_notes={"seq": S, "batch": B})
