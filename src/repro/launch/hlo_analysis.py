"""Roofline-term extraction from compiled dry-run artifacts.

Sources (per the brief):
  * ``compiled.cost_analysis()``  -> HLO FLOPs + HLO bytes (per-device: the
    compiled module is the SPMD per-device program).
  * ``compiled.as_text()``        -> post-partitioning HLO; we parse every
    all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
    and sum transferred bytes.

Hardware model (TPU v5e target):
  peak 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s per ICI link.

Terms (seconds, per training/serving step):
  compute    = flops_per_device / PEAK_FLOPS
  memory     = bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / ICI_BW

``wire_bytes`` uses the standard ring model per op (e.g. all-reduce moves
2(g-1)/g x payload per device); ``payload_bytes`` (the raw "sum of operand
sizes" the brief describes) is recorded alongside for transparency.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link
HBM_PER_CHIP = 16e9       # v5e HBM capacity

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[\w\[\]{},\d]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([\d,]*)\]")
_GROUPS_ARRAY_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)        # op -> #occurrences
    payload_bytes: float = 0.0                        # sum of result sizes
    wire_bytes: float = 0.0                           # ring-model per-device bytes
    by_op_bytes: dict = field(default_factory=dict)   # op -> wire bytes


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ARRAY_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result_bytes = _shape_bytes(m.group("shape"))
        g = max(_group_size(line, num_devices), 1)
        if op == "all-reduce":
            wire = 2.0 * (g - 1) / g * result_bytes
        elif op == "all-gather":
            wire = (g - 1) / g * result_bytes
        elif op == "reduce-scatter":
            wire = (g - 1) * result_bytes       # operand is g x result
        elif op == "all-to-all":
            wire = (g - 1) / g * result_bytes
        else:                                   # collective-permute
            wire = result_bytes
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.payload_bytes += result_bytes
        stats.wire_bytes += wire
        stats.by_op_bytes[op] = stats.by_op_bytes.get(op, 0.0) + wire
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    flops_per_device: float
    bytes_per_device: float
    transcendentals: float
    collectives: dict
    collective_payload_bytes: float
    collective_wire_bytes: float
    compute_seconds: float
    memory_seconds: float
    collective_seconds: float
    dominant: str
    model_flops: float            # 6*N_active*D (train) / 2*N_active*D (serve)
    model_flops_global: float
    useful_flops_ratio: float     # model_flops_global / (flops_per_device * chips)
    memory_stats: dict
    fits_hbm: bool

    def to_dict(self):
        return asdict(self)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     num_devices: int, model_flops_global: float) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    trans = float(ca.get("transcendentals", 0.0))
    colls = parse_collectives(compiled.as_text(), num_devices)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = colls.wire_bytes / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda kv: kv[1])[0]
    ma = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    resident = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    hlo_global = flops * num_devices
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, num_devices=num_devices,
        flops_per_device=flops, bytes_per_device=byts, transcendentals=trans,
        collectives=colls.counts,
        collective_payload_bytes=colls.payload_bytes,
        collective_wire_bytes=colls.wire_bytes,
        compute_seconds=compute_s, memory_seconds=memory_s,
        collective_seconds=coll_s, dominant=dominant,
        model_flops=model_flops_global / max(num_devices, 1),
        model_flops_global=model_flops_global,
        useful_flops_ratio=(model_flops_global / hlo_global) if hlo_global else 0.0,
        memory_stats=mem_stats,
        fits_hbm=bool(resident <= HBM_PER_CHIP),
    )


def model_flops_for_cell(cfg, shape_name: str) -> float:
    """6*N_active*D for training, 2*N_active*D for serving (forward-only)."""
    from repro.configs import SHAPES
    S, B, kind = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n_active * S * B
    if kind == "prefill":
        return 2.0 * n_active * S * B
    # decode: one token per sequence
    return 2.0 * n_active * B
