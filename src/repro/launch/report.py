"""Render EXPERIMENTS.md tables from the dry-run/probe JSON records.

  PYTHONPATH=src python -m repro.launch.report > experiments/tables.md
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def _records(mesh: str, probe: bool):
    suffix = "__probe.json" if probe else ".json"
    out = {}
    for p in sorted((ROOT / mesh).glob(f"*{suffix}")):
        if probe != p.name.endswith("__probe.json"):
            continue
        parts = p.name.replace("__probe.json", "").replace(".json", "").split("__")
        if len(parts) != 3:
            continue   # tagged perf-iteration snapshots (see §Perf) are skipped
        arch, shape, rules = parts
        out[(arch, shape, rules)] = json.loads(p.read_text())
    return out


def dryrun_table(mesh: str) -> str:
    recs = _records(mesh, probe=False)
    lines = [
        f"#### Mesh `{mesh}` — compile proofs",
        "",
        "| arch | shape | rules | kind | compile (s) | args/dev | temp/dev | fits 16GB | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, rules), r in sorted(recs.items()):
        ms = r["memory_stats"]
        colls = ",".join(f"{k}:{v}" for k, v in sorted(r.get("collectives", {}).items())) or "-"
        lines.append(
            f"| {arch} | {shape} | {rules} | {r.get('kind','?')} "
            f"| {r.get('compile_seconds','?')} "
            f"| {fmt_bytes(ms['argument_bytes'])} | {fmt_bytes(ms['temp_bytes'])} "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} | {colls} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "pod16x16", rules: str | None = None) -> str:
    recs = _records(mesh, probe=True)
    lines = [
        f"#### Mesh `{mesh}` — roofline terms (per step, layer-exact probes)",
        "",
        "| arch | shape | rules | compute (ms) | memory (ms) | collective (ms) | dominant "
        "| model GFLOPs | useful (6ND/HLO) | wire bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, rl), r in sorted(recs.items()):
        if rules is not None and rl != rules:
            continue
        lines.append(
            f"| {arch} | {shape} | {rl} "
            f"| {r['compute_seconds']*1e3:.1f} | {r['memory_seconds']*1e3:.1f} "
            f"| {r['collective_seconds']*1e3:.1f} | **{r['dominant']}** "
            f"| {r['model_flops_global']/1e9:,.0f} | {r['useful_flops_ratio']:.3f} "
            f"| {fmt_bytes(r['collective_wire_bytes'])} |")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("pod16x16", "pod2x16x16"):
        if (ROOT / mesh).exists():
            print(dryrun_table(mesh))
            print()
    print(roofline_table("pod16x16"))


if __name__ == "__main__":
    main()
