"""End-to-end training launcher (data pipeline -> train step -> checkpoints).

Runs reduced configs for real on CPU and full configs on a TPU mesh (same
code path; the mesh/sharding comes from --mesh).  Demonstrates the
fault-tolerance loop: async checkpointing, crash injection, resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt [--resume]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, make_batch_iterator
from repro.models import init_params
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainState, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="fault-injection: hard-exit at this step (tests)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(10, args.steps // 5 + 1),
                        total_steps=args.steps)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch, seed=args.seed)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = TrainState(params=params, opt=adamw_init(params, opt_cfg))
    start_step = 0

    mgr = CheckpointManager(args.ckpt) if args.ckpt else None
    if mgr and args.resume:
        restored = mgr.restore_latest(state)
        if restored:
            start_step, state, meta = restored
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, None), donate_argnums=0)
    it = make_batch_iterator(dcfg, cfg, start_step=start_step)

    t0 = time.time()
    for _ in range(args.steps - start_step):
        step, batch = next(it)
        batch = jax.tree.map(jnp.asarray, batch)
        state, metrics = step_fn(state, batch)
        if (step + 1) % 10 == 0 or step == start_step:
            print(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, {"arch": cfg.name}, blocking=False)
        if args.crash_at_step is not None and step + 1 == args.crash_at_step:
            print(f"injected crash at step {step + 1}")
            it.close()
            if mgr:
                mgr.wait()
            raise SystemExit(17)
    it.close()
    if mgr:
        mgr.save(args.steps, state, {"arch": cfg.name}, blocking=True)
    print("done")


if __name__ == "__main__":
    main()
