import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions and compiles on the production meshes.

  single pod : 16 x 16 = 256 chips, axes ("data", "model")
  multi pod  : 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")

The two lines above MUST precede any jax import: jax locks the device count
at first backend init, and only the dry-run wants 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
  python -m repro.launch.dryrun --arch ... --shape ... --rules fsdp

Each cell writes experiments/dryrun/<mesh>/<arch>__<shape>__<rules>.json with
memory analysis, cost analysis, the collective schedule and roofline terms
(consumed by EXPERIMENTS.md and benchmarks/roofline.py).
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch.hlo_analysis import analyze_compiled, model_flops_for_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.sharding.rules import (
    DEFAULT_RULES,
    FSDP_RULES,
    PUREDP_RULES,
    QROWS_RULES,
)

RULES = {"default": DEFAULT_RULES, "fsdp": FSDP_RULES,
         "puredp": PUREDP_RULES, "qrows": QROWS_RULES}
OUT_ROOT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _compile_cell(cfg, shape: str, mesh, rules_name: str):
    cell = build_cell(cfg, shape, mesh, RULES[rules_name])
    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return cell, compiled, t_lower, t_compile


PROBE_KEYS = ("flops", "bytes", "transcendentals", "wire", "payload")


def _measure_probe(cfg, shape, mesh, rules_name, verbose):
    from repro.launch.hlo_analysis import parse_collectives
    cell, compiled, t_lower, t_compile = _compile_cell(cfg, shape, mesh, rules_name)
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text(), mesh.devices.size)
    rec = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "wire": colls.wire_bytes,
        "payload": colls.payload_bytes,
        "counts": colls.counts,
        "by_op": colls.by_op_bytes,
    }
    if verbose:
        print(f"  probe L={cfg.num_layers} S(shape)={shape}: "
              f"flops={rec['flops']:.3e} wire={rec['wire']:.3e} "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return rec


def probe_roofline(arch: str, shape: str, multi_pod: bool,
                   rules_name: str = "default", verbose: bool = True,
                   config_overrides: dict | None = None) -> dict:
    """Layer-exact roofline via unrolled probes + linear extrapolation.

    XLA's cost analysis counts while-loop bodies once, so the scanned full
    model under-reports flops/bytes/collectives by ~L.  The probe compiles
    the same cell with 1 and 2 (unrolled) layer units; every per-layer
    quantity is the difference, and the full-depth value is
    f(1) + (units-1) * (f(2) - f(1)).  Exact for homogeneous stacks (all of
    ours: the hybrid's unit is its 6-layer group).

    For long-sequence prefill cells, unrolling the inner (query-block /
    SSD-chunk) scans at S=32k makes the probe HLO enormous; instead we probe
    at two shorter sequence lengths and fit the per-layer and fixed costs as
    b*S + c*S^2 (attention is quadratic in S, every other term linear),
    then evaluate the fit at the target S.  Exact for the same reason the
    layer fit is: the compiled cost IS a polynomial of that form.
    """
    from dataclasses import replace as dc_replace

    base = get_config(arch)
    if config_overrides:
        base = dc_replace(base, **config_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    unit = base.hybrid_attn_every if base.family == "hybrid" else 1
    total_units = base.num_layers // unit
    S_target, B, kind = SHAPES[shape]

    def cfg_for(n_units):
        over = dict(num_layers=unit * n_units, unroll_layers=True)
        if base.ssm is not None:
            over["ssm"] = base.ssm._replace(unroll=True)
        return dc_replace(base, **over)

    seq_fit = kind == "prefill" and S_target > 8192
    if seq_fit:
        # 2 units x 2 sequence lengths; quadratic-in-S fit per unit level.
        S1, S2 = 2048, 4096
        import repro.configs as cfgmod
        meas = {}
        for n_units in (1, 2):
            for S_probe in (S1, S2):
                key = f"__probe_{shape}_{S_probe}"
                cfgmod.SHAPES[key] = (S_probe, B, kind)
                try:
                    meas[(n_units, S_probe)] = _measure_probe(
                        cfg_for(n_units), key, mesh, rules_name, verbose)
                finally:
                    del cfgmod.SHAPES[key]

        def fit_eval(key):
            # layer(S) and fixed(S), each modeled as b*S + c*S^2
            def at(n, S):
                return meas[(n, S)][key]
            out = {}
            for part, val1, val2 in (
                ("layer", at(2, S1) - at(1, S1), at(2, S2) - at(1, S2)),
                ("fixed", 2 * at(1, S1) - at(2, S1), 2 * at(1, S2) - at(2, S2)),
            ):
                c = (val2 / S2 - val1 / S1) / (S2 - S1)
                b = val1 / S1 - c * S1
                out[part] = b * S_target + c * S_target ** 2
            return max(out["fixed"], 0.0) + total_units * max(out["layer"], 0.0)

        probes_extrap = {k: fit_eval(k) for k in PROBE_KEYS}
        # collective op counts don't depend on S; reuse the layer fit at S2
        c1, c2 = meas[(1, S2)]["counts"], meas[(2, S2)]["counts"]
        counts = {op: c1.get(op, 0) + (total_units - 1) * (c2.get(op, 0) - c1.get(op, 0))
                  for op in set(c1) | set(c2)}
        b1, b2 = meas[(1, S2)]["by_op"], meas[(2, S2)]["by_op"]
        scale = probes_extrap["wire"] / max(
            b1 and (sum(b1.values()) + (total_units - 1)
                    * (sum(b2.values()) - sum(b1.values()))) or 1.0, 1e-9)
        by_op = {op: (b1.get(op, 0.0) + (total_units - 1)
                      * (b2.get(op, 0.0) - b1.get(op, 0.0))) * scale
                 for op in set(b1) | set(b2)}
    else:
        probes = {n: _measure_probe(cfg_for(n), shape, mesh, rules_name, verbose)
                  for n in (1, 2)}

        def extrap(key):
            return probes[1][key] + (total_units - 1) * (probes[2][key] - probes[1][key])

        probes_extrap = {k: extrap(k) for k in PROBE_KEYS}
        counts = {
            op: probes[1]["counts"].get(op, 0)
            + (total_units - 1) * (probes[2]["counts"].get(op, 0) - probes[1]["counts"].get(op, 0))
            for op in set(probes[1]["counts"]) | set(probes[2]["counts"])}
        by_op = {
            op: probes[1]["by_op"].get(op, 0.0)
            + (total_units - 1) * (probes[2]["by_op"].get(op, 0.0) - probes[1]["by_op"].get(op, 0.0))
            for op in set(probes[1]["by_op"]) | set(probes[2]["by_op"])}

    from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
    flops, byts, wire = (probes_extrap["flops"], probes_extrap["bytes"],
                         probes_extrap["wire"])
    num_devices = mesh.devices.size
    mf = model_flops_for_cell(base, shape)
    compute_s, memory_s, coll_s = flops / PEAK_FLOPS, byts / HBM_BW, wire / ICI_BW
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "rules": rules_name,
        "num_devices": num_devices, "probe_units": [1, 2],
        "seq_fit": seq_fit,
        "total_units": total_units,
        "flops_per_device": flops, "bytes_per_device": byts,
        "transcendentals": probes_extrap["transcendentals"],
        "collective_wire_bytes": wire,
        "collective_payload_bytes": probes_extrap["payload"],
        "collectives": counts, "collective_bytes_by_op": by_op,
        "compute_seconds": compute_s, "memory_seconds": memory_s,
        "collective_seconds": coll_s,
        "dominant": max((("compute", compute_s), ("memory", memory_s),
                         ("collective", coll_s)), key=lambda kv: kv[1])[0],
        "model_flops_global": mf,
        "useful_flops_ratio": mf / (flops * num_devices) if flops else 0.0,
    }
    out_dir = OUT_ROOT / mesh_name
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape}__{rules_name}__probe.json").write_text(
        json.dumps(rec, indent=1))
    if verbose:
        print(f"[probe {mesh_name}] {arch} x {shape} ({rules_name}): "
              f"compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
              f"collective={coll_s*1e3:.2f}ms dominant={rec['dominant']} "
              f"useful={rec['useful_flops_ratio']:.3f}")
    return rec


def run_cell(arch: str, shape: str, multi_pod: bool, rules_name: str = "default",
             verbose: bool = True, config_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if config_overrides:
        from dataclasses import replace
        cfg = replace(cfg, **config_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = build_cell(cfg, shape, mesh, RULES[rules_name])

    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    if verbose:
        print(compiled.memory_analysis())   # proves it fits
        ca = compiled.cost_analysis() or {}
        print({k: ca[k] for k in ("flops", "bytes accessed", "transcendentals")
               if k in ca})

    roof = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name,
        num_devices=mesh.devices.size,
        model_flops_global=model_flops_for_cell(cfg, shape))
    rec = roof.to_dict()
    rec.update(kind=cell.kind, rules=rules_name,
               lower_seconds=round(t_lower, 2), compile_seconds=round(t_compile, 2))

    out_dir = OUT_ROOT / mesh_name
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape}__{rules_name}.json"
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        print(f"[{mesh_name}] {arch} x {shape} ({rules_name}): "
              f"compute={roof.compute_seconds*1e3:.2f}ms "
              f"memory={roof.memory_seconds*1e3:.2f}ms "
              f"collective={roof.collective_seconds*1e3:.2f}ms "
              f"dominant={roof.dominant} useful={roof.useful_flops_ratio:.3f} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
    return rec


def run_all(multi_pod: bool, rules_name: str, jobs: int) -> int:
    """Fan each cell out to a subprocess (isolates compile memory)."""
    import subprocess
    todo = cells()
    procs: list[tuple[str, str, subprocess.Popen]] = []
    failed = []
    done = 0

    def launch(a, s):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--rules", rules_name, "--quiet"]
        if multi_pod:
            cmd.append("--multi-pod")
        env = dict(os.environ)
        env["PYTHONPATH"] = str(Path(__file__).resolve().parents[2])
        return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)

    queue = list(todo)
    while queue or procs:
        while queue and len(procs) < jobs:
            a, s = queue.pop(0)
            procs.append((a, s, launch(a, s)))
        a, s, p = procs.pop(0)
        out, _ = p.communicate()
        done += 1
        status = "ok" if p.returncode == 0 else "FAIL"
        print(f"[{done}/{len(todo)}] {a} x {s}: {status}")
        if p.returncode != 0:
            failed.append((a, s))
            print(out[-4000:])
    if failed:
        print("FAILED CELLS:", failed)
        return 1
    print(f"all {len(todo)} cells compiled on "
          f"{'2x16x16' if multi_pod else '16x16'} mesh")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="default",
                    choices=list(RULES) + ["preferred"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="layer-exact roofline via 1/2-unit unrolled probes")
    ap.add_argument("--bf16-attn", action="store_true",
                    help="perf lever: bf16 attention softmax (default fp32)")
    ap.add_argument("--remat", default=None, choices=["none", "full", "dots"])
    ap.add_argument("--tag", default=None,
                    help="suffix for the output json (perf-iteration runs)")
    args = ap.parse_args()

    if args.all:
        sys.exit(run_all(args.multi_pod, args.rules, args.jobs))
    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    if args.rules == "preferred":
        from repro.configs import preferred_rules_name
        args.rules = preferred_rules_name(args.arch, args.shape)
        print(f"preferred rules for {args.arch} x {args.shape}: {args.rules}")
    overrides = {}
    if args.bf16_attn:
        overrides["attn_logits_fp32"] = False
    if args.remat:
        overrides["remat"] = args.remat
    if args.probe:
        rec = probe_roofline(args.arch, args.shape, args.multi_pod, args.rules,
                             verbose=not args.quiet,
                             config_overrides=overrides or None)
    else:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.rules,
                       verbose=not args.quiet,
                       config_overrides=overrides or None)
    if args.tag:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        suffix = "__probe" if args.probe else ""
        path = (OUT_ROOT / mesh_name /
                f"{args.arch}__{args.shape}__{args.rules}{suffix}__{args.tag}.json")
        path.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
