"""Production mesh definition.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — critical because the
dry-run pins ``xla_force_host_platform_device_count=512`` before first init
while tests/benches must see the single real CPU device.

Production target: TPU v5e pods of 16x16 = 256 chips; the multi-pod mesh
stacks 2 pods (512 chips) along a leading "pod" axis used for cross-pod data
parallelism (DCI domain).  The same code scales to more pods by changing the
leading extent — the scheduler fleet (repro.core.fleet) slices whichever mesh
it is handed.
"""

from __future__ import annotations

import jax
import numpy as np

try:  # AxisType landed after jax 0.4.x; meshes default to Auto without it
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))


def mesh_context(mesh):
    """Version-portable ``with mesh:`` — ``jax.sharding.set_mesh`` where it
    exists, the Mesh context manager on older releases."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_scoring_mesh(num_shards: int | None = None):
    """1-D ("shard",) mesh for the sharded GP-EI scoring plane
    (repro.shardgp): the model axis of the control-plane state is
    partitioned over these devices.  Defaults to every visible device; the
    control plane's decision path is exact for any extent (DESIGN.md §10),
    so shrinking the mesh is a capacity knob, not a correctness one."""
    devices = jax.devices()
    n = len(devices) if num_shards is None else num_shards
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"num_shards must be in [1, {len(devices)}], got {n}")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]), ("shard",))
