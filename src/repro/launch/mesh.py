"""Production mesh definition.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — critical because the
dry-run pins ``xla_force_host_platform_device_count=512`` before first init
while tests/benches must see the single real CPU device.

Production target: TPU v5e pods of 16x16 = 256 chips; the multi-pod mesh
stacks 2 pods (512 chips) along a leading "pod" axis used for cross-pod data
parallelism (DCI domain).  The same code scales to more pods by changing the
leading extent — the scheduler fleet (repro.core.fleet) slices whichever mesh
it is handed.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
