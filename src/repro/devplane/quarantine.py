"""Device quarantine: the failure-domain scoreboard (DESIGN.md §16).

A device that keeps timing out or failing is worse than a missing device:
the scheduler keeps feeding it trials, each one burns a full deadline
before the supervisor kills it, and the tenant's regret clock runs the
whole time.  :class:`QuarantineBoard` tracks a per-device strike history
(trial timeouts, slice failures) over a sliding window; when a device
accumulates ``threshold`` strikes inside ``window`` seconds it is pulled
from the launchable pool for ``duration`` seconds, then re-admitted *on
probation* — it must complete ``probation_trials`` clean trials before it
counts as healthy again, and a single strike during probation re-
quarantines it immediately (the "flap" the health plane pages on).

The board is pure host-side bookkeeping driven by sim-time values the
engine hands it, so it is deterministic under replay and snapshots into
the engine's crash-recovery state (``state_dict``/``load_state``).

Capacity coupling: the devplane engine subtracts ``quarantined_now()``
from the device count it reports to the autoscale controller, so a
quarantine shows up as lost capacity and can trigger a scale-up — the
fleet heals around a sick device instead of waiting for it.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class QuarantinePolicy:
    """Strike thresholds and timing for :class:`QuarantineBoard`.

    ``threshold`` strikes within ``window`` seconds quarantine a device
    for ``duration`` seconds; re-admission requires ``probation_trials``
    clean completions.
    """
    threshold: int = 3
    window: float = 60.0
    duration: float = 120.0
    probation_trials: int = 2

    def __post_init__(self):
        if self.threshold < 1:
            raise ValueError(
                f"threshold must be >= 1, got {self.threshold}")
        if self.window <= 0:
            raise ValueError(f"window must be positive, got {self.window}")
        if self.duration <= 0:
            raise ValueError(
                f"duration must be positive, got {self.duration}")
        if self.probation_trials < 1:
            raise ValueError(
                f"probation_trials must be >= 1, got {self.probation_trials}")


class QuarantineBoard:
    """Per-device strike scoreboard with quarantine and probation.

    States per device: ``"healthy"`` (default), ``"quarantined"`` (not
    launchable), ``"probation"`` (launchable, under observation).  The
    engine drives transitions: :meth:`strike` on timeout/failure,
    :meth:`begin_probation` when the quarantine timer fires,
    :meth:`on_success` on clean trial completion, :meth:`retire` when the
    device leaves the fleet.
    """

    def __init__(self, policy: QuarantinePolicy | None = None):
        self.policy = policy or QuarantinePolicy()
        self._strikes: dict[int, list[float]] = {}
        self._state: dict[int, str] = {}
        self._ok: dict[int, int] = {}
        self._counts: dict[int, int] = {}
        self.total_quarantines = 0

    def state(self, device: int) -> str:
        return self._state.get(device, "healthy")

    def is_quarantined(self, device: int) -> bool:
        return self._state.get(device) == "quarantined"

    def quarantined_now(self) -> int:
        return sum(1 for s in self._state.values() if s == "quarantined")

    def quarantine_count(self, device: int) -> int:
        """How many times this device has ever been quarantined."""
        return self._counts.get(device, 0)

    def _quarantine(self, device: int) -> None:
        self._state[device] = "quarantined"
        self._strikes.pop(device, None)
        self._ok[device] = 0
        self._counts[device] = self._counts.get(device, 0) + 1
        self.total_quarantines += 1

    def strike(self, device: int, t: float) -> bool:
        """Record one strike at sim-time ``t``.  Returns True iff the
        device *newly* entered quarantine (strikes while already
        quarantined are ignored; any strike during probation is an
        immediate re-quarantine — the flap)."""
        state = self._state.get(device, "healthy")
        if state == "quarantined":
            return False
        if state == "probation":
            self._quarantine(device)
            return True
        times = self._strikes.setdefault(device, [])
        times.append(float(t))
        lo = float(t) - self.policy.window
        while times and times[0] < lo:
            times.pop(0)
        if len(times) >= self.policy.threshold:
            self._quarantine(device)
            return True
        return False

    def begin_probation(self, device: int) -> None:
        """Quarantine timer fired: re-admit under observation."""
        self._state[device] = "probation"
        self._ok[device] = 0

    def on_success(self, device: int) -> None:
        """Clean trial completion; only probation cares."""
        if self._state.get(device) != "probation":
            return
        self._ok[device] = self._ok.get(device, 0) + 1
        if self._ok[device] >= self.policy.probation_trials:
            self._state.pop(device, None)
            self._ok.pop(device, None)
            self._strikes.pop(device, None)

    def retire(self, device: int) -> None:
        """Device left the fleet — drop all its entries so
        ``quarantined_now()`` never counts capacity that no longer
        exists."""
        self._strikes.pop(device, None)
        self._state.pop(device, None)
        self._ok.pop(device, None)

    # ---- crash-recovery persistence ----------------------------------

    def state_dict(self) -> dict:
        return {
            "strikes": [[d, list(ts)] for d, ts
                        in sorted(self._strikes.items())],
            "state": [[d, s] for d, s in sorted(self._state.items())],
            "ok": [[d, n] for d, n in sorted(self._ok.items())],
            "counts": [[d, n] for d, n in sorted(self._counts.items())],
            "total_quarantines": self.total_quarantines,
        }

    def load_state(self, state: dict) -> None:
        self._strikes = {int(d): [float(t) for t in ts]
                         for d, ts in state.get("strikes", [])}
        self._state = {int(d): str(s) for d, s in state.get("state", [])}
        self._ok = {int(d): int(n) for d, n in state.get("ok", [])}
        self._counts = {int(d): int(n) for d, n in state.get("counts", [])}
        self.total_quarantines = int(state.get("total_quarantines", 0))


__all__ = ["QuarantineBoard", "QuarantinePolicy"]
