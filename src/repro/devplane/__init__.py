"""Elastic device plane: heterogeneous fleets, device churn, joint batched
device<->model assignment (DESIGN.md §11).

The paper allocates M identical, static devices.  A provider's fleet is
neither: hardware classes differ (chips per slice, clock speed, memory —
and which class a trial lands on changes its cost, hence which candidate
wins), and the fleet itself churns (scale-ups, decommissions, spot
preemptions).  This package makes both first-class:

  registry.py   device classes + per-class trial costs routed through the
                roofline cost model — the (device x model) cost matrix is
                genuinely 2-D (affine per class), not rank-1 c(x)/speed_d
  assign.py     the joint batched assignment solver: k simultaneously-free
                devices served by ONE scoring pass (per-class EIrate top-k,
                dense or sharded) + a greedy auction, provably identical to
                k sequential argmaxes on homogeneous fleets
  autoscale.py  queue-depth-driven fleet sizing (join/retire at event times)
  quarantine.py per-device strike scoreboard: quarantine-on-threshold,
                probational re-admission, flap detection (DESIGN.md §16)
  engine.py     DevPlaneEngine: StreamEngine + DeviceJoin/Leave/Preempt
                handling, 2-D costs, batched assignment, autoscale,
                device quarantine

Equivalence ladder (each rung tested): ``scheduler.simulate`` ==
churn-free ``StreamEngine`` == device-churn-free ``DevPlaneEngine``; and
batched == sequential assignment on homogeneous fleets.
"""

from .assign import greedy_assign  # noqa: F401
from .autoscale import AutoscalePolicy  # noqa: F401
from .engine import DevPlaneEngine  # noqa: F401
from .quarantine import QuarantineBoard, QuarantinePolicy  # noqa: F401
from .registry import (  # noqa: F401
    BASE_CLASS,
    REFERENCE_CHIPS,
    DeviceClass,
    DeviceClassRegistry,
    two_class_registry,
)
