"""Device-class registry: the heterogeneous half of the elastic device plane.

The paper treats the M devices as identical; a real provider's fleet mixes
generations and slice sizes (the multi-cloud model-search line of work shows
the *hardware class* changes which candidate wins).  A :class:`DeviceClass`
names one such class — chips per slice, a clock-speed multiplier vs the
reference slice, an optional memory capacity, and a fixed per-trial
``overhead`` (setup/compile seconds that do NOT shrink on a faster chip).

Cost routing (DESIGN.md §11): class c's trial cost for a model with base
cost ``c(x)`` (measured on the reference slice) is

    cost(c, x) = overhead_c + c(x) / rate_c,      rate_c = speed * chips/ref

an *affine* map per class.  With ``overhead > 0`` the (class x model) cost
matrix is genuinely 2-D — no ``speed_d`` vector factorizes it — which is
what makes the joint (device, model) assignment a real 2-D problem instead
of k independent argmaxes over a shared ranking.  For data-plane-backed
models, :meth:`DeviceClass.from_cost_model` calibrates ``rate``/``overhead``
from the roofline (``core.cost_model.CostModel.class_trial_seconds``)
instead of the nominal chip ratio.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.fleet import DEFAULT_CLASS, DeviceSlice, Fleet

REFERENCE_CHIPS = 16     # chips of the "rate 1.0" reference slice


@dataclass(frozen=True)
class DeviceClass:
    """One hardware class: what a slice of it costs per trial."""
    name: str
    chips: int = REFERENCE_CHIPS
    speed: float = 1.0              # clock multiplier vs the reference slice
    overhead: float = 0.0           # fixed per-trial seconds (host-bound)
    mem_gb: float | None = None     # slice HBM; None = unconstrained
    chip_scale: float | None = None  # throughput factor from chip count;
                                     # None = nominal chips/REFERENCE_CHIPS

    def __post_init__(self):
        if self.speed <= 0:
            raise ValueError(f"speed must be positive, got {self.speed}")
        if self.overhead < 0:
            raise ValueError(f"overhead must be >= 0, got {self.overhead}")

    @property
    def rate(self) -> float:
        """Effective throughput multiplier vs the reference slice."""
        scale = (self.chips / REFERENCE_CHIPS if self.chip_scale is None
                 else self.chip_scale)
        return self.speed * scale

    def cost_on(self, base_cost) -> np.ndarray:
        """c(x, d) for this class, vectorized over base costs."""
        return self.overhead + np.asarray(base_cost, dtype=float) / self.rate

    def fits(self, model_mem_gb: float | None) -> bool:
        """Memory gate: can a model with this HBM footprint run here?"""
        return (self.mem_gb is None or model_mem_gb is None
                or model_mem_gb <= self.mem_gb)

    @classmethod
    def from_cost_model(cls, name: str, cost_model, arch: str, shape: str,
                        steps: int, *, chips: int, speed: float = 1.0,
                        overhead: float = 30.0, mem_gb: float | None = None,
                        cfg=None) -> "DeviceClass":
        """Calibrate the class against the roofline: ``chip_scale`` is the
        measured step-time ratio reference-slice/this-slice for the given
        (arch, shape) cell — exact when the roofline is linear in chips,
        and still right when a probe says otherwise."""
        ref = cost_model.class_trial_seconds(
            arch, shape, steps, chips=REFERENCE_CHIPS, speed=1.0,
            overhead=0.0, cfg=cfg)
        here = cost_model.class_trial_seconds(
            arch, shape, steps, chips=chips, speed=1.0, overhead=0.0, cfg=cfg)
        return cls(name=name, chips=chips, speed=speed, overhead=overhead,
                   mem_gb=mem_gb, chip_scale=ref / here)


BASE_CLASS = DeviceClass(DEFAULT_CLASS)


class DeviceClassRegistry:
    """Name -> :class:`DeviceClass`, plus the cost-matrix/fleet factories
    the elastic engine consumes."""

    def __init__(self, classes=()):
        self._classes: dict[str, DeviceClass] = {}
        for c in classes:
            self.register(c)

    def register(self, cls: DeviceClass) -> DeviceClass:
        if cls.name in self._classes:
            raise ValueError(f"device class {cls.name!r} already registered")
        self._classes[cls.name] = cls
        return cls

    def __getitem__(self, name: str) -> DeviceClass:
        try:
            return self._classes[name]
        except KeyError:
            raise KeyError(f"unknown device class {name!r}; "
                           f"registered: {sorted(self._classes)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._classes

    def __len__(self) -> int:
        return len(self._classes)

    @property
    def names(self) -> list[str]:
        return sorted(self._classes)

    def rows(self, names) -> tuple[np.ndarray, np.ndarray]:
        """(rates, overheads) float32 rows for ``choose_mdmt_batch`` — one
        entry per name, order preserved."""
        rates = np.asarray([self[n].rate for n in names], np.float32)
        overheads = np.asarray([self[n].overhead for n in names], np.float32)
        return rates, overheads

    def cost_matrix(self, base_cost, names=None,
                    model_mem_gb=None) -> np.ndarray:
        """(C, n) per-class trial costs for base costs ``c(x)``; models that
        do not fit a class's memory get +inf there — which the dense class
        scorer (``ei.eirate_class_scores``) turns into a hard -inf
        exclusion.  The streaming engine does not consume the gate (its
        tenant blocks carry no per-model footprint); it serves explicit
        cost-matrix consumers such as offline assignment analysis."""
        names = self.names if names is None else list(names)
        base = np.asarray(base_cost, dtype=float)
        out = np.stack([self[n].cost_on(base) for n in names])
        if model_mem_gb is not None:
            mem = np.asarray(model_mem_gb, dtype=float)
            for i, n in enumerate(names):
                cap = self[n].mem_gb
                if cap is not None:
                    out[i, mem > cap] = np.inf
        return out

    def build_fleet(self, counts) -> Fleet:
        """A Fleet from ``[(class_name, count), ...]`` (or a dict): slice
        ids are assigned in iteration order, ``speed`` is the class's
        effective rate, ``cls`` the class name."""
        items = counts.items() if isinstance(counts, dict) else counts
        slices = []
        for name, count in items:
            c = self[name]
            for _ in range(count):
                slices.append(DeviceSlice(
                    len(slices), c.chips, c.rate, cls=name))
        return Fleet(slices)

    @classmethod
    def from_fleet(cls, fleet: Fleet) -> "DeviceClassRegistry":
        """Synthesize a registry from an existing fleet: one zero-overhead
        class per distinct ``cls`` name (rank-1 costs — the backward-
        compatible default when no registry is supplied)."""
        reg = cls()
        for s in fleet.slices:
            if s.cls in reg:
                if reg[s.cls].rate != s.speed:
                    raise ValueError(
                        f"slices of class {s.cls!r} disagree on speed; "
                        "register explicit DeviceClasses instead")
                continue
            reg.register(DeviceClass(
                name=s.cls, chips=s.chips, speed=s.speed, chip_scale=1.0))
        return reg


def two_class_registry(fast_speed: float = 2.0, *, overhead: float = 0.0,
                       chips: int = REFERENCE_CHIPS) -> DeviceClassRegistry:
    """The benchmark/test fixture: a ``slow`` reference class and a ``fast``
    class at ``fast_speed``x, optionally with a per-trial overhead (making
    the cost matrix genuinely 2-D)."""
    return DeviceClassRegistry([
        DeviceClass("slow", chips=chips, speed=1.0, overhead=overhead,
                    chip_scale=1.0),
        DeviceClass("fast", chips=chips, speed=fast_speed, overhead=overhead,
                    chip_scale=1.0),
    ])


__all__ = ["DeviceClass", "DeviceClassRegistry", "BASE_CLASS",
           "REFERENCE_CHIPS", "two_class_registry"]
