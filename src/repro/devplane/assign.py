"""Joint batched device<->model assignment (DESIGN.md §11).

When k devices free simultaneously (a completion wave, a join, t=0), the
sequential engine runs k scoring passes — GP readout + whole-pool EIrate +
argmax, once per device.  But between those k launches *nothing the scores
depend on changes* except the ``selected`` mask: no observation folds, no
incumbent moves.  So the k decisions are exactly a greedy assignment over a
single frozen (device-class x model) EIrate matrix — which one scoring pass
(``ControlPlane.choose_mdmt_batch``: per-class top-k, sharded or dense)
provides.

:func:`greedy_assign` is that solver, host-side over the (C, k) candidate
lists.  Order of assignment is by *score*, greedily: repeatedly give the
globally best (device, model) pair its launch, mask the model, repeat — a
1-item-per-round auction.  Tie-breaks are fully deterministic: higher score
first, then lower model id, then earlier device in launch-priority order.

Equivalence contract (tested): on a homogeneous fleet every device shares
one candidate row, so round r hands the r-th ranked candidate to the r-th
device in priority order — the *identical* trial sequence the sequential
per-device argmax produces.  On a heterogeneous fleet the greedy pick
maximizes EIrate jointly (a fast device outbids a slow one for the same
model), which sequential stack order cannot do.

Sufficiency of per-class top-k: a batch assigns at most k models, so at
most k-1 are masked before any device's last scan — a per-class list of
length k can never run dry while unselected models remain.
"""

from __future__ import annotations

import numpy as np

NEG_FLOOR = -1e29   # at/below this a candidate is unlaunchable (matches the
                    # sequential chooser's None cutoff in ControlPlane)


def greedy_assign(values, ids, device_class_rows) -> list[tuple[int, int]]:
    """Solve the k-device joint assignment over per-class top-k candidates.

    Args:
      values: (C, k) per-class candidate scores, descending (lowest-id ties
        first — ``lax.top_k`` order).
      ids: (C, k) the candidates' global model ids.
      device_class_rows: length-k sequence; entry j is the class row (into
        ``values``/``ids``) of the j-th device in launch-priority order.

    Returns:
      ``[(device_pos, model_id), ...]`` in assignment (score) order;
      ``device_pos`` indexes ``device_class_rows``.  Devices whose class
      row runs out of launchable candidates are left out (the pool is
      exhausted for them, the sequential engine would have stopped too).
    """
    values = np.asarray(values)
    ids = np.asarray(ids)
    C, k = values.shape
    taken: set[int] = set()
    ptr = [0] * C                     # per-class scan position
    unassigned = list(range(len(device_class_rows)))
    out: list[tuple[int, int]] = []

    def head(c: int) -> tuple[float, int] | None:
        """First launchable candidate of class row c, skipping taken."""
        p = ptr[c]
        while p < k:
            v, g = float(values[c, p]), int(ids[c, p])
            if not np.isfinite(v) or v <= NEG_FLOOR:
                return None           # descending: the rest is worse
            if g not in taken:
                ptr[c] = p
                return v, g
            p += 1
        ptr[c] = p
        return None

    while unassigned:
        best = None                   # (-score, model_id, pos_rank, pos)
        for rank, pos in enumerate(unassigned):
            cand = head(device_class_rows[pos])
            if cand is None:
                continue
            key = (-cand[0], cand[1], rank)
            if best is None or key < best[0]:
                best = (key, pos, cand[1])
        if best is None:
            break                     # nobody has a launchable candidate
        _, pos, model = best
        taken.add(model)
        unassigned.remove(pos)
        out.append((pos, model))
    return out


__all__ = ["greedy_assign", "NEG_FLOOR"]
