"""Queue-depth-driven fleet autoscaling (DESIGN.md §11).

The signal is *backlog per device*: the number of live, unselected models
(work the policy still wants to run — warm-start entries and future EIrate
picks alike) divided by the in-fleet device count.  Sustained backlog above
``high_backlog`` joins a device of ``join_class``; backlog below
``low_backlog`` with an idle device retires the slowest free slice.  A
``cooldown`` between actions damps oscillation, and ``min_devices`` /
``max_devices`` bound the fleet.  Everything is a pure function of engine
state at event times, so autoscaled replays are deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class AutoscalePolicy:
    """Thresholds + bounds; the engine calls :meth:`decide` after every
    event and applies the returned action (see module docstring)."""

    high_backlog: float = 4.0    # unselected live models per device => join
    low_backlog: float = 1.0     # below this with an idle device => leave
    cooldown: float = 10.0       # min seconds between actions
    join_class: str = "base"     # device class joins are drawn from
    min_devices: int = 1
    max_devices: int = 64
    # cooldown clock — run state, not configuration: init=False so
    # dataclasses.replace() yields a fresh clock (the engine copies the
    # policy at construction; a caller-held instance is never mutated)
    _last_action: float = field(default=float("-inf"), repr=False,
                                init=False)

    def __post_init__(self):
        if self.low_backlog >= self.high_backlog:
            raise ValueError("low_backlog must be < high_backlog")
        if not 1 <= self.min_devices <= self.max_devices:
            raise ValueError("need 1 <= min_devices <= max_devices")

    def ready(self, t: float) -> bool:
        """Cheap cooldown precheck — lets the engine skip computing the
        backlog (an O(capacity) scan) on the common no-action path."""
        return t - self._last_action >= self.cooldown

    def decide(self, t: float, *, backlog: int, num_devices: int,
               num_free: int) -> str | None:
        """``"join"``, ``"leave"``, or None.  Mutates the cooldown clock
        when an action is returned."""
        if num_devices < 1 or not self.ready(t):
            return None
        per_device = backlog / num_devices
        if per_device > self.high_backlog and num_devices < self.max_devices:
            self._last_action = t
            return "join"
        if (per_device < self.low_backlog and num_free > 0
                and num_devices > self.min_devices):
            self._last_action = t
            return "leave"
        return None


__all__ = ["AutoscalePolicy"]
