"""The elastic streaming engine: device churn + joint batched assignment.

:class:`DevPlaneEngine` extends :class:`repro.stream.engine.StreamEngine`
with the device half of the service (DESIGN.md §11):

  DeviceJoin     -> ``Fleet.join`` appends a slice of the event's class; it
                    enters the free pool and the next launch pass uses it
  DeviceLeave    -> permanent decommission; the in-flight trial dies exactly
                    like a slice failure (model back to L \\ L(t)) but the
                    slice never recovers
  DevicePreempt  -> the in-flight trial is evicted and re-queued like a
                    slice failure; the slice is immediately schedulable
  autoscale      -> a queue-depth-driven policy (``autoscale.py``) joins /
                    retires devices at event times

Costs come from a :class:`~repro.devplane.registry.DeviceClassRegistry`:
durations and EIrate denominators are the per-class affine
``overhead_c + c(x)/rate_c``, so the (free devices x live models) score
matrix is genuinely 2-D and the launch decision is an *assignment*, not an
argmax.

``assign="batched"`` solves that assignment for ALL simultaneously-free
devices in one scoring pass (``ControlPlane.choose_mdmt_batch`` — per-class
top-k, dense or sharded — feeding ``assign.greedy_assign``) instead of one
pass per device.  ``assign="sequential"`` keeps per-device decisions but
scores them with the same 2-D costs (a batch of one), so the two modes are
decision-equivalent on homogeneous fleets (tested) and differ only where
heterogeneity makes joint assignment genuinely better.

With a homogeneous zero-overhead registry, no device events, and
``assign="sequential"`` the engine IS the base ``StreamEngine`` — byte-
identical trial sequences (tests/test_devplane.py), the same discipline as
the churn-free == ``scheduler.simulate`` contract.
"""

from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from repro.stream.engine import StreamEngine
from repro.stream.workload import DeviceJoin, DeviceLeave, DevicePreempt

from .assign import greedy_assign
from .autoscale import AutoscalePolicy
from .quarantine import QuarantineBoard, QuarantinePolicy
from .registry import DeviceClassRegistry

ASSIGN_MODES = ("batched", "sequential")


class DevPlaneEngine(StreamEngine):
    """Streaming GP-EI over an elastic, heterogeneous fleet (module
    docstring).  Extra knobs on top of StreamEngine:

    * ``registry`` — device classes; defaults to a zero-overhead rank-1
      registry synthesized from the fleet (backward-compatible costs).
    * ``assign`` — ``"batched"`` (one scoring pass per free wave) or
      ``"sequential"`` (one per device).  Non-mdmt policies always take the
      base per-tenant path.
    * ``autoscale`` — an :class:`AutoscalePolicy`, or None.
    * ``speed_oblivious`` — score as if every device were the reference
      class (durations stay real); the regret baseline the device-aware
      plane is measured against.
    * ``quarantine`` — a :class:`QuarantinePolicy`, or None.  Activates
      the per-device strike scoreboard (DESIGN.md §16): devices that keep
      timing out or failing are pulled from the launchable pool, re-
      admitted on probation, and subtracted from the device count the
      autoscale controller sees (sick capacity triggers scale-up).
    """

    def __init__(self, fleet, policy: str = "mdmt", *,
                 registry: DeviceClassRegistry | None = None,
                 assign: str = "batched",
                 autoscale: AutoscalePolicy | None = None,
                 speed_oblivious: bool = False,
                 quarantine: QuarantinePolicy | None = None,
                 **kw):
        super().__init__(fleet, policy, **kw)
        if assign not in ASSIGN_MODES:
            raise ValueError(
                f"assign must be one of {ASSIGN_MODES}, got {assign!r}")
        self.registry = registry or DeviceClassRegistry.from_fleet(fleet)
        self.assign = assign
        # private copy with a fresh cooldown clock: sharing one policy
        # object across engines must not leak run state between replays
        self.autoscale = (None if autoscale is None
                          else dataclasses.replace(autoscale))
        self.speed_oblivious = speed_oblivious
        if autoscale is not None and autoscale.join_class not in self.registry:
            raise ValueError(
                f"autoscale join_class {autoscale.join_class!r} is not in "
                "the registry")
        for s in fleet.slices:
            if s.cls not in self.registry:
                raise ValueError(f"slice {s.slice_id} has unregistered "
                                 f"device class {s.cls!r}")
        self.quarantine = (QuarantineBoard(quarantine)
                           if quarantine is not None else None)
        self._autoscale_joins = 0
        self._autoscale_leaves = 0
        self._scoring_passes = 0

    # ---- costs -------------------------------------------------------------

    def _duration_on(self, model: int, s) -> float:
        """The registry's 2-D cost: overhead + base/rate for the slice's
        class (reduces to the base engine's c(x)/speed for zero-overhead
        synthesized registries)."""
        return float(self.registry[s.cls].cost_on(self.cp.cost[model]))

    # ---- device lifecycle --------------------------------------------------

    def _ingest(self, ev) -> None:
        if isinstance(ev, DeviceJoin):
            self._push(ev.at, "dev_join", (ev,))
        elif isinstance(ev, DeviceLeave):
            self._push(ev.at, "dev_leave", (ev.slice_id,))
        elif isinstance(ev, DevicePreempt):
            self._push(ev.at, "dev_preempt", (ev.slice_id,))
        else:
            super()._ingest(ev)

    def _dispatch_extra(self, kind: str, payload: tuple) -> None:
        if kind == "dev_join":
            self._handle_dev_join(*payload)
        elif kind == "dev_leave":
            self._handle_dev_leave(*payload)
        elif kind == "dev_preempt":
            self._handle_dev_preempt(*payload)
        elif kind == "probation":
            self._handle_probation(*payload)
        else:
            super()._dispatch_extra(kind, payload)

    def _join_device(self, cls_name: str, chips: int | None = None):
        c = self.registry[cls_name]
        s = self.fleet.join(chips or c.chips, c.rate, cls=cls_name)
        self._free.append(s.slice_id)
        self.telemetry.on_device_join(self._t, s.slice_id, s.speed)
        return s

    def _handle_dev_join(self, ev: DeviceJoin) -> None:
        # the registry is authoritative for cost semantics; a trace that
        # declares a different speed for the class is a config error, not
        # something to silently override
        c = self.registry[ev.cls]
        if ev.speed != c.rate:
            raise ValueError(
                f"DeviceJoin speed {ev.speed} disagrees with registered "
                f"class {ev.cls!r} rate {c.rate}")
        self._join_device(ev.cls, ev.chips)

    def _handle_dev_leave(self, slice_id: int) -> None:
        if slice_id >= len(self.fleet.slices):
            return                     # trace id math raced autoscale joins
        s = self.fleet.slices[slice_id]
        if s.retired:
            return                     # duplicate leave in the trace
        killed = self.fleet.leave(slice_id)
        if killed is not None:
            self._kill_trial(killed)
        elif slice_id in self._free:
            self._free.remove(slice_id)
        if self.quarantine is not None:
            self.quarantine.retire(slice_id)
        self.telemetry.on_device_leave(self._t, slice_id)

    def _handle_dev_preempt(self, slice_id: int) -> None:
        if slice_id >= len(self.fleet.slices):
            return                     # trace id math raced autoscale joins
        s = self.fleet.slices[slice_id]
        if s.retired or not s.healthy:
            return                     # raced a leave / is already down
        killed = self.fleet.preempt(slice_id)
        if killed is not None:
            self._kill_trial(killed, preempted=True)
            # the slice survives the eviction: immediately schedulable
            # (unless quarantined — the scoreboard outranks the eviction)
            if slice_id not in self._free and not self._is_quarantined(
                    slice_id):
                self._free.append(slice_id)

    # ---- device quarantine (DESIGN.md §16) ---------------------------------

    def _device_strike(self, device: int, *, reason: str) -> bool:
        """Feed the strike scoreboard; True = device newly quarantined
        (the supervision hooks then keep it out of the free pool)."""
        board = self.quarantine
        if board is None or device >= len(self.fleet.slices):
            return False
        s = self.fleet.slices[device]
        if s.retired:
            return False
        newly = board.strike(device, self._t)
        if newly:
            if device in self._free:
                self._free.remove(device)
            self._push(self._t + board.policy.duration,
                       "probation", (device,))
            count = board.quarantine_count(device)
            self.telemetry.on_quarantine(self._t, device)
            if self.health is not None:
                self.health.on_quarantine(self._t, self.event_index,
                                          device, count=count)
            if self.metrics is not None:
                self.metrics.counter("engine.devices_quarantined",
                                     labels={"cls": s.cls}).inc()
            if self.forensics is not None:
                self.forensics.on_incident(
                    kind="device_quarantine", device=int(device),
                    reason=reason, count=int(count))
        return board.is_quarantined(device)

    def _device_ok(self, device: int) -> None:
        if self.quarantine is not None:
            self.quarantine.on_success(device)

    def _is_quarantined(self, device: int) -> bool:
        return (self.quarantine is not None
                and self.quarantine.is_quarantined(device))

    def _handle_probation(self, device: int) -> None:
        board = self.quarantine
        if board is None or board.state(device) != "quarantined":
            return                     # retired / already re-quarantined
        board.begin_probation(device)
        if device >= len(self.fleet.slices):
            return
        s = self.fleet.slices[device]
        # dual-gate with recover: a device that failed *while* quarantined
        # re-enters only via whichever of (recover, probation) fires last
        if (s.healthy and not s.retired and s.current_trial is None
                and device not in self._free):
            self._free.append(device)

    # ---- snapshot / restore (event sourcing, DESIGN.md §12) ----------------

    def _encode_payload(self, kind: str, payload: tuple) -> list:
        if kind == "dev_join":
            ev = payload[0]
            return [ev.at, ev.chips, ev.speed, ev.cls]
        if kind in ("dev_leave", "dev_preempt", "probation"):
            return list(payload)
        return super()._encode_payload(kind, payload)

    def _decode_payload(self, kind: str, data: list) -> tuple:
        if kind == "dev_join":
            at, chips, speed, cls = data
            return (DeviceJoin(at=at, chips=chips, speed=speed, cls=cls),)
        if kind in ("dev_leave", "dev_preempt", "probation"):
            return tuple(data)
        return super()._decode_payload(kind, data)

    def _snapshot_extra(self) -> dict:
        return {
            "autoscale_last_action": (None if self.autoscale is None
                                      else self.autoscale._last_action),
            "autoscale_joins": self._autoscale_joins,
            "autoscale_leaves": self._autoscale_leaves,
            "scoring_passes": self._scoring_passes,
            "quarantine": (self.quarantine.state_dict()
                           if self.quarantine is not None else None),
        }

    def _restore_extra(self, extra: dict) -> None:
        if self.autoscale is not None:
            last = extra["autoscale_last_action"]
            self.autoscale._last_action = (float("-inf") if last is None
                                           else last)
        self._autoscale_joins = extra["autoscale_joins"]
        self._autoscale_leaves = extra["autoscale_leaves"]
        self._scoring_passes = extra["scoring_passes"]
        if self.quarantine is not None and extra.get("quarantine"):
            self.quarantine.load_state(extra["quarantine"])

    def _capacity_extra(self) -> dict:
        """Elastic-fleet counters for the capacity plane
        (``capacity.autoscale_joins`` ... gauges, obs/accounting.py)."""
        return {
            "autoscale_joins": self._autoscale_joins,
            "autoscale_leaves": self._autoscale_leaves,
            "scoring_passes": self._scoring_passes,
            "devices_quarantined": (self.quarantine.quarantined_now()
                                    if self.quarantine is not None else 0),
        }

    # ---- autoscale ---------------------------------------------------------

    def _post_event(self, kind: str) -> None:
        if self.autoscale is None or not self.autoscale.ready(self._t):
            return                     # skip the O(capacity) backlog scan
        backlog = self._backlog()
        # quarantined devices are not serving capacity: report only the
        # in-service count so a sick fleet looks small and scales up
        quarantined = (self.quarantine.quarantined_now()
                       if self.quarantine is not None else 0)
        in_service = max(self.fleet.num_devices - quarantined,
                         1 if self.fleet.num_devices else 0)
        action = self.autoscale.decide(
            self._t, backlog=backlog, num_devices=in_service,
            num_free=len(self._free))
        if action == "join":
            self._join_device(self.autoscale.join_class)
            self._autoscale_joins += 1
        elif action == "leave":
            # retire the slowest idle slice (ties: lowest id)
            sid = min(self._free,
                      key=lambda d: (self.fleet.slices[d].speed, d))
            self.fleet.leave(sid)
            self._free.remove(sid)
            self.telemetry.on_device_leave(self._t, sid)
            self._autoscale_leaves += 1

    # ---- the joint batched launch pass -------------------------------------

    def _free_priority_order(self) -> list[int]:
        """Free-list indices in launch-priority order: the exact sequence
        ``_pick_free_index`` would visit as devices are consumed — the
        solver's device tie-break order, which is what keeps batched ==
        sequential on homogeneous fleets."""
        idxs = list(range(len(self._free)))
        if self.launch_order == "fastest":
            idxs.sort(key=lambda i:
                      (-self.fleet.slices[self._free[i]].speed, -i))
        else:
            idxs.reverse()
        return idxs

    def _try_launch(self, horizon: float) -> None:
        if self.policy != "mdmt":
            return super()._try_launch(horizon)
        while self._free:
            if self._t >= horizon:
                return
            if self._pop_pending_launch():
                continue               # warm-start entries keep the base
                                       # one-at-a-time semantics
            order = self._free_priority_order()
            if self.assign == "sequential":
                order = order[:1]      # a batch of one = per-device decision
            devices = [self._free[i] for i in order]
            # class rows: unique class names in first-appearance order
            cls_names: list[str] = []
            rows: list[int] = []
            for d in devices:
                name = self.fleet.slices[d].cls
                if name not in cls_names:
                    cls_names.append(name)
                rows.append(cls_names.index(name))
            if self.speed_oblivious:
                rates = np.ones(len(cls_names), np.float32)
                overheads = np.zeros(len(cls_names), np.float32)
            else:
                rates, overheads = self.registry.rows(cls_names)

            t0 = _time.perf_counter()
            with self.tracer.span("decide", batch=len(devices),
                                  classes=len(cls_names)):
                vals, gids = self.cp.choose_mdmt_batch(
                    rates, overheads, k=len(devices),
                    class_names=cls_names)
            dt = _time.perf_counter() - t0
            self._decision_seconds += dt
            self._decisions += 1
            self._scoring_passes += 1
            if self.metrics is not None:
                self._m_decision_s.observe(dt)
                self.metrics.counter("engine.scoring_passes").inc()

            with self.tracer.span("assign", batch=len(devices)):
                pairs = greedy_assign(vals, gids, rows)
            if not pairs:
                return                 # pool exhausted for every free device
            for pos, model in pairs:
                # indices shift as devices launch: resolve by slice id
                self._launch_on(self._free.index(devices[pos]), model, -1)
                self._policy_launches += 1
            if len(pairs) < len(devices):
                return                 # the leftovers found nothing either


__all__ = ["DevPlaneEngine", "ASSIGN_MODES"]
