"""Quickstart: the paper's MM-GP-EI scheduler in one page.

Builds the Fig-5 synthetic workload (50 tenants x 50 models, Matérn-5/2
prior), runs the three policies of Section 6 on 4 shared devices, and prints
the global-happiness metrics.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    POLICIES,
    final_regret,
    regret_curves,
    simulate,
    synthetic_matern_problem,
)


def main() -> None:
    problem = synthetic_matern_problem(num_users=20, num_models_per_user=30, seed=0)
    print(f"workload: {problem.name}  ({problem.num_users} tenants, "
          f"{problem.num_models} models, 4 devices)\n")

    results = {}
    for policy in POLICIES:
        res = simulate(problem, policy, num_devices=4, seed=0)
        curves = regret_curves(res)
        results[policy] = (final_regret(res), curves.time_to_instantaneous(0.01))
        print(f"{policy:12s}  cumulative regret = {results[policy][0]:8.1f}   "
              f"time to inst. regret 0.01 = {results[policy][1]:6.1f}")

    rr, mdmt = results["round_robin"][1], results["mdmt"][1]
    print(f"\nMM-GP-EI reaches regret 0.01 {rr / mdmt:.2f}x faster than "
          f"round robin (paper Fig. 2/5 qualitative claim).")


if __name__ == "__main__":
    main()
