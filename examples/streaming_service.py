"""Streaming multi-tenant GP-EI service demo: tenants churn, the fleet serves.

Generates a seeded churn trace (Poisson arrivals, heavy-tailed session
lengths, Zipf-skewed candidate-set sizes), replays it through the streaming
engine over an 8-slice fleet with admission control, and prints the
service-level telemetry — including per-device and speed-weighted
utilization.  ``--device-churn`` switches to the elastic device plane
(DESIGN.md §11): a 2-speed-class fleet with device joins/leaves/preemptions
overlaid on the tenant churn, joint batched assignment, and an autoscaler.
``--crash-at N`` demos the event-sourced crash recovery (DESIGN.md §12):
the run is killed at processed event N, rebuilt from its durable log +
newest snapshot, resumed, and compared against an uninterrupted run.
``--trace`` runs with the obs planes live (decision-path spans + metrics
registry + windowed export, DESIGN.md §13-§14), ``--health`` attaches the
SLO burn-rate / watchdog monitor, ``--forensics`` records per-decision
attribution, and ``--capacity`` attaches the resource accountant
(posterior bytes, shard occupancy, projected-bytes feed — DESIGN.md §15);
any of them triggers a bare twin re-run to verify the observation-only
guarantee: both trial sequences must be byte-identical.
``--chaos`` runs the failure-domain hardening demo (DESIGN.md §16): a
seeded chaos overlay (trial hangs, poisoned losses, slice flakes,
permanent device losses) on the tenant churn, served by the hardened
engine (trial supervision: timeout/retry/backoff; device quarantine) —
then verifies on the trace's failure-free twin that supervision with no
chaos is byte-identical to the bare, supervision-off engine (deadlines
always lose the race against real completions).
``--report-dir PATH`` renders the per-run experiment directory
(``PATH/<run_id>/`` with summary.json, timeline.csv, self-contained
report.html, plus alerts.jsonl / forensics.jsonl when those planes ran).
Used by CI as a smoke test:

  PYTHONPATH=src python examples/streaming_service.py --events 50
  PYTHONPATH=src python examples/streaming_service.py --events 50 --device-churn
  PYTHONPATH=src python examples/streaming_service.py --events 50 --crash-at 40
  PYTHONPATH=src python examples/streaming_service.py --events 50 --chaos
  PYTHONPATH=src python examples/streaming_service.py --events 60 --trace \\
      --health --forensics --capacity --report-dir obs_report
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile
import time

from repro.core.fleet import Fleet
from repro.stream import (EventLog, FaultInjector, SimulatedCrash,
                          StreamEngine, chaos_trace, device_churn_trace,
                          poisson_churn_trace, recover)


def demo_crash_recovery(make_engine, trace, crash_at, ref_eng, ref_res):
    """Kill a durable run at processed event ``crash_at``, recover from
    the log + newest snapshot, resume, and verify the replay reproduces
    the uninterrupted run (``ref_res``) exactly — the DESIGN.md §12 oracle,
    live."""
    with tempfile.TemporaryDirectory() as d:
        logdir, snapdir = f"{d}/log", f"{d}/snapshots"
        eng = make_engine(log=EventLog(logdir), snapshot_root=snapdir,
                          snapshot_every=16,
                          fault=FaultInjector(crash_at, "before"))
        try:
            eng.run(trace)
            print(f"\n--crash-at {crash_at}: the run only processed "
                  f"{eng.event_index} events — nothing to crash")
            return
        except SimulatedCrash as e:
            print(f"\ncrash injected: {e}")
        finally:
            eng.log.close()

        eng2, resumed_from = recover(make_engine, snapdir,
                                     EventLog.load(logdir))
        print(f"recovered from snapshot at event {resumed_from} "
              f"(+ log replay); resuming...")
        res2 = eng2.resume()

        same_trials = ([dataclasses.astuple(t) for t in res2.trials]
                       == [dataclasses.astuple(t) for t in ref_res.trials])
        same_summary = (res2.telemetry.summary()
                        == ref_res.telemetry.summary())
        print(f"replayed {eng2.event_index - resumed_from} events: "
              f"trials identical={same_trials}, "
              f"telemetry identical={same_summary}")
        assert same_trials and same_summary, \
            "crash recovery diverged from the uninterrupted run"


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--events", type=int, default=400,
                   help="approximate external events in the trace "
                        "(one session = arrive + depart)")
    p.add_argument("--slices", type=int, default=8)
    p.add_argument("--policy", choices=("mdmt", "round_robin", "random"),
                   default="mdmt")
    p.add_argument("--max-live-models", type=int, default=120,
                   help="admission-control cap (0 disables)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--device-churn", action="store_true",
                   help="elastic 2-speed-class fleet with device churn + "
                        "autoscale (repro.devplane)")
    p.add_argument("--chaos", action="store_true",
                   help="seeded chaos overlay (hangs/poisons/flakes/losses) "
                        "served by the hardened engine: trial supervision + "
                        "device quarantine (DESIGN.md §16); verifies "
                        "supervision-off byte-identity on the failure-free "
                        "twin")
    p.add_argument("--crash-at", type=int, default=None, metavar="N",
                   help="kill the engine at processed event N, recover "
                        "from the durable log + snapshots, resume, and "
                        "verify the replay matches an uninterrupted run "
                        "(DESIGN.md §12)")
    p.add_argument("--telemetry-json", default=None,
                   help="optional path for the full telemetry dump")
    p.add_argument("--trace", action="store_true",
                   help="run with decision-path tracing + metrics enabled, "
                        "then verify against an untraced twin run that "
                        "tracing changed no decision (DESIGN.md §13)")
    p.add_argument("--health", action="store_true",
                   help="attach the SLO burn-rate / watchdog monitor "
                        "(repro.obs.HealthMonitor, DESIGN.md §14); alerts "
                        "print at the end and land in the report")
    p.add_argument("--forensics", action="store_true",
                   help="record per-decision attribution (winner/runner-up "
                        "EIrate, margin, uniform-cost counterfactual — "
                        "DESIGN.md §14)")
    p.add_argument("--capacity", action="store_true",
                   help="attach the capacity accountant (per-tenant "
                        "posterior bytes, shard occupancy, projected-bytes "
                        "memory watchdog feed — DESIGN.md §15)")
    p.add_argument("--report-dir", default=None, metavar="PATH",
                   help="write the per-run experiment directory "
                        "(PATH/<run_id>/ with summary.json, timeline.csv, "
                        "report.html) — works with or without --trace")
    args = p.parse_args()
    slo = {"device_utilization": 0.25, "ttfo_p99": 100.0}

    if args.chaos and args.device_churn:
        p.error("--chaos and --device-churn are separate demos")

    sessions = max(1, args.events // 2)
    if args.chaos:
        trace = chaos_trace(
            num_sessions=sessions, arrival_rate=1.0, seed=args.seed,
            initial_slices=args.slices, hang_rate=0.15, poison_rate=0.10,
            flake_rate=0.05, loss_rate=0.02,
            m_min=2, m_max=16, session_scale=25.0)
    elif args.device_churn:
        from repro.devplane import (AutoscalePolicy, DevPlaneEngine,
                                    two_class_registry)
        trace = device_churn_trace(
            num_sessions=sessions, arrival_rate=1.0, seed=args.seed,
            initial_slices=args.slices,
            join_classes=(("fast", 32, 2.0), ("slow", 32, 1.0)),
            join_rate=0.05, leave_rate=0.02, preempt_rate=0.03,
            m_min=2, m_max=16, session_scale=25.0)
    else:
        trace = poisson_churn_trace(
            num_sessions=sessions, arrival_rate=1.0, seed=args.seed,
            m_min=2, m_max=16, session_scale=25.0,
            num_failure_slices=min(2, args.slices))
    print(f"trace: {trace.name} ({trace.num_events} events, "
          f"{trace.num_sessions} sessions)")

    def make_engine(**kw):
        # a fresh engine (and fresh Fleet — it is mutated) per run: the
        # crash demo needs one for the reference, crashed, and recovered runs
        if args.trace and "tracer" not in kw:
            # fresh obs planes per engine — spans/metrics never mix across
            # the reference, crashed, and recovered runs of the crash demo
            from repro.obs import MetricsExporter, MetricsRegistry, Tracer
            kw["tracer"] = Tracer(enabled=True)
            kw["metrics"] = MetricsRegistry()
            kw["exporter"] = MetricsExporter(kw["metrics"], window=20.0)
        if args.health and "health" not in kw:
            from repro.obs import HealthMonitor
            kw["health"] = HealthMonitor(slo=slo, window=20.0)
        if args.forensics and "forensics" not in kw:
            from repro.obs import ForensicsRecorder
            kw["forensics"] = ForensicsRecorder()
        if args.capacity and "accounting" not in kw:
            from repro.obs import CapacityAccountant, MetricsRegistry
            if "metrics" not in kw:
                kw["metrics"] = MetricsRegistry()
            kw["accounting"] = CapacityAccountant(kw["metrics"], window=20.0)
        if args.chaos:
            # the hardened engine (DESIGN.md §16); the bare twin passes
            # timeout_factor=None / quarantine=None through kw to disable
            from repro.devplane import DevPlaneEngine, QuarantinePolicy
            kw.setdefault("timeout_factor", 2.5)
            kw.setdefault("max_retries", 2)
            kw.setdefault("retry_backoff", 1.0)
            kw.setdefault("quarantine",
                          QuarantinePolicy(threshold=3, window=60.0,
                                           duration=30.0))
            fleet = Fleet.partition_pod(total_chips=32 * args.slices,
                                        num_slices=args.slices)
            return DevPlaneEngine(
                fleet, args.policy, seed=args.seed,
                max_live_models=args.max_live_models or None, **kw)
        if args.device_churn:
            reg = two_class_registry(2.0, overhead=0.5, chips=32)
            half = max(1, args.slices // 2)
            fleet = reg.build_fleet([("slow", args.slices - half),
                                     ("fast", half)])
            return DevPlaneEngine(
                fleet, args.policy, seed=args.seed, registry=reg,
                assign="batched", launch_order="fastest",
                autoscale=AutoscalePolicy(join_class="fast", cooldown=5.0,
                                          max_devices=2 * args.slices),
                max_live_models=args.max_live_models or None, **kw)
        fleet = Fleet.partition_pod(total_chips=32 * args.slices,
                                    num_slices=args.slices)
        return StreamEngine(
            fleet, args.policy, seed=args.seed,
            max_live_models=args.max_live_models or None, **kw)

    t0 = time.perf_counter()
    eng = make_engine()
    res = eng.run(trace)
    wall = time.perf_counter() - t0

    if args.crash_at is not None:
        demo_crash_recovery(make_engine, trace, args.crash_at, eng, res)

    s = res.telemetry.summary()
    print(f"\nreplayed in {wall:.2f}s wall "
          f"({res.decisions} decisions, "
          f"{1e6 * res.decision_seconds / max(res.decisions, 1):.0f} µs each)")
    print(json.dumps(s, indent=2, sort_keys=True))
    per_dev = res.telemetry.per_device()
    print("\nper-device utilization (busy / in-service window):")
    for d in sorted(per_dev):
        pd = per_dev[d]
        left = "-" if pd["left"] is None else f"{pd['left']:.1f}"
        print(f"  slice {d:3d}  speed {pd['speed']:.1f}  "
              f"window [{pd['joined']:.1f}, {left}]  "
              f"trials {pd['trials']:3d}  util {pd['utilization']:.3f}")
    if args.telemetry_json:
        path = res.telemetry.to_json(
            args.telemetry_json, metrics=eng.metrics,
            alerts=eng.health.alerts if args.health else None)
        print(f"telemetry -> {path}")

    if args.health:
        by_kind: dict[str, int] = {}
        for a in eng.health.alerts:
            by_kind[a.kind] = by_kind.get(a.kind, 0) + 1
        print(f"\nhealth: {len(eng.health.alerts)} alerts "
              f"{json.dumps(by_kind, sort_keys=True)}")
        for a in eng.health.alerts[:5]:
            print(f"  [{a.severity}] t={a.t:.1f} {a.kind} "
                  f"subject={a.subject} {json.dumps(a.detail)}")

    if args.forensics:
        recs = eng.forensics.records
        flips = sum(1 for r in recs
                    if (r.get("uniform_cost") or {}).get("changes_pick"))
        print(f"\nforensics: {len(recs)} decisions recorded, "
              f"{flips} flip under uniform cost")
        if recs:
            print("  sample:", json.dumps(recs[0]))

    if args.capacity:
        last = eng.accounting.latest() or {}
        print(f"\ncapacity: {len(eng.accounting.samples)} samples; final "
              f"gp_bytes={last.get('gp_bytes')} "
              f"projected={last.get('gp_bytes_projected')} "
              f"imbalance={last.get('load_imbalance')}")

    if args.chaos:
        print(f"\nchaos: trials_timed_out={s['trials_timed_out']} "
              f"trials_retried={s['trials_retried']} "
              f"devices_quarantined={s['devices_quarantined']} "
              f"observations_rejected={s['observations_rejected']}")
        # supervision is decision-neutral when nothing fails (DESIGN.md
        # §16): on the failure-free twin trace, the hardened engine and a
        # bare supervision-off engine must be byte-identical — every
        # deadline loses the race against its real completion
        twin_trace = trace.twin()
        hardened = make_engine().run(twin_trace)
        bare = make_engine(timeout_factor=None, quarantine=None).run(
            twin_trace)
        same = ([dataclasses.astuple(t) for t in hardened.trials]
                == [dataclasses.astuple(t) for t in bare.trials])
        print(f"failure-free twin ({twin_trace.num_events} events): "
              f"supervision-on == supervision-off byte-identical={same}")
        assert same, "supervision changed a decision on a chaos-free trace"

    if args.trace or args.health or args.forensics or args.capacity:
        # the observation-only guarantee (DESIGN.md §13-§15): a bare twin
        # of the same run must make byte-identical decisions — spans,
        # exports, alerts, forensics, and capacity samples observe the
        # engine's jit programs, they never change them
        twin = make_engine(tracer=None, metrics=None, exporter=None,
                           health=None, forensics=None,
                           accounting=None).run(trace)
        same = ([dataclasses.astuple(t) for t in res.trials]
                == [dataclasses.astuple(t) for t in twin.trials])
        n_spans = len(eng.tracer.records()) if args.trace else 0
        print(f"\nobs-enabled run: {n_spans} spans over {eng.event_index} "
              f"events; bare twin identical={same}")
        assert same, "an observability plane changed the decision sequence"

    if args.report_dir:
        from repro.obs import write_report
        run_dir = write_report(
            args.report_dir, trace.name,
            telemetry=res.telemetry,
            tracer=eng.tracer if args.trace else None,
            metrics=eng.metrics,
            result=res,
            alerts=eng.health.alerts if args.health else None,
            forensics=eng.forensics.records if args.forensics else None,
            accounting=eng.accounting if args.capacity else None,
            meta={"policy": args.policy, "slices": args.slices,
                  "seed": args.seed, "events": trace.num_events,
                  "traced": args.trace, "wall_s": round(wall, 3),
                  "slo": slo})
        print(f"report -> {run_dir}")

    # smoke-test invariants: the run must have actually served tenants
    assert s["sessions"] == sessions
    assert s["trials"] > 0 and s["sessions_served"] > 0
    # (global model ids are recycled across sessions — DESIGN.md §10 — so
    # uniqueness holds per tenant, not per id)
    seen = [(t.tenant_key, t.local_model) for t in res.trials
            if t.z is not None]
    assert len(seen) == len(set(seen)), "a tenant model was observed twice"
    print("ok")


if __name__ == "__main__":
    main()
