"""Adversarial health-plane demo: one seeded trace, every watchdog fires.

Hand-builds a churn trace engineered to trip each detector class of
``repro.obs.HealthMonitor`` (DESIGN.md §14) in a single run:

  regret_stall     tenant 0 has 14 models with IDENTICAL ground truth — the
                   first observation sets the incumbent and the next 13 never
                   improve it, crossing ``stall_k``
  gp_conditioning  tenant 1's two models are near-duplicates under the
                   kernel (correlation 0.99999): folding the second drives
                   the Cholesky pivot d² to the jitter floor
  class_starvation the fleet sits idle from ~t=5; at t=50 a simultaneous-
                   arrival burst creates backlog while launches are still
                   deferred to the end of the admission batch — the free
                   class has not launched for >= ``starvation_window`` of
                   demand-present time
  queue_runaway    the burst (12 tenants x 4 models) overflows the
                   ``max_live_models=20`` cap; admission-queue depth climbs
                   through ``queue_limit`` while rising
  slo_burn         the SLO demands device_utilization >= 0.9 from a mostly
                   idle fleet — every window burns, so the burn rate hits
                   1.0 (severity ``page``)
  memory_runaway   the memory budget (1 KiB) is smaller than tenant 0's
                   posterior block alone — the capacity plane's very first
                   sample projects (and already measures) bytes over
                   budget (severity ``page``)
  straggler        act 3 (t=100): tenant 20's trials hang on all four
                   devices; supervision kills each at ``timeout_factor x
                   predicted_seconds`` — one straggler alert per device
  retry_storm      the four killed models re-queue with backoff inside one
                   sliding window, crossing ``retry_storm_k`` (``page``)
  quarantine_flap  slice 0 hangs again and again: three strikes quarantine
                   it, probation re-admits it, the next hang re-quarantines
                   — two quarantines inside ``flap_window`` (``page``)
  poisoned_observation  a TrialPoison makes slice 1's trial return NaN; the
                   GP-ingest guard rejects it and alerts

The failure-domain detectors (DESIGN.md §16) need the hardened device
plane, so the engine is a DevPlaneEngine with trial supervision and the
quarantine scoreboard enabled.  The run also exercises the rest of the
live plane — windowed metrics export, per-decision forensics — and
re-runs a bare twin (obs planes off, supervision identical) to assert
the observation-only guarantee.  ``--report-dir PATH`` renders the experiment
directory (report.html shows the alert table); the committed copy lives at
``demo/health_report/``.  Used by CI as a smoke test:

  PYTHONPATH=src python examples/health_demo.py --report-dir demo_report
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core.fleet import Fleet
from repro.devplane import DevPlaneEngine, QuarantinePolicy
from repro.obs import (ALERT_KINDS, CapacityAccountant, ForensicsRecorder,
                       HealthMonitor, MetricsExporter, MetricsRegistry,
                       Tracer)
from repro.stream import (ChurnTrace, TenantArrive, TenantDepart, TrialHang,
                          TrialPoison)

SLO = {"device_utilization": 0.9}


def adversarial_trace() -> ChurnTrace:
    """The module-docstring scenario, seeded and fully deterministic."""
    rng = np.random.default_rng(7)
    ev: list = []

    # tenant 0: the staller — flat ground truth, nothing ever improves
    m = 14
    ev.append(TenantArrive(at=0.0, tenant_key=0, K_block=0.04 * np.eye(m),
                           mu0=np.zeros(m), cost=np.ones(m),
                           z_true=np.full(m, 0.5)))
    # tenant 1: near-duplicate pair — the conditioning pathology
    rho = 0.99999
    ev.append(TenantArrive(at=0.0, tenant_key=1,
                           K_block=0.04 * np.array([[1.0, rho], [rho, 1.0]]),
                           mu0=np.zeros(2), cost=np.ones(2),
                           z_true=np.array([0.3, 0.3])))
    ev.append(TenantDepart(at=30.0, tenant_key=0))
    ev.append(TenantDepart(at=30.0, tenant_key=1))

    # t=50: simultaneous-arrival burst — backlog appears while the fleet
    # has been idle, and the admission cap turns the tail into a queue
    for i in range(12):
        k = 4
        ev.append(TenantArrive(
            at=50.0, tenant_key=2 + i, K_block=0.04 * np.eye(k),
            mu0=np.zeros(k), cost=np.ones(k),
            z_true=rng.uniform(0.2, 0.9, size=k)))
    for i in range(12):
        ev.append(TenantDepart(at=90.0, tenant_key=2 + i))

    # act 3 (t=100): the failure-domain scenario.  tenant 20's uniform
    # cost 10 makes every deadline land at launch + 15 (timeout_factor
    # 1.5): hanging all four devices at t=101 produces four stragglers
    # whose re-queues form a retry storm at t=115; slice 0 then hangs
    # after every re-launch — three strikes quarantine it, probation
    # re-admits it, the next hang re-quarantines: the flap.  slice 1's
    # t=115 launch is poisoned and returns NaN at t=125.
    m = 18
    ev.append(TenantArrive(at=100.0, tenant_key=20, K_block=0.04 * np.eye(m),
                           mu0=np.zeros(m), cost=np.full(m, 10.0),
                           z_true=rng.uniform(0.2, 0.9, size=m)))
    for sid in range(4):
        ev.append(TrialHang(at=101.0, slice_id=sid))
    ev.append(TrialPoison(at=116.0, slice_id=1))
    for at in (116.0, 131.0, 156.0):
        ev.append(TrialHang(at=at, slice_id=0))
    ev.append(TenantDepart(at=250.0, tenant_key=20))
    return ChurnTrace(tuple(ev), name="health-demo-adversarial")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--report-dir", default=None, metavar="PATH",
                   help="write the experiment directory "
                        "(PATH/<run_id>/ with the alert table in "
                        "report.html)")
    args = p.parse_args()
    trace = adversarial_trace()

    def make_engine(**kw):
        fleet = Fleet.partition_pod(total_chips=128, num_slices=4)
        if "health" not in kw:
            kw.update(
                tracer=Tracer(enabled=True), metrics=MetricsRegistry(),
                health=HealthMonitor(
                    slo=SLO, window=10.0, burn_windows=2,
                    burn_threshold=0.75, stall_k=8, queue_limit=6,
                    starvation_window=10.0, memory_budget_bytes=1024),
                forensics=ForensicsRecorder())
            kw["exporter"] = MetricsExporter(kw["metrics"], window=10.0)
            kw["accounting"] = CapacityAccountant(kw["metrics"], window=10.0)
        # the hardened device plane (DESIGN.md §16): the failure-domain
        # detectors need supervision + the quarantine scoreboard — both
        # stay identical in the bare twin (they change decisions; only
        # the obs planes must be observation-only)
        return DevPlaneEngine(
            fleet, "mdmt", seed=0, max_live_models=20,
            timeout_factor=1.5, max_retries=3, retry_backoff=1.0,
            quarantine=QuarantinePolicy(threshold=3, window=100.0,
                                        duration=10.0, probation_trials=2),
            **kw)

    eng = make_engine()
    res = eng.run(trace)

    by_kind: dict[str, int] = {}
    for a in eng.health.alerts:
        by_kind[a.kind] = by_kind.get(a.kind, 0) + 1
    print(f"{len(eng.health.alerts)} alerts: "
          f"{json.dumps(by_kind, sort_keys=True)}")
    for a in eng.health.alerts:
        print(f"  [{a.severity}] t={a.t:5.1f} ev={a.event_index:3d} "
              f"{a.kind:17s} subject={a.subject} {json.dumps(a.detail)}")

    missing = [k for k in ALERT_KINDS if k not in by_kind]
    assert not missing, f"detector classes that never fired: {missing}"

    # observation-only guarantee: the fully-instrumented run must make the
    # exact decisions of a bare twin
    twin = make_engine(tracer=None, metrics=None, exporter=None,
                       health=None, forensics=None).run(trace)
    same = ([dataclasses.astuple(t) for t in res.trials]
            == [dataclasses.astuple(t) for t in twin.trials])
    print(f"\nbare twin identical={same}; "
          f"{len(eng.forensics.records)} forensics records, "
          f"{len(eng.exporter.records)} export windows, "
          f"{len(eng.accounting.samples)} capacity samples")
    assert same, "an observability plane changed the decision sequence"

    if args.report_dir:
        from repro.obs import write_report
        run_dir = write_report(
            args.report_dir, trace.name,
            telemetry=res.telemetry, tracer=eng.tracer,
            metrics=eng.metrics, result=res,
            alerts=eng.health.alerts, forensics=eng.forensics.records,
            accounting=eng.accounting,
            meta={"policy": "mdmt", "slices": 4, "seed": 0,
                  "events": trace.num_events, "slo": SLO,
                  "adversarial": True})
        print(f"report -> {run_dir}")
    print("ok")


if __name__ == "__main__":
    main()
