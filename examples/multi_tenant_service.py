"""End-to-end multi-tenant AutoML service with REAL training trials.

Every "model" is (tenant dataset x architecture from the assigned pool); a
trial genuinely trains the reduced config on the tenant's synthetic dataset
(CPU).  The service:

  1. estimates the GP prior from two held-out tenants (the paper's protocol),
  2. schedules trials with MM-GP-EI over a fleet of two heterogeneous mesh
     slices, with c(x) from the roofline cost model,
  3. checkpoints its control state after every event,
  4. simulates a coordinator crash and resumes, re-queueing in-flight trials.

  PYTHONPATH=src python examples/multi_tenant_service.py
"""

from repro.core.fleet import Fleet
from repro.core.service import (
    AutoMLService,
    RealExecutor,
    ServiceConfig,
    TenantSpec,
    estimate_prior,
)

ARCHS = ["olmo-1b", "qwen3-4b", "mamba2-1.3b", "h2o-danube-3-4b"]


def main() -> None:
    svc = ServiceConfig(steps_per_trial=10, eval_steps=2, seq_len=64, batch=4)
    executor = RealExecutor(svc)

    print("== fitting GP prior from 2 held-out tenants (8 trial trainings) ==")
    prior_tenants = [TenantSpec(100, 100, 1.1), TenantSpec(101, 101, 1.7)]
    mu, K = estimate_prior(ARCHS, prior_tenants, executor)
    print("prior mean per arch:", dict(zip(ARCHS, mu.round(4))))

    tenants = [TenantSpec(i, i, 1.0 + 0.25 * i) for i in range(3)]
    fleet = Fleet.partition_pod(total_chips=256, num_slices=2, speeds=[1.0, 0.6])
    service = AutoMLService(tenants, ARCHS, fleet, executor, svc,
                            prior=(mu, K), checkpoint_path="/tmp/automl_svc.json")

    print("\n== phase 1: run 5 trials, then 'crash' ==")
    service.run(max_trials=5)
    for t in service.trials:
        print(f"  t={t.t_start:7.1f} -> {t.t_end:7.1f}  slice {t.slice_id} "
              f"(speed {fleet.slices[t.slice_id].speed})  tenant {t.tenant}  "
              f"{t.arch:16s} z={t.z:.4f}")

    print("\n== phase 2: fresh coordinator restores from checkpoint ==")
    fleet2 = Fleet.partition_pod(total_chips=256, num_slices=2, speeds=[1.0, 0.6])
    service2 = AutoMLService(tenants, ARCHS, fleet2, executor, svc,
                             prior=(mu, K), checkpoint_path="/tmp/automl_svc.json")
    assert service2.restore()
    print(f"restored {len(service2.gp.observed)} observations; finishing run")
    service2.run()

    print("\n== final result per tenant ==")
    A = len(ARCHS)
    for i, tenant in enumerate(tenants):
        zbest, abest = max(
            (service2.gp._z.get(i * A + j, -1), ARCHS[j]) for j in range(A))
        print(f"  tenant {tenant.tenant_id} (zipf {tenant.zipf_a:.2f}): "
              f"best arch = {abest} (z = {zbest:.4f})")


if __name__ == "__main__":
    main()
