"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred steps.

Exercises the full data-plane stack on CPU: synthetic Zipf data pipeline ->
chunked-loss forward -> AdamW -> async checkpointing, with resume support.
(The identical code path runs the full configs on the TPU mesh via
``repro.launch.train --full``.)

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch_iterator
from repro.models import init_params
from repro.models.model import ModelConfig
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainState, make_train_step


def model_100m() -> ModelConfig:
    """~110M params: a 12L x 768 GQA decoder (GPT-2-small-ish, Qwen3 blocks)."""
    return ModelConfig(
        name="dense-100m", family="dense",
        num_layers=12, d_model=768, vocab_size=32000,
        num_heads=12, num_kv_heads=4, head_dim=64, qk_norm=True,
        d_ff=2048, tie_embeddings=True,
        q_chunk=128, xent_chunk=128,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/train_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = model_100m()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M parameters")

    opt_cfg = OptConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    state = TrainState(params=params, opt=adamw_init(params, opt_cfg))
    mgr = CheckpointManager(args.ckpt, keep=2)
    start = 0
    if args.resume:
        restored = mgr.restore_latest(state)
        if restored:
            start, state, _ = restored
            print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, None), donate_argnums=0)
    it = make_batch_iterator(
        DataConfig(seq_len=args.seq, global_batch=args.batch, seed=0), cfg,
        start_step=start)

    t0, tok_per_step = time.time(), args.batch * args.seq
    for _ in range(args.steps - start):
        step, batch = next(it)
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray, batch))
        if (step + 1) % 25 == 0 or step == start:
            dt = time.time() - t0
            print(f"step {step+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{tok_per_step*(step+1-start)/max(dt,1e-9):,.0f} tok/s")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, state, {"arch": cfg.name}, blocking=False)
    it.close()
    mgr.save(args.steps, state, {"arch": cfg.name}, blocking=True)
    print("done; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
