"""Serving demo: prefill a batch of prompts, then decode tokens greedily.

Uses the same prefill/decode code path the dry-run lowers for the
decode_32k / long_500k cells (KV ring-buffer caches, SSM state caches).

  PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-4b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import decode_step, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.frontend == "frames":
        raise SystemExit("use a token-input arch for this demo")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.frontend == "patches":
        prompts["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_frontend_tokens, cfg.frontend_dim)), jnp.float32)

    max_len = args.prompt_len + args.new_tokens + 8
    t0 = time.time()
    _, cache = prefill(params, prompts, cfg, None, max_len=max_len)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, b, c: decode_step(p, b, c, cfg, None))
    tok = prompts["tokens"][:, -1:]
    out = []
    t0 = time.time()
    for _ in range(args.new_tokens):
        logits, cache = step(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    print(f"decoded {args.new_tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s)")
    print("sample continuation (seq 0):", [int(o[0]) for o in out[:16]])


if __name__ == "__main__":
    main()
