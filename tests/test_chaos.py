"""Failure-domain hardening (DESIGN.md §16): trial supervision, device
quarantine, live scorer-mesh shrink, and the chaos plane.

The contract under test, layer by layer:

* supervision OFF is byte-identical to the pre-hardening engine (zero new
  heap events), and supervision ON over a chaos-free trace changes nothing
  (deadlines always lose the race against real completions);
* a hung trial strands its device forever without supervision, and is
  killed at ``timeout_factor x predicted_seconds`` with it — the model
  re-queues with exponential backoff until the retry budget runs out;
* a poisoned (non-finite) loss never reaches the GP at any layer: the
  engine routes it through ``record_failure``, ``record_observation``
  raises, ``BlockIncrementalGP.observe`` raises;
* the quarantine scoreboard pulls a striking device from the launchable
  pool, re-admits it on probation, and re-quarantines on a probation
  strike (the flap the health plane pages on);
* a mid-run mesh shrink re-shards every resident posterior slot through
  the checkpoint path and picks the identical trial sequence to an engine
  that started on the smaller mesh (and to fused at one shard);
* every new event kind — timeout, retry, hang, poison, probation,
  mesh_shrink — replays byte-identically through the crash-anywhere
  oracle.
"""

import dataclasses

import numpy as np
import pytest

from test_eventlog import (
    assert_replay_matches,
    crash_and_recover,
    crash_indices,
    run_reference,
)

from repro.core.control_plane import ControlPlane
from repro.core.fleet import Fleet
from repro.core.gp import BlockIncrementalGP
from repro.devplane import DevPlaneEngine, QuarantineBoard, QuarantinePolicy
from repro.obs.health import HealthMonitor
from repro.shardgp.layout import BlockPlacement, ShardLayout
from repro.stream import (
    ChaosTrace,
    ChurnTrace,
    MeshShrink,
    StreamEngine,
    TenantArrive,
    TenantDepart,
    TrialHang,
    TrialPoison,
    chaos_trace,
    poisson_churn_trace,
)


def fleet_of(n):
    return Fleet.partition_pod(total_chips=16 * n, num_slices=n)


def _tiny_tenant(key, at, m=3, seed=0, cost=10.0):
    rng = np.random.default_rng(seed)
    K = 0.04 * np.eye(m) + 0.01
    return TenantArrive(
        at=at, tenant_key=key, K_block=K, mu0=np.full(m, 0.5),
        cost=np.full(m, float(cost)), z_true=rng.uniform(0.2, 0.9, m))


def _seq(eng):
    return [dataclasses.astuple(t) for t in eng._trials]


# ---- chaos trace generation --------------------------------------------------

def test_chaos_trace_seeded_and_twin_strips_only_chaos():
    kw = dict(hang_rate=0.3, poison_rate=0.3, flake_rate=0.15,
              loss_rate=0.2, shrink_at=10.0, shrink_shards=2)
    from repro.stream.eventlog import serialize_event as ser
    a = chaos_trace(25, seed=7, **kw)
    b = chaos_trace(25, seed=7, **kw)
    assert isinstance(a, ChaosTrace)
    assert [ser(e) for e in a] == [ser(e) for e in b]   # seeded determinism
    kinds = {type(e).__name__ for e in a.events}
    assert {"TrialHang", "TrialPoison", "SliceFail", "DeviceLeave",
            "MeshShrink"} <= kinds
    # the twin is exactly the failure-free tenant stream
    base = poisson_churn_trace(25, seed=7)
    assert [ser(e) for e in a.twin()] == [ser(e) for e in base]
    # the overlay never perturbed the tenant stream
    tenant_events = [ser(e) for e in a.events
                     if type(e).__name__.startswith("Tenant")]
    assert tenant_events == [ser(e) for e in base]


def test_chaos_trace_loss_never_drains_fleet():
    tr = chaos_trace(40, seed=1, loss_rate=5.0, initial_slices=3)
    losses = [e for e in tr.events if type(e).__name__ == "DeviceLeave"]
    assert len(losses) == 2                      # 3 slices -> at most 2 losses
    assert len({e.slice_id for e in losses}) == 2


def test_chaos_trace_validation():
    with pytest.raises(ValueError, match="shrink_shards"):
        chaos_trace(5, seed=0, shrink_at=3.0)


def test_supervision_knob_validation():
    with pytest.raises(ValueError, match="timeout_factor"):
        StreamEngine(fleet_of(1), "mdmt", timeout_factor=1.0)
    with pytest.raises(ValueError, match="max_retries"):
        StreamEngine(fleet_of(1), "mdmt", timeout_factor=2.0, max_retries=-1)
    with pytest.raises(ValueError, match="retry_backoff"):
        StreamEngine(fleet_of(1), "mdmt", timeout_factor=2.0,
                     retry_backoff=0.0)


# ---- supervision: byte-identity when it has nothing to do --------------------

def test_supervision_on_chaos_free_trace_is_byte_identical():
    """Deadlines are pushed strictly after completions (timeout_factor >
    1), so over a chaos-free trace every deadline finds its trial done and
    the trial sequence is untouched."""
    trace = poisson_churn_trace(num_sessions=15, seed=1)
    bare = StreamEngine(fleet_of(4), "mdmt", seed=0, max_live_models=24)
    sup = StreamEngine(fleet_of(4), "mdmt", seed=0, max_live_models=24,
                       timeout_factor=2.0, max_retries=3)
    bare.run(trace)
    sup.run(trace)
    assert _seq(bare) == _seq(sup)
    s = sup.telemetry.summary(now=sup._t)
    assert s["trials_timed_out"] == 0 and s["trials_retried"] == 0


# ---- supervision: hang, timeout, retry, abandonment --------------------------

def _hang_trace(hang_ats, depart_at=200.0, m=3):
    events = [_tiny_tenant(0, at=0.0, m=m)]
    events += [TrialHang(at=t, slice_id=0) for t in hang_ats]
    events.append(TenantDepart(at=depart_at, tenant_key=0))
    return ChurnTrace(events=tuple(events), name="hang")


def test_hang_without_supervision_strands_device():
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0)
    eng.run(_hang_trace([1.0]))
    # one launch, zero observations, device busy forever
    assert len(eng._trials) == 1
    assert all(t.z is None for t in eng._trials)
    assert eng.fleet.slices[0].current_trial is not None


def test_timeout_rescues_device_and_retry_completes():
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0,
                       timeout_factor=1.5, max_retries=2, retry_backoff=1.0)
    eng.run(_hang_trace([1.0]))
    s = eng.telemetry.summary(now=eng._t)
    assert s["trials_timed_out"] == 1
    assert s["trials_retried"] == 1
    assert s["trials_abandoned"] == 0
    # the hung model was retried and observed; every model got its z
    observed = {t.local_model for t in eng._trials if t.z is not None}
    assert observed == {0, 1, 2}
    # the killed trial record is rewritten to its kill time (cost 10,
    # factor 1.5 -> deadline at t=15), not its predicted end
    killed = [t for t in eng._trials if t.z is None]
    assert len(killed) == 1 and killed[0].end == pytest.approx(15.0)


def test_retry_budget_exhaustion_abandons_model():
    """Every relaunch of the (single) cursed model hangs.  With
    max_retries=1 the second timeout abandons it: the model stays selected
    (never re-picked, never re-timed-out) and the engine still
    terminates."""
    # launch at 0 (dur 10, deadline 15); retry lands at 16, relaunch at 16
    # (deadline 31).  Hang both instances.
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0,
                       timeout_factor=1.5, max_retries=1, retry_backoff=1.0)
    eng.run(_hang_trace([1.0, 17.0], m=1))
    s = eng.telemetry.summary(now=eng._t)
    assert s["trials_timed_out"] == 2
    assert s["trials_retried"] == 1
    assert s["trials_abandoned"] == 1
    # exactly two launch attempts, neither observed, no third relaunch
    assert len(eng._trials) == 2
    assert all(t.z is None for t in eng._trials)
    assert [t.start for t in eng._trials] == pytest.approx([0.0, 16.0])


def test_timeout_deadline_scales_with_predicted_duration():
    """k x predicted_seconds, not a global constant: a slow model's
    deadline lands proportionally later."""
    events = (_tiny_tenant(0, at=0.0, m=1, cost=40.0),
              TrialHang(at=1.0, slice_id=0),
              TenantDepart(at=500.0, tenant_key=0))
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0,
                       timeout_factor=2.0, max_retries=0)
    eng.run(ChurnTrace(events=events, name="slow-hang"))
    killed = [t for t in eng._trials if t.z is None]
    assert killed and killed[0].end == pytest.approx(80.0)   # 2.0 x 40


# ---- poisoned observations ---------------------------------------------------

def test_poison_rejected_and_model_returns_to_pool():
    events = (_tiny_tenant(0, at=0.0, m=3),
              TrialPoison(at=1.0, slice_id=0),
              TenantDepart(at=200.0, tenant_key=0))
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0)
    eng.run(ChurnTrace(events=events, name="poison"))
    s = eng.telemetry.summary(now=eng._t)
    assert s["observations_rejected"] == 1
    # the poisoned model went back to the pool and was re-run clean
    observed = {t.local_model for t in eng._trials if t.z is not None}
    assert observed == {0, 1, 2}
    assert len(eng._trials) == 4                 # 3 models + 1 poisoned rerun


def test_control_plane_rejects_non_finite_observation(rng):
    from conftest import random_psd
    cp = ControlPlane(np.random.default_rng(0), num_shards=1)
    h = cp.add_tenant(random_psd(rng, 3, 0.04), np.zeros(3), np.ones(3))
    gid = int(h.models[0])
    cp.record_start(gid)
    for bad in (float("nan"), float("inf"), float("-inf")):
        with pytest.raises(ValueError, match="non-finite"):
            cp.record_observation(gid, bad)
    cp.record_observation(gid, 0.5)              # finite still folds


def test_block_gp_rejects_non_finite(rng):
    from conftest import random_psd
    gp = BlockIncrementalGP()
    gp.add_block(np.arange(3), random_psd(rng, 3, 0.04), np.zeros(3))
    with pytest.raises(ValueError, match="non-finite"):
        gp.observe(0, float("nan"))
    gp.observe(0, 0.3)


# ---- quarantine board (unit) -------------------------------------------------

def test_quarantine_policy_validation():
    for bad in (dict(threshold=0), dict(window=0.0), dict(duration=-1.0),
                dict(probation_trials=0)):
        with pytest.raises(ValueError):
            QuarantinePolicy(**bad)


def test_quarantine_board_lifecycle():
    b = QuarantineBoard(QuarantinePolicy(threshold=3, window=10.0,
                                         duration=5.0, probation_trials=2))
    assert b.strike(0, 1.0) is False
    assert b.strike(0, 2.0) is False
    assert b.strike(0, 3.0) is True              # third strike in window
    assert b.state(0) == "quarantined" and b.quarantined_now() == 1
    assert b.strike(0, 3.5) is False             # ignored while quarantined
    b.begin_probation(0)
    assert b.state(0) == "probation"
    assert not b.is_quarantined(0)               # launchable again
    b.on_success(0)
    assert b.state(0) == "probation"             # needs 2 clean trials
    b.on_success(0)
    assert b.state(0) == "healthy"
    assert b.quarantine_count(0) == 1


def test_quarantine_board_window_expiry_and_flap():
    b = QuarantineBoard(QuarantinePolicy(threshold=2, window=5.0,
                                         duration=5.0))
    assert b.strike(1, 0.0) is False
    assert b.strike(1, 10.0) is False            # first strike aged out
    assert b.strike(1, 11.0) is True
    b.begin_probation(1)
    assert b.strike(1, 20.0) is True             # probation strike = flap
    assert b.quarantine_count(1) == 2
    b.retire(1)
    assert b.quarantined_now() == 0
    assert b.state(1) == "healthy"


def test_quarantine_board_state_round_trip():
    b = QuarantineBoard(QuarantinePolicy(threshold=2, window=10.0,
                                         duration=5.0))
    b.strike(0, 1.0)
    b.strike(1, 1.0); b.strike(1, 2.0)
    b.begin_probation(1); b.on_success(1)
    c = QuarantineBoard(b.policy)
    c.load_state(b.state_dict())
    assert c.state_dict() == b.state_dict()
    assert c.state(1) == "probation"


# ---- quarantine in the engine ------------------------------------------------

def test_engine_quarantines_striking_device_and_readmits():
    """One device, repeated hangs: 2 strikes quarantine it, the retry has
    to wait out the quarantine, probation re-admits, everything finishes."""
    # trial A launches at 0 (deadline 15); the device then launches B at 15
    # (deadline 30).  Hangs at 1 and 17 kill both: strike 2 at t=30
    # quarantines until t=50; probation re-admits and everything retries.
    trace = ChurnTrace(events=(
        _tiny_tenant(0, at=0.0, m=3),
        TrialHang(at=1.0, slice_id=0),           # strike 1 at t=15
        TrialHang(at=17.0, slice_id=0),          # strike 2 at t=30
        TenantDepart(at=400.0, tenant_key=0),
    ), name="strikes")
    eng = DevPlaneEngine(fleet_of(1), "mdmt", seed=0,
                         timeout_factor=1.5, max_retries=3,
                         retry_backoff=1.0,
                         quarantine=QuarantinePolicy(
                             threshold=2, window=100.0, duration=20.0))
    eng.run(trace)
    s = eng.telemetry.summary(now=eng._t)
    assert s["devices_quarantined"] == 1
    assert s["trials_timed_out"] == 2
    assert eng.quarantine.quarantine_count(0) == 1
    assert eng.quarantine.state(0) == "healthy"  # probation served clean
    # no launch happened inside the quarantine window [30, 50)
    assert all(not (30.0 < t.start < 50.0) for t in eng._trials)
    assert any(t.start >= 50.0 for t in eng._trials)
    observed = {t.local_model for t in eng._trials if t.z is not None}
    assert observed == {0, 1, 2}


def test_quarantined_capacity_shrinks_autoscale_denominator():
    """Quarantined devices drop out of the autoscale denominator: the same
    workload that never crosses the join threshold on 3 healthy devices
    does cross it when one device is quarantined — sick capacity triggers
    a scale-up."""
    from repro.devplane import AutoscalePolicy
    from repro.stream import SliceFail

    # 12 models, 3 launched at t=0 -> backlog 9 (9/3 = 3 < high 4.0).
    # The flake at t=1 kills a trial (backlog 10) AND, with threshold=1,
    # quarantines the device: 10/2 = 5 > 4 -> join.
    trace = ChurnTrace(events=(
        _tiny_tenant(0, at=0.0, m=12),
        SliceFail(at=1.0, slice_id=0, downtime=5.0),
        TenantDepart(at=600.0, tenant_key=0),
    ), name="sick-fleet")

    def run(quarantine):
        eng = DevPlaneEngine(
            fleet_of(3), "mdmt", seed=0,
            autoscale=AutoscalePolicy(high_backlog=4.0, low_backlog=0.1,
                                      cooldown=0.0, max_devices=8),
            quarantine=quarantine)
        eng.run(trace)
        return eng

    sick = run(QuarantinePolicy(threshold=1, window=10.0, duration=100.0))
    assert sick.telemetry.summary(now=sick._t)["devices_quarantined"] == 1
    assert sick._autoscale_joins > 0
    healthy = run(None)                          # same trace, no scoreboard
    assert healthy._autoscale_joins == 0


# ---- mesh shrink -------------------------------------------------------------

def test_repartition_matches_fresh_placement_order(rng):
    lay = ShardLayout(num_shards=4, shard_capacity=8)
    sizes = [3, 5, 2, 4, 1]
    for k, m in enumerate(sizes):
        lay.place(k, m)
    lay.release(2)
    new_lay, remap = ShardLayout.repartition(lay.blocks, 2)
    assert new_lay.num_shards == 2
    assert set(new_lay.blocks) == set(lay.blocks)
    # a restart that admits the same blocks in the same order agrees
    fresh = ShardLayout(num_shards=2, shard_capacity=1)
    for k, pl in lay.blocks.items():
        fresh.place(k, pl.length)
    assert fresh.blocks == new_lay.blocks
    # the remap covers every live slot bijectively
    assert len(remap) == sum(pl.length for pl in lay.blocks.values())
    assert len(set(remap.values())) == len(remap)
    with pytest.raises(ValueError):
        ShardLayout.repartition({0: BlockPlacement(0, 2)}, 0)


def test_control_plane_reshard_preserves_decisions(rng):
    """Shrink the layout mid-stream: the posterior, incumbents, and the
    next decisions are unchanged up to the slot remap."""
    from conftest import random_psd
    cp = ControlPlane(np.random.default_rng(0), num_shards=4)
    hs = [cp.add_tenant(random_psd(rng, 3, 0.04), np.zeros(3), np.ones(3))
          for _ in range(3)]
    for h in hs:
        g = int(h.models[0])
        cp.record_start(g)
        cp.record_observation(g, float(rng.uniform(0.2, 0.8)))
    pick_before, _ = cp.choose_mdmt()
    mu_before = {(h.tenant_id, j): float(cp.gp.posterior()[0][h.models[j]])
                 for h in hs for j in range(3)}

    remap = cp.reshard(2)
    assert remap and cp._layout.num_shards == 2
    assert cp.reshard(2) == {}                   # no-op at the same size
    pick_after, _ = cp.choose_mdmt()
    assert remap[pick_before] == pick_after
    # the posterior followed every slot through the remap
    mu, _ = cp.gp.posterior()
    for h in hs:
        for j in range(3):
            assert float(mu[remap[int(h.models[j])]]) == \
                pytest.approx(mu_before[(h.tenant_id, j)], abs=1e-6)


def test_control_plane_reshard_guards():
    cp = ControlPlane(np.random.default_rng(0), num_shards=2)
    with pytest.raises(ValueError, match="num_shards"):
        cp.reshard(0)
    from repro.core import synthetic_matern_problem
    frozen = ControlPlane.from_problem(
        synthetic_matern_problem(num_users=2, num_models_per_user=3, seed=0))
    with pytest.raises(RuntimeError, match="dynamic"):
        frozen.reshard(1)


def test_mesh_shrink_equals_engine_started_on_smaller_mesh():
    """The acceptance bar: a mid-run MeshShrink(2) on a 4-shard engine
    produces the identical trial sequence to a 2-shard engine running the
    same trace (for which the shrink is a no-op) — no decision dropped or
    changed across the re-shard.  Global slot ids are layout-dependent, so
    the comparison projects to (tenant, local model, device, times, z)."""
    trace = chaos_trace(20, seed=11, shrink_at=8.0, shrink_shards=2)
    runs = {}
    for shards in (4, 2):
        eng = StreamEngine(fleet_of(4), "mdmt", seed=0, num_shards=shards,
                           max_live_models=24)
        eng.run(trace)
        runs[shards] = [(t.tenant_key, t.local_model, t.device,
                         t.start, t.end, t.z) for t in eng._trials]
    assert runs[4] == runs[2]


def test_mesh_shrink_to_one_falls_back_to_fused():
    """On a real forced 4-device mesh: a sharded engine shrunk 4 -> 1
    mid-run swaps to the fused scorer and still matches the all-fused
    twin's trial sequence exactly."""
    from conftest import run_forced_devices_subprocess
    res = run_forced_devices_subprocess("""
        import json
        from repro.core.fleet import Fleet
        from repro.stream import StreamEngine, chaos_trace

        trace = chaos_trace(16, seed=13, shrink_at=6.0, shrink_shards=1)
        seqs, scorers = {}, {}
        for scorer, shards in (("sharded", 4), ("fused", 1)):
            eng = StreamEngine(Fleet.partition_pod(16 * 4, 4), "mdmt",
                               seed=0, scorer=scorer, num_shards=shards,
                               max_live_models=24)
            eng.run(trace)
            seqs[scorer] = [(t.tenant_key, t.local_model, t.device,
                             t.start, t.end, t.z) for t in eng._trials]
            scorers[scorer] = eng.cp.scorer
        print(json.dumps({
            "equal": seqs["sharded"] == seqs["fused"],
            "num_trials": len(seqs["fused"]),
            "final_scorer": scorers["sharded"],
        }))
    """, devices=4)
    assert res["num_trials"] > 16
    assert res["final_scorer"] == "fused"        # the fallback actually fired
    assert res["equal"], "shrink-to-1 diverged from the fused twin"


# ---- health detectors (unit feeds) -------------------------------------------

def test_health_straggler_and_retry_storm_detectors():
    h = HealthMonitor(window=10.0, retry_storm_k=2)
    h.on_timeout(1.0, 3, device=0, tenant=7, overrun=15.0)
    h.on_timeout(2.0, 4, device=0, tenant=7, overrun=15.0)   # deduped
    h.on_timeout(3.0, 5, device=1, tenant=8, overrun=9.0)
    kinds = [a.kind for a in h.alerts]
    assert kinds.count("straggler") == 2
    h.on_retry(4.0, 6, tenant=7, model=3, attempt=1)
    assert "retry_storm" not in [a.kind for a in h.alerts]
    h.on_retry(4.5, 7, tenant=8, model=9, attempt=1)
    storms = [a for a in h.alerts if a.kind == "retry_storm"]
    assert len(storms) == 1 and storms[0].severity == "page"
    # disarmed until the rate halves; re-arms after the window drains
    h.on_retry(5.0, 8, tenant=9, model=2, attempt=2)
    assert len([a for a in h.alerts if a.kind == "retry_storm"]) == 1
    h.on_retry(30.0, 9, tenant=9, model=2, attempt=3)        # window empty
    h.on_retry(30.5, 10, tenant=7, model=3, attempt=2)
    assert len([a for a in h.alerts if a.kind == "retry_storm"]) == 2


def test_health_quarantine_flap_and_poisoned_detectors():
    h = HealthMonitor(window=10.0, flap_window=50.0)
    h.on_quarantine(1.0, 2, device=3, count=1)
    assert "quarantine_flap" not in [a.kind for a in h.alerts]
    h.on_quarantine(20.0, 8, device=3, count=2)              # 2 in 50s: flap
    flaps = [a for a in h.alerts if a.kind == "quarantine_flap"]
    assert len(flaps) == 1 and flaps[0].severity == "page"
    h.on_poisoned(21.0, 9, tenant=4, model=17)
    poisons = [a for a in h.alerts if a.kind == "poisoned_observation"]
    assert len(poisons) == 1 and poisons[0].severity == "warn"
    # round-trip the new detector state
    h2 = HealthMonitor(window=10.0, flap_window=50.0)
    h2.load_state(h.state_dict())
    assert h2.state_dict() == h.state_dict()


# ---- crash-anywhere with the full chaos plane --------------------------------

def test_crash_anywhere_under_chaos(tmp_path):
    """The replay oracle over every new event kind at once: supervision +
    quarantine + chaos trace (hangs, poisons, flakes, losses, a mesh
    shrink), killed and restored at stride-sampled (all, under
    FAULT_EVENTS=all) processed-event indices."""
    trace = chaos_trace(num_sessions=30, arrival_rate=1.2, seed=9,
                        initial_slices=4, hang_rate=0.30, poison_rate=0.20,
                        flake_rate=0.10, loss_rate=0.04,
                        shrink_at=10.0, shrink_shards=1,
                        m_min=2, m_max=8, session_scale=10.0)

    def make(**kw):
        return DevPlaneEngine(
            fleet_of(4), "mdmt", seed=0, max_live_models=40, num_shards=2,
            timeout_factor=2.5, max_retries=2, retry_backoff=1.0,
            quarantine=QuarantinePolicy(threshold=2, window=40.0,
                                        duration=15.0),
            compact_every=3, **kw)

    ref_eng, ref_res = run_reference(make, trace)
    s = ref_res.telemetry.summary()
    # the trace must actually exercise the hardening paths
    assert s["trials_timed_out"] > 0
    assert s["trials_retried"] > 0
    assert s["observations_rejected"] > 0
    n = ref_eng.event_index
    for idx in crash_indices(n):
        out = crash_and_recover(make, trace, idx, "before", tmp_path,
                                snapshot_every=8)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"chaos_before_{idx}")
