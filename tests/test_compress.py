"""Gradient compression with error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.train.compress import (
    compress_tree,
    decompress_tree,
    dequantize,
    init_error_state,
    quantize,
    quantize_ef,
    wire_bytes_saved,
)


def test_quantize_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = quantize(x)
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_error_feedback_mean_converges(seed):
    """Sum of dequantized transmissions approaches the sum of true signals —
    the EF property that keeps quantized SGD unbiased."""
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((20, 64)).astype(np.float32)
    err = jnp.zeros(64)
    sent = np.zeros(64, np.float32)
    for x in xs:
        q, s, err = quantize_ef(jnp.asarray(x), err)
        sent += np.asarray(dequantize(q, s))
    residual = np.abs(sent + np.asarray(err) - xs.sum(0))
    assert residual.max() < 1e-3


def test_compress_tree_shapes(rng):
    grads = {"a": jnp.asarray(rng.standard_normal((4, 5)), jnp.float32),
             "b": {"c": jnp.asarray(rng.standard_normal(7), jnp.float32)}}
    errs = init_error_state(grads)
    codes, scales, new_errs = compress_tree(grads, errs)
    assert codes["a"].dtype == jnp.int8
    deq = decompress_tree(codes, scales)
    for k in ("a",):
        np.testing.assert_allclose(
            np.asarray(deq[k]), np.asarray(grads[k]), atol=float(scales[k]))


def test_quantized_sgd_still_converges(rng):
    """Least squares with int8+EF gradients reaches the same loss basin."""
    A = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(32), jnp.float32)
    loss = lambda w: jnp.mean((A @ w - b) ** 2)
    g = jax.grad(loss)

    w_exact = jnp.zeros(8)
    w_q = jnp.zeros(8)
    err = jnp.zeros(8)
    for _ in range(300):
        w_exact = w_exact - 0.05 * g(w_exact)
        q, s, err = quantize_ef(g(w_q), err)
        w_q = w_q - 0.05 * dequantize(q, s)
    # both reach the least-squares floor (nonzero: overdetermined system);
    # the quantized run must match the exact one, not an absolute value.
    w_star, *_ = jnp.linalg.lstsq(A, b)
    floor = float(loss(w_star))
    assert abs(float(loss(w_q)) - floor) < 0.05 * max(floor, 0.1)
    assert abs(float(loss(w_q)) - float(loss(w_exact))) < 0.02


def test_wire_bytes_saved():
    params = {"w": jnp.zeros((100, 100))}
    fp32, int8 = wire_bytes_saved(params)
    assert fp32 == 40000 and int8 < fp32 / 3.9
