"""GP posterior engines vs the closed form (Supplemental A)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.gp import IncrementalGP, posterior_masked

from conftest import random_psd


def closed_form(K, mu0, z, obs, jitter=1e-6):
    obs = list(obs)
    Koo = K[np.ix_(obs, obs)] + jitter * np.eye(len(obs))
    Kxo = K[:, obs]
    sol = np.linalg.solve(Koo, z[obs] - mu0[obs])
    mu = mu0 + Kxo @ sol
    var = np.diag(K) - np.einsum("ij,jk,ik->i", Kxo, np.linalg.inv(Koo), Kxo)
    return mu, np.maximum(var, 0.0)


@pytest.mark.parametrize("n,k", [(8, 3), (20, 12), (5, 5)])
def test_masked_matches_closed_form(rng, n, k):
    K = random_psd(rng, n)
    mu0 = rng.standard_normal(n)
    z = rng.standard_normal(n)
    obs = rng.choice(n, size=k, replace=False)
    mask = np.zeros(n, bool)
    mask[obs] = True
    mu, var = posterior_masked(
        jnp.asarray(K, jnp.float32), jnp.asarray(mu0, jnp.float32),
        jnp.asarray(z, jnp.float32), jnp.asarray(mask))
    mu_ref, var_ref = closed_form(K, mu0, z, obs)
    np.testing.assert_allclose(np.asarray(mu), mu_ref, atol=2e-4)
    np.testing.assert_allclose(np.asarray(var), var_ref, atol=2e-4)


def test_incremental_matches_masked_any_order(rng):
    n = 15
    K = random_psd(rng, n)
    mu0 = rng.standard_normal(n)
    z = rng.standard_normal(n)
    for order_seed in range(3):
        order = np.random.default_rng(order_seed).permutation(n)[:9]
        gp = IncrementalGP(K.astype(np.float32), mu0.astype(np.float32))
        for i in order:
            gp.observe(int(i), float(z[i]))
        mu_i, var_i = gp.posterior()
        mu_ref, var_ref = closed_form(K, mu0, z, list(order))
        np.testing.assert_allclose(np.asarray(mu_i), mu_ref, atol=3e-4)
        np.testing.assert_allclose(np.asarray(var_i), var_ref, atol=3e-4)


def test_posterior_interpolates_observations(rng):
    n = 10
    K = random_psd(rng, n)
    z = rng.standard_normal(n)
    gp = IncrementalGP(K.astype(np.float32), np.zeros(n, np.float32))
    for i in (2, 5, 7):
        gp.observe(i, float(z[i]))
    mu, var = gp.posterior()
    for i in (2, 5, 7):
        assert abs(float(mu[i]) - z[i]) < 1e-2
        assert float(var[i]) < 1e-2


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(0, 1_000_000))
def test_variance_never_increases(n, seed):
    """Conditioning reduces (marginal) variance — the property Theorem 2's
    proof leans on (eq. 13)."""
    rng = np.random.default_rng(seed)
    K = random_psd(rng, n)
    z = rng.standard_normal(n)
    gp = IncrementalGP(K.astype(np.float32), np.zeros(n, np.float32))
    prev_var = np.asarray(gp.posterior()[1])
    order = rng.permutation(n)
    for i in order:
        gp.observe(int(i), float(z[i]))
        var = np.asarray(gp.posterior()[1])
        assert (var <= prev_var + 1e-3).all()
        prev_var = var


def test_duplicate_observation_rejected(rng):
    K = random_psd(rng, 4)
    gp = IncrementalGP(K.astype(np.float32), np.zeros(4, np.float32))
    gp.observe(1, 0.5)
    with pytest.raises(ValueError):
        gp.observe(1, 0.7)
