"""Data pipeline, optimizer, checkpointing, cost model, fleet."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.core.cost_model import CostModel
from repro.core.fleet import Fleet
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at


# --- data -------------------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = get_smoke_config("olmo-1b")
    d = DataConfig(seq_len=32, global_batch=4, seed=7)
    s1, s2 = SyntheticLMStream(d, cfg), SyntheticLMStream(d, cfg)
    for step in (0, 5, 1000):
        b1, b2 = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(0)["tokens"], s1.batch_at(1)["tokens"])


def test_data_host_sharding_distinct():
    cfg = get_smoke_config("olmo-1b")
    b0 = SyntheticLMStream(DataConfig(32, 8, seed=7, num_hosts=2, host_id=0), cfg).batch_at(3)
    b1 = SyntheticLMStream(DataConfig(32, 8, seed=7, num_hosts=2, host_id=1), cfg).batch_at(3)
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_data_zipf_skew_increases_top_token_mass():
    cfg = get_smoke_config("olmo-1b")
    flat = SyntheticLMStream(DataConfig(256, 8, seed=1, zipf_a=1.01), cfg).batch_at(0)
    skew = SyntheticLMStream(DataConfig(256, 8, seed=1, zipf_a=2.5), cfg).batch_at(0)
    top_mass = lambda t: np.mean(np.asarray(t) < 10)
    assert top_mass(skew["tokens"]) > top_mass(flat["tokens"])


def test_frames_and_patches_batches():
    for arch in ("musicgen-medium", "paligemma-3b"):
        cfg = get_smoke_config(arch)
        b = SyntheticLMStream(DataConfig(64, 2, seed=0), cfg).batch_at(0)
        if cfg.frontend == "frames":
            assert b["frames"].shape == (2, 64, cfg.frontend_dim)
            assert b["labels"].shape == (2, 64, cfg.num_lm_heads)
        else:
            assert b["patches"].shape == (2, cfg.num_frontend_tokens, cfg.frontend_dim)


# --- optimizer ---------------------------------------------------------------

def adamw_numpy(p, g, mu, nu, step, cfg):
    mu = cfg.b1 * mu + (1 - cfg.b1) * g
    nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
    mh = mu / (1 - cfg.b1 ** step)
    vh = nu / (1 - cfg.b2 ** step)
    lr = float(lr_at(cfg, jnp.asarray(step)))
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), mu, nu


def test_adamw_matches_reference():
    cfg = OptConfig(lr=1e-2, clip_norm=1e9, warmup_steps=0, total_steps=10**9,
                    min_lr_ratio=1.0)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
    state = adamw_init(p, cfg)
    p2, state2, _ = adamw_update(p, g, state, cfg)
    ref, _, _ = adamw_numpy(np.asarray(p["w"]), np.asarray(g["w"]),
                            np.zeros(5), np.zeros(5), 1, cfg)
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, atol=1e-5)


def test_grad_clipping_caps_update():
    cfg = OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=10**9,
                    min_lr_ratio=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    state = adamw_init(p, cfg)
    _, _, metrics = adamw_update(p, g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-3)


# --- checkpointing -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(3.5)}}
    save_checkpoint(tmp_path, 7, tree, {"note": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = load_checkpoint(tmp_path, 7, like)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert meta["note"] == "x"


def test_checkpoint_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.ones(3)}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree, blocking=False)
    mgr.wait()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_checkpoint_restore_latest(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(4.0)}
    mgr.save(3, tree)
    mgr.save(9, jax.tree.map(lambda x: x * 2, tree))
    step, restored, _ = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree))
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(4.0) * 2)


# --- cost model / fleet ---------------------------------------------------------

def test_cost_model_analytic_scales_with_chips():
    cm = CostModel()
    cfg = get_smoke_config("qwen3-4b")
    t256 = cm._analytic(cfg, "train_4k", 256)
    t16 = cm._analytic(cfg, "train_4k", 16)
    assert t16 > t256 > 0


def test_cost_model_measured_blend():
    cm = CostModel()
    cfg = get_smoke_config("qwen3-4b")
    before = cm.trial_seconds("qwen3-4b-smoke", "train_4k", steps=10, chips=16, cfg=cfg)
    cm.observe("qwen3-4b-smoke", "train_4k", 16, measured_seconds=before * 10)
    after = cm.trial_seconds("qwen3-4b-smoke", "train_4k", steps=10, chips=16, cfg=cfg)
    assert after > before


def test_fleet_failure_and_recovery():
    fleet = Fleet.partition_pod(total_chips=256, num_slices=4)
    assert fleet.num_devices == 4 and fleet.slices[0].chips == 64
    fleet.slices[1].current_trial = 42
    killed = fleet.fail(1)
    assert killed == 42
    assert len(fleet.free_at(0.0)) == 3
    fleet.recover(1)
    assert len(fleet.free_at(0.0)) == 4


def test_fleet_repaired_slice_is_immediately_schedulable():
    """Regression: a failed slice whose killed trial had reserved it far into
    the future must be free right after repair, not at the stale busy_until."""
    fleet = Fleet.partition_pod(total_chips=256, num_slices=2)
    s = fleet.slices[0]
    s.current_trial = 7
    s.busy_until = 100.0          # the killed trial would have run until t=100
    fleet.fail(0)
    fleet.recover(0)
    assert s in fleet.free_at(5.0)
