"""Property test: churn round-trips leave survivors' posteriors intact.

Interleaved ``add_block`` / ``retire_block`` / slot-reuse sequences on a
dynamic ControlPlane (which recycles model and tenant slots through the
shardgp allocator, DESIGN.md §10) must leave every surviving tenant's
posterior equal — to float32 tolerance — to a fresh ``BlockIncrementalGP``
built from only the survivors with only their observations.  Sequences also
exercise ``compact()`` mid-stream, so block relocation is covered by the
same invariant.

The harness (churn_round_trip / assert_survivors_match_fresh) and a
deterministic seeded variant live in tests/test_gp_churn.py — this file
skips entirely without hypothesis, matching the repo's import-guard
convention.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from test_gp_churn import assert_survivors_match_fresh, churn_round_trip


@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["add", "retire", "observe", "observe",
                             "observe"]),
            st.integers(0, 10 ** 6),
            st.integers(0, 10 ** 6)),
        min_size=4, max_size=30),
    compact_at=st.frozensets(st.integers(0, 29), max_size=3),
)
def test_interleaved_churn_preserves_survivor_posteriors(ops, compact_at):
    cp, live = churn_round_trip(ops, compact_at)
    assert_survivors_match_fresh(cp, live)
