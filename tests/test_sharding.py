"""Sharding rules + small-mesh distributed execution (8 fake CPU devices).

The multi-device tests run in a subprocess so xla_force_host_platform_device_count
doesn't leak into the single-device test session.
"""

import pytest

from repro.sharding.rules import (
    DEFAULT_RULES,
    FSDP_RULES,
    AxisRules,
    ParamSpec,
)

from conftest import run_forced_devices_subprocess


def test_rules_lookup_and_override():
    assert DEFAULT_RULES.lookup("heads") == "model"
    assert DEFAULT_RULES.lookup("batch") == ("pod", "data")
    assert DEFAULT_RULES.lookup(None) is None
    assert FSDP_RULES.lookup("embed") == "data"
    r = DEFAULT_RULES.override(heads=None)
    assert r.lookup("heads") is None
    assert DEFAULT_RULES.lookup("heads") == "model"   # original untouched


def test_mesh_axes_deduplicates_repeated_axes():
    spec = DEFAULT_RULES.mesh_axes(("heads", "mlp"))   # both -> "model"
    assert spec[0] == "model" and spec[1] is None


@pytest.mark.slow
def test_train_step_runs_on_2x4_mesh():
    """Real sharded execution: smoke config, 2x4 mesh, loss finite, params
    actually sharded over the model axis."""
    res = run_forced_devices_subprocess("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_test_mesh, mesh_context
        from repro.launch.specs import build_cell
        from repro.models.layers import init_from_specs
        from repro.sharding.rules import DEFAULT_RULES

        mesh = make_test_mesh(data=2, model=4)
        cfg = get_smoke_config("qwen3-4b")
        cell = build_cell(cfg, "train_4k", mesh, DEFAULT_RULES)
        # materialize real (tiny) state matching the cell's sharding
        from repro.train.optimizer import OptConfig, adamw_init
        from repro.train.train_step import TrainState, make_train_step
        from repro.models import init_params
        import repro.launch.specs as specs_mod

        # shrink the batch for speed: reuse batch specs but with real data
        rng = np.random.default_rng(0)
        B, S = 8, 64
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = OptConfig()
        state = TrainState(params=params, opt=adamw_init(params, opt_cfg))
        fn = make_train_step(cfg, opt_cfg, DEFAULT_RULES)
        from repro.sharding.rules import shardings_for_tree
        from repro.train.train_step import train_state_specs
        st_sh = shardings_for_tree(train_state_specs(cfg, opt_cfg), mesh, DEFAULT_RULES)
        state = jax.device_put(state, st_sh)
        with mesh_context(mesh):
            step = jax.jit(fn, in_shardings=(st_sh, None), out_shardings=(st_sh, None))
            state2, metrics = step(state, batch)
        wq = state2.params["blocks"]["attn"]["wq"]
        nshards = len({(s.index) and str(s.index) for s in wq.addressable_shards})
        print(json.dumps({
            "loss": float(metrics["loss"]),
            "finite": bool(jnp.isfinite(metrics["loss"])),
            "wq_num_distinct_shards": len({str(s.index) for s in wq.addressable_shards}),
        }))
    """)
    assert res["finite"]
    assert 0 < res["loss"] < 20
    assert res["wq_num_distinct_shards"] == 4   # heads sharded over model axis


@pytest.mark.slow
def test_dryrun_cell_on_small_mesh_has_collectives():
    """Lower+compile a smoke train cell on a 2x4 mesh and check the SPMD
    module contains gradient collectives (all-reduce/reduce-scatter)."""
    res = run_forced_devices_subprocess("""
        import json
        import jax
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_test_mesh, mesh_context
        from repro.launch.specs import build_cell
        from repro.launch.hlo_analysis import parse_collectives
        from repro.sharding.rules import DEFAULT_RULES

        mesh = make_test_mesh(data=2, model=4)
        cfg = get_smoke_config("qwen3-4b")
        cell = build_cell(cfg, "train_4k", mesh, DEFAULT_RULES)
        with mesh_context(mesh):
            compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                               out_shardings=cell.out_shardings,
                               donate_argnums=cell.donate_argnums
                               ).lower(*cell.args_sds).compile()
        stats = parse_collectives(compiled.as_text(), 8)
        print(json.dumps({"counts": stats.counts, "wire": stats.wire_bytes}))
    """)
    assert any(op in res["counts"] for op in ("all-reduce", "reduce-scatter"))
    assert res["wire"] > 0


def test_sanitize_drops_nondivisible_dims():
    import os
    # pure-python path: sanitize needs only mesh.shape
    class FakeMesh:
        shape = {"data": 2, "model": 4}
    from repro.sharding.rules import _sanitize_pspec, logical_to_pspec
    from jax.sharding import PartitionSpec as P
    spec = P("model", "data")
    out = _sanitize_pspec(spec, (6, 4), FakeMesh)   # 6 % 4 != 0 -> None
    assert out[0] is None and out[1] == "data"
    out2 = _sanitize_pspec(P(("pod", "data"), None), (4, 4), FakeMesh)  # pod absent
    assert out2[0] == "data"
