"""Multi-tenant service loop with a deterministic fake executor."""

import numpy as np
import pytest

from repro.core.fleet import Fleet
from repro.core.service import AutoMLService, ServiceConfig, TenantSpec


class FakeExecutor:
    """Deterministic z-table + constant durations; counts invocations."""

    def __init__(self, z_table, seconds=1.0):
        self.z = z_table        # dict (tenant_id, arch) -> z
        self.seconds = seconds
        self.calls = []

    def run(self, tenant, arch):
        self.calls.append((tenant.tenant_id, arch))
        return self.z[(tenant.tenant_id, arch)], self.seconds


ARCHS = ["olmo-1b", "qwen3-4b", "mamba2-1.3b"]


def make_service(tmp_path=None, policy="mdmt", num_slices=2):
    tenants = [TenantSpec(i, i, 1.2) for i in range(3)]
    z = {(t.tenant_id, a): 0.3 + 0.1 * ((t.tenant_id + j) % 3)
         for t in tenants for j, a in enumerate(ARCHS)}
    ex = FakeExecutor(z)
    fleet = Fleet.partition_pod(256, num_slices)
    svc = ServiceConfig(policy=policy)
    service = AutoMLService(
        tenants, ARCHS, fleet, ex, svc,
        checkpoint_path=str(tmp_path / "svc.json") if tmp_path else None)
    return service, ex, z


@pytest.mark.parametrize("policy", ["mdmt", "round_robin", "random"])
def test_service_observes_all_models(policy):
    service, ex, z = make_service(policy=policy)
    trials = service.run()
    assert len(trials) == 9
    assert len(set((t.tenant, t.arch) for t in trials)) == 9
    # best per tenant matches the table's max
    for i in range(3):
        want = max(z[(i, a)] for a in ARCHS)
        assert service.best[i] == pytest.approx(want)


def test_service_checkpoint_requeues_inflight(tmp_path):
    service, ex, _ = make_service(tmp_path)
    service.run(max_trials=4)
    # simulate a crash: build a fresh service, restore
    service2, _, _ = make_service(tmp_path)
    assert service2.restore()
    assert len(service2.gp.observed) >= 3
    # anything selected-but-unobserved must have been requeued
    assert (service2.selected.sum() == len(service2.gp.observed))
    # finish the run
    service2.run()
    assert service2.selected.all()


def test_service_cost_model_updates_from_measured():
    service, ex, _ = make_service()
    before = dict(service.cost_model._measured)
    service.run(max_trials=2)
    assert len(service.cost_model._measured) > len(before)


class CrashingExecutor(FakeExecutor):
    """Raises on the Nth trial launch — simulates a coordinator dying with
    trials in flight (the checkpoint then holds selected-but-unobserved
    models)."""

    def __init__(self, z_table, crash_at, seconds=1.0):
        super().__init__(z_table, seconds)
        self.crash_at = crash_at

    def run(self, tenant, arch):
        if len(self.calls) + 1 >= self.crash_at:
            raise RuntimeError("coordinator crash")
        return super().run(tenant, arch)


def test_service_crash_mid_episode_restores_and_replays(tmp_path):
    """Kill the coordinator mid-episode, restart from the JSON checkpoint:
    in-flight trials are re-queued and the combined trial sequence matches
    an uninterrupted run exactly."""
    import json

    ck = tmp_path / "svc.json"
    service0, _, _ = make_service()
    service0.run()
    uninterrupted = [t.model for t in service0.trials]

    # crash while trial #3 is still in flight (2 completed, 1 launched)
    tenants = [TenantSpec(i, i, 1.2) for i in range(3)]
    z = {(t.tenant_id, a): 0.3 + 0.1 * ((t.tenant_id + j) % 3)
         for t in tenants for j, a in enumerate(ARCHS)}
    crashed = AutoMLService(
        tenants, ARCHS, Fleet.partition_pod(256, 2),
        CrashingExecutor(z, crash_at=4), ServiceConfig(),
        checkpoint_path=str(ck))
    with pytest.raises(RuntimeError):
        crashed.run()
    state = json.loads(ck.read_text())
    completed = [int(k) for k in state["observations"]]
    assert sum(state["selected"]) > len(completed), "crash left trials in flight"

    # fresh coordinator, same checkpoint
    restored, _, _ = make_service(tmp_path)
    assert restored.restore()
    # in-flight trials were re-queued: only completed trials stay selected
    assert int(restored.selected.sum()) == len(restored.gp.observed) == len(completed)
    restored.run()
    combined = completed + [t.model for t in restored.trials]
    assert combined == uninterrupted
    # nothing trained twice, nothing lost
    assert sorted(combined) == list(range(restored.n))
