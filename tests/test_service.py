"""Multi-tenant service loop with a deterministic fake executor."""

import numpy as np
import pytest

from repro.core.fleet import Fleet
from repro.core.service import AutoMLService, ServiceConfig, TenantSpec


class FakeExecutor:
    """Deterministic z-table + constant durations; counts invocations."""

    def __init__(self, z_table, seconds=1.0):
        self.z = z_table        # dict (tenant_id, arch) -> z
        self.seconds = seconds
        self.calls = []

    def run(self, tenant, arch):
        self.calls.append((tenant.tenant_id, arch))
        return self.z[(tenant.tenant_id, arch)], self.seconds


ARCHS = ["olmo-1b", "qwen3-4b", "mamba2-1.3b"]


def make_service(tmp_path=None, policy="mdmt", num_slices=2):
    tenants = [TenantSpec(i, i, 1.2) for i in range(3)]
    z = {(t.tenant_id, a): 0.3 + 0.1 * ((t.tenant_id + j) % 3)
         for t in tenants for j, a in enumerate(ARCHS)}
    ex = FakeExecutor(z)
    fleet = Fleet.partition_pod(256, num_slices)
    svc = ServiceConfig(policy=policy)
    service = AutoMLService(
        tenants, ARCHS, fleet, ex, svc,
        checkpoint_path=str(tmp_path / "svc.json") if tmp_path else None)
    return service, ex, z


@pytest.mark.parametrize("policy", ["mdmt", "round_robin", "random"])
def test_service_observes_all_models(policy):
    service, ex, z = make_service(policy=policy)
    trials = service.run()
    assert len(trials) == 9
    assert len(set((t.tenant, t.arch) for t in trials)) == 9
    # best per tenant matches the table's max
    for i in range(3):
        want = max(z[(i, a)] for a in ARCHS)
        assert service.best[i] == pytest.approx(want)


def test_service_checkpoint_requeues_inflight(tmp_path):
    service, ex, _ = make_service(tmp_path)
    service.run(max_trials=4)
    # simulate a crash: build a fresh service, restore
    service2, _, _ = make_service(tmp_path)
    assert service2.restore()
    assert len(service2.gp.observed) >= 3
    # anything selected-but-unobserved must have been requeued
    assert (service2.selected.sum() == len(service2.gp.observed))
    # finish the run
    service2.run()
    assert service2.selected.all()


def test_service_cost_model_updates_from_measured():
    service, ex, _ = make_service()
    before = dict(service.cost_model._measured)
    service.run(max_trials=2)
    assert len(service.cost_model._measured) > len(before)
