"""BlockIncrementalGP runtime block add/retire (tenant churn, DESIGN.md §9).

Separate from test_gp.py because these tests have no hypothesis dependency
(test_gp.py skips entirely when hypothesis is missing).
"""

import numpy as np
import pytest

from repro.core import ControlPlane
from repro.core.gp import BlockIncrementalGP, IncrementalGP
from repro.core.tenancy import _matern_block_chol

from conftest import random_psd


def churn_round_trip(ops: list[tuple], compact_at: frozenset[int]):
    """Drive one interleaved add/retire/observe sequence (with slot reuse
    and optional compaction passes) on a dynamic ControlPlane.  Returns
    (cp, survivors) where survivors maps tenant_id -> (K, mu0,
    [(local, z), ...]).  Shared with the hypothesis property in
    test_churn_property.py."""
    K_cache: dict[int, np.ndarray] = {}
    cp = ControlPlane(np.random.default_rng(0), model_capacity=8,
                      tenant_capacity=2, num_shards=2)
    live: dict[int, tuple] = {}   # tid -> (K, mu0, obs list)
    z_rng = np.random.default_rng(1234)
    for step, (kind, a, b) in enumerate(ops):
        if kind == "add":
            m = 2 + a % 5
            if m not in K_cache:
                K_cache[m] = _matern_block_chol(m, 0.2, 0.04)[0]
            mu0 = np.full(m, (b % 7) / 10.0)
            h = cp.add_tenant(K_cache[m], mu0, np.ones(m))
            live[h.tenant_id] = (K_cache[m], mu0, [])
        elif kind == "retire" and live:
            tid = sorted(live)[a % len(live)]
            cp.retire_tenant(tid)
            del live[tid]
        elif kind == "observe" and live:
            tid = sorted(live)[a % len(live)]
            ids = np.nonzero(cp.membership[tid])[0]
            unobserved = [g for g in ids if not cp.observed[g]]
            if not unobserved:
                continue
            g = int(unobserved[b % len(unobserved)])
            z = float(z_rng.uniform(0.0, 1.0))
            cp.record_start(g)
            cp.record_observation(g, z)
            live[tid][2].append((int(g - ids[0]), z))
        if step in compact_at:
            cp.compact(1.0)
    return cp, live


def assert_survivors_match_fresh(cp: ControlPlane, live: dict) -> None:
    """Survivors' posteriors == a fresh BlockIncrementalGP built from only
    the survivors, float32 tolerance."""
    mu_now, var_now = map(np.asarray, cp.gp.posterior())
    fresh = BlockIncrementalGP.empty()
    placements = {}
    cursor = 0
    for tid in sorted(live):
        K, mu0, obs = live[tid]
        m = len(mu0)
        ids = np.arange(cursor, cursor + m)
        fresh.add_block(ids, K, mu0)
        placements[tid] = ids
        cursor += m
        for local, z in obs:
            fresh.observe(int(ids[0] + local), z)
    mu_ref, var_ref = map(np.asarray, fresh.posterior())
    for tid, fresh_ids in placements.items():
        now_ids = np.nonzero(cp.membership[tid])[0]
        np.testing.assert_allclose(
            mu_now[now_ids], mu_ref[fresh_ids], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            var_now[now_ids], var_ref[fresh_ids], rtol=1e-5, atol=1e-6)


def test_add_block_matches_static_construction(rng):
    """Blocks added one by one == the same blocks at construction time."""
    blocks = [np.arange(0, 4), np.arange(4, 9), np.arange(9, 12)]
    n = 12
    K = np.zeros((n, n))
    mu0 = rng.standard_normal(n)
    for b in blocks:
        K[np.ix_(b, b)] = random_psd(rng, len(b))
    static = BlockIncrementalGP(K, mu0, blocks)
    dyn = BlockIncrementalGP.empty()
    for b in blocks:
        dyn.add_block(b, K[np.ix_(b, b)], mu0[b])
    z = rng.standard_normal(n)
    for i in (1, 5, 10, 6, 0):
        static.observe(int(i), float(z[i]))
        dyn.observe(int(i), float(z[i]))
    mu_s, var_s = static.posterior()
    mu_d, var_d = dyn.posterior()
    np.testing.assert_array_equal(np.asarray(mu_s), np.asarray(mu_d))
    np.testing.assert_array_equal(np.asarray(var_s), np.asarray(var_d))


def test_retire_block_leaves_others_untouched(rng):
    dyn = BlockIncrementalGP.empty()
    Ks, mus, bids = [], [], []
    for bi in range(3):
        m = 4
        Kb = random_psd(rng, m)
        mu = rng.standard_normal(m)
        Ks.append(Kb); mus.append(mu)
        bids.append(dyn.add_block(np.arange(bi * m, (bi + 1) * m), Kb, mu))
    z = rng.standard_normal(12)
    for i in (0, 5, 9, 2):
        dyn.observe(int(i), float(z[i]))
    mu_before, var_before = map(np.asarray, dyn.posterior())
    dyn.retire_block(bids[1])
    mu_after, var_after = map(np.asarray, dyn.posterior())
    keep = np.r_[0:4, 8:12]
    np.testing.assert_array_equal(mu_before[keep], mu_after[keep])
    np.testing.assert_array_equal(var_before[keep], var_after[keep])
    # retired models stop accepting observations...
    with pytest.raises(KeyError):
        dyn.observe(5, 0.0)
    # ...but live blocks keep working, and the result matches a fresh engine
    dyn.observe(10, float(z[10]))
    ref = IncrementalGP(Ks[2], mus[2])
    ref.observe(1, float(z[9]))
    ref.observe(2, float(z[10]))
    mu_ref, var_ref = map(np.asarray, ref.posterior())
    mu_now = np.asarray(dyn.posterior()[0])
    np.testing.assert_array_equal(mu_now[8:12], mu_ref)


def test_add_after_retire_appends_at_new_indices(rng):
    dyn = BlockIncrementalGP.empty()
    b0 = dyn.add_block(np.arange(0, 3), random_psd(rng, 3), np.zeros(3))
    dyn.retire_block(b0)
    # new tenants get fresh index space; the retired range stays dead
    b1 = dyn.add_block(np.arange(3, 6), random_psd(rng, 3), np.ones(3))
    assert b1 != b0
    dyn.observe(4, 0.7)
    with pytest.raises(KeyError):
        dyn.observe(0, 0.1)
    assert dyn.n >= 6


def test_ensure_capacity_pads_readout(rng):
    dyn = BlockIncrementalGP.empty()
    dyn.add_block(np.arange(0, 2), random_psd(rng, 2), np.zeros(2))
    dyn.ensure_capacity(10)
    mu, var = dyn.posterior()
    assert mu.shape == (10,) and var.shape == (10,)
    # padding is inert: mu 0, var 0
    assert float(np.asarray(mu)[5]) == 0.0
    assert float(np.asarray(var)[5]) == 0.0


def test_duplicate_indices_rejected(rng):
    dyn = BlockIncrementalGP.empty()
    dyn.add_block(np.arange(0, 3), random_psd(rng, 3), np.zeros(3))
    with pytest.raises(AssertionError):
        dyn.add_block(np.arange(2, 5), random_psd(rng, 3), np.zeros(3))


def test_slot_reuse_at_retired_indices(rng):
    """Index recycling (DESIGN.md §10): a new block may land on a retired
    block's indices, and behaves exactly like a fresh engine there."""
    dyn = BlockIncrementalGP.empty()
    b0 = dyn.add_block(np.arange(0, 3), random_psd(rng, 3), np.zeros(3))
    dyn.observe(1, 0.4)
    dyn.retire_block(b0)
    Kb = random_psd(rng, 3)
    dyn.add_block(np.arange(0, 3), Kb, np.ones(3))   # same global ids
    dyn.observe(1, 0.9)
    ref = IncrementalGP(Kb, np.ones(3))
    ref.observe(1, 0.9)
    mu, var = map(np.asarray, dyn.posterior())
    mu_r, var_r = map(np.asarray, ref.posterior())
    np.testing.assert_array_equal(mu[:3], mu_r)
    np.testing.assert_array_equal(var[:3], var_r)


def test_relocate_block_moves_posterior_and_remaps_observations(rng):
    dyn = BlockIncrementalGP.empty()
    Kb = random_psd(rng, 3)
    bid = dyn.add_block(np.arange(0, 3), Kb, np.zeros(3))
    other = dyn.add_block(np.arange(3, 6), random_psd(rng, 3), np.zeros(3))
    dyn.observe(0, 0.7)
    dyn.observe(4, 0.2)
    mu_b, var_b = map(np.asarray, dyn.posterior())
    dyn.relocate_block(bid, np.arange(8, 11))
    mu_a, var_a = map(np.asarray, dyn.posterior())
    np.testing.assert_array_equal(mu_a[8:11], mu_b[0:3])
    np.testing.assert_array_equal(var_a[8:11], var_b[0:3])
    np.testing.assert_array_equal(mu_a[3:6], mu_b[3:6])   # untouched block
    # vacated entries are inert padding
    assert (mu_a[0:3] == 0).all() and (var_a[0:3] == 0).all()
    # observations continue at the new indices; old ones are dead
    dyn.observe(9, 0.5)
    with pytest.raises(KeyError):
        dyn.observe(1, 0.5)
    ref = IncrementalGP(Kb, np.zeros(3))
    ref.observe(0, 0.7)
    ref.observe(1, 0.5)
    mu_ref = np.asarray(ref.posterior()[0])
    np.testing.assert_array_equal(np.asarray(dyn.posterior()[0])[8:11], mu_ref)


def test_relocate_block_clash_rejected(rng):
    dyn = BlockIncrementalGP.empty()
    bid = dyn.add_block(np.arange(0, 3), random_psd(rng, 3), np.zeros(3))
    dyn.add_block(np.arange(3, 6), random_psd(rng, 3), np.zeros(3))
    with pytest.raises(AssertionError):
        dyn.relocate_block(bid, np.arange(4, 7))


def test_deterministic_churn_round_trip_matches_fresh_engine(rng):
    """Seeded variant of the hypothesis property in test_churn_property.py
    (which skips without hypothesis): interleaved add/retire/observe with
    slot reuse and a compaction pass leaves survivors' posteriors equal to
    a fresh engine built from only the survivors."""
    r = np.random.default_rng(7)
    ops = [("add", 0, 0), ("add", 3, 2), ("observe", 0, 1), ("add", 5, 1),
           ("observe", 1, 0), ("retire", 0, 0), ("add", 2, 4),
           ("observe", 2, 2), ("observe", 0, 0), ("retire", 1, 0),
           ("add", 4, 3), ("observe", 1, 1), ("observe", 2, 0),
           ("add", 1, 1), ("retire", 2, 0), ("observe", 0, 2)]
    ops += [("observe", int(a), int(b))
            for a, b in r.integers(0, 50, size=(10, 2))]
    cp, live = churn_round_trip(ops, compact_at=frozenset({9, 14}))
    assert live, "sequence must leave survivors"
    assert_survivors_match_fresh(cp, live)
