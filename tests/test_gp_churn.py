"""BlockIncrementalGP runtime block add/retire (tenant churn, DESIGN.md §9).

Separate from test_gp.py because these tests have no hypothesis dependency
(test_gp.py skips entirely when hypothesis is missing).
"""

import numpy as np
import pytest

from repro.core.gp import BlockIncrementalGP, IncrementalGP

from conftest import random_psd


def test_add_block_matches_static_construction(rng):
    """Blocks added one by one == the same blocks at construction time."""
    blocks = [np.arange(0, 4), np.arange(4, 9), np.arange(9, 12)]
    n = 12
    K = np.zeros((n, n))
    mu0 = rng.standard_normal(n)
    for b in blocks:
        K[np.ix_(b, b)] = random_psd(rng, len(b))
    static = BlockIncrementalGP(K, mu0, blocks)
    dyn = BlockIncrementalGP.empty()
    for b in blocks:
        dyn.add_block(b, K[np.ix_(b, b)], mu0[b])
    z = rng.standard_normal(n)
    for i in (1, 5, 10, 6, 0):
        static.observe(int(i), float(z[i]))
        dyn.observe(int(i), float(z[i]))
    mu_s, var_s = static.posterior()
    mu_d, var_d = dyn.posterior()
    np.testing.assert_array_equal(np.asarray(mu_s), np.asarray(mu_d))
    np.testing.assert_array_equal(np.asarray(var_s), np.asarray(var_d))


def test_retire_block_leaves_others_untouched(rng):
    dyn = BlockIncrementalGP.empty()
    Ks, mus, bids = [], [], []
    for bi in range(3):
        m = 4
        Kb = random_psd(rng, m)
        mu = rng.standard_normal(m)
        Ks.append(Kb); mus.append(mu)
        bids.append(dyn.add_block(np.arange(bi * m, (bi + 1) * m), Kb, mu))
    z = rng.standard_normal(12)
    for i in (0, 5, 9, 2):
        dyn.observe(int(i), float(z[i]))
    mu_before, var_before = map(np.asarray, dyn.posterior())
    dyn.retire_block(bids[1])
    mu_after, var_after = map(np.asarray, dyn.posterior())
    keep = np.r_[0:4, 8:12]
    np.testing.assert_array_equal(mu_before[keep], mu_after[keep])
    np.testing.assert_array_equal(var_before[keep], var_after[keep])
    # retired models stop accepting observations...
    with pytest.raises(KeyError):
        dyn.observe(5, 0.0)
    # ...but live blocks keep working, and the result matches a fresh engine
    dyn.observe(10, float(z[10]))
    ref = IncrementalGP(Ks[2], mus[2])
    ref.observe(1, float(z[9]))
    ref.observe(2, float(z[10]))
    mu_ref, var_ref = map(np.asarray, ref.posterior())
    mu_now = np.asarray(dyn.posterior()[0])
    np.testing.assert_array_equal(mu_now[8:12], mu_ref)


def test_add_after_retire_appends_at_new_indices(rng):
    dyn = BlockIncrementalGP.empty()
    b0 = dyn.add_block(np.arange(0, 3), random_psd(rng, 3), np.zeros(3))
    dyn.retire_block(b0)
    # new tenants get fresh index space; the retired range stays dead
    b1 = dyn.add_block(np.arange(3, 6), random_psd(rng, 3), np.ones(3))
    assert b1 != b0
    dyn.observe(4, 0.7)
    with pytest.raises(KeyError):
        dyn.observe(0, 0.1)
    assert dyn.n >= 6


def test_ensure_capacity_pads_readout(rng):
    dyn = BlockIncrementalGP.empty()
    dyn.add_block(np.arange(0, 2), random_psd(rng, 2), np.zeros(2))
    dyn.ensure_capacity(10)
    mu, var = dyn.posterior()
    assert mu.shape == (10,) and var.shape == (10,)
    # padding is inert: mu 0, var 0
    assert float(np.asarray(mu)[5]) == 0.0
    assert float(np.asarray(var)[5]) == 0.0


def test_duplicate_indices_rejected(rng):
    dyn = BlockIncrementalGP.empty()
    dyn.add_block(np.arange(0, 3), random_psd(rng, 3), np.zeros(3))
    with pytest.raises(AssertionError):
        dyn.add_block(np.arange(2, 5), random_psd(rng, 3), np.zeros(3))
