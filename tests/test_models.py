"""Per-architecture smoke tests: reduced configs, forward + train + serve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (
    decode_step,
    forward_logits_last,
    forward_loss,
    init_cache,
    init_params,
    prefill,
)
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import TrainState, make_train_step


def make_batch(cfg, B=2, S=64, seed=0, with_labels=True):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.frontend is None:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
        if with_labels:
            batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    elif cfg.frontend == "patches":
        ni = cfg.num_frontend_tokens
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, ni, cfg.frontend_dim)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - ni)))
        if with_labels:
            batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - ni)))
    else:  # frames
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.frontend_dim)), jnp.float32)
        if with_labels:
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S, cfg.num_lm_heads)))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss = forward_loss(params, batch, cfg, None)
    assert jnp.isfinite(loss), arch
    assert 0.0 < float(loss) < 20.0

    if cfg.frontend == "frames":
        db = {"frames": jnp.ones((2, 1, cfg.frontend_dim), jnp.float32)}
    else:
        db = {"tokens": jnp.ones((2, 1), jnp.int32)}
    cache = init_cache(cfg, batch=2, max_len=96)
    logits, cache2 = decode_step(params, db, cache, cfg, None)
    assert jnp.isfinite(logits).all(), arch
    expected_v = cfg.vocab_size
    assert logits.shape[-1] == expected_v


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-1.3b", "arctic-480b"])
def test_smoke_train_step_improves_loss(arch):
    cfg = get_smoke_config(arch)
    opt_cfg = OptConfig(lr=5e-3, warmup_steps=1, total_steps=20, weight_decay=0.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params, opt_cfg))
    step = jax.jit(make_train_step(cfg, opt_cfg, None))
    batch = make_batch(cfg, B=4, S=32)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)   # overfit one batch
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["qwen3-4b", "h2o-danube-3-4b", "mamba2-1.3b",
                                  "zamba2-2.7b", "olmo-1b", "musicgen-medium"])
def test_prefill_decode_matches_full_forward(arch):
    """decode(prefill(x[:S-1]), x[S-1]) logits == full forward logits at S."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 48
    full = make_batch(cfg, B=B, S=S, with_labels=False)

    if cfg.frontend == "frames":
        prefix = {"frames": full["frames"][:, : S - 1]}
        last = {"frames": full["frames"][:, S - 1 : S]}
    else:
        prefix = {k: (v[:, : S - 1] if k == "tokens" else v) for k, v in full.items()}
        last = {"tokens": full["tokens"][:, -1:]}

    want = forward_logits_last(params, full, cfg, None)
    _, cache = prefill(params, prefix, cfg, None, max_len=S + 8)
    got, _ = decode_step(params, last, cache, cfg, None)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2)


def test_sliding_window_ring_buffer_decode():
    """With SWA, decoding past the window must match a fresh prefill of the
    last `window` tokens."""
    cfg = get_smoke_config("h2o-danube-3-4b")   # window 64
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 96                                # > window
    full = make_batch(cfg, B=B, S=S, with_labels=False)
    prefix = {"tokens": full["tokens"][:, : S - 1]}
    last = {"tokens": full["tokens"][:, -1:]}
    want = forward_logits_last(params, full, cfg, None)
    _, cache = prefill(params, prefix, cfg, None, max_len=S + 8)
    got, _ = decode_step(params, last, cache, cfg, None)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2)


def test_full_configs_match_published_shapes():
    expect = {
        "musicgen-medium": (48, 1536, 2048),
        "zamba2-2.7b": (54, 2560, 32000),
        "paligemma-3b": (18, 2048, 257216),
        "mamba2-1.3b": (48, 2048, 50280),
        "arctic-480b": (35, 7168, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 151936),
        "qwen3-4b": (36, 2560, 151936),
        "qwen3-8b": (36, 4096, 151936),
        "olmo-1b": (16, 2048, 50304),
        "h2o-danube-3-4b": (24, 3840, 32000),
    }
    for arch, (L, d, V) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.vocab_size) == (L, d, V), arch


def test_param_counts_in_published_ballpark():
    """Total parameters should land near the names on the tin."""
    expect_b = {"qwen3-8b": (7.0, 9.5), "arctic-480b": (420, 520),
                "qwen3-moe-235b-a22b": (200, 260), "mamba2-1.3b": (1.0, 1.6),
                "olmo-1b": (0.9, 1.5), "h2o-danube-3-4b": (3.3, 4.6)}
    for arch, (lo, hi) in expect_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)
    # MoE active params
    act = get_config("qwen3-moe-235b-a22b").active_param_count() / 1e9
    assert 15 <= act <= 30, act
