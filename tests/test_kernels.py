"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


# --- EIrate -------------------------------------------------------------------

@pytest.mark.parametrize("n,N,bm,bu", [
    (64, 8, 64, 8), (200, 33, 64, 16), (513, 100, 128, 64), (17, 3, 256, 256),
])
def test_eirate_kernel_sweep(rng, n, N, bm, bu):
    mu = jnp.asarray(rng.standard_normal(n), jnp.float32)
    sg = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    sg = sg.at[: n // 4].set(0.0)                      # degenerate sigmas
    best = jnp.asarray(rng.standard_normal(N), jnp.float32)
    mem = jnp.asarray(rng.random((N, n)) < 0.4)
    cost = jnp.asarray(rng.uniform(0.3, 3.0, n), jnp.float32)
    sel = jnp.asarray(rng.random(n) < 0.25)
    got = ops.eirate(mu, sg, best, mem, cost, sel,
                     block_models=bm, block_users=bu, interpret=True)
    want = ref.eirate_ref(mu, sg, best, mem, cost, sel)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n,N,k,bm,bu", [
    (64, 8, 4, 64, 8), (200, 33, 8, 64, 16), (513, 100, 16, 128, 64),
    (17, 3, 4, 256, 256), (5, 2, 8, 256, 256),   # k > n: padded candidates
])
def test_eirate_topk_epilogue_sweep(rng, n, N, k, bm, bu):
    """The block-local top-k epilogue == lax.top_k over the full score
    vector: same values (fp32 tol) and same indices wherever scores are
    distinct enough to rank."""
    mu = jnp.asarray(rng.standard_normal(n), jnp.float32)
    sg = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    sg = sg.at[: n // 4].set(0.0)
    best = jnp.asarray(rng.standard_normal(N), jnp.float32)
    mem = jnp.asarray(rng.random((N, n)) < 0.4)
    cost = jnp.asarray(rng.uniform(0.3, 3.0, n), jnp.float32)
    sel = jnp.asarray(rng.random(n) < 0.25)
    vk, ik = ops.eirate_topk(mu, sg, best, mem, cost, sel, k=k,
                             block_models=bm, block_users=bu, interpret=True)
    vr, ir = ref.eirate_topk_ref(mu, sg, best, mem, cost, sel, k=k)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               atol=1e-4, rtol=1e-4)
    valid = np.asarray(vr) > -1e29
    assert (np.asarray(ik)[valid] == np.asarray(ir)[valid]).all()


@pytest.mark.parametrize("n,N,C,bm,bu", [
    (64, 8, 2, 64, 8), (200, 33, 3, 64, 16), (17, 3, 5, 256, 256),
])
def test_eirate_classes_kernel_sweep(rng, n, N, C, bm, bu):
    """The class-axis epilogue (one (C, n) cost matrix, tenant sum
    accumulated once) == the naive per-class reference, and row c ==
    the single-class kernel run with cost row c."""
    mu = jnp.asarray(rng.standard_normal(n), jnp.float32)
    sg = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    sg = sg.at[: n // 4].set(0.0)
    best = jnp.asarray(rng.standard_normal(N), jnp.float32)
    mem = jnp.asarray(rng.random((N, n)) < 0.4)
    cm = jnp.asarray(rng.uniform(0.3, 3.0, (C, n)), jnp.float32)
    sel = jnp.asarray(rng.random(n) < 0.25)
    got = ops.eirate_classes(mu, sg, best, mem, cm, sel,
                             block_models=bm, block_users=bu, interpret=True)
    want = ref.eirate_classes_ref(mu, sg, best, mem, cm, sel)
    assert got.shape == (C, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    row = ops.eirate(mu, sg, best, mem, cm[1], sel,
                     block_models=bm, block_users=bu, interpret=True)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(row),
                               atol=1e-6, rtol=1e-6)


def test_eirate_topk_tie_break_lowest_index():
    """All-equal scores: the epilogue must rank candidates by ascending
    index across blocks, exactly like lax.top_k (the sharded argmax's
    exactness depends on it)."""
    n, N = 48, 3
    mu = jnp.zeros(n, jnp.float32)
    sg = jnp.ones(n, jnp.float32)
    best = jnp.zeros(N, jnp.float32)
    mem = jnp.ones((N, n), bool)
    cost = jnp.ones(n, jnp.float32)
    sel = jnp.zeros(n, bool)
    v, i = ops.eirate_topk(mu, sg, best, mem, cost, sel, k=6,
                           block_models=16, interpret=True)
    assert list(np.asarray(i)) == [0, 1, 2, 3, 4, 5]
    assert (np.asarray(v) == np.asarray(v)[0]).all()


# --- GP readout ----------------------------------------------------------------

@pytest.mark.parametrize("k,n,bk,bn", [
    (32, 64, 32, 64), (100, 257, 64, 128), (7, 1024, 512, 512), (512, 33, 128, 32),
])
def test_gp_readout_kernel_sweep(rng, k, n, bk, bn):
    W = jnp.asarray(rng.standard_normal((k, n)) * 0.3, jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(k), jnp.float32)
    mu0 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    kd = (W * W).sum(0) + jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    m1, v1 = ops.gp_readout(W, alpha, mu0, kd, block_n=bn, block_k=bk, interpret=True)
    m2, v2 = ref.gp_readout_ref(W, alpha, mu0, kd)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=2e-4, rtol=2e-4)
    # emit_sd epilogue: sigma in one pass, kernel and reference paths agree
    m3, s3 = ops.gp_readout(W, alpha, mu0, kd, block_n=bn, block_k=bk,
                            interpret=True, emit_sd=True)
    np.testing.assert_allclose(np.asarray(m3), np.asarray(m2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s3), np.sqrt(np.asarray(v2)),
                               atol=2e-4, rtol=2e-4)
    m4, s4 = ops.gp_readout(W, alpha, mu0, kd, emit_sd=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(s4), np.asarray(s3), atol=2e-4, rtol=2e-4)


# --- flash attention --------------------------------------------------------------

@pytest.mark.parametrize("S,Hq,Hkv,D,window,dtype", [
    (128, 4, 4, 32, None, jnp.float32),     # MHA
    (256, 8, 2, 16, None, jnp.float32),     # GQA 4:1
    (128, 4, 1, 32, None, jnp.float32),     # MQA
    (256, 4, 2, 32, 64, jnp.float32),       # sliding window
    (128, 4, 2, 32, None, jnp.bfloat16),    # bf16
])
def test_flash_attention_sweep(rng, S, Hq, Hkv, D, window, dtype):
    B = 2
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


def test_flash_blocks_divide_requirement(rng):
    q = jnp.asarray(rng.standard_normal((1, 96, 2, 16)), jnp.float32)
    with pytest.raises(AssertionError):
        ops.flash_attention(q, q, q, block_q=64, block_k=64, interpret=True)


# --- SSD ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,P,N,chunk,dtype", [
    (64, 2, 16, 8, 16, jnp.float32),
    (128, 4, 32, 16, 32, jnp.float32),
    (96, 3, 16, 8, 32, jnp.float32),
    (64, 2, 16, 8, 64, jnp.float32),        # single chunk
    (64, 2, 16, 8, 16, jnp.bfloat16),
])
def test_ssd_kernel_sweep(rng, S, H, P, N, chunk, dtype):
    B = 2
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, S, H)), jnp.float32)
    la = -dt * jnp.asarray(rng.uniform(0.5, 2.0, (1, 1, H)), jnp.float32)
    la = jnp.broadcast_to(la, (B, S, H))
    b = jnp.asarray(rng.standard_normal((B, S, N)), dtype)
    c = jnp.asarray(rng.standard_normal((B, S, N)), dtype)
    got = ops.ssd_mix(x, dt, la, b, c, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, dt, la, b, c)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **_tol(dtype))


# --- model-level XLA paths vs the same oracles ------------------------------------

def test_model_ssd_chunked_matches_recurrence(rng):
    """The substrate's chunked SSD (models/ssm.py) against the step oracle."""
    from repro.models.ssm import SSMConfig, ssm_specs, ssm_train
    from repro.models.layers import init_from_specs
    cfg = SSMConfig(d_model=32, d_inner=64, headdim=16, d_state=8, chunk=16)
    p = init_from_specs(ssm_specs(cfg), jax.random.PRNGKey(0))
    u = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)

    out_16 = ssm_train(p, u, cfg, None)
    out_64 = ssm_train(p, u, cfg._replace(chunk=64), None)   # single chunk
    np.testing.assert_allclose(np.asarray(out_16), np.asarray(out_64),
                               atol=1e-3, rtol=1e-3)


def test_model_attention_chunked_matches_ref(rng):
    from repro.models.attention import AttnConfig, attn_specs, attention_train
    from repro.models.layers import init_from_specs, rope
    cfg = AttnConfig(d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
                     q_chunk=16)
    p = init_from_specs(attn_specs(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)
    pos = jnp.arange(64)
    y_chunked = attention_train(p, x, pos, cfg, None)
    y_full = attention_train(p, x, pos, cfg._replace(q_chunk=64), None)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
