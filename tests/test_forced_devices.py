"""Direct unit tests for the shared forced-device subprocess recipe
(tests/conftest.py ``run_forced_devices_subprocess`` / the
``forced_devices`` fixture) — previously only exercised implicitly by the
sharding suites, so a recipe regression surfaced as a confusing cascade of
multi-device failures instead of one pointed test."""

import pytest


@pytest.mark.slow
def test_honors_device_count_and_parses_last_json_line(forced_devices):
    """The env recipe must actually fake the requested CPU device count,
    and the harness must return the LAST stdout line as JSON — earlier
    prints (progress noise, jax warnings redirected to stdout) must not
    break parsing."""
    res = forced_devices("""
        import json
        import jax
        print("preamble noise that is not JSON")
        print(json.dumps({"devices": len(jax.devices()),
                          "platform": jax.devices()[0].platform}))
    """, devices=3)
    assert res == {"devices": 3, "platform": "cpu"}


def test_failing_subprocess_surfaces_stderr(forced_devices):
    """A non-zero exit must fail the calling test with the subprocess's
    stderr in the assertion message (the only debugging handle there is)."""
    with pytest.raises(AssertionError, match="boom-marker"):
        forced_devices("""
            import sys
            sys.stderr.write("boom-marker\\n")
            sys.exit(7)
        """, devices=2)
