"""Maximum Incremental Uncertainty (Section 5.1)."""

import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.miu import (
    miu_cumulative_exact,
    miu_diag_paper_bound,
    miu_diag_upper_bound,
    miu_greedy,
    miu_s_exact,
)

from conftest import random_psd


def miu_det_ratio(K: np.ndarray, s: int) -> float:
    """Literal det(K_S)/det(K_S') definition, for cross-checking."""
    n = K.shape[0]
    best = 0.0
    for S in itertools.combinations(range(n), s):
        dS = np.linalg.det(K[np.ix_(S, S)])
        for Sp in itertools.combinations(S, s - 1):
            dSp = np.linalg.det(K[np.ix_(Sp, Sp)]) if Sp else 1.0
            if abs(dSp) > 1e-12:
                best = max(best, dS / dSp)
    return float(np.sqrt(max(best, 0.0)))


@pytest.mark.parametrize("n,s", [(4, 2), (5, 3), (6, 4)])
def test_exact_matches_det_ratio_definition(rng, n, s):
    K = random_psd(rng, n)
    assert abs(miu_s_exact(K, s) - miu_det_ratio(K, s)) < 1e-8


def test_diagonal_K_gives_max_sqrt_diag(rng):
    d = np.abs(rng.standard_normal(6)) + 0.1
    K = np.diag(d)
    expected = float(np.sqrt(d.max()))
    for s in range(1, 7):
        assert abs(miu_s_exact(K, s) - expected) < 1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_miu_nonincreasing_in_s(n, seed):
    """Conditioning on more points cannot raise the max conditional variance."""
    rng = np.random.default_rng(seed)
    K = random_psd(rng, n)
    vals = [miu_s_exact(K, s) for s in range(1, n + 1)]
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-9


def test_greedy_lower_bounds_exact(rng):
    for _ in range(5):
        K = random_psd(rng, 6)
        for s in (2, 3, 4):
            assert miu_greedy(K, s) <= miu_s_exact(K, s) + 1e-9


def test_diag_upper_bound(rng):
    """The corrected diagonal bound holds: MIU(T,K) <= (t-1) max sqrt(K_ii)."""
    for trial in range(5):
        K = random_psd(np.random.default_rng(trial), 6)
        for t in range(2, 7):
            assert miu_cumulative_exact(K, t) <= miu_diag_upper_bound(K, t) + 1e-9


def test_paper_diag_bound_is_false_counterexample():
    """Reproduction finding: the bound stated in Section 5.2 fails on a
    diagonal K with one dominant variance (documented in miu.py)."""
    K = np.diag([1.0, 1e-4, 1e-4])
    claimed = miu_diag_paper_bound(K, 3)     # 1 + 0.01 + 0.01
    actual = miu_cumulative_exact(K, 3)      # MIU_2 + MIU_3 = 1 + 1
    assert actual > claimed                  # the stated bound is violated
    assert actual <= miu_diag_upper_bound(K, 3) + 1e-12


def test_linearly_dependent_increment_is_zero():
    """Adding a variable that is a linear combination of S' adds no uncertainty."""
    v = np.array([[1.0, 0.5], [0.5, 2.0]])
    A = np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]])  # third = first + second
    K = A @ v @ A.T
    # with s = 3, the only choice is S = {0,1,2}; adding any element to the
    # other two is linearly determined -> MIU_3 ~ 0
    assert miu_s_exact(K, 3) < 1e-5
