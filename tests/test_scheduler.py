"""Event-driven MM-GP-EI scheduler + baselines (Algorithm 1, Section 6)."""

import numpy as np
import pytest

from repro.core import (
    POLICIES,
    FailureEvent,
    azure_problem,
    final_regret,
    regret_curves,
    simulate,
    synthetic_matern_problem,
)


@pytest.fixture(scope="module")
def small_problem():
    return synthetic_matern_problem(num_users=6, num_models_per_user=12, seed=3)


@pytest.mark.parametrize("policy", POLICIES)
def test_every_model_observed_exactly_once(small_problem, policy):
    res = simulate(small_problem, policy, num_devices=3, seed=0)
    observed = [t.model for t in res.trials if t.z is not None]
    assert sorted(observed) == list(range(small_problem.num_models))


def test_device_count_respected(small_problem):
    res = simulate(small_problem, "mdmt", num_devices=2, seed=0)
    # no more than 2 trials overlap at any time
    events = []
    for t in res.trials:
        events += [(t.start, 1), (t.end, -1)]
    events.sort()
    load, peak = 0, 0
    for _, d in events:
        load += d
        peak = max(peak, load)
    assert peak <= 2


def test_failure_requeues_model(small_problem):
    fails = [FailureEvent(device=0, at=2.5, downtime=1.0)]
    res = simulate(small_problem, "mdmt", num_devices=2, seed=0, failures=fails)
    failed = [t for t in res.trials if t.z is None]
    assert len(failed) == 1
    # the failed model is eventually observed anyway
    observed = {t.model for t in res.trials if t.z is not None}
    assert failed[0].model in observed


def test_more_devices_never_slower(small_problem):
    times = []
    for M in (1, 2, 4):
        res = simulate(small_problem, "mdmt", num_devices=M, seed=0)
        times.append(regret_curves(res).time_to_instantaneous(0.02))
    assert times[0] >= times[1] >= times[2]


def test_mdmt_beats_random_on_azure():
    """Paper Fig. 2 qualitative claim, averaged over seeds."""
    r_mdmt, r_rand = [], []
    for seed in range(4):
        prob = azure_problem(seed=seed)
        r_mdmt.append(final_regret(simulate(prob, "mdmt", 1, seed=seed)))
        r_rand.append(final_regret(simulate(prob, "random", 1, seed=seed)))
    assert np.mean(r_mdmt) < np.mean(r_rand)


def test_heterogeneous_devices_prefer_fast(small_problem):
    res = simulate(small_problem, "mdmt", num_devices=2, seed=0,
                   device_speeds=np.array([1.0, 4.0]))
    per_dev = {0: 0, 1: 0}
    for t in res.trials:
        per_dev[t.device] += 1
    assert per_dev[1] > per_dev[0]


def test_warm_start_two_fastest():
    prob = synthetic_matern_problem(num_users=3, num_models_per_user=8,
                                    seed=1, cost="lognormal")
    res = simulate(prob, "mdmt", num_devices=1, seed=0, warm_start=2)
    first6 = [t.model for t in res.trials[:6]]
    for u in range(3):
        idx = np.nonzero(prob.membership[u])[0]
        fastest2 = set(idx[np.argsort(prob.cost[idx])][:2])
        assert fastest2 <= set(first6)
