"""EI / EIrate (eqs. 3-6) and Lemma 1."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ei import (
    choose_next,
    ei_total,
    eirate_scores,
    expected_improvement,
    tau,
)


def test_lemma1_against_monte_carlo(rng):
    """E[max(X - a, 0)] = sigma * tau((mu - a)/sigma) for X ~ N(mu, sigma^2)."""
    for mu, sigma, a in [(0.0, 1.0, 0.5), (1.2, 0.3, 1.0), (-0.5, 2.0, 0.0)]:
        xs = rng.normal(mu, sigma, size=2_000_000)
        mc = np.maximum(xs - a, 0.0).mean()
        cf = float(expected_improvement(
            jnp.float32(mu), jnp.float32(sigma), jnp.float32(a)))
        assert abs(mc - cf) < 5e-3, (mu, sigma, a, mc, cf)


@settings(max_examples=50, deadline=None)
@given(st.floats(-20, 20))
def test_tau_properties(x):
    """tau(u) >= max(u, 0), monotone nondecreasing, tau(u) - tau(-u) = u."""
    t = float(tau(jnp.float32(x)))
    assert t >= max(x, 0.0) - 1e-4
    assert abs((t - float(tau(jnp.float32(-x)))) - x) < 1e-3
    assert float(tau(jnp.float32(x + 0.1))) >= t - 1e-5


def test_sigma_zero_degenerates_to_plus_part():
    ei = expected_improvement(
        jnp.asarray([1.0, 0.2]), jnp.asarray([0.0, 0.0]), jnp.asarray(0.5))
    np.testing.assert_allclose(np.asarray(ei), [0.5, 0.0], atol=1e-6)


def test_ei_total_sums_over_owners(rng):
    n, N = 6, 3
    mu = jnp.asarray(rng.standard_normal(n), jnp.float32)
    sigma = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32)) + 0.1
    best = jnp.asarray(rng.standard_normal(N), jnp.float32)
    member = np.zeros((N, n), bool)
    member[0, :4] = True
    member[1, 2:] = True      # overlap on models 2,3
    member[2, 0] = True
    total = np.asarray(ei_total(mu, sigma, best, jnp.asarray(member)))
    per_user = [np.asarray(ei_total(mu, sigma, best[i:i+1],
                                    jnp.asarray(member[i:i+1]))) for i in range(N)]
    np.testing.assert_allclose(total, sum(per_user), atol=1e-5)


def test_eirate_masks_selected_and_divides_cost(rng):
    n, N = 5, 2
    mu = jnp.zeros(n)
    sigma = jnp.ones(n)
    best = jnp.zeros(N)
    member = jnp.ones((N, n), bool)
    cost = jnp.asarray([1.0, 2.0, 4.0, 1.0, 1.0])
    selected = jnp.asarray([False, False, False, True, False])
    scores = np.asarray(eirate_scores(mu, sigma, best, member, cost, selected))
    assert scores[3] == -np.inf
    assert abs(scores[0] / scores[1] - 2.0) < 1e-5
    assert abs(scores[0] / scores[2] - 4.0) < 1e-5
    idx, val = choose_next(mu, sigma, best, member, cost, selected)
    assert int(idx) in (0, 4) and np.isfinite(float(val))


def test_cheap_model_preferred_at_equal_ei():
    """EIrate (eq. 5) is the tie-breaker the paper adds over plain EI."""
    n = 2
    mu, sigma = jnp.zeros(n), jnp.ones(n)
    best = jnp.zeros(1)
    member = jnp.ones((1, n), bool)
    cost = jnp.asarray([10.0, 1.0])
    idx, _ = choose_next(mu, sigma, best, member, cost, jnp.zeros(n, bool))
    assert int(idx) == 1
