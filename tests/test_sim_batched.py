"""Batched synchronous-slot engine vs the event-driven simulator (DESIGN.md §6)."""

import numpy as np
import pytest

from repro.core import (
    EpisodeSpec,
    regret_curves,
    simulate,
    simulate_batch,
    synthetic_matern_problem,
)


@pytest.fixture(scope="module")
def problem():
    # 3 tenants x 8 models: the acceptance problem, small enough that every
    # test shares one jit entry per (shape) signature.
    return synthetic_matern_problem(num_users=3, num_models_per_user=8, seed=5)


def event_sequence(res):
    return [(t.model, t.user_hint, t.device) for t in res.trials]


def batched_sequence(batch, i):
    n = batch.problem.num_models
    return [(int(batch.trial_model[i, j]), int(batch.trial_user[i, j]),
             int(batch.trial_device[i, j])) for j in range(n)]


def assert_episode_matches(problem, batch, i, res):
    """Trial-for-trial equality: models/devices/hints exact, times close."""
    assert batched_sequence(batch, i) == event_sequence(res)
    np.testing.assert_allclose(
        batch.trial_start[i], [t.start for t in res.trials], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        batch.trial_end[i], [t.end for t in res.trials], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        batch.trial_z[i], [t.z for t in res.trials], rtol=1e-6)


@pytest.mark.parametrize("policy", ["mdmt", "round_robin"])
def test_matches_event_engine(problem, policy):
    """The acceptance gate: identical seed => identical trial sequence."""
    res = simulate(problem, policy, num_devices=2, seed=0)
    batch = simulate_batch(problem, [EpisodeSpec(policy, 2, 0)])
    assert_episode_matches(problem, batch, 0, res)


def test_matches_event_engine_no_warm_start(problem):
    """Algorithm 1 line 1-2 initialization (prior-mean argmax per tenant)."""
    res = simulate(problem, "mdmt", num_devices=2, seed=0, warm_start=0)
    batch = simulate_batch(problem, [EpisodeSpec("mdmt", 2, 0)], warm_start=0)
    assert_episode_matches(problem, batch, 0, res)


def test_heterogeneous_device_speeds(problem):
    """Device-aware EIrate: durations scale by speed, sequence still matches."""
    speeds = (1.0, 4.0)
    res = simulate(problem, "mdmt", num_devices=2, seed=3,
                   device_speeds=np.asarray(speeds))
    batch = simulate_batch(
        problem, [EpisodeSpec("mdmt", 2, 3, device_speeds=speeds)])
    assert_episode_matches(problem, batch, 0, res)
    # the fast device does more of the work
    per_dev = np.bincount(batch.trial_device[0], minlength=2)
    assert per_dev[1] > per_dev[0]


def test_vmap_batch_matches_singleton_runs(problem):
    """vmap over episodes == python loop of single-episode batches."""
    specs = [
        EpisodeSpec("mdmt", 2, 0),
        EpisodeSpec("round_robin", 2, 1),
        EpisodeSpec("random", 2, 2),
        EpisodeSpec("mdmt", 1, 3),
    ]
    batch = simulate_batch(problem, specs)
    for i, spec in enumerate(specs):
        # pad with a throwaway episode so Mmax (a static shape) is unchanged
        single = simulate_batch(problem, [spec, EpisodeSpec("mdmt", 2, 99)])
        assert batched_sequence(batch, i) == batched_sequence(single, 0)
        np.testing.assert_array_equal(batch.trial_start[i], single.trial_start[0])
        np.testing.assert_array_equal(batch.trial_end[i], single.trial_end[0])


@pytest.mark.parametrize("policy", ["mdmt", "round_robin", "random"])
def test_every_model_observed_exactly_once(problem, policy):
    batch = simulate_batch(problem, [EpisodeSpec(policy, 2, 0)])
    assert sorted(batch.trial_model[0].tolist()) == list(range(problem.num_models))


def test_regret_curves_match_host_metrics(problem):
    """In-scan regret integration vs the exact host-side regret.py curves."""
    specs = [EpisodeSpec("mdmt", 2, 0), EpisodeSpec("round_robin", 2, 1)]
    batch = simulate_batch(problem, specs)
    for i in range(len(specs)):
        curves = regret_curves(batch.episode_result(i))
        mask = batch.obs_model[i] >= 0
        times = batch.obs_time[i][mask]
        np.testing.assert_allclose(times, curves.times[1:], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            batch.cum_regret[i][mask], curves.cumulative[1:],
            rtol=1e-3, atol=1e-2)
        # Simultaneous finishes are folded in launch order by the scan but in
        # model-index order by regret.py, so the instantaneous trace is only
        # comparable at tie-group boundaries (where both orders have absorbed
        # the same observation set).
        last_of_time = np.r_[np.diff(times) > 1e-9, True]
        np.testing.assert_allclose(
            batch.inst_regret[i][mask][last_of_time],
            curves.instantaneous[1:][last_of_time],
            rtol=1e-4, atol=1e-5)


def test_instantaneous_regret_monotone(problem):
    """Best-so-far only improves, so the mean per-user gap never rises."""
    batch = simulate_batch(
        problem, [EpisodeSpec(p, 2, s) for s in range(2)
                  for p in ("mdmt", "round_robin", "random")])
    for i in range(batch.num_episodes):
        inst = batch.inst_regret[i][batch.obs_model[i] >= 0]
        assert (np.diff(inst) <= 1e-6).all()


def test_per_episode_z_true_override(problem):
    """Many-seed mode: fresh GP sample per episode, shared prior."""
    other = synthetic_matern_problem(num_users=3, num_models_per_user=8, seed=9)
    batch = simulate_batch(problem, [
        EpisodeSpec("mdmt", 2, 0),
        EpisodeSpec("mdmt", 2, 0, z_true=other.z_true),
    ])
    # episode 1 must behave as if the problem had `other`'s ground truth
    res = simulate(other, "mdmt", num_devices=2, seed=0)
    assert batched_sequence(batch, 1) == event_sequence(res)
    # and the two episodes genuinely differ
    assert batched_sequence(batch, 0) != batched_sequence(batch, 1)


def test_episode_result_respects_z_override(problem):
    """regret.py metrics on an overridden episode must use the override's
    ground truth (z_star/worst), not the shared problem's."""
    other = synthetic_matern_problem(num_users=3, num_models_per_user=8, seed=9)
    batch = simulate_batch(
        problem, [EpisodeSpec("mdmt", 2, 0, z_true=other.z_true)])
    res = batch.episode_result(0)
    np.testing.assert_array_equal(res.problem.z_true, other.z_true)
    curves = regret_curves(res)
    ref = regret_curves(simulate(other, "mdmt", num_devices=2, seed=0))
    np.testing.assert_allclose(curves.cumulative, ref.cumulative, rtol=1e-5)
    # trial z values round-trip through float32, so allow f32-level slack
    assert (curves.instantaneous >= -1e-6).all()


def test_synthetic_matern_z_matches_problem():
    """The cheap many-seed sampler must replay the full generator's draw."""
    from repro.core import synthetic_matern_z
    full = synthetic_matern_problem(num_users=4, num_models_per_user=6, seed=11)
    np.testing.assert_array_equal(
        synthetic_matern_z(num_users=4, num_models_per_user=6, seed=11),
        full.z_true)


def test_rejects_non_block_problems(problem):
    membership = np.ones((2, problem.num_models), dtype=bool)  # overlapping
    bad = type(problem)(
        K=problem.K, mu0=problem.mu0, z_true=problem.z_true,
        cost=problem.cost, membership=membership)
    with pytest.raises(ValueError):
        simulate_batch(bad, [EpisodeSpec("mdmt", 1, 0)])
