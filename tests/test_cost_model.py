"""Direct coverage for the roofline cost model (core/cost_model.py).

Previously only exercised indirectly through test_service / test_launch;
these tests pin the three behaviors the device plane now leans on: the
analytic fallback when no probe JSON exists, chips -> step-time scaling,
and the measured-duration EMA blend (Remark 1's "historical data")."""

import json

import pytest

from repro.core import cost_model as cm
from repro.core.cost_model import REFERENCE_CHIPS, CostModel

ARCH, SHAPE = "olmo-1b", "train_4k"


# --- analytic fallback --------------------------------------------------------

def test_analytic_fallback_when_no_probe(tmp_path, monkeypatch):
    """No probe JSON for the cell => the analytic roofline answers, and it
    is positive and finite."""
    monkeypatch.setattr(cm, "DRYRUN_DIR", tmp_path)   # guaranteed empty
    m = CostModel()
    assert m._probe(ARCH, SHAPE) is None
    step = m.step_seconds(ARCH, SHAPE, chips=REFERENCE_CHIPS)
    assert 0.0 < step < float("inf")
    # trial cost = overhead + steps * step time
    assert m.trial_seconds(ARCH, SHAPE, steps=10, overhead=30.0) == \
        pytest.approx(30.0 + 10 * step)


def test_probe_json_preferred_over_analytic(tmp_path, monkeypatch):
    monkeypatch.setattr(cm, "DRYRUN_DIR", tmp_path)
    cell = tmp_path / "pod16x16"
    cell.mkdir()
    (cell / f"{ARCH}__{SHAPE}__default__probe.json").write_text(json.dumps(
        {"compute_seconds": 0.5, "memory_seconds": 0.2,
         "collective_seconds": 0.1}))
    m = CostModel()
    # the roofline max of the probe terms at reference chips
    assert m.step_seconds(ARCH, SHAPE, chips=REFERENCE_CHIPS) == \
        pytest.approx(0.5)
    # fewer chips => proportionally more per-chip work
    assert m.step_seconds(ARCH, SHAPE, chips=REFERENCE_CHIPS // 4) == \
        pytest.approx(2.0)


# --- chips scaling ------------------------------------------------------------

def test_step_time_monotone_in_chips(tmp_path, monkeypatch):
    """More chips per slice => strictly smaller step time (both the compute
    and the memory roofline terms scale with the slice size)."""
    monkeypatch.setattr(cm, "DRYRUN_DIR", tmp_path)
    m = CostModel()
    steps = [m.step_seconds(ARCH, SHAPE, chips=c) for c in (16, 32, 64, 256)]
    assert all(a > b for a, b in zip(steps, steps[1:]))


def test_class_trial_seconds_affine_overhead(tmp_path, monkeypatch):
    """The device-class route: speed divides the step term only — the fixed
    overhead is host-bound — so the per-class cost is affine, not rank-1."""
    monkeypatch.setattr(cm, "DRYRUN_DIR", tmp_path)
    m = CostModel()
    slow = m.class_trial_seconds(ARCH, SHAPE, 10, chips=64, speed=1.0,
                                 overhead=30.0)
    fast = m.class_trial_seconds(ARCH, SHAPE, 10, chips=64, speed=2.0,
                                 overhead=30.0)
    assert fast - 30.0 == pytest.approx((slow - 30.0) / 2.0)
    assert fast > 30.0                      # overhead never disappears
    with pytest.raises(ValueError):
        m.class_trial_seconds(ARCH, SHAPE, 10, chips=64, speed=0.0)


# --- measured-duration EMA blend ----------------------------------------------

def test_observe_ema_and_blend(tmp_path, monkeypatch):
    monkeypatch.setattr(cm, "DRYRUN_DIR", tmp_path)
    m = CostModel()
    base = m.trial_seconds(ARCH, SHAPE, steps=10, chips=64)
    # first observation seeds the EMA at the measured value
    m.observe(ARCH, SHAPE, 64, 100.0)
    assert m._measured[(ARCH, SHAPE, 64)] == pytest.approx(100.0)
    # second observation: EMA with weight 0.5
    m.observe(ARCH, SHAPE, 64, 50.0)
    assert m._measured[(ARCH, SHAPE, 64)] == pytest.approx(75.0)
    # estimate blends analytic and measured with measured_blend
    est = m.trial_seconds(ARCH, SHAPE, steps=10, chips=64)
    assert est == pytest.approx(0.5 * base + 0.5 * 75.0)
    # other (arch, shape, chips) keys are untouched
    assert m.trial_seconds(ARCH, SHAPE, steps=10, chips=128) == \
        pytest.approx(m.trial_seconds(ARCH, SHAPE, steps=10, chips=128))
    assert (ARCH, SHAPE, 128) not in m._measured
