import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_psd(rng, n: int, scale: float = 1.0) -> np.ndarray:
    A = rng.standard_normal((n, n))
    K = A @ A.T / n + 0.25 * np.eye(n)
    return scale * K
