import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_psd(rng, n: int, scale: float = 1.0) -> np.ndarray:
    A = rng.standard_normal((n, n))
    K = A @ A.T / n + 0.25 * np.eye(n)
    return scale * K


def run_forced_devices_subprocess(code: str, devices: int = 8) -> dict:
    """Run ``code`` in a subprocess with ``devices`` faked CPU devices and
    return the JSON printed on its last stdout line.  Multi-device tests
    must run out of process: xla_force_host_platform_device_count only
    takes effect before jax initializes, and must not leak into the
    single-device test session.  Shared by test_sharding and test_shardgp —
    the env recipe here (JAX_PLATFORMS=cpu pins past minutes of libtpu
    probing on images that bundle it) must stay in one place."""
    prog = textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", prog],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS":
                 f"--xla_force_host_platform_device_count={devices}"},
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.fixture
def forced_devices():
    """The forced-device subprocess recipe as a fixture — test modules that
    only need to *run* code on a faked multi-device host take this instead
    of importing the helper, keeping the env recipe in one place."""
    return run_forced_devices_subprocess
