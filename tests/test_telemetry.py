"""TelemetrySink edge cases: tenants departing before their first
observation, all-None/±inf percentile inputs, and the metrics-registry
ride-along.  The load-bearing contract: ``summary()`` and ``per_tenant()``
yield explicit nulls — never NaN/±inf — so every JSON export in the repo
can run with ``allow_nan=False``."""

from __future__ import annotations

import json

import numpy as np

from repro.obs import MetricsRegistry
from repro.stream.telemetry import TelemetrySink, _pct


def test_depart_before_first_observation_yields_nulls():
    tel = TelemetrySink()
    tel.on_arrive(0.0, 7, best_possible=1.0)
    tel.on_admit(0.1, 7)
    tel.on_depart(0.5, 7)              # never observed: zero trials ran
    tel.on_end(1.0, num_slices=2)
    s = tel.summary()
    assert s["sessions"] == 1 and s["sessions_served"] == 0
    assert s["ttfo_p50"] is None and s["ttfo_p99"] is None
    assert s["serve_gap_p50"] is None and s["serve_gap_max"] is None
    assert s["tenant_regret_mean"] is None and s["tenant_regret_max"] is None
    json.dumps(s, allow_nan=False)     # the whole point: no NaN/-inf leaks
    pt = tel.per_tenant()[7]
    assert pt["best_z"] is None and pt["regret"] is None
    json.dumps(pt, allow_nan=False)


def test_depart_of_never_seen_tenant_is_ignored():
    tel = TelemetrySink()
    tel.on_depart(1.0, 99)             # mid-stream replay: no KeyError
    assert 99 not in tel.tenants
    json.dumps(tel.summary(), allow_nan=False)


def test_pct_filters_none_and_nonfinite():
    assert _pct([], 50) is None
    assert _pct([None, None], 99) is None
    assert _pct([np.inf, -np.inf, np.nan, None], 50) is None
    assert _pct([None, 1.0, 3.0, np.inf], 50) == 2.0


def test_observation_for_unknown_tenant_counts_busy_only():
    tel = TelemetrySink()
    tel.on_observation(1.0, 42, model=3, z=0.5, duration=1.0)
    assert tel.tenants == {} and tel.busy_seconds == 1.0


def test_unknown_best_possible_keeps_regret_null():
    # a tenant whose true optimum is unknown (best_possible=inf) must not
    # poison the fleet regret aggregate even after being served
    tel = TelemetrySink()
    tel.on_arrive(0.0, 1, best_possible=np.inf)
    tel.on_admit(0.0, 1)
    tel.on_observation(1.0, 1, model=0, z=0.7, duration=1.0)
    tel.on_depart(2.0, 1)
    tel.on_end(2.0, num_slices=1)
    s = tel.summary()
    assert s["sessions_served"] == 1
    assert s["ttfo_p50"] == 1.0
    assert s["tenant_regret_mean"] is None
    assert tel.per_tenant()[1]["regret"] is None
    json.dumps(s, allow_nan=False)


def test_to_json_carries_metrics_snapshot(tmp_path):
    tel = TelemetrySink()
    tel.on_arrive(0.0, 1, best_possible=1.0)
    tel.on_admit(0.0, 1)
    tel.on_observation(1.0, 1, model=0, z=0.7, duration=1.0)
    tel.on_end(2.0, num_slices=1)
    reg = MetricsRegistry()
    reg.counter("engine.events").inc(5)
    reg.histogram("engine.decision_seconds").observe(1e-3)
    path = tel.to_json(tmp_path / "tel.json", metrics=reg)
    payload = json.loads(path.read_text())
    assert payload["metrics"]["counters"]["engine.events"] == 5
    hist = payload["metrics"]["histograms"]["engine.decision_seconds"]
    assert hist["count"] == 1
    assert payload["summary"]["trials"] == 0   # launches are engine-side
