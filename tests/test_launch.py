"""Launch-layer units that need no devices: cell specs, cost model probes,
collective parser, report rendering."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, get_smoke_config
from repro.core.cost_model import CostModel
from repro.launch.hlo_analysis import (
    CollectiveStats,
    model_flops_for_cell,
    parse_collectives,
)
from repro.launch.specs import batch_specs, input_specs, rules_for_shape
from repro.sharding.rules import DEFAULT_RULES, ParamSpec


def test_cells_inventory_matches_applicability():
    cs = cells()
    assert len(cs) == 33          # 40 assigned minus 7 documented long_500k skips
    long_archs = {a for a, s in cs if s == "long_500k"}
    assert long_archs == {"mamba2-1.3b", "zamba2-2.7b", "h2o-danube-3-4b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_cover_every_shape(arch):
    cfg = get_config(arch)
    for shape, (S, B, kind) in SHAPES.items():
        specs = batch_specs(cfg, shape)
        sds = input_specs(cfg, shape)
        assert set(specs) == set(sds)
        for k, spec in specs.items():
            assert isinstance(spec, ParamSpec)
            assert sds[k].shape == spec.shape
            assert spec.shape[0] == B          # leading dim is global batch
        if kind == "train":
            assert "labels" in specs
        if kind in ("decode", "long_decode"):
            lead = specs.get("tokens", specs.get("frames"))
            assert lead.shape[1] == 1          # one new token


def test_long_decode_rules_unshard_batch():
    cfg = get_config("mamba2-1.3b")
    r = rules_for_shape(cfg, "long_500k", DEFAULT_RULES)
    assert r.lookup("batch") is None
    assert r.lookup("kv_seq") == "data"


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = f32[16,256]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) all-reduce(%a, %b), replica_groups=[32,8]<=[256]
  %rs = f32[4,64]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3}}
  %cp = f32[2,2]{1,0} collective-permute(%z)
  %done = f32[1]{0} all-reduce-done(%w)
"""
    stats = parse_collectives(hlo, num_devices=256)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                            "reduce-scatter": 1, "collective-permute": 1}
    ag = 15 / 16 * 16 * 256 * 4
    ar = 2 * 7 / 8 * (2 * 8 * 128 * 2)
    rs = 3 * 4 * 64 * 4
    cp = 2 * 2 * 4
    assert stats.wire_bytes == pytest.approx(ag + ar + rs + cp)


def test_model_flops_conventions():
    cfg = get_config("qwen3-8b")
    n = cfg.param_count()
    assert model_flops_for_cell(cfg, "train_4k") == pytest.approx(6 * n * 4096 * 256)
    assert model_flops_for_cell(cfg, "prefill_32k") == pytest.approx(2 * n * 32768 * 32)
    assert model_flops_for_cell(cfg, "decode_32k") == pytest.approx(2 * n * 128)
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.15 * moe.param_count()


def test_cost_model_reads_probe_json(tmp_path, monkeypatch):
    import repro.core.cost_model as cm_mod
    mesh_dir = tmp_path / "pod16x16"
    mesh_dir.mkdir(parents=True)
    rec = {"compute_seconds": 0.010, "memory_seconds": 0.050,
           "collective_seconds": 0.002}
    (mesh_dir / "fake-arch__train_4k__default__probe.json").write_text(json.dumps(rec))
    monkeypatch.setattr(cm_mod, "DRYRUN_DIR", tmp_path)
    cm = CostModel()
    # roofline max-term on 256 chips, linearly rescaled to a 64-chip slice
    assert cm.step_seconds("fake-arch", "train_4k", chips=64) == pytest.approx(0.050 * 4)
    t = cm.trial_seconds("fake-arch", "train_4k", steps=100, chips=256, overhead=30)
    assert t == pytest.approx(30 + 100 * 0.050)


def test_report_renders(tmp_path):
    from repro.launch import report
    # uses the real experiments/ dir; just assert it renders without raising
    out = report.roofline_table("pod16x16")
    assert "roofline" in out or "| arch |" in out
