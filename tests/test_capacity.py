"""Capacity observability plane (DESIGN.md §15).

Three contracts:

* **analytic accounting** — ``resource_stats``/``capacity_stats``/
  ``occupancy`` report exactly the bytes/slots the closed-form formulas
  give ((2n²+3n)·itemsize alloc, (k·n+k)·itemsize active, 2·cap·4 readout)
  across the full tenant lifecycle (add → observe → retire → compact), and
  the accountant's projection is the least-squares slope at horizon.
* **observation-only + replay-stable** — a run with the accountant (and
  the memory watchdog) attached makes byte-identical decisions to a bare
  twin, and a crash-recovered run re-emits the identical capacity-sample
  suffix (the cursor rides in the engine snapshot; samples do not).
* **regression plane** — ``benchmarks/regress.py`` flags a synthetic 2x
  regression, stays quiet inside the noise floor, and *refuses* (skips)
  cross-environment / cross-schema / legacy comparisons instead of
  averaging apples with oranges.
"""

import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from conftest import random_psd
from repro.core.control_plane import ControlPlane
from repro.core.fleet import Fleet
from repro.core.gp import IncrementalGP
from repro.devplane import DevPlaneEngine, two_class_registry
from repro.obs import (
    CapacityAccountant,
    HealthMonitor,
    MetricsExporter,
    MetricsRegistry,
)
from repro.stream import (
    EventLog,
    FaultInjector,
    SimulatedCrash,
    StreamEngine,
    device_churn_trace,
    poisson_churn_trace,
    recover,
)
from test_eventlog import assert_replay_matches, run_reference


# ---- analytic byte accounting ------------------------------------------------

def _analytic(m: int, k: int, item: int) -> tuple[int, int]:
    """(alloc_bytes, active_bytes) for one block: W + K (m,m) each plus
    alpha/diag_acc/mu0 (m,) each; active = k Cholesky rows of W + k alpha."""
    return (2 * m * m + 3 * m) * item, (k * m + k) * item


def test_incremental_gp_resource_stats_analytic(rng):
    m = 7
    gp = IncrementalGP(random_psd(rng, m, 0.04), np.zeros(m))
    item = gp.K.dtype.itemsize
    for k in range(4):
        stats = gp.resource_stats()
        alloc, active = _analytic(m, k, item)
        assert stats["models"] == m and stats["obs"] == k
        assert stats["alloc_bytes"] == alloc
        assert stats["active_bytes"] == active
        assert stats["dtype_bytes"] == item
        if k < 4:
            gp.observe(k, float(rng.uniform()))


def test_block_gp_accounting_across_tenant_lifecycle(rng):
    """capacity_stats stays analytically exact through add_tenant /
    record_observation / retire_tenant / compact, keyed by tenant slot."""
    cp = ControlPlane(np.random.default_rng(0), model_capacity=64,
                      tenant_capacity=8, num_shards=2)
    sizes = {0: 3, 1: 5, 2: 4}
    obs_per = {0: 2, 1: 0, 2: 3}
    handles = {}
    for tid, m in sizes.items():
        h = cp.add_tenant(random_psd(rng, m, 0.04), np.zeros(m), np.ones(m))
        handles[h.tenant_id] = h
        for j in range(obs_per[tid]):
            g = int(h.models[j])
            cp.record_start(g)
            cp.record_observation(g, float(rng.uniform(0.2, 0.8)))

    def check(live: dict):
        stats = cp.capacity_stats()
        gp, layout = stats["gp"], stats["layout"]
        assert set(gp["tenants"]) == set(live)
        for tid, b in gp["tenants"].items():
            m, k, item = live[tid], obs_per[tid], b["dtype_bytes"]
            alloc, active = _analytic(m, k, item)
            assert (b["models"], b["obs"]) == (m, k)
            assert b["alloc_bytes"] == alloc and b["active_bytes"] == active
        assert gp["num_blocks"] == len(live)
        assert gp["obs_total"] == sum(obs_per[t] for t in live)
        assert gp["alloc_bytes"] == sum(
            b["alloc_bytes"] for b in gp["tenants"].values())
        assert gp["active_bytes"] == sum(
            b["active_bytes"] for b in gp["tenants"].values())
        assert gp["readout_bytes"] == 2 * gp["capacity"] * 4
        # layout occupancy: slot counts are exact, imbalance = max/mean
        live_slots = sum(live.values())
        assert layout["slots_live"] == live_slots
        assert sum(layout["per_shard"]) == live_slots
        assert layout["slots_total"] == \
            layout["slots_live"] + layout["slots_free"]
        counts = layout["per_shard"]
        if live_slots:
            assert layout["imbalance"] == pytest.approx(
                max(counts) / (live_slots / len(counts)))

    check(dict(sizes))
    cp.retire_tenant(1)
    check({0: 3, 2: 4})
    cp.compact()
    check({0: 3, 2: 4})


def test_accountant_projection_is_least_squares_slope():
    """Byte growth of 10 B/sim-s projected 60 s ahead => +600 B; the tick
    cursor samples once per window and round-trips through state_dict."""

    class _Shim:
        def __init__(self):
            self.bytes = 100.0
            self.fleet = type("F", (), {"slices": []})()
            self.health = None
            self.cp = self

        def capacity_stats(self):
            return {"gp": {"num_blocks": 1, "capacity": 8, "obs_total": 0,
                           "alloc_bytes": self.bytes, "active_bytes": 0,
                           "readout_bytes": 0, "tenants": {}},
                    "layout": None}

        def _capacity_extra(self):
            return {"scoring_passes": 5}

    shim = _Shim()
    reg = MetricsRegistry()
    acc = CapacityAccountant(reg, window=10.0, horizon=60.0)
    r0 = acc.sample(0.0, 0, shim)
    assert r0["gp_bytes_slope"] == 0.0
    assert r0["gp_bytes_projected"] == 100
    shim.bytes = 200.0
    acc.tick(10.0, 1, shim)
    r1 = acc.samples[-1]
    assert r1["gp_bytes_slope"] == pytest.approx(10.0)
    assert r1["gp_bytes_projected"] == 800     # 200 + 10 * 60
    assert r1["scoring_passes"] == 5           # _capacity_extra flows through
    # gauges published under capacity.*
    snap = reg.snapshot()["gauges"]
    assert snap["capacity.gp_bytes"]["value"] == 200
    assert snap["capacity.gp_bytes_projected"]["value"] == 800
    assert snap["capacity.scoring_passes"]["value"] == 5
    # tick is once-per-window...
    acc.tick(12.0, 2, shim)
    assert len(acc.samples) == 2
    # ...and the cursor + projection history survive a snapshot round-trip
    acc2 = CapacityAccountant(MetricsRegistry(), window=10.0, horizon=60.0)
    acc2.load_state(acc.state_dict())
    assert acc2.samples == []                  # suffix-only re-emission
    acc2.tick(15.0, 3, shim)
    assert acc2.samples == []                  # window 1 already emitted
    shim.bytes = 300.0
    acc2.tick(20.0, 4, shim)
    assert acc2.samples[-1]["gp_bytes_slope"] == pytest.approx(10.0)


def test_memory_runaway_watchdog_arms_and_rearms():
    h = HealthMonitor(memory_budget_bytes=1000.0)
    # projected over budget but measured under: warn, then disarm
    h.on_capacity(0.0, 1, bytes_now=500.0, projected_bytes=1200.0)
    h.on_capacity(1.0, 2, bytes_now=600.0, projected_bytes=1300.0)
    assert [(a.kind, a.severity) for a in h.alerts] == \
        [("memory_runaway", "warn")]
    # drop below 80% of budget re-arms without alerting
    h.on_capacity(2.0, 3, bytes_now=600.0, projected_bytes=700.0)
    assert len(h.alerts) == 1
    # measured over budget: page
    h.on_capacity(3.0, 4, bytes_now=1500.0, projected_bytes=1500.0)
    assert [(a.kind, a.severity) for a in h.alerts] == \
        [("memory_runaway", "warn"), ("memory_runaway", "page")]
    assert h.alerts[-1].detail["budget_bytes"] == 1000.0
    # no budget => no-op
    h2 = HealthMonitor()
    h2.on_capacity(0.0, 1, bytes_now=1e9, projected_bytes=1e9)
    assert h2.alerts == []


# ---- observation-only + replay-stable ----------------------------------------

def _churny_trace():
    return poisson_churn_trace(num_sessions=10, arrival_rate=1.2, seed=6,
                               m_min=2, m_max=8, session_scale=12.0,
                               num_failure_slices=1)


def _factory(bag):
    def make(**kw):
        reg = MetricsRegistry()
        planes = dict(
            metrics=reg,
            exporter=MetricsExporter(reg, window=5.0),
            health=HealthMonitor(slo={"device_utilization": 1.5},
                                 window=5.0, burn_windows=2, stall_k=4,
                                 queue_limit=2,
                                 memory_budget_bytes=4096.0),
            accounting=CapacityAccountant(reg, window=5.0))
        bag.append(planes)
        return StreamEngine(Fleet.partition_pod(16 * 3, 3), "mdmt",
                            seed=0, max_live_models=30, num_shards=2,
                            **planes, **kw)
    return make


def test_accounting_is_observation_only_and_tracks_final_state():
    trace = _churny_trace()
    bag = []
    eng = _factory(bag)()
    res = eng.run(trace)
    twin = StreamEngine(Fleet.partition_pod(16 * 3, 3), "mdmt", seed=0,
                        max_live_models=30, num_shards=2).run(trace)
    assert [dataclasses.astuple(t) for t in res.trials] == \
        [dataclasses.astuple(t) for t in twin.trials]

    acc = bag[0]["accounting"]
    assert len(acc.samples) >= 2
    # the end-of-run sample equals a fresh introspection of the final plane
    final = acc.samples[-1]
    stats = eng.cp.capacity_stats()
    assert final["gp_alloc_bytes"] == stats["gp"]["alloc_bytes"]
    assert final["gp_obs"] == stats["gp"]["obs_total"]
    assert final["slots_live"] == stats["layout"]["slots_live"]
    assert final["shard_slots"] == list(stats["layout"]["per_shard"])
    # devices gauge counts the live fleet by class
    assert sum(final["devices"].values()) == \
        sum(1 for s in eng.fleet.slices if not s.retired)
    # the engine auto-wired the exporter to the health plane: records and
    # the scrape surface both carry per-kind alert counts
    assert eng.exporter.health is eng.health
    assert all("alerts" in r for r in eng.exporter.records)
    if eng.health.alerts:
        kind = eng.health.alerts[0].kind
        assert f'health_alerts_total{{kind="{kind}"}}' \
            in eng.exporter.prometheus()


def test_capacity_samples_replay_stable_across_crash(tmp_path):
    """§15 replay contract: the sample cursor rides in the snapshot, the
    samples themselves do not — a recovered run re-emits exactly the
    uninterrupted run's sample suffix, record-for-record."""
    trace = _churny_trace()
    ref_bag = []
    ref_eng, ref_res = run_reference(_factory(ref_bag), trace)
    ref_samples = ref_bag[0]["accounting"].samples
    assert len(ref_samples) >= 3, "trace too short to exercise replay"
    n = ref_eng.event_index

    for crash_at in (2, n // 2, n - 1):
        bag = []
        make = _factory(bag)
        workdir = tmp_path / f"c{crash_at}"
        eng = make(log=EventLog(workdir / "log"),
                   snapshot_root=str(workdir / "snap"), snapshot_every=5,
                   fault=FaultInjector(crash_at, "before"))
        with pytest.raises(SimulatedCrash):
            eng.run(trace)
        eng.log.close()
        durable = EventLog.load(workdir / "log")
        eng2, resumed_from = recover(make, str(workdir / "snap"), durable)
        res2 = eng2.resume()
        prefix = [r for r in durable.processed if r[0] <= resumed_from]
        assert_replay_matches(ref_eng, ref_res, eng2, res2, prefix,
                              context=f"capacity_before_{crash_at}")
        # capacity samples are pure host introspection of replayed state:
        # the resumed suffix is byte-identical, not merely same-schedule
        assert bag[-1]["accounting"].samples == \
            [r for r in ref_samples if r["event_index"] > resumed_from]


def test_exporter_windows_and_capacity_under_device_churn():
    """Join/leave/preempt mid-window: export emission stays a deterministic
    once-per-window function of the event stream, and the capacity plane
    sees the fleet composition change."""
    trace = device_churn_trace(
        num_sessions=40, arrival_rate=1.0, seed=1, initial_slices=4,
        join_classes=(("fast", 16, 2.0), ("slow", 16, 1.0)),
        join_rate=0.05, leave_rate=0.03, preempt_rate=0.05,
        m_min=2, m_max=10, session_scale=25.0)
    reg_factory = two_class_registry

    def run_once():
        reg = MetricsRegistry()
        dreg = reg_factory(2.0, overhead=0.5)
        planes = dict(metrics=reg,
                      exporter=MetricsExporter(reg, window=5.0),
                      health=HealthMonitor(queue_limit=4),
                      accounting=CapacityAccountant(reg, window=5.0))
        eng = DevPlaneEngine(dreg.build_fleet([("slow", 2), ("fast", 2)]),
                             "mdmt", seed=0, registry=dreg,
                             launch_order="fastest", max_live_models=80,
                             **planes)
        res = eng.run(trace)
        return eng, res, planes

    eng, res, planes = run_once()
    recs = planes["exporter"].records
    assert len(recs) >= 3
    body, final = recs[:-1], recs[-1]
    assert final.get("final") is True and not body[-1].get("final")
    # one record per crossed window, strictly increasing, window = t//w
    windows = [r["window"] for r in body]
    assert windows == sorted(set(windows))
    assert all(r["window"] == int(r["t"] // 5.0) for r in body)
    assert all("alerts" in r for r in recs)     # health auto-wired

    # the device-churn trace must actually change fleet composition, and
    # the accounting samples must see it
    samples = planes["accounting"].samples
    compositions = {tuple(sorted(s["devices"].items())) for s in samples}
    assert len(compositions) >= 2
    # devplane _capacity_extra rides along in every sample
    assert all({"autoscale_joins", "autoscale_leaves",
                "scoring_passes"} <= set(s) for s in samples)

    # emission schedule is a pure function of the event stream
    eng2, res2, planes2 = run_once()
    keys = [(r["window"], r["t"], r["event_index"], bool(r.get("final")))
            for r in recs]
    keys2 = [(r["window"], r["t"], r["event_index"], bool(r.get("final")))
             for r in planes2["exporter"].records]
    assert keys == keys2
    assert planes2["accounting"].samples == samples


def test_prometheus_renders_alert_counts_and_capacity_gauges():
    reg = MetricsRegistry()
    reg.gauge("capacity.gp_bytes").set(1234)
    reg.gauge("capacity.shard_slots", {"shard": "0"}).set(7)
    h = HealthMonitor(memory_budget_bytes=100.0)
    h.on_capacity(0.0, 1, bytes_now=200.0, projected_bytes=200.0)
    exp = MetricsExporter(reg, window=5.0, health=h)
    text = exp.prometheus()
    assert "capacity_gp_bytes 1234" in text
    assert 'capacity_shard_slots{shard="0"} 7' in text
    assert "# TYPE health_alerts_total counter" in text
    assert 'health_alerts_total{kind="memory_runaway"} 1' in text
    # alert counts also fold into every windowed record
    exp.tick(0.1, 1)
    assert exp.records[0]["alerts"] == {"memory_runaway": 1}
    # without a health plane the series is absent entirely
    bare = MetricsExporter(reg, window=5.0)
    bare.tick(0.1, 1)
    assert "health_alerts_total" not in bare.prometheus()
    assert "alerts" not in bare.records[0]


# ---- perf-regression plane (benchmarks/regress.py) ---------------------------

from benchmarks import regress  # noqa: E402  (needs repo root on sys.path)
from benchmarks.common import BENCH_SCHEMA_VERSION  # noqa: E402

ENV = {"platform": "linux", "machine": "x86_64", "device_kind": "cpu",
       "device_count": 8, "fast": False}


def _payload(rows: dict, env=ENV, suite="demo", schema=BENCH_SCHEMA_VERSION):
    return {"schema_version": schema, "suite": suite, "git_sha": "deadbeef",
            "environment": dict(env) if env is not None else None,
            "rows": {k: {"us_per_call": float(v)} for k, v in rows.items()}}


def test_regress_flags_synthetic_2x_regression():
    verdict = regress.compare_suites(
        _payload({"hot": 10_000.0, "cold": 400.0}),
        _payload({"hot": 20_000.0, "cold": 400.0}),
        threshold=1.5, min_us=1000.0, allow_legacy=False)
    assert verdict["status"] == "regression"
    by_name = {r["name"]: r for r in verdict["rows"]}
    assert by_name["hot"]["status"] == "regression"
    assert by_name["hot"]["ratio"] == pytest.approx(2.0)
    assert by_name["cold"]["status"] == "ok"


def test_regress_noise_floor_needs_ratio_and_absolute_delta():
    # 3x ratio but only 6 µs absolute: scheduler jitter, not a regression
    v = regress.compare_suites(_payload({"tiny": 3.0}),
                               _payload({"tiny": 9.0}),
                               threshold=1.5, min_us=1000.0,
                               allow_legacy=False)
    assert v["status"] == "ok"
    # 2 ms absolute but ratio 1.2: inside the ratio threshold
    v = regress.compare_suites(_payload({"slow": 10_000.0}),
                               _payload({"slow": 12_000.0}),
                               threshold=1.5, min_us=1000.0,
                               allow_legacy=False)
    assert v["status"] == "ok"


def test_regress_refuses_cross_environment_and_cross_schema():
    other_env = dict(ENV, device_count=1)
    v = regress.compare_suites(_payload({"a": 1.0}),
                               _payload({"a": 9_999.0}, env=other_env),
                               threshold=1.5, min_us=1.0, allow_legacy=False)
    assert v["status"] == "skipped" and "device_count" in v["reason"]
    v = regress.compare_suites(_payload({"a": 1.0}, schema=0),
                               _payload({"a": 9_999.0}),
                               threshold=1.5, min_us=1.0, allow_legacy=False)
    assert v["status"] == "skipped" and "schema_version" in v["reason"]


def test_regress_legacy_baseline_skipped_unless_allowed():
    base = _payload({"a": 100.0}, env=None)
    fresh = _payload({"a": 100.0})
    v = regress.compare_suites(base, fresh, threshold=1.5, min_us=1.0,
                               allow_legacy=False)
    assert v["status"] == "skipped" and "legacy" in v["reason"]
    v = regress.compare_suites(base, fresh, threshold=1.5, min_us=1.0,
                               allow_legacy=True)
    assert v["status"] == "ok" and v["legacy_baseline"] is True


def test_regress_tracks_row_set_drift():
    v = regress.compare_suites(_payload({"gone": 1.0, "kept": 1.0}),
                               _payload({"kept": 1.0, "born": 1.0}),
                               threshold=1.5, min_us=1.0, allow_legacy=False)
    status = {r["name"]: r["status"] for r in v["rows"]}
    assert status == {"gone": "missing_in_fresh", "kept": "ok",
                      "born": "new_in_fresh"}
    assert v["status"] == "ok"        # drift alone is not a regression


def test_regress_cli_check_report_and_history(tmp_path):
    import json
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "BENCH_demo.json").write_text(
        json.dumps(_payload({"hot": 10_000.0})))
    (fresh_dir / "BENCH_demo.json").write_text(
        json.dumps(_payload({"hot": 30_000.0})))
    report = tmp_path / "regress_report.json"
    history = tmp_path / "BENCH_history.jsonl"
    rc = regress.main(["--check", "--baseline-dir", str(base_dir),
                       "--fresh-dir", str(fresh_dir),
                       "--report", str(report), "--history", str(history)])
    assert rc == 1
    rep = json.loads(report.read_text())
    assert rep["suites"][0]["status"] == "regression"
    hist = [json.loads(line) for line in history.read_text().splitlines()]
    assert hist[0]["suite"] == "demo"
    assert hist[0]["rows"] == {"hot": 30_000.0}

    # identical payloads pass --check
    (fresh_dir / "BENCH_demo.json").write_text(
        json.dumps(_payload({"hot": 10_000.0})))
    assert regress.main(["--check", "--baseline-dir", str(base_dir),
                         "--fresh-dir", str(fresh_dir),
                         "--report", str(report)]) == 0
    # a fresh suite with no baseline passes by default, fails --strict
    (fresh_dir / "BENCH_new.json").write_text(
        json.dumps(_payload({"x": 1.0}, suite="new")))
    common = ["--check", "--baseline-dir", str(base_dir),
              "--fresh-dir", str(fresh_dir), "--report", str(report)]
    assert regress.main(common) == 0
    assert regress.main(common + ["--strict"]) == 1
    # no payloads at all is a usage error, not a silent pass
    empty = tmp_path / "empty"
    empty.mkdir()
    assert regress.main(["--check", "--fresh-dir", str(empty),
                         "--report", str(report)]) == 2
