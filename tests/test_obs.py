"""Observability planes (DESIGN.md §13-§14): tracer determinism, metrics
registry (labels, histograms), streaming export, the health monitor's
detectors, per-decision forensics, span aggregation, the report plane, and
the two contracts that make every plane safe to leave wired into the
engines —

* **observation-only**: an instrumented run's decisions are byte-identical
  to a bare twin's (spans/exports/alerts/forensics observe the engine's
  jit programs, never change them), and processed-log records only grow
  their trace-id field when tracing is on;
* **replay-stable**: trace ids are processed-event indices, span ids count
  from 0 within each trace, export windows and alert content are pure
  functions of the sim-time event stream, so a crash-recovered run
  re-emits identical spans/windows/alerts for the replayed suffix
  (the crash-side half lives in tests/test_eventlog.py).
"""

from __future__ import annotations

import dataclasses
import json
import re
import time
from pathlib import Path

import pytest

from repro.core.fleet import Fleet
from repro.obs import (ALERT_KINDS, NULL_TRACER, ForensicsRecorder,
                       HealthMonitor, MetricsExporter, MetricsRegistry,
                       Tracer, aggregate_spans, prometheus_text,
                       write_report)
from repro.obs.metrics import Histogram
from repro.obs.report import _slo_section
from repro.obs.trace import ROOT_TRACE
from repro.stream import (EventLog, FaultInjector, SimulatedCrash,
                          StreamEngine, poisson_churn_trace, recover)


# ---- tracer -----------------------------------------------------------------

def test_span_ids_deterministic_nesting():
    def drive(tr):
        tr.begin_trace(5)
        with tr.span("a", k=1):
            with tr.span("b"):
                pass
        with tr.span("c"):
            pass

    tr = Tracer()
    drive(tr)
    recs = tr.records()
    # completion order: children close before parents
    assert [r["name"] for r in recs] == ["b", "a", "c"]
    by_name = {r["name"]: r for r in recs}
    assert by_name["a"]["span"] == 0 and by_name["a"]["parent"] is None
    assert by_name["b"]["span"] == 1 and by_name["b"]["parent"] == 0
    assert by_name["c"]["span"] == 2 and by_name["c"]["parent"] is None
    assert all(r["trace"] == 5 for r in recs)
    assert by_name["a"]["attrs"] == {"k": 1}
    # ids depend only on the code path: a second tracer driving the same
    # path emits the identical signature (this is the replay-oracle lever)
    tr2 = Tracer()
    drive(tr2)
    assert tr2.signature() == tr.signature()


def test_begin_trace_resets_span_ids():
    tr = Tracer()
    tr.begin_trace(0)
    with tr.span("x"):
        pass
    tr.begin_trace(1)
    with tr.span("x"):
        pass
    assert [(r["trace"], r["span"]) for r in tr.records()] == [(0, 0), (1, 0)]
    assert tr.signature(min_trace=1) == [(1, 0, None, "x", ())]


def test_disabled_tracer_is_inert():
    tr = Tracer(enabled=False)
    tr.begin_trace(3)
    assert tr.current_trace is None
    assert tr.span("a") is tr.span("b")    # the shared no-op manager
    with tr.span("a", big=1):
        pass
    obj = object()
    assert tr.sync(obj) is obj             # pass-through, no device sync
    assert tr.records() == [] and tr.signature() == []
    assert NULL_TRACER.enabled is False


def test_spans_survive_exceptions():
    tr = Tracer()
    tr.begin_trace(0)
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise RuntimeError("boom")
    assert [r["name"] for r in tr.records()] == ["inner", "outer"]
    assert tr._stack == []


def test_spans_before_begin_trace_land_in_root_trace():
    tr = Tracer()
    with tr.span("setup"):
        pass
    assert tr.records()[0]["trace"] == ROOT_TRACE


def test_to_json_roundtrip(tmp_path):
    tr = Tracer()
    tr.begin_trace(0)
    with tr.span("a", device=2):
        pass
    payload = json.loads(tr.to_json(tmp_path / "t.json").read_text())
    assert payload["spans"][0]["name"] == "a"
    assert payload["spans"][0]["attrs"] == {"device": 2}


# ---- metrics ----------------------------------------------------------------

def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2)
    g = reg.gauge("g")
    g.set(3.0)
    g.set(1.0)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == {"value": 1.0, "max": 3.0}
    assert reg.counter("c") is c           # get-or-create returns the handle


def test_histogram_percentiles_and_nonfinite():
    h = Histogram(bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 3.0, 5.0):
        h.observe(v)
    h.observe(float("nan"))
    h.observe(float("inf"))
    h.observe(None)
    s = h.summary()
    assert s["count"] == 4 and s["dropped_non_finite"] == 3
    assert s["min"] == 0.5 and s["max"] == 5.0
    assert s["mean"] == pytest.approx(2.5)
    assert s["min"] <= s["p50"] <= s["p99"] <= s["max"]
    json.dumps(s, allow_nan=False)


def test_histogram_empty_summary_is_null_clean():
    s = Histogram().summary()
    assert s["count"] == 0 and s["p50"] is None and s["p99"] is None
    json.dumps(s, allow_nan=False)


def test_histogram_overflow_bucket_clamps_to_observed_max():
    h = Histogram(bounds=(1.0,))
    h.observe(100.0)
    assert h.counts == [0, 1]
    assert h.percentile(50) == 100.0
    assert h.saturated is True
    assert h.summary()["saturated"] is True


def test_histogram_saturated_flag_tracks_overflow_bucket_only():
    h = Histogram(bounds=(1.0, 2.0))
    h.observe(0.5)
    h.observe(2.0)                  # at the top bound: still in-range
    assert h.saturated is False
    assert h.summary()["saturated"] is False
    h.observe(2.1)
    assert h.counts == [1, 1, 1]
    assert h.saturated is True
    assert h.summary()["saturated"] is True


def test_histogram_bounds_validation():
    with pytest.raises(ValueError):
        Histogram(bounds=())
    with pytest.raises(ValueError):
        Histogram(bounds=(2.0, 1.0))


def test_registry_kind_collision():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.histogram("x")


def test_labeled_counters_and_gauges():
    reg = MetricsRegistry()
    reg.counter("launches", labels={"cls": "fast"}).inc(2)
    reg.counter("launches", labels={"cls": "slow"}).inc()
    reg.counter("launches").inc(5)      # the bare series coexists
    reg.gauge("depth", labels={"q": "admit"}).set(3.0)
    snap = reg.snapshot()
    assert snap["counters"]['launches{cls="fast"}'] == 2
    assert snap["counters"]['launches{cls="slow"}'] == 1
    assert snap["counters"]["launches"] == 5
    assert snap["gauges"]['depth{q="admit"}'] == {"value": 3.0, "max": 3.0}
    # get-or-create per label set: the hot-path per-call lookup is stable
    assert (reg.counter("launches", labels={"cls": "fast"})
            is reg.counter("launches", labels={"cls": "fast"}))
    json.dumps(snap, allow_nan=False)


def test_labeled_key_is_sorted_and_series_is_structured():
    reg = MetricsRegistry()
    c = reg.counter("m", labels={"b": "2", "a": "1"})
    assert 'm{a="1",b="2"}' in reg.snapshot()["counters"]
    assert reg.series("m") == [({"a": "1", "b": "2"}, c)]
    assert reg.series("nope") == []
    # a family prefix must not leak sibling families into series()
    reg.counter("meters").inc()
    assert reg.series("m") == [({"a": "1", "b": "2"}, c)]


def test_labeled_family_kind_collision():
    reg = MetricsRegistry()
    reg.counter("fam", labels={"x": "1"})
    with pytest.raises(ValueError):
        reg.gauge("fam", labels={"x": "2"})
    with pytest.raises(ValueError):
        reg.gauge("fam")                # the bare name shares the family


# ---- span aggregation -------------------------------------------------------

def test_aggregate_spans_paths_and_self_time():
    tr = Tracer()
    tr.begin_trace(0)
    with tr.span("root"):
        with tr.span("child"):
            pass
        with tr.span("child"):
            pass
    agg = aggregate_spans(tr.records())
    assert set(agg) == {"root", "root/child"}
    assert agg["root"]["count"] == 1 and agg["root/child"]["count"] == 2
    assert agg["root"]["self_us"] == pytest.approx(
        agg["root"]["total_us"] - agg["root/child"]["total_us"])


# ---- engine integration -----------------------------------------------------

def _trace():
    return poisson_churn_trace(num_sessions=6, arrival_rate=1.0, seed=3,
                               m_min=2, m_max=6, session_scale=10.0,
                               num_failure_slices=1)


def _factory(tracers=None, **cfg):
    """Engine factory for recover(): a fresh Fleet per engine (it is
    mutated) and, when ``tracers`` is given, a fresh enabled Tracer per
    engine (spans from the reference / crashed / recovered runs must never
    mix — exactly the crash-demo discipline in examples/)."""
    def make(**kw):
        if tracers is not None and "tracer" not in kw:
            tr = Tracer(enabled=True)
            tracers.append(tr)
            kw["tracer"] = tr
        return StreamEngine(Fleet.partition_pod(16 * 3, 3), "mdmt", seed=0,
                            max_live_models=30, num_shards=2, **cfg, **kw)
    return make


def test_traced_run_matches_untraced_and_stamps_records():
    trace = _trace()
    tr, reg = Tracer(enabled=True), MetricsRegistry()
    traced_log, plain_log = EventLog(), EventLog()
    eng = _factory()(tracer=tr, metrics=reg, log=traced_log)
    res = eng.run(trace)
    ref = _factory()(log=plain_log).run(trace)

    # the observation-only guarantee
    assert ([dataclasses.astuple(t) for t in res.trials]
            == [dataclasses.astuple(t) for t in ref.trials])
    assert res.telemetry.summary() == ref.telemetry.summary()

    # traced processed records carry the trace id (== the event index)...
    assert traced_log.processed
    assert all(len(r) == 5 and r[4] == r[0] for r in traced_log.processed)
    # ...while untraced records keep the legacy 4-field shape
    assert all(len(r) == 4 for r in plain_log.processed)

    names = {r["name"] for r in tr.records()}
    assert {"event", "decide", "posterior", "score", "launch",
            "gp_fold"} <= names

    snap = reg.snapshot()
    assert snap["counters"]["engine.events"] == eng.event_index
    assert snap["counters"]["engine.launches"] == len(res.trials)
    assert snap["histograms"]["engine.decision_seconds"]["count"] > 0
    assert "engine.decisions_per_s" in snap["gauges"]
    assert any(k.endswith(".busy_fraction") for k in snap["gauges"])
    json.dumps(snap, allow_nan=False)


def test_replayed_suffix_reemits_identical_span_tree(tmp_path):
    trace = _trace()
    ref_tracers = []
    ref = _factory(ref_tracers)().run(trace)
    ref_tr = ref_tracers[0]

    tracers = []
    make = _factory(tracers)
    logdir, snapdir = tmp_path / "log", tmp_path / "snap"
    eng = make(log=EventLog(logdir), snapshot_root=str(snapdir),
               snapshot_every=5, fault=FaultInjector(15, "before"))
    with pytest.raises(SimulatedCrash):
        eng.run(trace)
    eng.log.close()

    eng2, resumed_from = recover(make, str(snapdir), EventLog.load(logdir))
    res2 = eng2.resume()

    # the replay oracle still holds under tracing...
    assert ([dataclasses.astuple(t) for t in res2.trials]
            == [dataclasses.astuple(t) for t in ref.trials])
    # ...and the recovered run re-emitted the reference's exact span tree
    # for the replayed suffix — ids are event indices, not tracer state
    suffix = ref_tr.signature(min_trace=resumed_from + 1)
    assert suffix, "crash point must leave a non-empty replayed suffix"
    assert eng2.tracer.signature(min_trace=resumed_from + 1) == suffix
    # the crashed prefix and the reference prefix also agree span-for-span
    crashed_tr = tracers[0]
    upto = min(s["trace"] for s in crashed_tr.records() if s["trace"] >= 0)
    assert (crashed_tr.signature(min_trace=upto)[:20]
            == [s for s in ref_tr.signature(min_trace=upto)
                if s[0] <= eng.event_index][:20])


# ---- report plane -----------------------------------------------------------

def test_write_report_renders_run_directory(tmp_path):
    trace = _trace()
    tr, reg = Tracer(enabled=True), MetricsRegistry()
    eng = _factory()(tracer=tr, metrics=reg)
    res = eng.run(trace)
    run_dir = write_report(
        tmp_path, "run0", telemetry=res.telemetry, tracer=tr, metrics=reg,
        result=res, meta={"seed": 0, "slo": {"device_utilization": 0.0,
                                             "ttfo_p99": 1e9}})
    payload = json.loads((run_dir / "summary.json").read_text())
    assert payload["run_id"] == "run0"
    assert payload["telemetry"]["trials"] == len(res.trials)
    assert payload["spans"] and payload["metrics"]["counters"]
    assert payload["num_spans"] == len(tr.records())

    html_text = (run_dir / "report.html").read_text()
    assert "run0" in html_text and "met" in html_text

    lines = (run_dir / "timeline.csv").read_text().splitlines()
    assert lines[0] == "kind,t,tenant,model,device,value"
    assert len(lines) > 1
    assert (run_dir / "trace.json").exists()


def test_write_report_minimal(tmp_path):
    run_dir = write_report(tmp_path, "empty")
    payload = json.loads((run_dir / "summary.json").read_text())
    assert payload["run_id"] == "empty" and payload["spans"] == {}
    assert (run_dir / "report.html").exists()
    assert not (run_dir / "trace.json").exists()


def _attainment(html_text: str, key: str) -> str:
    m = re.search(rf'<td class="l">{key}</td>'
                  r'<td>[^<]*</td><td>[^<]*</td>'
                  r'<td class="l">([^<]*)</td>', html_text)
    assert m, f"no SLO row for {key}"
    return m.group(1)


def test_slo_section_floor_vs_ceiling_semantics():
    summary = {"device_utilization": 0.8, "ttfo_p99": 50.0,
               "tenant_regret_max": 0.5}
    text = _slo_section(summary, {"device_utilization": 0.9,
                                  "ttfo_p99": 100.0,
                                  "tenant_regret_max": 0.1})
    # utilization targets are floors: 0.8 < 0.9 misses
    assert _attainment(text, "device_utilization") == "MISSED"
    # latency targets are ceilings: 50 <= 100 meets
    assert _attainment(text, "ttfo_p99") == "met"
    # regret targets are ceilings too: 0.5 > 0.1 misses
    assert _attainment(text, "tenant_regret_max") == "MISSED"
    # boundary values meet on both sides of the semantics split
    text = _slo_section({"device_utilization": 0.9, "ttfo_p99": 100.0},
                        {"device_utilization": 0.9, "ttfo_p99": 100.0})
    assert _attainment(text, "device_utilization") == "met"
    assert _attainment(text, "ttfo_p99") == "met"


def test_slo_section_missing_targets_and_values():
    text = _slo_section({"ttfo_p50": None, "serve_gap_p50": 1.0},
                        {"ttfo_p50": 5.0})
    # target set but the run produced no data
    assert _attainment(text, "ttfo_p50") == "no data"
    # value present but no target: ungraded, not "met"
    assert _attainment(text, "serve_gap_p50") == "–"
    # absent from both: still a row, still ungraded
    assert _attainment(text, "tenant_regret_mean") == "–"


# ---- streaming export -------------------------------------------------------

def test_exporter_windows_are_a_function_of_the_event_stream(tmp_path):
    reg = MetricsRegistry()
    c = reg.counter("n")
    path = tmp_path / "export.jsonl"
    ex = MetricsExporter(reg, path=str(path), window=10.0)
    ex.tick(0.0, 0)                 # window 0: emits
    c.inc()
    ex.tick(5.0, 1)                 # same window: silent
    ex.tick(23.0, 2)                # window 2 (idle window 1 emits nothing)
    ex.final(30.0, 3)
    ex.close()
    assert [(r["window"], r["event_index"]) for r in ex.records] == \
           [(0, 0), (2, 2), (3, 3)]
    assert ex.records[0]["metrics"]["counters"]["n"] == 0
    assert ex.records[1]["metrics"]["counters"]["n"] == 1
    assert ex.records[-1]["final"] is True
    # the JSONL stream is the in-memory list, write-through
    lines = [json.loads(s) for s in path.read_text().splitlines()]
    assert lines == ex.records


def test_exporter_cursor_state_roundtrip():
    reg = MetricsRegistry()
    ex = MetricsExporter(reg, window=10.0)
    ex.tick(25.0, 4)
    resumed = MetricsExporter(reg, window=10.0)
    resumed.load_state(json.loads(json.dumps(ex.state_dict())))
    resumed.tick(27.0, 5)           # same window as the pre-crash emit
    assert resumed.records == []
    resumed.tick(31.0, 6)
    assert [r["window"] for r in resumed.records] == [3]


def test_exporter_rejects_nonpositive_window():
    with pytest.raises(ValueError):
        MetricsExporter(MetricsRegistry(), window=0.0)


def test_prometheus_text_rendering():
    reg = MetricsRegistry()
    reg.counter("engine.events").inc(3)
    reg.counter("launches", labels={"cls": "fast"}).inc()
    reg.gauge("depth").set(2.0)
    h = reg.histogram("lat", bounds=(1.0, 2.0))
    h.observe(0.5)
    text = prometheus_text(reg.snapshot())
    assert "# TYPE engine_events_total counter" in text
    assert "engine_events_total 3" in text
    assert 'launches_total{cls="fast"} 1' in text      # labels pass through
    assert "# TYPE depth gauge" in text
    assert "depth 2.0" in text and "depth_max 2.0" in text
    assert "# TYPE lat summary" in text
    assert 'lat{quantile="0.5"} 0.5' in text
    assert "lat_sum 0.5" in text and "lat_count 1" in text
    # empty histograms render NaN quantiles, not a crash
    reg2 = MetricsRegistry()
    reg2.histogram("empty")
    assert 'empty{quantile="0.5"} NaN' in prometheus_text(reg2.snapshot())


# ---- health monitor ---------------------------------------------------------

def test_queue_runaway_fires_on_rise_and_rearms_on_drain():
    hm = HealthMonitor(queue_limit=4)
    for depth in (1, 2, 3):
        hm.on_event(float(depth), depth, queue_depth=depth, backlog=0)
    hm.on_event(4.0, 4, queue_depth=4, backlog=0)   # crosses while rising
    assert [(a.kind, a.severity) for a in hm.alerts] == \
           [("queue_runaway", "page")]
    assert hm.alerts[0].detail == {"depth": 4, "limit": 4}
    hm.on_event(5.0, 5, queue_depth=6, backlog=0)   # still high: no re-fire
    assert len(hm.alerts) == 1
    hm.on_event(6.0, 6, queue_depth=2, backlog=0)   # <= limit//2: re-arms
    hm.on_event(7.0, 7, queue_depth=5, backlog=0)
    assert [a.kind for a in hm.alerts] == ["queue_runaway"] * 2


def test_regret_stall_counts_and_rearms_on_improvement():
    hm = HealthMonitor(stall_k=3)
    hm.on_observation(0.0, 0, 7, True)
    for i in range(1, 4):
        hm.on_observation(float(i), i, 7, False)
    assert [a.kind for a in hm.alerts] == ["regret_stall"]
    assert hm.alerts[0].subject == "7"
    assert hm.alerts[0].detail["observations_since_improvement"] == 3
    hm.on_observation(4.0, 4, 7, False)     # still stalled: deduped
    assert len(hm.alerts) == 1
    hm.on_observation(5.0, 5, 7, True)      # improvement re-arms
    for i in range(6, 9):
        hm.on_observation(float(i), i, 7, False)
    assert [a.kind for a in hm.alerts] == ["regret_stall"] * 2
    # an unrelated tenant keeps its own counter
    hm.on_observation(9.0, 9, 8, False)
    assert len(hm.alerts) == 2


def test_gp_conditioning_threshold_and_per_window_dedupe():
    hm = HealthMonitor(window=10.0, conditioning_scale=10.0)
    hm.on_observation(1.0, 0, "t", True, d2=5e-6, jitter=1e-6)
    hm.on_observation(2.0, 1, "t", True, d2=5e-6, jitter=1e-6)   # same window
    hm.on_observation(12.0, 2, "t", True, d2=5e-6, jitter=1e-6)  # next window
    hm.on_observation(13.0, 3, "t", True, d2=1e-3, jitter=1e-6)  # healthy
    hm.on_observation(14.0, 4, "t", True)                         # no d2 fed
    assert [a.kind for a in hm.alerts] == ["gp_conditioning"] * 2
    assert hm.alerts[0].detail == {"model": -1, "d2": 5e-6, "jitter": 1e-6}
    assert [a.event_index for a in hm.alerts] == [0, 2]


def test_class_starvation_clock_only_runs_while_demand_present():
    hm = HealthMonitor(starvation_window=10.0)
    # idle WITHOUT demand: the clock keeps resetting, no alert ever
    for t in range(0, 30, 5):
        hm.on_event(float(t), t, queue_depth=0, backlog=0,
                    free_classes=("base",))
    assert hm.alerts == []
    # demand appears at t=30; last demand-free tick was t=25
    hm.on_event(30.0, 30, queue_depth=0, backlog=2, free_classes=("base",))
    assert hm.alerts == []                  # only 5s on the demand clock
    hm.on_event(35.0, 31, queue_depth=0, backlog=2, free_classes=("base",))
    assert [a.kind for a in hm.alerts] == ["class_starvation"]
    assert hm.alerts[0].subject == "base"
    assert hm.alerts[0].detail == {"idle_for": 10.0, "backlog": 2}
    # a launch on the class re-arms and restarts its clock
    hm.on_launch(36.0, 32, 0, 1, "base")
    hm.on_event(40.0, 33, queue_depth=0, backlog=2, free_classes=("base",))
    assert len(hm.alerts) == 1
    hm.on_event(47.0, 34, queue_depth=0, backlog=2, free_classes=("base",))
    assert len(hm.alerts) == 2


def test_slo_burn_rate_window_grading_and_rearm():
    vals = iter([0.1, 0.1, 0.9, 0.1, 0.1])
    summary_fn = lambda: {"device_utilization": next(vals)}  # noqa: E731
    hm = HealthMonitor(slo={"device_utilization": 0.5}, window=10.0,
                       burn_windows=2, burn_threshold=0.75)
    hm.on_event(10.0, 1, queue_depth=0, backlog=0, summary_fn=summary_fn)
    assert hm.alerts == []          # one window of history < burn_windows
    hm.on_event(20.0, 2, queue_depth=0, backlog=0, summary_fn=summary_fn)
    assert [(a.kind, a.severity) for a in hm.alerts] == [("slo_burn", "page")]
    assert hm.alerts[0].detail == {"burn_rate": 1.0, "value": 0.1,
                                   "target": 0.5}
    hm.on_event(30.0, 3, queue_depth=0, backlog=0, summary_fn=summary_fn)
    hm.on_event(40.0, 4, queue_depth=0, backlog=0, summary_fn=summary_fn)
    assert len(hm.alerts) == 1      # compliant window re-armed; burn 0.5 < .75
    hm.on_event(50.0, 5, queue_depth=0, backlog=0, summary_fn=summary_fn)
    assert len(hm.alerts) == 2      # two failing windows again: page again
    # mid-window events never grade (the iterator would raise StopIteration)
    hm.on_event(51.0, 6, queue_depth=0, backlog=0, summary_fn=summary_fn)


def test_slo_burn_uses_report_plane_floor_vs_ceiling_semantics():
    mk = lambda: HealthMonitor(slo={"ttfo_p99": 100.0}, window=10.0,  # noqa: E731
                               burn_windows=1, burn_threshold=0.5)
    hm = mk()
    hm.on_event(10.0, 1, queue_depth=0, backlog=0,
                summary_fn=lambda: {"ttfo_p99": 250.0})
    assert [a.kind for a in hm.alerts] == ["slo_burn"]      # ceiling exceeded
    hm2 = mk()
    hm2.on_event(10.0, 1, queue_depth=0, backlog=0,
                 summary_fn=lambda: {"ttfo_p99": 50.0})
    assert hm2.alerts == []                                  # under the ceiling


def test_health_state_roundtrip_reemits_exactly_the_suffix():
    def drive(hm, start):
        for i in range(start, start + 6):
            hm.on_observation(float(i), i, "t0", False)
            hm.on_event(float(i), i, queue_depth=i, backlog=0)

    cfg = dict(stall_k=9, queue_limit=8)
    prefix_hm = HealthMonitor(**cfg)
    drive(prefix_hm, 0)
    state = json.loads(json.dumps(prefix_hm.state_dict()))  # snapshot-safe

    full = HealthMonitor(**cfg)
    drive(full, 0)
    drive(full, 6)
    resumed = HealthMonitor(**cfg)
    resumed.load_state(state)
    assert resumed.alerts == [] and resumed.drain_new() == []
    drive(resumed, 6)
    # the resumed monitor emits the full run's alerts minus the prefix
    assert full.alerts[len(prefix_hm.alerts):] == resumed.alerts
    assert {a.kind for a in resumed.alerts} == {"regret_stall",
                                                "queue_runaway"}


def test_alert_record_roundtrip_and_drain():
    from repro.obs import Alert
    hm = HealthMonitor(queue_limit=1)
    hm.on_event(1.0, 1, queue_depth=1, backlog=0)
    (a,) = hm.drain_new()
    assert hm.drain_new() == []         # drained exactly once
    rec = json.loads(json.dumps(a.to_record(), allow_nan=False))
    assert Alert.from_record(rec) == a
    assert rec["kind"] in ALERT_KINDS


# ---- forensics --------------------------------------------------------------

def test_forensics_uniform_cost_counterfactual_flip():
    fr = ForensicsRecorder()
    fr.begin_event(3.0, 17)
    # model 11 wins on EIrate (0.5 vs 0.1) but model 4 has the larger EI
    # (1.0 vs 0.5): the pick is cheapness-driven and the counterfactual
    # flips it
    rec = fr.on_decision(scorer="fused", values=[0.5, 0.1], gids=[11, 4],
                         eff_costs=[1.0, 10.0], mu=[0.2, 0.4],
                         sd=[0.1, 0.3])
    assert (rec["t"], rec["event_index"], rec["seq"]) == (3.0, 17, 0)
    assert rec["winner"]["model"] == 11 and rec["runner_up"]["model"] == 4
    assert rec["winner"]["ei"] == pytest.approx(0.5)
    assert rec["runner_up"]["ei"] == pytest.approx(1.0)
    assert rec["winner"]["mu"] == 0.2 and rec["winner"]["sd"] == 0.1
    assert rec["margin"] == pytest.approx(0.4)
    assert rec["uniform_cost"] == {"model": 4, "changes_pick": True}
    # seq separates same-event decisions; a lone candidate has no runner-up
    rec2 = fr.on_decision(scorer="fused", values=[0.5], gids=[11],
                          eff_costs=[1.0])
    assert rec2["seq"] == 1 and rec2["runner_up"] is None
    assert rec2["margin"] is None
    assert rec2["uniform_cost"] == {"model": 11, "changes_pick": False}
    json.dumps(fr.records, allow_nan=False)


def test_forensics_truncates_padded_topk_tail(tmp_path):
    path = tmp_path / "forensics.jsonl"
    fr = ForensicsRecorder(path=str(path))
    fr.begin_event(0.0, 0)
    # -1e30 is the sharded scorer's masked-slot fill: the tail after it is
    # padding, not candidates — even if finite values follow
    rec = fr.on_decision(scorer="sharded", values=[1.0, -1e30, 0.5],
                         gids=[1, 2, 3], eff_costs=[1.0, 1.0, 1.0])
    assert [c["model"] for c in rec["topk"]] == [1]
    assert rec["runner_up"] is None
    fr.close()
    assert [json.loads(s) for s in path.read_text().splitlines()] == [rec]


# ---- engine integration: every plane at once --------------------------------

def test_all_planes_enabled_run_matches_bare_twin():
    trace = _trace()
    reg = MetricsRegistry()
    eng = _factory()(tracer=Tracer(enabled=True), metrics=reg,
                     exporter=MetricsExporter(reg, window=5.0),
                     health=HealthMonitor(slo={"device_utilization": 1.5},
                                          window=5.0, burn_windows=2),
                     forensics=ForensicsRecorder())
    res = eng.run(trace)
    ref = _factory()().run(trace)

    # the observation-only guarantee with the full stack attached
    assert ([dataclasses.astuple(t) for t in res.trials]
            == [dataclasses.astuple(t) for t in ref.trials])
    assert res.telemetry.summary() == ref.telemetry.summary()

    # every plane actually observed the run
    assert eng.exporter.records and eng.exporter.records[-1].get("final")
    assert eng.forensics.records
    assert all(r["winner"] is not None for r in eng.forensics.records)
    assert all(r["scorer"] for r in eng.forensics.records)
    # a >1.0 utilization floor is unreachable: the burn detector must page
    assert any(a.kind == "slo_burn" and a.severity == "page"
               for a in eng.health.alerts)
    # the engine forwarded every alert to the durable log, in order
    assert eng.log.alerts == [a.to_record() for a in eng.health.alerts]
    # labeled per-class launch counters (S1) fed from the launch path
    fam = reg.series("engine.launches_by_class")
    assert fam and all(set(labels) == {"cls"} for labels, _ in fam)
    assert sum(c.value for _, c in fam) == len(res.trials)


def test_devplane_batched_forensics_carries_class_and_seq():
    from repro.devplane import DevPlaneEngine, two_class_registry
    from repro.stream import device_churn_trace

    trace = device_churn_trace(
        num_sessions=8, arrival_rate=1.5, seed=2, initial_slices=4,
        join_classes=(("fast", 16, 2.0), ("slow", 16, 1.0)),
        join_rate=0.05, leave_rate=0.02, preempt_rate=0.03,
        m_min=2, m_max=6, session_scale=10.0)

    def make(**kw):
        reg = two_class_registry(2.0, overhead=0.5, chips=16)
        fleet = reg.build_fleet([("slow", 2), ("fast", 2)])
        return DevPlaneEngine(fleet, "mdmt", seed=0, registry=reg,
                              assign="batched", launch_order="fastest",
                              max_live_models=30, **kw)

    fr = ForensicsRecorder()
    res = make(forensics=fr).run(trace)
    ref = make().run(trace)
    assert ([dataclasses.astuple(t) for t in res.trials]
            == [dataclasses.astuple(t) for t in ref.trials])
    assert fr.records
    # batched per-class decisions stamp the class name
    classes = {r["device_class"] for r in fr.records}
    assert {"slow", "fast"} <= classes
    assert all(r["winner"]["cost"] > 0 for r in fr.records)


def test_batched_decision_records_one_forensics_row_per_class():
    import numpy as np
    from repro.core.control_plane import ControlPlane

    cp = ControlPlane(np.random.default_rng(0))
    m = 4
    cp.add_tenant(0.04 * np.eye(m), np.zeros(m), np.ones(m))
    fr = ForensicsRecorder()
    cp.set_forensics(fr)
    fr.begin_event(1.0, 5)
    v, g = cp.choose_mdmt_batch([4.0, 1.0], [0.25, 0.0], k=2,
                                class_names=["fast", "slow"])
    # one record per class row of the SAME event: seq separates them
    assert [(r["seq"], r["device_class"]) for r in fr.records] == \
           [(0, "fast"), (1, "slow")]
    assert all(r["event_index"] == 5 and r["t"] == 1.0 for r in fr.records)
    # effective costs are the class's affine row: cost/rate + overhead
    assert fr.records[0]["winner"]["cost"] == pytest.approx(1 / 4 + 0.25)
    assert fr.records[1]["winner"]["cost"] == pytest.approx(1.0)
    # and the recorded scores are the rows the assignment solver consumed
    assert fr.records[0]["winner"]["eirate"] == pytest.approx(float(v[0][0]))
    assert fr.records[1]["winner"]["eirate"] == pytest.approx(float(v[1][0]))


# ---- S2: the disabled stack must stay under 1% of a decision ----------------

def test_disabled_obs_stack_overhead_under_one_percent():
    bench = Path(__file__).resolve().parents[1] / "BENCH_decision_trace.json"
    if not bench.exists():
        pytest.skip("no committed decision-cost baseline to compare against")
    rows = json.loads(bench.read_text())["rows"]
    row = rows.get("decision_trace_L100000_S1")
    if row is None:
        pytest.skip("baseline lacks the L=100k reference row")
    decision_us = float(row["fused_us"])

    # the engine's per-event obs sites with every plane disabled: four
    # attribute loads + None checks (src/repro/stream/engine.py _drain)
    eng = _factory()()
    assert (eng.exporter is None and eng.health is None
            and eng.forensics is None and eng.metrics is None)

    def sites():
        if eng.forensics is not None:
            eng.forensics.begin_event(0.0, 0)
        if eng.metrics is not None:
            pass
        if eng.health is not None:
            eng._health_tick()
        if eng.exporter is not None:
            eng.exporter.tick(0.0, 0)

    iters = 20_000
    for _ in range(500):            # warm the attribute caches
        sites()
    t0 = time.perf_counter()
    for _ in range(iters):
        sites()
    site_us = (time.perf_counter() - t0) / iters * 1e6
    assert site_us < 0.01 * decision_us, (
        f"disabled obs stack costs {site_us:.3f}µs — more than 1% of the "
        f"committed L=100k decision ({decision_us:.0f}µs)")
