"""Observability planes (DESIGN.md §13): tracer determinism, metrics
registry, span aggregation, the report plane, and the two contracts that
make tracing safe to leave wired into the engines —

* **observation-only**: a traced run's decisions are byte-identical to an
  untraced twin's (spans wrap the engine's jit programs, never change
  them), and processed-log records only grow their trace-id field when
  tracing is on;
* **replay-stable**: trace ids are processed-event indices and span ids
  count from 0 within each trace, so a crash-recovered run re-emits the
  identical span tree for the replayed suffix with no tracer state in the
  snapshot.
"""

from __future__ import annotations

import dataclasses
import json

import pytest

from repro.core.fleet import Fleet
from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer,
                       aggregate_spans, write_report)
from repro.obs.metrics import Histogram
from repro.obs.trace import ROOT_TRACE
from repro.stream import (EventLog, FaultInjector, SimulatedCrash,
                          StreamEngine, poisson_churn_trace, recover)


# ---- tracer -----------------------------------------------------------------

def test_span_ids_deterministic_nesting():
    def drive(tr):
        tr.begin_trace(5)
        with tr.span("a", k=1):
            with tr.span("b"):
                pass
        with tr.span("c"):
            pass

    tr = Tracer()
    drive(tr)
    recs = tr.records()
    # completion order: children close before parents
    assert [r["name"] for r in recs] == ["b", "a", "c"]
    by_name = {r["name"]: r for r in recs}
    assert by_name["a"]["span"] == 0 and by_name["a"]["parent"] is None
    assert by_name["b"]["span"] == 1 and by_name["b"]["parent"] == 0
    assert by_name["c"]["span"] == 2 and by_name["c"]["parent"] is None
    assert all(r["trace"] == 5 for r in recs)
    assert by_name["a"]["attrs"] == {"k": 1}
    # ids depend only on the code path: a second tracer driving the same
    # path emits the identical signature (this is the replay-oracle lever)
    tr2 = Tracer()
    drive(tr2)
    assert tr2.signature() == tr.signature()


def test_begin_trace_resets_span_ids():
    tr = Tracer()
    tr.begin_trace(0)
    with tr.span("x"):
        pass
    tr.begin_trace(1)
    with tr.span("x"):
        pass
    assert [(r["trace"], r["span"]) for r in tr.records()] == [(0, 0), (1, 0)]
    assert tr.signature(min_trace=1) == [(1, 0, None, "x", ())]


def test_disabled_tracer_is_inert():
    tr = Tracer(enabled=False)
    tr.begin_trace(3)
    assert tr.current_trace is None
    assert tr.span("a") is tr.span("b")    # the shared no-op manager
    with tr.span("a", big=1):
        pass
    obj = object()
    assert tr.sync(obj) is obj             # pass-through, no device sync
    assert tr.records() == [] and tr.signature() == []
    assert NULL_TRACER.enabled is False


def test_spans_survive_exceptions():
    tr = Tracer()
    tr.begin_trace(0)
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise RuntimeError("boom")
    assert [r["name"] for r in tr.records()] == ["inner", "outer"]
    assert tr._stack == []


def test_spans_before_begin_trace_land_in_root_trace():
    tr = Tracer()
    with tr.span("setup"):
        pass
    assert tr.records()[0]["trace"] == ROOT_TRACE


def test_to_json_roundtrip(tmp_path):
    tr = Tracer()
    tr.begin_trace(0)
    with tr.span("a", device=2):
        pass
    payload = json.loads(tr.to_json(tmp_path / "t.json").read_text())
    assert payload["spans"][0]["name"] == "a"
    assert payload["spans"][0]["attrs"] == {"device": 2}


# ---- metrics ----------------------------------------------------------------

def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2)
    g = reg.gauge("g")
    g.set(3.0)
    g.set(1.0)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == {"value": 1.0, "max": 3.0}
    assert reg.counter("c") is c           # get-or-create returns the handle


def test_histogram_percentiles_and_nonfinite():
    h = Histogram(bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 3.0, 5.0):
        h.observe(v)
    h.observe(float("nan"))
    h.observe(float("inf"))
    h.observe(None)
    s = h.summary()
    assert s["count"] == 4 and s["dropped_non_finite"] == 3
    assert s["min"] == 0.5 and s["max"] == 5.0
    assert s["mean"] == pytest.approx(2.5)
    assert s["min"] <= s["p50"] <= s["p99"] <= s["max"]
    json.dumps(s, allow_nan=False)


def test_histogram_empty_summary_is_null_clean():
    s = Histogram().summary()
    assert s["count"] == 0 and s["p50"] is None and s["p99"] is None
    json.dumps(s, allow_nan=False)


def test_histogram_overflow_bucket_clamps_to_observed_max():
    h = Histogram(bounds=(1.0,))
    h.observe(100.0)
    assert h.counts == [0, 1]
    assert h.percentile(50) == 100.0


def test_histogram_bounds_validation():
    with pytest.raises(ValueError):
        Histogram(bounds=())
    with pytest.raises(ValueError):
        Histogram(bounds=(2.0, 1.0))


def test_registry_kind_collision():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.histogram("x")


# ---- span aggregation -------------------------------------------------------

def test_aggregate_spans_paths_and_self_time():
    tr = Tracer()
    tr.begin_trace(0)
    with tr.span("root"):
        with tr.span("child"):
            pass
        with tr.span("child"):
            pass
    agg = aggregate_spans(tr.records())
    assert set(agg) == {"root", "root/child"}
    assert agg["root"]["count"] == 1 and agg["root/child"]["count"] == 2
    assert agg["root"]["self_us"] == pytest.approx(
        agg["root"]["total_us"] - agg["root/child"]["total_us"])


# ---- engine integration -----------------------------------------------------

def _trace():
    return poisson_churn_trace(num_sessions=6, arrival_rate=1.0, seed=3,
                               m_min=2, m_max=6, session_scale=10.0,
                               num_failure_slices=1)


def _factory(tracers=None, **cfg):
    """Engine factory for recover(): a fresh Fleet per engine (it is
    mutated) and, when ``tracers`` is given, a fresh enabled Tracer per
    engine (spans from the reference / crashed / recovered runs must never
    mix — exactly the crash-demo discipline in examples/)."""
    def make(**kw):
        if tracers is not None and "tracer" not in kw:
            tr = Tracer(enabled=True)
            tracers.append(tr)
            kw["tracer"] = tr
        return StreamEngine(Fleet.partition_pod(16 * 3, 3), "mdmt", seed=0,
                            max_live_models=30, num_shards=2, **cfg, **kw)
    return make


def test_traced_run_matches_untraced_and_stamps_records():
    trace = _trace()
    tr, reg = Tracer(enabled=True), MetricsRegistry()
    traced_log, plain_log = EventLog(), EventLog()
    eng = _factory()(tracer=tr, metrics=reg, log=traced_log)
    res = eng.run(trace)
    ref = _factory()(log=plain_log).run(trace)

    # the observation-only guarantee
    assert ([dataclasses.astuple(t) for t in res.trials]
            == [dataclasses.astuple(t) for t in ref.trials])
    assert res.telemetry.summary() == ref.telemetry.summary()

    # traced processed records carry the trace id (== the event index)...
    assert traced_log.processed
    assert all(len(r) == 5 and r[4] == r[0] for r in traced_log.processed)
    # ...while untraced records keep the legacy 4-field shape
    assert all(len(r) == 4 for r in plain_log.processed)

    names = {r["name"] for r in tr.records()}
    assert {"event", "decide", "posterior", "score", "launch",
            "gp_fold"} <= names

    snap = reg.snapshot()
    assert snap["counters"]["engine.events"] == eng.event_index
    assert snap["counters"]["engine.launches"] == len(res.trials)
    assert snap["histograms"]["engine.decision_seconds"]["count"] > 0
    assert "engine.decisions_per_s" in snap["gauges"]
    assert any(k.endswith(".busy_fraction") for k in snap["gauges"])
    json.dumps(snap, allow_nan=False)


def test_replayed_suffix_reemits_identical_span_tree(tmp_path):
    trace = _trace()
    ref_tracers = []
    ref = _factory(ref_tracers)().run(trace)
    ref_tr = ref_tracers[0]

    tracers = []
    make = _factory(tracers)
    logdir, snapdir = tmp_path / "log", tmp_path / "snap"
    eng = make(log=EventLog(logdir), snapshot_root=str(snapdir),
               snapshot_every=5, fault=FaultInjector(15, "before"))
    with pytest.raises(SimulatedCrash):
        eng.run(trace)
    eng.log.close()

    eng2, resumed_from = recover(make, str(snapdir), EventLog.load(logdir))
    res2 = eng2.resume()

    # the replay oracle still holds under tracing...
    assert ([dataclasses.astuple(t) for t in res2.trials]
            == [dataclasses.astuple(t) for t in ref.trials])
    # ...and the recovered run re-emitted the reference's exact span tree
    # for the replayed suffix — ids are event indices, not tracer state
    suffix = ref_tr.signature(min_trace=resumed_from + 1)
    assert suffix, "crash point must leave a non-empty replayed suffix"
    assert eng2.tracer.signature(min_trace=resumed_from + 1) == suffix
    # the crashed prefix and the reference prefix also agree span-for-span
    crashed_tr = tracers[0]
    upto = min(s["trace"] for s in crashed_tr.records() if s["trace"] >= 0)
    assert (crashed_tr.signature(min_trace=upto)[:20]
            == [s for s in ref_tr.signature(min_trace=upto)
                if s[0] <= eng.event_index][:20])


# ---- report plane -----------------------------------------------------------

def test_write_report_renders_run_directory(tmp_path):
    trace = _trace()
    tr, reg = Tracer(enabled=True), MetricsRegistry()
    eng = _factory()(tracer=tr, metrics=reg)
    res = eng.run(trace)
    run_dir = write_report(
        tmp_path, "run0", telemetry=res.telemetry, tracer=tr, metrics=reg,
        result=res, meta={"seed": 0, "slo": {"device_utilization": 0.0,
                                             "ttfo_p99": 1e9}})
    payload = json.loads((run_dir / "summary.json").read_text())
    assert payload["run_id"] == "run0"
    assert payload["telemetry"]["trials"] == len(res.trials)
    assert payload["spans"] and payload["metrics"]["counters"]
    assert payload["num_spans"] == len(tr.records())

    html_text = (run_dir / "report.html").read_text()
    assert "run0" in html_text and "met" in html_text

    lines = (run_dir / "timeline.csv").read_text().splitlines()
    assert lines[0] == "kind,t,tenant,model,device,value"
    assert len(lines) > 1
    assert (run_dir / "trace.json").exists()


def test_write_report_minimal(tmp_path):
    run_dir = write_report(tmp_path, "empty")
    payload = json.loads((run_dir / "summary.json").read_text())
    assert payload["run_id"] == "empty" and payload["spans"] == {}
    assert (run_dir / "report.html").exists()
    assert not (run_dir / "trace.json").exists()
