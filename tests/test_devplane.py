"""Elastic device plane: device churn, 2-D costs, joint batched assignment
(DESIGN.md §11)."""

import numpy as np
import pytest

from repro.core import ControlPlane, simulate, synthetic_matern_problem
from repro.core.fleet import Fleet
from repro.devplane import (
    AutoscalePolicy,
    DeviceClass,
    DeviceClassRegistry,
    DevPlaneEngine,
    greedy_assign,
    two_class_registry,
)
from repro.stream import (
    DeviceJoin,
    DeviceLeave,
    DevicePreempt,
    ChurnTrace,
    StreamEngine,
    TenantArrive,
    device_churn_trace,
    poisson_churn_trace,
    trace_from_problem,
)


@pytest.fixture(scope="module")
def problem():
    return synthetic_matern_problem(num_users=6, num_models_per_user=8, seed=3)


def fleet_of(n):
    return Fleet.partition_pod(total_chips=16 * n, num_slices=n)


def _tiny_tenant(key, at, m=3, seed=0, cost=None):
    rng = np.random.default_rng(seed)
    K = 0.04 * np.eye(m) + 0.01
    return TenantArrive(
        at=at, tenant_key=key, K_block=K, mu0=np.full(m, 0.5),
        cost=np.ones(m) if cost is None else np.asarray(cost, float),
        z_true=rng.uniform(0.2, 0.9, m))


def _seq(res):
    return [(t.model, t.device, t.start, t.end) for t in res.trials]


# --- equivalence ladder -------------------------------------------------------

@pytest.mark.parametrize("num_devices", [1, 3])
def test_devplane_matches_stream_and_simulate(problem, num_devices):
    """Satellite acceptance: static homogeneous fleet + empty device trace
    => the devplane engine reproduces the StreamEngine (and transitively
    scheduler.simulate) trial sequence exactly — batched assignment and
    all."""
    res = simulate(problem, "mdmt", num_devices=num_devices, seed=0)
    sres = StreamEngine(fleet_of(num_devices), "mdmt", seed=0).run(
        trace_from_problem(problem))
    dres = DevPlaneEngine(fleet_of(num_devices), "mdmt", seed=0,
                          assign="batched").run(trace_from_problem(problem))
    assert _seq(dres) == _seq(sres)
    assert [(t.model, t.device) for t in dres.trials] == \
           [(t.model, t.device) for t in res.trials]
    assert [t.z for t in dres.trials] == [t.z for t in sres.trials]


@pytest.mark.parametrize("scorer", ["fused", "ops"])
def test_batched_equals_sequential_on_homogeneous(scorer):
    """The tentpole equivalence proof: one joint scoring pass + greedy
    assignment picks the identical trial sequence to per-device sequential
    argmaxes whenever the fleet is homogeneous — under full tenant churn."""
    trace = poisson_churn_trace(num_sessions=30, arrival_rate=1.0, seed=0,
                                m_min=2, m_max=10, session_scale=25.0)
    runs = {}
    for assign in ("batched", "sequential"):
        eng = DevPlaneEngine(fleet_of(4), "mdmt", seed=0, assign=assign,
                             scorer=scorer)
        runs[assign] = eng.run(trace)
    assert _seq(runs["batched"]) == _seq(runs["sequential"])
    # and the batched path did its work in fewer scoring passes
    assert runs["batched"].decisions <= runs["sequential"].decisions


def test_batched_equals_sequential_with_overhead_class():
    """Homogeneous extends to a single *class with overhead*: both modes
    score with the same affine cost row, so the sequence still matches."""
    reg = DeviceClassRegistry([DeviceClass("base", speed=1.0, overhead=0.7,
                                           chip_scale=1.0)])
    trace = poisson_churn_trace(num_sessions=20, arrival_rate=1.0, seed=2,
                                m_min=2, m_max=8, session_scale=20.0)
    runs = [DevPlaneEngine(reg.build_fleet([("base", 3)]), "mdmt", seed=0,
                           registry=reg, assign=a).run(trace)
            for a in ("batched", "sequential")]
    assert _seq(runs[0]) == _seq(runs[1])


def test_batched_beats_sequential_scoring_passes_on_waves():
    """Uniform costs synchronize completions into waves, so the batched
    path must make strictly fewer scoring passes per policy launch."""
    trace = poisson_churn_trace(num_sessions=40, arrival_rate=2.0, seed=1,
                                m_min=4, m_max=12, session_scale=30.0)
    b = DevPlaneEngine(fleet_of(8), "mdmt", seed=0, assign="batched").run(trace)
    s = DevPlaneEngine(fleet_of(8), "mdmt", seed=0, assign="sequential").run(trace)
    assert _seq(b) == _seq(s)               # homogeneous: same sequence
    assert b.policy_launches == s.policy_launches > 0
    assert b.decisions < s.decisions        # strictly fewer passes


# --- 2-D cost structure -------------------------------------------------------

def test_registry_cost_matrix_is_not_rank_one():
    """With per-class overheads the (class x model) cost matrix cannot be
    factorized as c(x)/speed_d — the per-model ratio between class rows is
    not constant."""
    reg = two_class_registry(2.0, overhead=5.0)
    base = np.array([1.0, 10.0, 100.0])
    m = reg.cost_matrix(base, ["slow", "fast"])
    ratios = m[0] / m[1]
    assert ratios.std() > 1e-3              # rank-1 would make these equal
    # zero overhead degenerates back to rank-1
    reg0 = two_class_registry(2.0, overhead=0.0)
    m0 = reg0.cost_matrix(base, ["slow", "fast"])
    np.testing.assert_allclose(m0[0] / m0[1], 2.0)


def test_registry_memory_gate_and_fleet_factory():
    reg = DeviceClassRegistry([
        DeviceClass("big", mem_gb=64.0, chip_scale=1.0),
        DeviceClass("small", mem_gb=8.0, chip_scale=1.0),
    ])
    m = reg.cost_matrix(np.ones(3), ["big", "small"],
                        model_mem_gb=[1.0, 16.0, 100.0])
    assert np.isposinf(m[1, 1]) and np.isposinf(m[0, 2]) and np.isposinf(m[1, 2])
    assert np.isfinite(m[0, :2]).all() and np.isfinite(m[1, 0])
    fleet = reg.build_fleet([("big", 2), ("small", 1)])
    assert [s.cls for s in fleet.slices] == ["big", "big", "small"]
    with pytest.raises(KeyError):
        reg["nope"]
    with pytest.raises(ValueError):
        reg.register(DeviceClass("big"))


def test_infinite_cost_is_hard_exclusion_in_class_scores():
    """The memory gate's +inf cost must score -inf (never assigned), not
    the 0 a naive division would give — 0 could still win a row whose
    fitting candidates all have zero EI."""
    import jax.numpy as jnp
    from repro.core.ei import eirate_class_scores
    mu = jnp.zeros(3); sd = jnp.zeros(3)
    best = jnp.array([10.0])                 # EI of every model is 0
    mem = jnp.ones((1, 3), bool)
    cm = jnp.array([[1.0, jnp.inf, 1.0]])
    sel = jnp.zeros(3, bool)
    s = np.asarray(eirate_class_scores(mu, sd, best, mem, cm, sel))
    assert s[0, 0] == 0.0 and s[0, 2] == 0.0
    assert np.isneginf(s[0, 1])


def test_device_join_speed_must_match_registry():
    reg = two_class_registry(2.0)
    trace = ChurnTrace((_tiny_tenant(0, at=0.0),
                        DeviceJoin(at=1.0, chips=16, speed=3.0, cls="fast")))
    eng = DevPlaneEngine(reg.build_fleet([("slow", 1), ("fast", 1)]),
                         "mdmt", seed=0, registry=reg)
    with pytest.raises(ValueError, match="disagrees"):
        eng.run(trace)


def test_autoscale_policy_reuse_across_engines_is_fresh():
    """One policy object driving two engines must not leak the cooldown
    clock between runs (the engine takes a private copy)."""
    ta = _tiny_tenant(0, at=0.0, m=20, cost=np.full(20, 5.0))
    policy = AutoscalePolicy(high_backlog=4.0, low_backlog=1.0, cooldown=5.0,
                             join_class="base", max_devices=4)
    runs = []
    for _ in range(2):
        eng = DevPlaneEngine(fleet_of(1), "mdmt", seed=0, autoscale=policy)
        res = eng.run(ChurnTrace((ta,)))
        runs.append((eng._autoscale_joins, eng._autoscale_leaves,
                     [(t.model, t.device, t.start) for t in res.trials]))
    assert runs[0] == runs[1]
    assert policy._last_action == float("-inf")   # caller's object untouched


def test_choose_mdmt_batch_head_matches_sequential_pick(problem):
    """Row 0 of a 1-class batch == choose_mdmt, over several steps."""
    a = ControlPlane.from_problem(problem)
    b = ControlPlane.from_problem(problem)
    for _ in range(8):
        pick = a.choose_mdmt()
        vals, gids = b.choose_mdmt_batch(np.ones(1), np.zeros(1), k=3)
        assert pick[0] == int(gids[0, 0])
        z = float(problem.z_true[pick[0]])
        for cp in (a, b):
            cp.record_start(pick[0]); cp.record_observation(pick[0], z)


# --- greedy solver ------------------------------------------------------------

def test_greedy_assign_homogeneous_is_rank_order():
    vals = np.array([[5.0, 4.0, 3.0, 2.0]])
    ids = np.array([[7, 3, 9, 1]])
    out = greedy_assign(vals, ids, [0, 0, 0])
    assert out == [(0, 7), (1, 3), (2, 9)]


def test_greedy_assign_fast_device_outbids_slow():
    # model 7 scores 10 on class 1 (fast) and 5 on class 0 (slow):
    # the fast device takes it, the slow device falls back to model 3
    vals = np.array([[5.0, 2.0], [10.0, 1.0]])
    ids = np.array([[7, 3], [7, 8]])
    out = greedy_assign(vals, ids, [0, 1])   # device 0 slow, device 1 fast
    assert out == [(1, 7), (0, 3)]


def test_greedy_assign_exhaustion_and_floor():
    vals = np.array([[5.0, -1e30]])
    ids = np.array([[2, 0]])
    out = greedy_assign(vals, ids, [0, 0, 0])
    assert out == [(0, 2)]                   # one candidate, one launch


# --- device lifecycle ---------------------------------------------------------

def test_device_join_expands_service():
    ta = _tiny_tenant(0, at=0.0, m=6, cost=np.full(6, 4.0))
    trace = ChurnTrace((ta, DeviceJoin(at=1.0, chips=16, speed=1.0,
                                       cls="base")))
    res = DevPlaneEngine(fleet_of(1), "mdmt", seed=0).run(trace)
    assert res.num_devices == 2
    assert any(t.device == 1 for t in res.trials)    # the joined slice served
    obs = {t.local_model for t in res.trials if t.z is not None}
    assert obs == set(range(6))
    dev = res.telemetry.per_device()
    assert dev[1]["joined"] == 1.0 and dev[1]["trials"] > 0


def test_device_leave_kills_and_requeues():
    ta = _tiny_tenant(0, at=0.0, m=3, cost=np.full(3, 4.0))
    trace = ChurnTrace((ta, DeviceLeave(at=1.0, slice_id=1)))
    res = DevPlaneEngine(fleet_of(2), "mdmt", seed=0).run(trace)
    killed = [t for t in res.trials if t.z is None]
    assert len(killed) == 1 and killed[0].device == 1 and killed[0].end == 1.0
    # the killed model is re-issued on the surviving slice and observed
    obs = {t.local_model for t in res.trials if t.z is not None}
    assert obs == set(range(3))
    assert all(t.device == 0 for t in res.trials if t.start > 1.0)
    assert res.num_devices == 1
    assert res.telemetry.summary()["devices_left"] == 1
    assert res.telemetry.per_device()[1]["left"] == 1.0


def test_preempt_requeues_like_slice_failure_but_no_downtime():
    ta = _tiny_tenant(0, at=0.0, m=3, cost=np.full(3, 4.0))
    trace = ChurnTrace((ta, DevicePreempt(at=1.0, slice_id=0)))
    res = DevPlaneEngine(fleet_of(1), "mdmt", seed=0).run(trace)
    s = res.telemetry.summary()
    assert s["trials_preempted"] == 1 and s["trials_failed"] == 0
    pre = [t for t in res.trials if t.z is None]
    assert len(pre) == 1 and pre[0].end == 1.0
    # the slice relaunches IMMEDIATELY (no downtime) — next start at t=1.0
    restarts = [t for t in res.trials if t.start == 1.0]
    assert len(restarts) == 1
    # the preempted model returns to the pool and is eventually observed
    obs = {t.local_model for t in res.trials if t.z is not None}
    assert obs == set(range(3))


def test_slice_fail_mid_batched_wave_keeps_batched_equal_sequential():
    """Regression (DESIGN.md §16): a SliceFail landing exactly at a wave
    boundary — several devices freed at the same instant, one of them
    failing before the launch pass drains — must not desynchronize the
    batched and sequential assignment paths.  Uniform costs force
    synchronized completion waves; the failures hit at those wave times."""
    ta = _tiny_tenant(0, at=0.0, m=16, cost=np.full(16, 4.0))
    events = [ta]
    from repro.stream import SliceFail
    # waves complete at t=4, 8, 12, ...: fail a mid-wave slice at each of
    # the first two boundaries (downtime spans one wave), and once mid-wave
    for at, sid in ((4.0, 1), (8.0, 2), (10.0, 0)):
        events.append(SliceFail(at=at, slice_id=sid, downtime=4.0))
    trace = ChurnTrace(events=tuple(sorted(events, key=lambda e: e.at)),
                       name="fail-mid-wave")
    runs = {}
    for assign in ("batched", "sequential"):
        eng = DevPlaneEngine(fleet_of(4), "mdmt", seed=0, assign=assign)
        res = eng.run(trace)
        runs[assign] = [(t.model, t.device, t.start, t.end, t.z)
                        for t in res.trials]
    assert runs["batched"] == runs["sequential"]
    # the failures actually killed in-flight work and it was re-queued
    killed = [t for t in runs["batched"] if t[4] is None]
    assert killed
    obs = {t[0] for t in runs["batched"] if t[4] is not None}
    assert len(obs) == 16                       # every model still observed


def test_leave_then_recover_race_stays_retired():
    """A slice that fails, then leaves while down, must not rejoin when the
    pending repair fires."""
    from repro.stream import SliceFail
    ta = _tiny_tenant(0, at=0.0, m=4, cost=np.full(4, 10.0))
    trace = ChurnTrace((ta, SliceFail(at=1.0, slice_id=0, downtime=2.0),
                        DeviceLeave(at=2.0, slice_id=0)))
    res = DevPlaneEngine(fleet_of(2), "mdmt", seed=0).run(trace)
    assert res.num_devices == 1
    assert all(t.device == 1 for t in res.trials if t.start > 1.0)


# --- autoscale ----------------------------------------------------------------

def test_autoscale_joins_under_backlog_and_retires_when_idle():
    ta = _tiny_tenant(0, at=0.0, m=20, cost=np.full(20, 5.0))
    policy = AutoscalePolicy(high_backlog=4.0, low_backlog=1.0, cooldown=0.0,
                             join_class="base", min_devices=1, max_devices=4)
    trace = ChurnTrace((ta,))
    eng = DevPlaneEngine(fleet_of(1), "mdmt", seed=0, autoscale=policy)
    res = eng.run(trace)
    assert eng._autoscale_joins > 0
    assert eng._autoscale_leaves > 0         # drained backlog => scale down
    assert 1 <= res.num_devices <= 4
    obs = {t.local_model for t in res.trials if t.z is not None}
    assert obs == set(range(20))             # elasticity never loses work
    s = res.telemetry.summary()
    assert s["devices_joined"] == eng._autoscale_joins
    assert s["devices_left"] == eng._autoscale_leaves


def test_autoscale_bounds_validated():
    with pytest.raises(ValueError):
        AutoscalePolicy(high_backlog=1.0, low_backlog=2.0)
    with pytest.raises(ValueError):
        AutoscalePolicy(min_devices=0)
    with pytest.raises(ValueError):
        DevPlaneEngine(fleet_of(1), "mdmt",
                       autoscale=AutoscalePolicy(join_class="nope"))


# --- end-to-end heterogeneous churn ------------------------------------------

def test_device_churn_trace_end_to_end_consistency():
    """Tenant + device churn together: per-tenant observations stay unique,
    preemptions and leaves are all accounted, telemetry windows close."""
    reg = two_class_registry(2.0, overhead=0.5)
    fleet = reg.build_fleet([("slow", 2), ("fast", 2)])
    trace = device_churn_trace(
        num_sessions=40, arrival_rate=1.0, seed=1, initial_slices=4,
        join_classes=(("fast", 16, 2.0), ("slow", 16, 1.0)),
        join_rate=0.05, leave_rate=0.03, preempt_rate=0.05,
        m_min=2, m_max=10, session_scale=25.0)
    eng = DevPlaneEngine(fleet, "mdmt", seed=0, registry=reg,
                         launch_order="fastest", max_live_models=80)
    res = eng.run(trace)
    s = res.telemetry.summary()
    assert s["sessions"] == 40 and s["trials"] > 40
    seen = [(t.tenant_key, t.local_model) for t in res.trials
            if t.z is not None]
    assert len(seen) == len(set(seen))
    # durations follow the 2-D cost: every trial on a fast slice of base
    # cost c lasted overhead + c/2, on a slow one overhead + c
    for t in res.trials:
        sl = eng.fleet.slices[t.device]
        base = None
        tr = res.tenants[t.tenant_key]
        if tr.model_start is not None:
            base = float(tr.arrive.cost[t.local_model])
        if base is not None and t.z is not None:
            want = reg[sl.cls].cost_on(base)
            assert t.end - t.start == pytest.approx(float(want))
    assert s["speed_weighted_utilization"] is not None
    # every device window is closed and non-negative
    for d in res.telemetry.per_device().values():
        assert d["busy_seconds"] >= 0.0 and d["utilization"] <= 1.0 + 1e-9


# --- sharded scorer drives the batched assignment (multi-device) --------------

def test_sharded_class_decision_matches_dense_4dev():
    """On a forced 4-device mesh: ShardedScorer.decide_topk_classes == the
    dense choose_topk_classes (values to fp32 tolerance, ids exact), and a
    full heterogeneous devplane episode with scorer="sharded" picks the
    identical trial sequence as scorer="fused" — the 2-speed-class fleet
    the CI hetero lane runs."""
    from conftest import run_forced_devices_subprocess
    res = run_forced_devices_subprocess("""
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
        from repro.core.ei import choose_topk_classes
        from repro.devplane import DevPlaneEngine, two_class_registry
        from repro.shardgp import ShardedScorer
        from repro.stream import device_churn_trace

        rng = np.random.default_rng(0)
        sc = ShardedScorer(4, topk=4)
        ok_ids = ok_vals = checks = 0
        for trial in range(10):
            n = int(rng.integers(4, 41)) * 4
            N = int(rng.integers(2, 7))
            C = int(rng.integers(1, 4))
            mu = rng.normal(size=n).astype(np.float32)
            sd = np.abs(rng.normal(size=n)).astype(np.float32)
            best = rng.normal(size=N).astype(np.float32)
            mem = rng.random((N, n)) < (1.0 / N)
            cost = rng.uniform(0.5, 2.0, n).astype(np.float32)
            sel = rng.random(n) < 0.3
            rates = rng.uniform(0.5, 4.0, C).astype(np.float32)
            overs = rng.uniform(0.0, 1.0, C).astype(np.float32)
            sc.refresh(mem, cost)
            v_s, g_s = sc.decide_topk_classes(mu, sd, best, sel,
                                              rates, overs, k=4)
            cm = (jnp.asarray(cost)[None, :] / jnp.asarray(rates)[:, None]
                  + jnp.asarray(overs)[:, None])
            v_d, g_d = choose_topk_classes(
                jnp.asarray(mu), jnp.asarray(sd), jnp.asarray(best),
                jnp.asarray(mem), cm, jnp.asarray(sel), k=4)
            checks += 1
            ok_ids += bool((np.asarray(g_s) == np.asarray(g_d)).all())
            ok_vals += bool(np.allclose(np.asarray(v_s), np.asarray(v_d),
                                        atol=1e-5, rtol=1e-5))

        reg = two_class_registry(2.0, overhead=0.5)
        trace = device_churn_trace(
            num_sessions=25, arrival_rate=1.0, seed=2, initial_slices=4,
            join_classes=(("fast", 16, 2.0),), join_rate=0.03,
            leave_rate=0.02, preempt_rate=0.03,
            m_min=2, m_max=10, session_scale=20.0)
        seqs = {}
        for scorer in ("fused", "sharded"):
            eng = DevPlaneEngine(
                reg.build_fleet([("slow", 2), ("fast", 2)]), "mdmt",
                seed=0, registry=reg, scorer=scorer, num_shards=4,
                max_live_models=60)
            r = eng.run(trace)
            seqs[scorer] = [(t.tenant_key, t.local_model, t.device,
                             round(t.start, 9), t.z) for t in r.trials]
        print(json.dumps({
            "devices": len(jax.devices()),
            "checks": checks, "ok_ids": ok_ids, "ok_vals": ok_vals,
            "num_trials": len(seqs["fused"]),
            "equal": seqs["fused"] == seqs["sharded"],
        }))
    """, devices=4)
    assert res["devices"] == 4
    assert res["ok_ids"] == res["checks"] == 10
    assert res["ok_vals"] == res["checks"]
    assert res["num_trials"] > 25
    assert res["equal"], "sharded class decisions diverged from dense"


def test_speed_oblivious_mode_changes_only_scoring():
    """speed_oblivious scores as if homogeneous but keeps real durations —
    on a heterogeneous fleet the device-aware plane must not do worse on
    makespan for the same closed workload."""
    reg = two_class_registry(4.0)
    fleet = reg.build_fleet([("slow", 1), ("fast", 1)])
    ta = _tiny_tenant(0, at=0.0, m=12, seed=5,
                      cost=np.linspace(2.0, 8.0, 12))
    aware = DevPlaneEngine(reg.build_fleet([("slow", 1), ("fast", 1)]),
                           "mdmt", seed=0, registry=reg,
                           launch_order="fastest").run(ChurnTrace((ta,)))
    obliv = DevPlaneEngine(fleet, "mdmt", seed=0, registry=reg,
                           speed_oblivious=True).run(ChurnTrace((ta,)))
    assert {t.local_model for t in aware.trials if t.z is not None} == \
           {t.local_model for t in obliv.trials if t.z is not None}
    assert aware.end_time <= obliv.end_time + 1e-9
