"""Streaming control plane: churn engine vs the offline simulator (DESIGN.md §9)."""

import json

import numpy as np
import pytest

from repro.core import ControlPlane, simulate, synthetic_matern_problem
from repro.core.fleet import Fleet
from repro.stream import (
    StreamEngine,
    TenantArrive,
    TenantDepart,
    SliceFail,
    ChurnTrace,
    poisson_churn_trace,
    trace_from_problem,
)


@pytest.fixture(scope="module")
def problem():
    return synthetic_matern_problem(num_users=6, num_models_per_user=8, seed=3)


def fleet_of(n):
    return Fleet.partition_pod(total_chips=16 * n, num_slices=n)


# --- equivalence: churn disabled == scheduler.simulate ------------------------

@pytest.mark.parametrize("policy", ["mdmt", "round_robin"])
@pytest.mark.parametrize("num_devices", [1, 3])
def test_no_churn_matches_simulate(problem, policy, num_devices):
    """The acceptance gate: all tenants at t=0, none depart => the streaming
    engine replays the offline engine's trial sequence exactly."""
    res = simulate(problem, policy, num_devices=num_devices, seed=0)
    eng = StreamEngine(fleet_of(num_devices), policy, seed=0)
    sres = eng.run(trace_from_problem(problem))
    assert [(t.model, t.device) for t in sres.trials] == \
           [(t.model, t.device) for t in res.trials]
    np.testing.assert_allclose(
        [t.start for t in sres.trials], [t.start for t in res.trials])
    np.testing.assert_allclose(
        [t.end for t in sres.trials], [t.end for t in res.trials])
    assert [t.z for t in sres.trials] == [t.z for t in res.trials]


def test_no_churn_matches_simulate_no_warm_start(problem):
    res = simulate(problem, "mdmt", num_devices=2, seed=0, warm_start=0)
    eng = StreamEngine(fleet_of(2), "mdmt", seed=0, warm_start=0)
    sres = eng.run(trace_from_problem(problem))
    assert [(t.model, t.device) for t in sres.trials] == \
           [(t.model, t.device) for t in res.trials]


def test_no_churn_heterogeneous_speeds(problem):
    speeds = [1.0, 4.0]
    res = simulate(problem, "mdmt", num_devices=2, seed=0,
                   device_speeds=np.asarray(speeds))
    fleet = Fleet.partition_pod(32, 2, speeds=speeds)
    sres = StreamEngine(fleet, "mdmt", seed=0).run(trace_from_problem(problem))
    assert [(t.model, t.device) for t in sres.trials] == \
           [(t.model, t.device) for t in res.trials]


# --- launch order (fastest-free-first satellite) ------------------------------

def test_fastest_launch_order_homogeneous_replay_byte_identical(problem):
    """On a homogeneous fleet ``launch_order="fastest"`` ties back to the
    stack top, so the full trial log is byte-identical to LIFO — under
    tenant churn and failures, not just the frozen replay."""
    trace = poisson_churn_trace(num_sessions=20, arrival_rate=1.0, seed=7,
                                m_min=2, m_max=8, session_scale=20.0,
                                num_failure_slices=1)
    a = StreamEngine(fleet_of(3), "mdmt", seed=0).run(trace)
    b = StreamEngine(fleet_of(3), "mdmt", seed=0,
                     launch_order="fastest").run(trace)
    assert [(t.model, t.device, t.start, t.end, t.z) for t in a.trials] == \
           [(t.model, t.device, t.start, t.end, t.z) for t in b.trials]
    # and the frozen replay still matches simulate exactly
    res = simulate(problem, "mdmt", num_devices=3, seed=0)
    c = StreamEngine(fleet_of(3), "mdmt", seed=0,
                     launch_order="fastest").run(trace_from_problem(problem))
    assert [(t.model, t.device) for t in c.trials] == \
           [(t.model, t.device) for t in res.trials]


def test_fastest_launch_order_improves_heterogeneous_makespan():
    """Regression for the LIFO blind spot: with one model ready and both a
    fast and a slow slice free, the stack pop lands it on the slow slice
    (highest id = stack top); fastest-free-first lands it on the fast one
    and strictly improves makespan."""
    ta = TenantArrive(at=0.0, tenant_key=0, K_block=0.04 * np.eye(1) + 0.0,
                      mu0=np.array([0.5]), cost=np.array([8.0]),
                      z_true=np.array([0.7]))
    fleet_kw = dict(total_chips=32, num_slices=2)
    lifo = StreamEngine(Fleet.partition_pod(speeds=[4.0, 1.0], **fleet_kw),
                        "mdmt", seed=0).run(ChurnTrace((ta,)))
    fast = StreamEngine(Fleet.partition_pod(speeds=[4.0, 1.0], **fleet_kw),
                        "mdmt", seed=0,
                        launch_order="fastest").run(ChurnTrace((ta,)))
    assert lifo.trials[0].device == 1 and lifo.end_time == pytest.approx(8.0)
    assert fast.trials[0].device == 0 and fast.end_time == pytest.approx(2.0)
    assert fast.end_time < lifo.end_time


def test_launch_order_validated():
    with pytest.raises(ValueError):
        StreamEngine(fleet_of(1), "mdmt", launch_order="nope")


# --- churn semantics ----------------------------------------------------------

def _tiny_tenant(key, at, m=3, seed=0, z=None):
    rng = np.random.default_rng(seed)
    K = 0.04 * np.eye(m) + 0.01
    z = rng.uniform(0.2, 0.9, m) if z is None else np.asarray(z, float)
    return TenantArrive(at=at, tenant_key=key, K_block=K,
                        mu0=np.full(m, 0.5), cost=np.ones(m), z_true=z)


def test_churn_trace_end_to_end_n_much_greater_than_m():
    """200 sessions over time on M=8 slices (the acceptance scenario)."""
    trace = poisson_churn_trace(num_sessions=200, arrival_rate=1.0, seed=0,
                                m_min=2, m_max=16, session_scale=25.0,
                                num_failure_slices=2)
    assert trace.num_sessions == 200
    eng = StreamEngine(fleet_of(8), "mdmt", seed=0, max_live_models=120)
    res = eng.run(trace)
    s = res.telemetry.summary()
    assert s["sessions"] == 200
    assert s["trials"] > 200
    assert 0 < s["sessions_admitted"] <= 200
    # admission control actually engaged under N >> M pressure
    assert s["queue_depth_max"] > 0
    # every successful observation belongs to an admitted tenant, and no
    # tenant has any of its models observed twice (global ids are recycled
    # across sessions, so uniqueness holds per tenant, not per id)
    seen = [(t.tenant_key, t.local_model) for t in res.trials if t.z is not None]
    assert len(seen) == len(set(seen))
    # the cap was respected at all times (checked via engine accounting)
    assert eng._live_models <= 120
    # slot reuse bounds the index space by the live-model cap, not by the
    # ~2000 models the 200 sessions brought in total (DESIGN.md §10)
    assert eng.cp.capacity <= 4 * 120
    total_admitted_models = sum(
        tr.arrive.num_models for tr in res.tenants.values()
        if tr.admitted_at is not None)
    assert total_admitted_models > eng.cp.capacity


def test_departed_tenant_stops_being_served():
    ta = _tiny_tenant(0, at=0.0, m=4, seed=1)
    tb = _tiny_tenant(1, at=0.0, m=4, seed=2)
    trace = ChurnTrace((ta, tb, TenantDepart(at=2.5, tenant_key=0)))
    res = StreamEngine(fleet_of(1), "mdmt", seed=0).run(trace)
    # after the departure, no tenant-0 launches
    for t in res.trials:
        if t.start >= 2.5:
            assert t.tenant_key == 1
    # tenant 1 is fully explored eventually
    t1_obs = {t.local_model for t in res.trials
              if t.tenant_key == 1 and t.z is not None}
    assert t1_obs == set(range(4))


def test_observation_after_depart_is_discarded():
    # one slow trial for tenant 0 in flight when the tenant departs
    ta = _tiny_tenant(0, at=0.0, m=2, seed=1)
    ta = TenantArrive(at=0.0, tenant_key=0, K_block=ta.K_block, mu0=ta.mu0,
                      cost=np.array([10.0, 10.0]), z_true=ta.z_true)
    trace = ChurnTrace((ta, TenantDepart(at=1.0, tenant_key=0)))
    eng = StreamEngine(fleet_of(2), "mdmt", seed=0)
    res = eng.run(trace)
    s = res.telemetry.summary()
    assert s["observations_rejected_after_depart"] == 2
    assert all(t.z is None for t in res.trials)


def test_admission_control_queues_and_admits_on_departure():
    t0 = _tiny_tenant(0, at=0.0, m=4, seed=1)
    t1 = _tiny_tenant(1, at=1.0, m=4, seed=2)   # doesn't fit: must queue
    trace = ChurnTrace((
        t0, t1, TenantDepart(at=6.0, tenant_key=0)))
    eng = StreamEngine(fleet_of(2), "mdmt", seed=0, max_live_models=4)
    res = eng.run(trace)
    r1 = res.tenants[1]
    assert r1.admitted_at is not None and r1.admitted_at >= 6.0
    assert res.telemetry.summary()["queue_depth_max"] == 1
    # the queued tenant is served after admission
    assert any(t.tenant_key == 1 and t.z is not None for t in res.trials)


def test_slice_failure_requeues_model_and_slice_recovers():
    ta = _tiny_tenant(0, at=0.0, m=3, seed=1)
    ta = TenantArrive(at=0.0, tenant_key=0, K_block=ta.K_block, mu0=ta.mu0,
                      cost=np.full(3, 4.0), z_true=ta.z_true)
    trace = ChurnTrace((ta, SliceFail(at=1.0, slice_id=0, downtime=2.0)))
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0)
    res = eng.run(trace)
    failed = [t for t in res.trials if t.z is None]
    assert len(failed) == 1 and failed[0].end == 1.0
    # the killed model is re-issued after repair and eventually observed
    observed = {t.local_model for t in res.trials if t.z is not None}
    assert failed[0].local_model in observed
    assert observed == {0, 1, 2}


def test_telemetry_json_roundtrip(tmp_path):
    trace = poisson_churn_trace(num_sessions=10, arrival_rate=1.0, seed=2,
                                m_min=2, m_max=6, session_scale=20.0)
    eng = StreamEngine(fleet_of(2), "mdmt", seed=0)
    res = eng.run(trace)
    path = res.telemetry.to_json(tmp_path / "telemetry.json")
    payload = json.loads(path.read_text())
    assert payload["summary"]["sessions"] == 10
    assert set(payload["tenants"]) == {str(k) for k in range(10)}
    assert payload["summary"]["device_utilization"] >= 0.0


# --- dynamic ControlPlane details --------------------------------------------

def test_control_plane_capacity_growth_preserves_decisions(problem):
    """A tiny initial capacity (forcing several doublings) must not change
    any decision vs a roomy one."""
    m = 8
    small = ControlPlane(np.random.default_rng(0), model_capacity=2,
                         tenant_capacity=1)
    big = ControlPlane(np.random.default_rng(0), model_capacity=256,
                       tenant_capacity=16)
    for cp in (small, big):
        for u in range(problem.num_users):
            sl = slice(u * m, (u + 1) * m)
            cp.add_tenant(problem.K[sl, sl], problem.mu0[sl], problem.cost[sl])
    for _ in range(10):
        a, b = small.choose_mdmt(), big.choose_mdmt()
        assert a == b
        small.record_start(a[0]); big.record_start(a[0])
        z = float(problem.z_true[a[0]])
        small.record_observation(a[0], z); big.record_observation(a[0], z)


def test_control_plane_rejects_churn_on_static_instances(problem):
    cp = ControlPlane.from_problem(problem)
    with pytest.raises(RuntimeError):
        cp.add_tenant(np.eye(2), np.zeros(2), np.ones(2))
    with pytest.raises(RuntimeError):
        cp.retire_tenant(0)


def test_scorer_ops_matches_fused(problem):
    """The kernels/ops.eirate scoring path picks the same models as the
    fused XLA path (same math, different dispatch)."""
    fused = ControlPlane.from_problem(problem, scorer="fused")
    ops_cp = ControlPlane.from_problem(problem, scorer="ops")
    for _ in range(8):
        a, b = fused.choose_mdmt(), ops_cp.choose_mdmt()
        assert a == b
        z = float(problem.z_true[a[0]])
        for cp in (fused, ops_cp):
            cp.record_start(a[0]); cp.record_observation(a[0], z)


def test_queued_tenant_departure_unblocks_the_line():
    """Regression: a queued (never-admitted) tenant leaving must let the
    tenants stuck behind it through — not wait for an *admitted* departure."""
    a = _tiny_tenant(0, at=0.0, m=8, seed=1)
    b = _tiny_tenant(1, at=1.0, m=5, seed=2)   # queued: 8+5 > 10
    c = _tiny_tenant(2, at=2.0, m=2, seed=3)   # queued behind b (FIFO)
    trace = ChurnTrace((a, b, c, TenantDepart(at=3.0, tenant_key=1)))
    eng = StreamEngine(fleet_of(2), "mdmt", seed=0, max_live_models=10)
    res = eng.run(trace)
    rc = res.tenants[2]
    assert rc.admitted_at == 3.0   # admitted the moment b left the queue head
    assert res.tenants[1].admitted_at is None


def test_arrive_then_depart_while_queued_full_audit():
    """Satellite audit: a tenant that departs while still in the admission
    queue must leave every account clean — telemetry depart mark, queue
    depth series, session counts, live-model capacity — and must never be
    admitted or served afterwards."""
    a = _tiny_tenant(0, at=0.0, m=8, seed=1)
    b = _tiny_tenant(1, at=1.0, m=8, seed=2)      # queued: 8+8 > 10
    trace = ChurnTrace((a, b, TenantDepart(at=2.0, tenant_key=1),
                        TenantDepart(at=50.0, tenant_key=0)))
    eng = StreamEngine(fleet_of(2), "mdmt", seed=0, max_live_models=10)
    res = eng.run(trace)
    s = res.telemetry.summary()
    rb = res.tenants[1]
    assert rb.departed and rb.admitted_at is None and rb.tenant_id is None
    assert res.telemetry.tenants[1].departed == 2.0
    assert s["sessions_departed_while_queued"] == 1
    assert s["sessions_admitted"] == 1            # only tenant 0
    # queue depth series saw the enqueue (1) and the drop back to 0
    depths = [d for _, d in res.telemetry.queue_depth_samples]
    assert 1 in depths and depths[-1] == 0
    # the departed-queued tenant never ran, live-model accounting balanced
    assert not any(t.tenant_key == 1 for t in res.trials)
    assert eng._live_models == 0
    # tenant 0 was unaffected: fully explored
    t0 = {t.local_model for t in res.trials if t.tenant_key == 0 and t.z is not None}
    assert t0 == set(range(8))


def test_stale_warm_start_entry_on_recycled_slot_is_skipped():
    """Regression for slot reuse: tenant A departs with warm-start entries
    still queued; tenant B reuses A's model slots.  The stale entries must
    be skipped (they belong to A), not launched as B's models."""
    slow = TenantArrive(at=0.0, tenant_key=9, K_block=0.04 * np.eye(1) + 0.0,
                        mu0=np.array([0.5]), cost=np.array([30.0]),
                        z_true=np.array([0.7]))
    a = _tiny_tenant(0, at=1.0, m=3, seed=1)
    b = _tiny_tenant(1, at=3.0, m=3, seed=2)
    trace = ChurnTrace((slow, a, TenantDepart(at=2.0, tenant_key=0), b))
    # one slice: busy with the slow trial until t=30, so A's warm entries
    # are still pending when A departs and B recycles A's slots
    eng = StreamEngine(fleet_of(1), "mdmt", seed=0)
    res = eng.run(trace)
    assert not any(t.tenant_key == 0 for t in res.trials)
    assert res.tenants[1].model_start == res.tenants[0].model_start  # reused
    b_obs = {t.local_model for t in res.trials
             if t.tenant_key == 1 and t.z is not None}
    assert b_obs == {0, 1, 2}


def test_engine_compaction_keeps_service_consistent():
    """compact_every: block relocations under churn must not corrupt
    ownership, launch bookkeeping, or posteriors (per-tenant uniqueness and
    full exploration still hold)."""
    trace = poisson_churn_trace(num_sessions=40, arrival_rate=1.0, seed=5,
                                m_min=2, m_max=10, session_scale=30.0)
    eng = StreamEngine(fleet_of(4), "mdmt", seed=0, max_live_models=40,
                       num_shards=4, compact_every=1, compact_imbalance=1.05)
    res = eng.run(trace)
    seen = [(t.tenant_key, t.local_model) for t in res.trials if t.z is not None]
    assert len(seen) == len(set(seen))
    assert res.compaction_moves > 0
    # the control plane's view stayed coherent: every live block's ids are
    # exactly the membership row, confined to one shard span
    cp = eng.cp
    for tid in np.nonzero(cp.tenant_live)[0]:
        ids = np.nonzero(cp.membership[tid])[0]
        pl = cp._layout.blocks[int(tid)]
        assert ids[0] == pl.start and ids[-1] == pl.stop - 1
        assert cp._layout.shard_of(pl.start) == cp._layout.shard_of(pl.stop - 1)


def test_rejected_observations_count_as_busy_time():
    """Regression: a slice that ran a departed tenant's trial to completion
    was busy — utilization must reflect it."""
    ta = _tiny_tenant(0, at=0.0, m=2, seed=1)
    ta = TenantArrive(at=0.0, tenant_key=0, K_block=ta.K_block, mu0=ta.mu0,
                      cost=np.array([10.0, 10.0]), z_true=ta.z_true)
    trace = ChurnTrace((ta, TenantDepart(at=1.0, tenant_key=0)))
    res = StreamEngine(fleet_of(2), "mdmt", seed=0).run(trace)
    s = res.telemetry.summary()
    assert s["observations_rejected_after_depart"] == 2
    assert s["device_utilization"] == pytest.approx(1.0)
