"""Sharded scoring plane + index-space compaction (DESIGN.md §10).

Covers the two acceptance contracts:

* decision equivalence — ``scorer="sharded"`` picks the identical
  (model, tenant) sequence as ``scorer="fused"``, including tie-breaking,
  on a 1-shard mesh inline and on a forced 4-device host mesh in a
  subprocess (xla_force_host_platform_device_count must be set before jax
  initializes, so multi-device runs cannot share this test session);

* bounded memory — a churny service with slot reuse ends with
  readout-buffer capacity O(live-model cap), not O(models ever admitted),
  with no posterior drift for surviving tenants.
"""

import numpy as np
import pytest

from repro.core import ControlPlane
from repro.core.fleet import Fleet
from repro.core.gp import IncrementalGP
from repro.core.tenancy import _matern_block_chol
from repro.shardgp import RangeAllocator, ShardLayout, ShardedScorer, plan_moves
from repro.stream import StreamEngine, poisson_churn_trace

from conftest import run_forced_devices_subprocess


# --- RangeAllocator -----------------------------------------------------------

def test_allocator_first_fit_and_coalesce():
    a = RangeAllocator(16)
    assert a.alloc(4) == 0 and a.alloc(4) == 4 and a.alloc(8) == 8
    assert a.alloc(1) is None
    a.free(4, 4)
    assert a.alloc(2) == 4          # lowest fit, splits the hole
    a.free(0, 4)
    a.free(4, 2)                    # coalesces with [0,4) and [6,8)
    assert a.alloc(8) == 0
    assert a.live_slots == 16


def test_allocator_bounded_alloc_and_grow():
    a = RangeAllocator(8)
    assert a.alloc(4, lo=4, hi=8) == 4
    assert a.alloc(4, lo=4, hi=8) is None
    a.grow(16)
    assert a.capacity == 16 and a.alloc(8, lo=8, hi=16) == 8


def test_allocator_double_free_rejected():
    a = RangeAllocator(8)
    assert a.alloc(4) == 0
    a.free(0, 4)
    with pytest.raises(ValueError):
        a.free(2, 2)


# --- ShardLayout --------------------------------------------------------------

def test_layout_blocks_confined_to_spans_and_balanced():
    lay = ShardLayout(num_shards=4, shard_capacity=8)
    for key, m in enumerate([6, 6, 3, 5]):
        lay.place(key, m)
    for pl in lay.blocks.values():
        assert lay.shard_of(pl.start) == lay.shard_of(pl.stop - 1)
    # least-loaded placement spread them one per span
    assert sorted(lay.live_counts()) == [3, 5, 6, 6]


def test_layout_growth_never_splits_blocks():
    lay = ShardLayout(num_shards=4, shard_capacity=4)
    for key in range(8):
        lay.place(key, 3)           # forces several doublings
    assert lay.capacity == 4 * lay.shard_capacity
    for pl in lay.blocks.values():
        assert lay.shard_of(pl.start) == lay.shard_of(pl.stop - 1)


def test_layout_release_and_reuse():
    lay = ShardLayout(num_shards=2, shard_capacity=8)
    s0 = lay.place(0, 4)
    lay.place(1, 4)
    lay.release(0)
    assert lay.place(2, 4) == s0    # freed span slot is recycled


def test_plan_moves_restores_balance_and_respects_pins():
    lay = ShardLayout(num_shards=2, shard_capacity=16)
    for key in range(4):
        lay.place(key, 4)
    for key in (1, 3):
        lay.release(key)            # all remaining load on shard 0
    assert lay.imbalance() == 2.0
    # pinned: nothing movable -> no moves, bounded loop
    assert plan_moves(lay, set(), 1.05) == []
    moves = plan_moves(lay, {0, 2}, 1.05)
    assert len(moves) == 1 and lay.imbalance() == 1.0


# --- sharded scorer (1-shard mesh; multi-shard runs in the subprocess) --------

def _dyn_plane(scorer, seed=0, **kw):
    K, _ = _matern_block_chol(5, 0.2, 0.04)
    cp = ControlPlane(np.random.default_rng(seed), scorer=scorer,
                      model_capacity=16, tenant_capacity=4, **kw)
    for _ in range(5):
        cp.add_tenant(K, np.zeros(5), np.ones(5))
    return cp


def test_sharded_scorer_matches_fused_decisions():
    cpf = _dyn_plane("fused", num_shards=1)
    cps = _dyn_plane("sharded", num_shards=1)
    rng = np.random.default_rng(3)
    for step in range(15):
        a, b = cpf.choose_mdmt(), cps.choose_mdmt()
        assert a == b, f"step {step}: fused {a} vs sharded {b}"
        z = float(rng.uniform(0, 1))
        for cp in (cpf, cps):
            cp.record_start(a[0])
            cp.record_observation(a[0], z)


def test_sharded_scorer_tie_break_is_lowest_global_id():
    # identical tenants, no observations: scores tie across blocks exactly,
    # and the pick must be the lowest global id, like jnp.argmax
    cps = _dyn_plane("sharded")
    pick = cps.choose_mdmt()
    assert pick == (0, -1)


def test_sharded_scorer_exhaustion_returns_none():
    cp = _dyn_plane("sharded")
    cp.selected[:] = True
    cp._selected_j = cp._selected_j.at[:].set(True)
    assert cp.choose_mdmt() is None


def test_sharded_scorer_topk_shapes_and_order():
    cp = _dyn_plane("sharded")
    sc: ShardedScorer = cp._sharded
    mu, sd = cp.gp.posterior_sd()
    v, g = sc.decide_topk(mu, sd, cp._best_j, cp.selected)
    v, g = np.asarray(v), np.asarray(g)
    assert v.shape == (sc.topk,) and g.shape == (sc.topk,)
    assert (np.diff(v) <= 0).all()
    # ties (identical tenants) resolve in ascending global id
    assert (np.diff(g[v == v[0]]) > 0).all()


def test_sharded_scorer_pool_smaller_than_topk():
    """Regression: a shard slice smaller than topk must clamp+pad, not
    crash in lax.top_k (tiny pool / many shards / from_problem with small
    n all hit this)."""
    sc = ShardedScorer(1, topk=8)
    n = 4
    member = np.zeros((2, n), dtype=bool)
    member[0, :2] = True
    member[1, 2:] = True
    sc.refresh(member, np.ones(n, np.float32))
    v, g = sc.decide_topk(np.zeros(n, np.float32), np.ones(n, np.float32),
                          np.zeros(2, np.float32), np.zeros(n, bool))
    v, g = np.asarray(v), np.asarray(g)
    assert v.shape == (8,) and g.shape == (8,)
    assert list(g[:n]) == [0, 1, 2, 3]        # real candidates, tie-ordered
    assert (v[n:] == -np.inf).all()           # padding is inert
    idx, score = sc.decide(np.zeros(n, np.float32), np.ones(n, np.float32),
                           np.zeros(2, np.float32), np.zeros(n, bool))
    assert idx == 0 and np.isfinite(score)


@pytest.mark.parametrize("kernel", ["pallas", "pallas_topk"])
def test_sharded_scorer_kernel_paths_agree(kernel):
    """The Pallas scoring paths pick the same argmax as the XLA path (same
    math, erf-based tau formulation — values agree to fp32 tolerance)."""
    cpx = _dyn_plane("sharded", score_kernel="xla")
    cpk = _dyn_plane("sharded", score_kernel=kernel)
    rng = np.random.default_rng(7)
    for step in range(8):
        a, b = cpx.choose_mdmt(), cpk.choose_mdmt()
        assert a == b, f"step {step}: xla {a} vs {kernel} {b}"
        z = float(rng.uniform(0, 1))
        for cp in (cpx, cpk):
            cp.record_start(a[0])
            cp.record_observation(a[0], z)


# --- compaction ---------------------------------------------------------------

def test_compact_moves_posteriors_with_blocks():
    cp = _dyn_plane("fused", num_shards=4)
    rng = np.random.default_rng(0)
    for t in range(5):
        g = int(np.nonzero(cp.membership[t])[0][t % 5])
        cp.record_start(g)
        cp.record_observation(g, float(rng.uniform(0, 1)))
    for t in (0, 2):
        cp.retire_tenant(t)
    mu_before, var_before = map(np.asarray, cp.gp.posterior())
    ids_before = {t: np.nonzero(cp.membership[t])[0]
                  for t in np.nonzero(cp.tenant_live)[0]}
    remap = cp.compact(1.0)     # force full rebalance
    mu_after, var_after = map(np.asarray, cp.gp.posterior())
    for t, old_ids in ids_before.items():
        new_ids = np.nonzero(cp.membership[t])[0]
        if int(t) in remap:
            np.testing.assert_array_equal(remap[int(t)][0], old_ids)
            np.testing.assert_array_equal(remap[int(t)][1], new_ids)
        np.testing.assert_array_equal(mu_before[old_ids], mu_after[new_ids])
        np.testing.assert_array_equal(var_before[old_ids], var_after[new_ids])


def test_compact_pins_in_flight_blocks():
    cp = _dyn_plane("fused", num_shards=4)
    g = int(np.nonzero(cp.membership[1])[0][0])
    cp.record_start(g)          # tenant 1 now has an in-flight model
    for t in (0, 2, 3):
        cp.retire_tenant(t)
    ids_before = np.nonzero(cp.membership[1])[0]
    remap = cp.compact(1.0)
    assert 1 not in remap       # pinned
    np.testing.assert_array_equal(np.nonzero(cp.membership[1])[0], ids_before)


# --- acceptance: bounded memory under churn (criterion 2) ---------------------

def test_churn_service_memory_bounded_no_posterior_drift():
    """500 sessions against a 5k live-model cap: the index space ends
    O(live cap) while the models ever admitted are several times larger,
    and surviving tenants' posteriors match a fresh per-tenant engine
    replaying only their own observations."""
    from repro.stream import ChurnTrace, TenantDepart
    sessions = 500
    base = poisson_churn_trace(num_sessions=sessions, arrival_rate=2.0,
                               seed=11, m_min=2, m_max=50,
                               session_scale=12.0)
    # keep every 10th tenant live to the end so drift is checkable
    trace = ChurnTrace(tuple(
        e for e in base.events
        if not (isinstance(e, TenantDepart) and e.tenant_key % 10 == 0)),
        name=base.name)
    eng = StreamEngine(Fleet.partition_pod(16 * 8, 8), "mdmt", seed=0,
                       max_live_models=5000)
    res = eng.run(trace)
    cp = eng.cp
    total_admitted = sum(tr.arrive.num_models for tr in res.tenants.values()
                         if tr.admitted_at is not None)
    assert total_admitted >= 2000
    # O(cap): within one doubling of the peak live load, far below the
    # append-only total (the pre-§10 behavior grew to total_admitted)
    assert cp.capacity < total_admitted / 2
    assert cp.capacity <= 2048
    assert cp.gp.n <= cp.capacity

    # no posterior drift: replay each survivor's own observations into a
    # fresh engine and compare over the tenant's current global ids
    survivors = [tr for tr in res.tenants.values()
                 if tr.tenant_id is not None and not tr.departed]
    assert survivors, "trace should leave some tenants live at the end"
    obs_by_tenant: dict[int, list[tuple[int, float]]] = {}
    for t in res.trials:
        if t.z is not None:
            obs_by_tenant.setdefault(t.tenant_key, []).append(
                (t.local_model, t.z))
    mu_now, var_now = map(np.asarray, cp.gp.posterior())
    for tr in survivors:
        ids = np.nonzero(cp.membership[tr.tenant_id])[0]
        fresh = IncrementalGP(tr.arrive.K_block, tr.arrive.mu0)
        for li, z in obs_by_tenant.get(tr.key, []):
            fresh.observe(li, z)
        mu_ref, var_ref = map(np.asarray, fresh.posterior())
        np.testing.assert_allclose(mu_now[ids], mu_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(var_now[ids], var_ref, rtol=1e-5, atol=1e-5)


# --- acceptance: multi-device decision equivalence (criterion 1) --------------

def test_sharded_equals_fused_streaming_episode_4dev():
    """The acceptance gate: on a forced 4-device host mesh, a full streaming
    episode under churn picks the identical (tenant, model) sequence with
    scorer="sharded" as with scorer="fused" (same index space: both planes
    run num_shards=4)."""
    res = run_forced_devices_subprocess("""
        import json
        import numpy as np
        from repro.core.fleet import Fleet
        from repro.stream import StreamEngine, poisson_churn_trace

        trace = poisson_churn_trace(num_sessions=30, arrival_rate=1.0,
                                    seed=4, m_min=2, m_max=12,
                                    session_scale=20.0,
                                    num_failure_slices=1)
        seqs = {}
        for scorer in ("fused", "sharded"):
            eng = StreamEngine(Fleet.partition_pod(16 * 4, 4), "mdmt",
                               seed=0, max_live_models=60, scorer=scorer,
                               num_shards=4, compact_every=2)
            r = eng.run(trace)
            seqs[scorer] = [(t.tenant_key, t.local_model, t.device,
                             round(t.start, 9), t.z) for t in r.trials]
        import jax
        print(json.dumps({
            "devices": len(jax.devices()),
            "num_trials": len(seqs["fused"]),
            "equal": seqs["fused"] == seqs["sharded"],
        }))
    """, devices=4)
    assert res["devices"] == 4
    assert res["num_trials"] > 50
    assert res["equal"], "sharded scorer diverged from fused on 4 shards"


def test_sharded_decide_matches_argmax_4dev_random_states():
    """Property-style check on raw states: sharded decide == jnp.argmax of
    the fused score vector on a 4-way mesh, bit-exact including the score
    value, across random posteriors with exact score ties.

    Membership is the dynamic plane's invariant — at most two owners per
    model — which is what makes the per-model score *bit*-identical between
    the sliced and full-shape computation (a tenant-axis sum with <= 2
    nonzero terms has exactly one rounding regardless of association; see
    DESIGN.md §10's exactness argument)."""
    res = run_forced_devices_subprocess("""
        import json
        import numpy as np
        import jax.numpy as jnp
        from repro.core.ei import choose_next_fused
        from repro.shardgp import ShardedScorer

        rng = np.random.default_rng(0)
        sc = ShardedScorer(4, topk=4)
        checks = 0
        for trial in range(20):
            n = int(rng.integers(4, 97)) * 4
            N = int(rng.integers(2, 9))
            mu = rng.standard_normal(n).astype(np.float32)
            sd = (np.abs(rng.standard_normal(n)) *
                  (rng.random(n) > 0.2)).astype(np.float32)
            if trial % 3 == 0:
                mu[:] = 0.25; sd[:] = 1.0   # force exact ties everywhere
            best = rng.standard_normal(N).astype(np.float32)
            owner = rng.integers(0, N, size=n)
            member = np.zeros((N, n), dtype=bool)
            member[owner, np.arange(n)] = True
            second = rng.random(n) < 0.2    # a few doubly-owned models
            member[(owner[second] + 1) % N, np.nonzero(second)[0]] = True
            cost = rng.uniform(0.5, 2.0, n).astype(np.float32)
            selected = rng.random(n) < 0.4
            sc.refresh(member, cost)
            idx, score = sc.decide(mu, sd, best, selected)
            ref_idx, ref_score = choose_next_fused(
                jnp.asarray(mu), jnp.asarray(sd), jnp.asarray(best),
                jnp.asarray(member), jnp.asarray(cost),
                jnp.asarray(selected))
            assert idx == int(ref_idx), (trial, idx, int(ref_idx))
            assert score == float(ref_score) or (
                np.isinf(score) and np.isinf(float(ref_score))), (
                trial, score, float(ref_score))
            checks += 1
        print(json.dumps({"checks": checks}))
    """, devices=4)
    assert res["checks"] == 20
