"""Property tests for the event-sourced control plane (hypothesis).

Skipped cleanly when hypothesis is not installed (same convention as
tests/test_churn_property.py — the deterministic twins of every property
here live in tests/test_eventlog.py and always run).

Two properties:

* the replay oracle under *fuzzed* churn interleavings: an arbitrary
  seeded mixture of tenant arrivals/departures and device
  joins/leaves/preemptions, killed at an arbitrary processed-event index,
  recovers byte-identically (trials + telemetry + regret);
* departure-boundary compaction accounting: with ``compact_every=k`` the
  engine runs exactly ``admitted_departures // k`` passes regardless of
  interleaving; with ``compact_max_moves`` and no period it runs one
  bounded pass per departure.
"""

import tempfile
from pathlib import Path

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.devplane import DevPlaneEngine  # noqa: E402
from repro.core.fleet import Fleet  # noqa: E402
from repro.stream import device_churn_trace  # noqa: E402

from test_eventlog import (  # noqa: E402
    assert_replay_matches,
    crash_and_recover,
    fingerprint,
    run_reference,
)


def _make_factory(compact_every, compact_max_moves):
    def make(**kw):
        return DevPlaneEngine(Fleet.partition_pod(16 * 4, 4), "mdmt",
                              seed=0, max_live_models=30, num_shards=2,
                              assign="batched", compact_every=compact_every,
                              compact_max_moves=compact_max_moves, **kw)
    return make


churn_traces = st.builds(
    device_churn_trace,
    num_sessions=st.integers(4, 10),
    arrival_rate=st.floats(0.5, 2.0),
    seed=st.integers(0, 10_000),
    initial_slices=st.integers(2, 4),
    join_rate=st.floats(0.0, 0.15),
    leave_rate=st.floats(0.0, 0.10),
    preempt_rate=st.floats(0.0, 0.10),
    m_min=st.just(2), m_max=st.just(6),
    session_scale=st.floats(5.0, 15.0),
)


@settings(max_examples=10, deadline=None)
@given(trace=churn_traces,
       compact_every=st.sampled_from([None, 1, 2]),
       crash_frac=st.floats(0.0, 1.0),
       point=st.sampled_from(["before", "after"]))
def test_replay_oracle_under_fuzzed_churn(trace, compact_every, crash_frac,
                                          point):
    make = _make_factory(compact_every, None)
    ref_eng, ref_res = run_reference(make, trace)
    n = ref_eng.event_index
    idx = min(n, max(1, round(crash_frac * n)))
    with tempfile.TemporaryDirectory() as d:
        out = crash_and_recover(make, trace, idx, point, Path(d),
                                snapshot_every=8)
        assert_replay_matches(ref_eng, ref_res, *out[:3],
                              context=f"fuzz_{trace.name}_{point}_{idx}")


@settings(max_examples=10, deadline=None)
@given(trace=churn_traces,
       compact_every=st.sampled_from([None, 1, 2, 3]),
       max_moves=st.sampled_from([None, 1, 2]))
def test_compaction_boundary_count_property(trace, compact_every, max_moves):
    make = _make_factory(compact_every, max_moves)
    eng, res = run_reference(make, trace)
    counts = eng.compaction_move_counts
    if compact_every:
        assert len(counts) == eng._departures // compact_every
    elif max_moves:
        assert len(counts) == eng._departures   # one bounded pass per depart
    else:
        assert counts == []
    if max_moves:
        assert all(c <= max_moves for c in counts)
    # determinism sanity: the same trace + config reruns identically
    eng2, res2 = run_reference(make, trace)
    assert fingerprint(eng2, res2) == fingerprint(eng, res)
