"""Serving engine: waves, stopping, utilization accounting."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Request, ServeConfig, StaticBatchEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("qwen3-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return StaticBatchEngine(cfg, params, ServeConfig(batch_slots=2, max_len=128))


def test_engine_serves_all_requests(engine):
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 255, size=8 + i).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert len(done) == 5
    assert all(r.done and len(r.output) == 4 for r in done)
    assert engine.stats["waves"] == 3           # 2 + 2 + 1 slots


def test_engine_eos_stops_early():
    cfg = get_smoke_config("qwen3-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = StaticBatchEngine(cfg, params, ServeConfig(batch_slots=1, max_len=128))
    probe = Request(0, np.arange(8, dtype=np.int32), max_new_tokens=1)
    eng.submit(probe)
    eng.run()
    first = probe.output[0]
    # same prompt with that token as EOS stops after one step
    r = Request(1, np.arange(8, dtype=np.int32), max_new_tokens=16, eos_id=first)
    eng.submit(r)
    eng.run()
    assert len(r.output) == 1 and r.output[0] == first
    assert 0.0 < eng.slot_utilization <= 1.0
